//! Quickstart: load the AOT artifacts, prefill a prompt dense vs sparse,
//! and generate a short continuation — the 60-second tour of the API.
//!
//!     make artifacts && cargo run --release --example quickstart

use std::rc::Rc;

use anyhow::Result;
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::manifest::Manifest;
use fastforward::runtime::Runtime;
use fastforward::tokenizer::Tokenizer;
use fastforward::weights::WeightStore;

fn main() -> Result<()> {
    // 1. Load the artifact bundle produced by `make artifacts`.
    let dir = std::path::PathBuf::from(
        std::env::var("FF_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let manifest = Rc::new(Manifest::load(&dir)?);
    let weights = Rc::new(WeightStore::load(&manifest)?);
    let runtime = Rc::new(Runtime::new(manifest, weights)?);
    let engine = Engine::new(runtime);
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    println!(
        "loaded {} ({} executables, {} weights)",
        engine.manifest().model.name,
        engine.manifest().executables.len(),
        engine.manifest().weights.len(),
    );

    // 2. Build a long-ish prompt ending in a QA-style question.
    let mut rng = fastforward::util::rng::Rng::new(7);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let prompt_text = format!(
        "{} the passkey is kwxqzj. remember it. {}\nthe passkey is",
        bank.filler(&mut rng, 400),
        bank.filler(&mut rng, 500),
    );
    let prompt = tok.encode(&prompt_text);
    println!("prompt: {} tokens", prompt.len());

    // 3. Prefill dense vs FastForward-50% and compare.
    for (label, cfg) in [
        ("dense (baseline)", SparsityConfig::dense()),
        ("fastforward @50%", SparsityConfig::fastforward(0.5)),
    ] {
        // warm once so compile time doesn't pollute the comparison
        let _ = engine.prefill(&prompt, &cfg)?;
        let pre = engine.prefill(&prompt, &cfg)?;
        println!(
            "{label:20} prefill {:7.1} ms ({} blocks, {} dense, tail {})",
            pre.timing.total.as_secs_f64() * 1e3,
            pre.timing.blocks,
            pre.timing.dense_blocks,
            pre.timing.tail_tokens,
        );
    }

    // 4. Generate with the full FastForward configuration.
    let gen = engine.generate(&prompt, 24, &SparsityConfig::fastforward(0.5))?;
    println!("generated: {:?}", gen.text);
    println!(
        "ttft {:.1} ms | tpot {:.2} ms/token",
        gen.ttft_ms, gen.tpot_ms
    );
    Ok(())
}
