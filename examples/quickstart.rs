//! Quickstart: load a model, prefill a prompt dense vs sparse, and
//! generate a short continuation — the 60-second tour of the API.
//!
//! Works on any machine: with AOT artifacts (`make artifacts`) and the
//! `pjrt` feature it runs them on PJRT; with `FF_BACKEND=cpu` (or
//! without artifacts) it runs the deterministic synthetic reference
//! model on the pure-Rust CPU backend — no setup at all.
//!
//!     cargo run --release --example quickstart                # auto
//!     FF_BACKEND=cpu cargo run --release --example quickstart # forced
//!     make artifacts && cargo run --release --features pjrt \
//!         --example quickstart

use std::sync::Arc;

use anyhow::{anyhow, Result};
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::manifest::{Manifest, SyntheticSpec};
use fastforward::runtime::{BackendKind, Runtime};
use fastforward::tokenizer::Tokenizer;
use fastforward::weights::WeightStore;

fn load_engine() -> Result<Engine> {
    let dir = std::path::PathBuf::from(
        std::env::var("FF_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()),
    );
    let kind = match std::env::var("FF_BACKEND") {
        Ok(s) => BackendKind::parse(&s)
            .ok_or_else(|| anyhow!("unknown FF_BACKEND {s:?}"))?,
        Err(_) => BackendKind::default_for_build(),
    };
    // The CPU backend serves the synthetic reference model (artifact
    // bundles are PJRT-only); pjrt without a bundle falls back to it
    // so the example runs everywhere.
    if kind == BackendKind::Cpu || !dir.join("manifest.json").exists() {
        println!("backend: cpu (synthetic reference model, no artifacts)");
        return Engine::synthetic_cpu(&SyntheticSpec::default());
    }
    println!("backend: {} over artifacts at {dir:?}", kind.label());
    let manifest = Arc::new(Manifest::load(&dir)?);
    let weights = Arc::new(WeightStore::load(&manifest)?);
    Ok(Engine::new(Arc::new(Runtime::with_backend(
        kind, manifest, weights,
    )?)))
}

fn main() -> Result<()> {
    // 1. Load the model (artifact bundle or synthetic reference).
    let engine = load_engine()?;
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    println!(
        "loaded {} ({} executables, {} weights)",
        engine.manifest().model.name,
        engine.manifest().executables.len(),
        engine.manifest().weights.len(),
    );

    // 2. Build a long-ish prompt ending in a QA-style question, sized
    //    to the model's context window.
    let max_ctx = engine.manifest().model.max_ctx;
    let mut rng = fastforward::util::rng::Rng::new(7);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let prompt_text = format!(
        "{} the passkey is kwxqzj. remember it. {}\nthe passkey is",
        bank.filler(&mut rng, (max_ctx / 4).min(400)),
        bank.filler(&mut rng, (max_ctx / 3).min(500)),
    );
    let prompt = tok.encode(&prompt_text);
    println!("prompt: {} tokens", prompt.len());

    // 3. Prefill dense vs FastForward-50% and compare.
    for (label, cfg) in [
        ("dense (baseline)", SparsityConfig::dense()),
        ("fastforward @50%", SparsityConfig::fastforward(0.5)),
    ] {
        // warm once so compile time doesn't pollute the comparison
        let _ = engine.prefill(&prompt, &cfg)?;
        let pre = engine.prefill(&prompt, &cfg)?;
        println!(
            "{label:20} prefill {:7.1} ms ({} blocks, {} dense, tail {})",
            pre.timing.total.as_secs_f64() * 1e3,
            pre.timing.blocks,
            pre.timing.dense_blocks,
            pre.timing.tail_tokens,
        );
    }

    // 4. Generate with the full FastForward configuration.
    let gen = engine.generate(&prompt, 24, &SparsityConfig::fastforward(0.5))?;
    println!("generated: {:?}", gen.text);
    println!(
        "ttft {:.1} ms | tpot {:.2} ms/token",
        gen.ttft_ms, gen.tpot_ms
    );
    Ok(())
}
