//! Ablation study runner (paper §6, Tables 4–7): evaluates the component
//! ablations on longbench-sim through the real engine.
//!
//!     cargo run --release --example ablation_sweep -- --ablation all
//!     cargo run --release --example ablation_sweep -- --ablation schedule
//!         (schedule | dense-blocks | compensator | predictor | all)

use std::sync::Arc;

use anyhow::Result;
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::eval::{self, EvalSpec};
use fastforward::manifest::Manifest;
use fastforward::runtime::Runtime;
use fastforward::sparsity::masks::ExpertSource;
use fastforward::util::cli::Args;
use fastforward::weights::WeightStore;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let which = args.str("ablation", "all");
    let spec = EvalSpec {
        tasks_per_group: args.usize("tasks", 3),
        prompt_chars: args.usize("prompt-chars", 1024),
        seed: args.usize("seed", 17) as u64,
        with_generation: false,
        max_gen_tokens: 16,
    };

    let m = Arc::new(Manifest::load(&dir)?);
    let w = Arc::new(WeightStore::load(&m)?);
    let engine = Engine::new(Arc::new(Runtime::new(m, w)?));
    let tasks = eval::build_tasks(&spec);

    let dense = eval::evaluate(&engine, &tasks, &SparsityConfig::dense(),
                               &spec)?;
    println!("{}", eval::TABLE_HEADER);
    println!("{}", eval::format_row("dense reference", &dense, 0.0));
    let mut run = |label: &str, cfg: &SparsityConfig| -> Result<f64> {
        let r = eval::evaluate(&engine, &tasks, cfg, &spec)?;
        println!(
            "{}",
            eval::format_row(label, &r, r.rel_gap_pct(dense.average))
        );
        Ok(r.average)
    };

    let base = SparsityConfig::fastforward(0.5);

    if which == "schedule" || which == "all" {
        println!("\n-- Table 4: layerwise vs uniform sparsity schedule --");
        run("layerwise 50%", &base)?;
        let mut uni = base.clone();
        uni.layerwise = false;
        run("uniform 50%", &uni)?;
    }

    if which == "dense-blocks" || which == "all" {
        println!("\n-- Table 5: dense first/last block --");
        let mut none = base.clone();
        none.layerwise = false;
        none.dense_first = false;
        none.dense_last = false;
        run("uniform 50% (all sparse)", &none)?;
        let mut first = none.clone();
        first.dense_first = true;
        run("+ dense first", &first)?;
        let mut both = first.clone();
        both.dense_last = true;
        run("+ dense first & last", &both)?;
    }

    if which == "compensator" || which == "all" {
        println!("\n-- Table 6: error compensator --");
        run("50% with compensator", &base)?;
        let mut nc = base.clone();
        nc.compensator = false;
        run("50% without compensator", &nc)?;
    }

    if which == "predictor" || which == "all" {
        println!("\n-- Table 7: expert predictor variants --");
        // paper setting: dense first block, 50% sparsity elsewhere,
        // no layerwise schedule, isolate the selector
        let mut t7 = SparsityConfig::fastforward(0.5);
        t7.layerwise = false;
        t7.dense_last = false;
        for (label, source) in [
            ("trained predictor", ExpertSource::Trained),
            ("per-block dynamic (oracle)", ExpertSource::Oracle),
            ("first-block static (GRIFFIN)", ExpertSource::FirstBlockStatic),
            ("CATS thresholding (baseline)", ExpertSource::Cats),
        ] {
            let mut cfg = t7.clone();
            cfg.source = source;
            run(label, &cfg)?;
        }
    }
    Ok(())
}
