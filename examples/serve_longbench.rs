//! End-to-end serving driver (DESIGN.md "end-to-end validation"): boots
//! the full stack (router → dynamic batcher → engine), replays a
//! longbench-sim request trace through it with Poisson arrivals, and
//! reports TTFT / TPOT / throughput dense-vs-sparse plus the accuracy
//! summary — the paper's headline quantities on one screen.
//!
//!     cargo run --release --example serve_longbench -- \
//!         --requests 12 --prompt-chars 1024 --sparsity 0.5

use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::Result;
use fastforward::batcher::{Batcher, BatcherConfig};
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::eval::{self, EvalSpec};
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::router::{Response, Router, TokenEvent};
use fastforward::runtime::Runtime;
use fastforward::tokenizer::Tokenizer;
use fastforward::trace::longbench::{TaskGen, TaskGroup};
use fastforward::util::cli::Args;
use fastforward::util::rng::Rng;
use fastforward::util::stats::Summary;
use fastforward::weights::WeightStore;

fn main() -> Result<()> {
    let args = Args::parse_env();
    let dir = std::path::PathBuf::from(args.str("artifacts", "artifacts"));
    let n_requests = args.usize("requests", 12);
    let prompt_chars = args.usize("prompt-chars", 1024);
    let sparsity = args.f64("sparsity", 0.5);
    let rate = args.f64("rate", 2.0);

    // ---- serving stack -------------------------------------------------
    let metrics = Arc::new(Metrics::new());
    let probe = Manifest::load(&dir)?;
    let router = Arc::new(Router::new(
        256,
        probe.model.max_ctx,
        16 * probe.model.max_ctx / 128,
        128,
        metrics.clone(),
    ));
    let r2 = router.clone();
    let dir2 = dir.clone();
    let exec = std::thread::spawn(move || -> Result<()> {
        let m = Arc::new(Manifest::load(&dir2)?);
        let w = Arc::new(WeightStore::load(&m)?);
        let rt = Arc::new(Runtime::new(m, w)?);
        Batcher::new(
            Engine::new(rt),
            r2,
            BatcherConfig {
                max_active: 8,
                prefill_block_budget: 4,
                ..Default::default()
            },
        )
        .run()
    });

    // ---- trace replay ----------------------------------------------------
    let tok = Tokenizer::new(probe.model.vocab);
    let mut taskgen = TaskGen::new(77);
    let mut rng = Rng::new(42);
    let cfg = if sparsity > 0.0 {
        SparsityConfig::fastforward(sparsity)
    } else {
        SparsityConfig::dense()
    };
    println!(
        "replaying {n_requests} longbench-sim requests (~{prompt_chars} tokens, \
         poisson {rate}/s) at sparsity {sparsity}"
    );
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    let groups = TaskGroup::all();
    for i in 0..n_requests {
        let wait = -(1.0 - rng.f64()).ln() / rate;
        std::thread::sleep(std::time::Duration::from_secs_f64(wait.min(1.0)));
        let task = taskgen.generate(groups[i % groups.len()], prompt_chars);
        let (tx, rx) = channel::<TokenEvent>();
        match router.submit(tok.encode(&task.prompt), 16, cfg.clone(), tx) {
            Ok(id) => pending.push((id, rx)),
            Err(e) => println!("  request {i} rejected: {e:?}"),
        }
    }
    let mut ttft = Summary::new();
    let mut tpot = Summary::new();
    let mut total_tokens = 0usize;
    for (id, rx) in pending {
        let resp = Response::collect(&rx)
            .ok_or_else(|| anyhow::anyhow!("executor dropped request"))?;
        if let Some(e) = resp.error {
            println!("  request {id} failed: {e}");
            continue;
        }
        ttft.add(resp.ttft_ms);
        if resp.tokens > 0 {
            tpot.add(resp.tpot_ms);
        }
        total_tokens += resp.tokens;
    }
    let wall = t0.elapsed().as_secs_f64();
    router.close();
    exec.join().unwrap()?;

    println!("\n== serving metrics ({n_requests} requests, {wall:.1}s wall) ==");
    println!(
        "TTFT   p50 {:8.1} ms   p95 {:8.1} ms   mean {:8.1} ms",
        ttft.percentile(50.0),
        ttft.percentile(95.0),
        ttft.mean()
    );
    println!(
        "TPOT   p50 {:8.2} ms   p95 {:8.2} ms   mean {:8.2} ms",
        tpot.percentile(50.0),
        tpot.percentile(95.0),
        tpot.mean()
    );
    println!(
        "throughput: {:.2} req/s, {:.1} generated tok/s",
        n_requests as f64 / wall,
        total_tokens as f64 / wall
    );
    println!("\n== prometheus snapshot ==");
    for line in metrics.export().lines().filter(|l| !l.starts_with('#')) {
        println!("  {line}");
    }

    // ---- offline accuracy summary on the same task family ---------------
    println!("\n== accuracy (offline, same engine artifacts) ==");
    let m = Arc::new(Manifest::load(&dir)?);
    let w = Arc::new(WeightStore::load(&m)?);
    let engine = Engine::new(Arc::new(Runtime::new(m, w)?));
    let spec = EvalSpec {
        tasks_per_group: 2,
        prompt_chars,
        ..Default::default()
    };
    let tasks = eval::build_tasks(&spec);
    println!("{}", eval::TABLE_HEADER);
    let dense = eval::evaluate(&engine, &tasks, &SparsityConfig::dense(),
                               &spec)?;
    println!("{}", eval::format_row("dense (0%)", &dense, 0.0));
    let sparse = eval::evaluate(&engine, &tasks, &cfg, &spec)?;
    println!(
        "{}",
        eval::format_row(
            &format!("fastforward {:.0}%", sparsity * 100.0),
            &sparse,
            sparse.rel_gap_pct(dense.average)
        )
    );
    Ok(())
}
