//! Production-workload study (paper Table 1 + §1 motivation): generates
//! the three workload families, reproduces the prompt/decode statistics
//! table, and uses the cost model to show where FastForward's prefill
//! savings land for each workload's prompt-length distribution.
//!
//!     cargo run --release --example rag_workload

use fastforward::cost::CostModel;
use fastforward::trace::{generate_trace, trace_stats, WorkloadSpec};

fn main() {
    // ---- Table 1 reproduction -------------------------------------------
    let specs = WorkloadSpec::all();
    let trace = generate_trace(&specs, 8.0, 6000, 1 << 20, 20260711);
    println!("== paper Table 1: workload prompt/decode statistics ==");
    println!(
        "{:<16} {:>14} {:>13} {:>14}",
        "workload", "prompt len", "output len", "prompt:decode"
    );
    let paper = [
        ("programming", 3871.0, 1656.0, 190.0, 343.0, 20.4),
        ("tool_use", 1835.0, 742.0, 43.0, 16.0, 42.7),
        ("embodied_agent", 2285.0, 471.0, 16.0, 13.0, 142.8),
    ];
    for (name, pm, ps, om, os, ratio) in paper {
        let (gpm, gps, gom, gos, gratio) =
            trace_stats(&trace, name).expect("workload present");
        println!(
            "{name:<16} {gpm:6.0} ± {gps:5.0} {gom:6.0} ± {gos:4.0} {gratio:13.1}:1"
        );
        println!(
            "{:<16} {pm:6.0} ± {ps:5.0} {om:6.0} ± {os:4.0} {ratio:13.1}:1   (paper)",
            ""
        );
    }

    // ---- where the savings land ------------------------------------------
    println!("\n== compute-bound prefill speedup at each workload's mean prompt length ==");
    println!(
        "{:<16} {:>8} {:>12} {:>12} {:>12}",
        "workload", "tokens", "llama-1b", "llama-3b", "llama-8b"
    );
    let models = [
        ("llama-1b", CostModel::llama1b()),
        ("llama-3b", CostModel::llama3b()),
        ("llama-8b", CostModel::llama8b()),
    ];
    for spec in &specs {
        let ctx = spec.prompt_mean as usize;
        print!("{:<16} {ctx:>8}", spec.name);
        for (_, m) in &models {
            let dens = vec![0.5; m.n_layers];
            print!("{:>11.2}x", m.speedup(ctx, &dens, true, true));
        }
        println!();
    }

    // ---- prefill-vs-decode FLOP share (the paper's §1 argument) ----------
    println!("\n== prefill share of total request FLOPs (llama-8b, 50% sparsity off) ==");
    let m = CostModel::llama8b();
    for spec in &specs {
        let p = spec.prompt_mean as usize;
        let g = spec.output_mean as usize;
        let prefill = m.dense_prefill(p).total();
        // each decode step ~ one-token block against a growing cache
        let mut decode = 0.0;
        for i in 0..g {
            decode += m
                .layer_flops(1, p + i + 1, m.d_ffn, false)
                .total()
                * m.n_layers as f64;
        }
        println!(
            "{:<16} prefill {:6.1} GFLOP  decode {:6.1} GFLOP  → prefill share {:5.1}%",
            spec.name,
            prefill / 1e9,
            decode / 1e9,
            100.0 * prefill / (prefill + decode)
        );
    }
    println!(
        "\n(large prompt:decode ratios make prefill the dominant cost — the\n\
         motivation for FFN sparsity during prompt processing, paper §1)"
    );
}
