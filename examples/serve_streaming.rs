//! Streaming client tour: boot the serving stack in-process, submit an
//! interactive streamed request and a batch-class request concurrently,
//! and print tokens as they arrive — the programmatic equivalent of:
//!
//!     curl -N localhost:8080/generate -d '{
//!       "prompt": "the quick brown fox", "max_tokens": 24,
//!       "stream": true, "class": "interactive"}'
//!
//!     make artifacts && cargo run --release --example serve_streaming
//!
//! Requires artifacts and the `pjrt` feature (prints a hint otherwise).

use std::io::Write as _;

use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::Result;
use fastforward::batcher::{Batcher, BatcherConfig};
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::router::{Router, SloClass, SubmitOpts, TokenEvent};
use fastforward::runtime::Runtime;
use fastforward::tokenizer::Tokenizer;
use fastforward::weights::WeightStore;

fn main() -> Result<()> {
    let Some(dir) = fastforward::test_artifacts_dir() else {
        eprintln!("run `make artifacts` and build with --features pjrt");
        return Ok(());
    };

    // one-replica serving stack, SLO scheduling on (the default)
    let metrics = Arc::new(Metrics::new());
    let probe = Manifest::load(&dir)?;
    let router = Arc::new(Router::new(
        64,
        probe.model.max_ctx,
        16 * probe.model.max_ctx / probe.model.block,
        probe.model.block,
        metrics.clone(),
    ));
    let r2 = router.clone();
    let exec = std::thread::spawn(move || -> Result<()> {
        let m = Arc::new(Manifest::load(&dir)?);
        let w = Arc::new(WeightStore::load(&m)?);
        let rt = Arc::new(Runtime::new(m, w)?);
        Batcher::new(Engine::new(rt), r2, BatcherConfig::default()).run()
    });
    let tok = Tokenizer::new(probe.model.vocab);

    // a batch-class request runs alongside; the scheduler preempts its
    // prefill whenever the interactive stream needs the engine
    let mut rng = fastforward::util::rng::Rng::new(3);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let (batch_tx, batch_rx) = channel();
    router
        .submit_with(
            tok.encode(&bank.filler(&mut rng, 1200)),
            8,
            SparsityConfig::fastforward(0.5),
            SubmitOpts {
                class: SloClass::Batch,
                ..Default::default()
            },
            batch_tx,
        )
        .expect("batch admission");

    // the interactive stream: print tokens the moment they decode
    let prompt = format!(
        "{} the quick brown fox",
        bank.filler(&mut rng, 200)
    );
    let (tx, rx) = channel();
    router
        .submit(
            tok.encode(&prompt),
            24,
            SparsityConfig::fastforward(0.5),
            tx,
        )
        .expect("interactive admission");
    print!("streaming: ");
    std::io::stdout().flush()?;
    loop {
        match rx.recv()? {
            TokenEvent::First { ttft_ms, reused_blocks } => {
                print!("[first token after {ttft_ms:.1} ms, \
                        {reused_blocks} cached blocks] ");
                std::io::stdout().flush()?;
            }
            TokenEvent::Token { text, .. } => {
                print!("{text}");
                std::io::stdout().flush()?;
            }
            TokenEvent::Done(resp) => {
                println!();
                match resp.error {
                    Some(e) => println!("failed: {e}"),
                    None => println!(
                        "done: {} tokens, ttft {:.1} ms, tpot {:.2} ms",
                        resp.tokens, resp.ttft_ms, resp.tpot_ms
                    ),
                }
                break;
            }
        }
    }

    // the batch request completes afterwards, having yielded the engine
    if let Some(resp) =
        fastforward::router::Response::collect(&batch_rx)
    {
        println!(
            "batch request finished too: {} tokens, e2e {:.1} ms \
             (preemptions observed: {})",
            resp.tokens,
            resp.e2e_ms,
            metrics.preemptions()
        );
    }
    router.close();
    exec.join().unwrap()?;
    Ok(())
}
