"""Layer-1 Pallas kernel for the error compensation network (paper §3.3).

A low-rank (r' = d/8) two-layer MLP applied per token, run in parallel
with the sparse FFN; its output is added to the sparse FFN output. Small
enough that the whole computation fits one VMEM-resident kernel step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ffn import INTERPRET


def _comp_kernel(x_ref, w1_ref, w2_ref, o_ref):
    x = x_ref[...]
    h = jax.nn.relu(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    )
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@jax.jit
def compensator(x, w1, w2):
    """Ycomp = relu(x W1) W2. x: [T, d], w1: [d, r'], w2: [r', d]."""
    T, d = x.shape
    r = w1.shape[1]
    return pl.pallas_call(
        _comp_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((d, r), lambda j: (0, 0)),
            pl.BlockSpec((r, d), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((T, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=INTERPRET,
    )(x, w1, w2)
