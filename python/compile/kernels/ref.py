"""Pure-jnp reference oracles for every Pallas kernel.

These are the CORE correctness signal: pytest/hypothesis sweeps shapes,
dtypes and sparsity levels and asserts the Pallas kernels (run in
interpret mode) match these to tight tolerances.

All functions operate on a single 128-token (or 1-token, for decode)
block, mirroring the paper's block-wise prompt processing (§3.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# FFN (gated / SwiGLU) — paper eq. (10)
# ---------------------------------------------------------------------------

def ffn_dense(x, wg, wu, wd):
    """Dense gated FFN: y = (silu(x Wg) ⊙ (x Wu)) Wd.

    x: [T, d], wg/wu: [d, f], wd: [f, d] → [T, d]
    """
    h = silu(x @ wg) * (x @ wu)
    return h @ wd


def ffn_sparse(x, wg, wu, wd, idx):
    """Sparse gated FFN over the top-K expert neurons (paper eq. 15-18).

    idx: int32[K] column indices into the f dimension. Equivalent to
    running the dense FFN with all non-selected intermediate neurons
    zeroed.
    """
    wg_s = jnp.take(wg, idx, axis=1)          # [d, K]
    wu_s = jnp.take(wu, idx, axis=1)          # [d, K]
    wd_s = jnp.take(wd, idx, axis=0)          # [K, d]
    h = silu(x @ wg_s) * (x @ wu_s)
    return h @ wd_s


def ffn_neuron_scores(x, wg, wu):
    """Per-neuron importance for the oracle / GRIFFIN-style selection:
    L2 norm over the block of the gated intermediate activation.

    Returns [f] scores (the 'flocking' statistic of Dong et al. 2024).
    """
    h = silu(x @ wg) * (x @ wu)               # [T, f]
    return jnp.sqrt(jnp.sum(h * h, axis=0))


# ---------------------------------------------------------------------------
# Expert neuron predictor — paper §3.2, eq. (12)-(13)
# ---------------------------------------------------------------------------

def predictor_scores(x, q, w1, w2):
    """Attention-pool the block with trainable query q, then 2-layer MLP.

    x: [T, d], q: [d], w1: [d, r], w2: [r, f] → [f]
    """
    logits = (x @ q) / jnp.sqrt(jnp.asarray(x.shape[-1], x.dtype))  # [T]
    a = jax.nn.softmax(logits, axis=-1) @ x                          # [d]
    return jax.nn.relu(a @ w1) @ w2                                  # [f]


# ---------------------------------------------------------------------------
# Error compensation network — paper §3.3, eq. (20)
# ---------------------------------------------------------------------------

def compensator(x, w1, w2):
    """Low-rank corrective term: Ycomp = relu(x W1) W2.

    x: [T, d], w1: [d, r'], w2: [r', d] → [T, d]
    """
    return jax.nn.relu(x @ w1) @ w2


# ---------------------------------------------------------------------------
# Block-causal attention with KV cache (the token-mixing substrate)
# ---------------------------------------------------------------------------

def block_attention(q, k, v, mask):
    """Multi-head attention of a query block against the (padded) KV cache.

    q: [T, nh, dh]   query block
    k: [S, nkv, dh]  key cache (padded to bucket size S)
    v: [S, nkv, dh]  value cache
    mask: [T, S]     additive mask (0 where attendable, -inf elsewhere);
                     encodes causality w.r.t. the block position AND
                     padding beyond the true cache length.
    Returns [T, nh, dh]. GQA: head h reads kv head h // (nh // nkv).
    """
    T, nh, dh = q.shape
    S, nkv, _ = k.shape
    rep = nh // nkv
    kx = jnp.repeat(k, rep, axis=1)            # [S, nh, dh]
    vx = jnp.repeat(v, rep, axis=1)
    scores = jnp.einsum("thd,shd->hts", q, kx) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )                                           # [nh, T, S]
    scores = scores + mask[None, :, :]
    w = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hts,shd->thd", w, vx)


def attention_mass_non_sink(q, k, mask, sink_len):
    """Calibration statistic (paper eq. 23): total attention mass received
    by keys outside the first (sink) block, summed over heads and queries.

    Used by calibrate.py to derive the layerwise sparsity schedule.
    """
    T, nh, dh = q.shape
    S, nkv, _ = k.shape
    rep = nh // nkv
    kx = jnp.repeat(k, rep, axis=1)
    scores = jnp.einsum("thd,shd->hts", q, kx) / jnp.sqrt(
        jnp.asarray(dh, q.dtype)
    )
    scores = scores + mask[None, :, :]
    w = jax.nn.softmax(scores, axis=-1)        # [nh, T, S]
    return jnp.sum(w[:, :, sink_len:])


# ---------------------------------------------------------------------------
# RMSNorm + RoPE (layer plumbing, also used by model.py)
# ---------------------------------------------------------------------------

def rmsnorm(x, g, eps=1e-5):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * g


def rope(x, positions, base=10000.0):
    """Rotary position embedding. x: [T, n, dh], positions: [T] int32."""
    T, n, dh = x.shape
    half = dh // 2
    freqs = 1.0 / (base ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[:, None, :]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    ).astype(x.dtype)
