"""Layer-1 Pallas kernel: block-causal attention against a padded KV cache.

The token-mixing substrate for block-wise prefill. The grid walks the KV
cache in 128-key tiles with an online-softmax accumulator (flash-style),
so the [T, S] score matrix never materializes in VMEM. Causality and
cache-length padding are encoded in an additive mask computed (cheaply,
elementwise) by the L2 model outside the kernel — keeping the kernel free
of dynamic scalar plumbing.

GQA: queries keep nh heads; kv stay at nkv heads and head h reads kv head
h // (nh // nkv) via the BlockSpec index map (no materialized repeat).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ffn import INTERPRET

STILE = 128  # KV tile width
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref):
    """One (head, kv-tile) grid step with online softmax.

    q_ref:    [1, T, dh]      queries for head h
    k_ref:    [1, STILE, dh]  key tile (of the matching kv head)
    v_ref:    [1, STILE, dh]  value tile
    mask_ref: [T, STILE]      additive mask tile
    o_ref:    [1, T, dh]      output for head h
    m/l/acc:  VMEM scratch: running max [T,1], denom [T,1], acc [T,dh]
    """
    i = pl.program_id(1)  # kv tile index

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                                   # [T, dh]
    k = k_ref[0]                                   # [STILE, dh]
    dh = q.shape[-1]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(jnp.asarray(dh, jnp.float32)) + mask_ref[...]

    m_prev = m_ref[...]                            # [T, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    # Guard fully-masked rows: keep the exp argument finite.
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(s <= NEG_INF / 2, 0.0, p)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, jnp.exp(m_prev - m_safe))

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0], preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(i == pl.num_programs(1) - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stile",))
def block_attention(q, k, v, mask, *, stile=STILE):
    """Flash-style block attention. q: [T, nh, dh], k/v: [S, nkv, dh],
    mask: [T, S] additive (0 attendable / -inf masked) → [T, nh, dh]."""
    T, nh, dh = q.shape
    S, nkv, _ = k.shape
    rep = nh // nkv
    assert S % stile == 0, f"S={S} not a multiple of {stile}"
    grid = (nh, S // stile)

    qt = jnp.transpose(q, (1, 0, 2))          # [nh, T, dh]
    kt = jnp.transpose(k, (1, 0, 2))          # [nkv, S, dh]
    vt = jnp.transpose(v, (1, 0, 2))

    out = pl.pallas_call(
        _attn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, T, dh), lambda h, i: (h, 0, 0)),
            pl.BlockSpec((1, stile, dh), lambda h, i: (h // rep, i, 0)),
            pl.BlockSpec((1, stile, dh), lambda h, i: (h // rep, i, 0)),
            pl.BlockSpec((T, stile), lambda h, i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, T, dh), lambda h, i: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((nh, T, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, 1), jnp.float32),
            pltpu.VMEM((T, dh), jnp.float32),
        ],
        interpret=INTERPRET,
    )(qt, kt, vt, mask)
    return jnp.transpose(out, (1, 0, 2))


def make_block_mask(pos, T, S, dtype=jnp.float32):
    """Additive causal+padding mask for a query block starting at `pos`.

    Query t sits at global position pos + t; key s is attendable iff
    s <= pos + t (causal w.r.t. the running cache, which holds keys
    [0, pos + T) after this block's K/V are appended). `pos` may be a
    traced scalar — the mask is built with broadcasting only.
    """
    rows = pos + jnp.arange(T, dtype=jnp.int32)[:, None]   # [T, 1]
    cols = jnp.arange(S, dtype=jnp.int32)[None, :]         # [1, S]
    return jnp.where(cols <= rows, 0.0, NEG_INF).astype(dtype)
