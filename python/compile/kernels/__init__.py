"""FastForward Layer-1 Pallas kernels (interpret-mode on CPU PJRT)."""

from .attention import block_attention, make_block_mask  # noqa: F401
from .compensator import compensator  # noqa: F401
from .ffn import ffn_dense, ffn_neuron_scores, ffn_sparse  # noqa: F401
from .predictor import predictor_scores  # noqa: F401
