"""Layer-1 Pallas kernels for the FastForward FFN hot path.

Hardware adaptation (DESIGN.md §2): the paper's custom CUDA kernels tile
the gathered sub-FFN per thread-block; here the same schedule is expressed
for the TPU model Pallas exposes — the grid walks the intermediate (f or K)
dimension in MXU-friendly 128-wide tiles, the gate⊙up SwiGLU is fused
between the two projections so the intermediate never leaves VMEM, and the
down-projection accumulates into the output tile across grid steps.

All kernels are lowered with interpret=True: CPU PJRT cannot execute
Mosaic custom-calls, so interpret mode is the correctness (and artifact)
path; real-TPU efficiency is estimated analytically in DESIGN.md §8.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly tile along the intermediate dimension. All f / K used by
# the AOT pipeline are multiples of this (the sparsity scheduler quantizes
# per-layer budgets to it).
FTILE = 128

INTERPRET = True  # CPU PJRT: Mosaic custom-calls are not executable.


def _ffn_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    """One grid step: a 128-wide slab of intermediate neurons.

    x_ref:  [T, d]      (whole block resident in VMEM)
    wg_ref: [d, FTILE]  gate slab
    wu_ref: [d, FTILE]  up slab
    wd_ref: [FTILE, d]  down slab
    o_ref:  [T, d]      output accumulator
    """
    j = pl.program_id(0)
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u            # fused SwiGLU, stays in VMEM
    y = jnp.dot(h, wd_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = y.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ftile",))
def ffn_dense(x, wg, wu, wd, *, ftile=FTILE):
    """Dense gated FFN via the tiled Pallas kernel.

    x: [T, d], wg/wu: [d, f], wd: [f, d] → [T, d].
    f must be a multiple of `ftile`.
    """
    T, d = x.shape
    f = wg.shape[1]
    assert f % ftile == 0, f"f={f} not a multiple of {ftile}"
    grid = (f // ftile,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((d, ftile), lambda j: (0, j)),
            pl.BlockSpec((d, ftile), lambda j: (0, j)),
            pl.BlockSpec((ftile, d), lambda j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((T, d), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, d), x.dtype),
        interpret=INTERPRET,
    )(x, wg, wu, wd)


def ffn_sparse(x, wg, wu, wd, idx, *, ftile=FTILE):
    """Sparse (gathered) gated FFN over the top-K expert neurons.

    The gather runs as an XLA op feeding the kernel (on TPU it fuses into
    the HBM→VMEM staging of the weight slabs; the kernel itself is the
    same MXU schedule with f → K). idx: int32[K], K a multiple of `ftile`.
    """
    wg_s = jnp.take(wg, idx, axis=1)
    wu_s = jnp.take(wu, idx, axis=1)
    wd_s = jnp.take(wd, idx, axis=0)
    return ffn_dense(x, wg_s, wu_s, wd_s, ftile=ftile)


def _acts_kernel(x_ref, wg_ref, wu_ref, o_ref):
    """Per-neuron squared-activation-norm slab (oracle statistic)."""
    x = x_ref[...]
    g = jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    u = jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)
    h = (g * jax.nn.sigmoid(g)) * u            # [T, FTILE]
    o_ref[...] = jnp.sum(h * h, axis=0, keepdims=True).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("ftile",))
def ffn_neuron_scores(x, wg, wu, *, ftile=FTILE):
    """GRIFFIN 'flocking' statistic: L2 norm per intermediate neuron over
    the block. Feeds the per-block-dynamic oracle and the GRIFFIN
    first-block-static baseline (paper Table 7).
    """
    T, d = x.shape
    f = wg.shape[1]
    assert f % ftile == 0
    out = pl.pallas_call(
        _acts_kernel,
        grid=(f // ftile,),
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((d, ftile), lambda j: (0, j)),
            pl.BlockSpec((d, ftile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ftile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, f), x.dtype),
        interpret=INTERPRET,
    )(x, wg, wu)
    return jnp.sqrt(out[0])
