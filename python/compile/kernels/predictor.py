"""Layer-1 Pallas kernel for the expert neuron predictor (paper §3.2).

A single-head attention pool with a trainable query aggregates the block
into one d-vector, then a 2-layer MLP projects it to per-neuron scores in
the d_ffn space. The whole thing is one kernel: the pooled vector and the
rank-r hidden stay in VMEM, and the grid walks the d_ffn output in
128-wide slabs (matching the FFN kernel's tiling, so the top-K indices it
induces line up with the sub-FFN weight slabs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ffn import FTILE, INTERPRET


def _predictor_kernel(x_ref, q_ref, w1_ref, w2_ref, o_ref):
    """Grid step j emits scores for neurons [j*FTILE, (j+1)*FTILE).

    The attention pool + first MLP layer are recomputed per slab; both are
    O(T·d + d·r) — negligible next to the FFN they gate, and recomputing
    keeps every operand in VMEM with no cross-step scratch.
    """
    x = x_ref[...]                                  # [T, d]
    q = q_ref[...]                                  # [1, d]
    d = x.shape[-1]
    logits = jnp.dot(x, q.T, preferred_element_type=jnp.float32)  # [T, 1]
    logits = logits / jnp.sqrt(jnp.asarray(d, jnp.float32))
    w = jax.nn.softmax(logits[:, 0], axis=-1)       # [T]
    a = jnp.dot(w[None, :], x, preferred_element_type=jnp.float32)  # [1, d]
    h = jax.nn.relu(
        jnp.dot(a, w1_ref[...], preferred_element_type=jnp.float32)
    )                                               # [1, r]
    s = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = s.astype(o_ref.dtype)              # [1, FTILE]


@functools.partial(jax.jit, static_argnames=("ftile",))
def predictor_scores(x, q, w1, w2, *, ftile=FTILE):
    """Score all f FFN neurons for a block. x: [T, d], q: [d],
    w1: [d, r], w2: [r, f] → [f]."""
    T, d = x.shape
    r = w1.shape[1]
    f = w2.shape[1]
    assert f % ftile == 0
    out = pl.pallas_call(
        _predictor_kernel,
        grid=(f // ftile,),
        in_specs=[
            pl.BlockSpec((T, d), lambda j: (0, 0)),
            pl.BlockSpec((1, d), lambda j: (0, 0)),
            pl.BlockSpec((d, r), lambda j: (0, 0)),
            pl.BlockSpec((r, ftile), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, ftile), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, f), x.dtype),
        interpret=INTERPRET,
    )(x, q[None, :], w1, w2)
    return out[0]
