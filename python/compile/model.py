"""Layer-2 JAX model: a LLaMA-architecture transformer with block-wise
prefill and FastForward FFN sparsity.

Two parallel implementations of every layer op:

* a **pure-jnp path** (`ref.py` ops) used by training / calibration where
  trace-and-grad speed matters, and
* a **Pallas path** (`kernels/`) used by every AOT entry point, so the
  artifacts the Rust runtime executes go through the paper's kernels.

AOT entry points take *explicit flat arguments* (no pytrees) so the HLO
parameter order is self-evident and recorded verbatim in the artifact
manifest for the Rust dispatcher.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref

# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """LLaMA-shape hyperparameters (ratios match LLaMA-3: SwiGLU FFN,
    GQA, RMSNorm, RoPE). See DESIGN.md §3 for the scale substitution."""

    name: str = "ff-mini-128"
    vocab: int = 384
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ffn: int = 512
    block: int = 128           # paper §3.1: 128-token prompt blocks
    ftile: int = 64            # intermediate-dim tile; K quantum
    rope_base: float = 10000.0
    norm_eps: float = 1e-5
    max_ctx: int = 4096
    # Paper: r = d_model/16 (pred), r' = d_model/8 (comp), rounded to a
    # pow2. At our scale those collapse to <16, starving the modules, so
    # we floor both at 32 (documented deviation, DESIGN.md §3).
    pred_r: int = 32
    comp_r: int = 32

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def buckets(self) -> List[int]:
        """KV-cache padding buckets (powers of two up to max_ctx)."""
        out, s = [], 512
        while s <= self.max_ctx:
            out.append(s)
            s *= 2
        return out


CONFIGS: Dict[str, ModelConfig] = {
    "ff-mini-128": ModelConfig(),
    "ff-mini-256": ModelConfig(
        name="ff-mini-256", d_model=256, n_layers=8, n_heads=8,
        n_kv_heads=4, d_ffn=1024, ftile=128, pred_r=32, comp_r=32,
    ),
    "ff-mini-512": ModelConfig(
        name="ff-mini-512", d_model=512, n_layers=12, n_heads=8,
        n_kv_heads=4, d_ffn=2048, ftile=128, pred_r=32, comp_r=64,
    ),
}


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig) -> Dict[str, Any]:
    d, f = cfg.d_model, cfg.d_ffn
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 7)
    sd = d ** -0.5
    return {
        "rms1": jnp.ones((d,), jnp.float32),
        "wq": jax.random.normal(ks[0], (d, nh * dh)) * sd,
        "wk": jax.random.normal(ks[1], (d, nkv * dh)) * sd,
        "wv": jax.random.normal(ks[2], (d, nkv * dh)) * sd,
        "wo": jax.random.normal(ks[3], (nh * dh, d)) * sd,
        "rms2": jnp.ones((d,), jnp.float32),
        "wg": jax.random.normal(ks[4], (d, f)) * sd,
        "wu": jax.random.normal(ks[5], (d, f)) * sd,
        "wd": jax.random.normal(ks[6], (f, d)) * (f ** -0.5),
    }


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    keys = jax.random.split(key, cfg.n_layers + 1)
    return {
        "embed": jax.random.normal(keys[0], (cfg.vocab, cfg.d_model)) * 0.02,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": [init_layer(keys[i + 1], cfg) for i in range(cfg.n_layers)],
    }


def init_predictor(key, cfg: ModelConfig) -> List[Dict[str, Any]]:
    """Per-layer expert-predictor params (paper §3.2)."""
    out = []
    for k in jax.random.split(key, cfg.n_layers):
        k1, k2, k3 = jax.random.split(k, 3)
        out.append({
            "q": jax.random.normal(k1, (cfg.d_model,)) * 0.02,
            "w1": jax.random.normal(k2, (cfg.d_model, cfg.pred_r))
            * (cfg.d_model ** -0.5),
            "w2": jax.random.normal(k3, (cfg.pred_r, cfg.d_ffn))
            * (cfg.pred_r ** -0.5),
        })
    return out


def init_compensator(key, cfg: ModelConfig) -> List[Dict[str, Any]]:
    """Per-layer error-compensator params (paper §3.3). W2 starts at zero
    so the untrained compensator is a no-op."""
    out = []
    for k in jax.random.split(key, cfg.n_layers):
        out.append({
            "w1": jax.random.normal(k, (cfg.d_model, cfg.comp_r))
            * (cfg.d_model ** -0.5),
            "w2": jnp.zeros((cfg.comp_r, cfg.d_model), jnp.float32),
        })
    return out


# ---------------------------------------------------------------------------
# Layer ops — pure-jnp path (training / calibration)
# ---------------------------------------------------------------------------


def attn_sublayer_jnp(lp, cfg, x, k_cache, v_cache, pos, mask):
    """h = x + Wo·Attn(RoPE(Wq·x̂), cache ∪ RoPE(Wk·x̂), ...), x̂=rms1(x).

    Returns (h, k_rows, v_rows): the new K/V rows for this block, already
    rotary-encoded, to be appended to the cache by the caller.
    """
    T = x.shape[0]
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    xh = ref.rmsnorm(x, lp["rms1"], cfg.norm_eps)
    positions = pos + jnp.arange(T, dtype=jnp.int32)
    q = ref.rope((xh @ lp["wq"]).reshape(T, nh, dh), positions, cfg.rope_base)
    k = ref.rope((xh @ lp["wk"]).reshape(T, nkv, dh), positions, cfg.rope_base)
    v = (xh @ lp["wv"]).reshape(T, nkv, dh)
    k_all = jax.lax.dynamic_update_slice(k_cache, k, (pos, 0, 0))
    v_all = jax.lax.dynamic_update_slice(v_cache, v, (pos, 0, 0))
    o = ref.block_attention(q, k_all, v_all, mask)
    h = x + o.reshape(T, nh * dh) @ lp["wo"]
    return h, k, v


def ffn_dense_sublayer_jnp(lp, cfg, h):
    xh = ref.rmsnorm(h, lp["rms2"], cfg.norm_eps)
    return h + ref.ffn_dense(xh, lp["wg"], lp["wu"], lp["wd"])


def forward_train(params, cfg: ModelConfig, tokens):
    """Full-sequence causal forward for training. tokens: [B, T] → logits."""

    def one(seq):
        T = seq.shape[0]
        x = params["embed"][seq]
        mask = kernels.make_block_mask(0, T, T)
        kz = jnp.zeros((T, cfg.n_kv_heads, cfg.d_head), jnp.float32)
        for lp in params["layers"]:
            h, _, _ = attn_sublayer_jnp(lp, cfg, x, kz, kz, 0, mask)
            x = ffn_dense_sublayer_jnp(lp, cfg, h)
        x = ref.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return x @ params["embed"].T

    return jax.vmap(one)(tokens)


def forward_ffn_inputs(params, cfg: ModelConfig, tokens):
    """Forward over one sequence returning per-layer FFN inputs
    (post-rms2 hidden states), used for predictor/compensator training.
    tokens: [T] → (logits, ffn_inputs [L, T, d], resid_states [L, T, d])."""
    T = tokens.shape[0]
    x = params["embed"][tokens]
    mask = kernels.make_block_mask(0, T, T)
    kz = jnp.zeros((T, cfg.n_kv_heads, cfg.d_head), jnp.float32)
    ffn_in, resid = [], []
    for lp in params["layers"]:
        h, _, _ = attn_sublayer_jnp(lp, cfg, x, kz, kz, 0, mask)
        resid.append(h)
        ffn_in.append(ref.rmsnorm(h, lp["rms2"], cfg.norm_eps))
        x = ffn_dense_sublayer_jnp(lp, cfg, h)
    x = ref.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return x @ params["embed"].T, jnp.stack(ffn_in), jnp.stack(resid)


# ---------------------------------------------------------------------------
# AOT entry points — Pallas path, explicit flat arguments
# ---------------------------------------------------------------------------
# Argument order in these signatures is the artifact ABI: aot.py records it
# verbatim in manifest.json and the Rust runtime feeds buffers in the same
# order. Never reorder without bumping the manifest schema.


def make_entry_points(cfg: ModelConfig) -> Dict[str, Any]:
    """Build the jittable entry-point functions for one model config."""
    nh, nkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    eps = cfg.norm_eps

    def embed(embed_w, tokens):
        return (jnp.take(embed_w, tokens, axis=0),)

    def lm_head(final_norm, embed_w, x):
        xh = ref.rmsnorm(x, final_norm, eps)
        return (xh @ embed_w.T,)

    def _attn(rms1, wq, wk, wv, wo, x, k_cache, v_cache, pos):
        T = x.shape[0]
        S = k_cache.shape[0]
        xh = ref.rmsnorm(x, rms1, eps)
        positions = pos + jnp.arange(T, dtype=jnp.int32)
        q = ref.rope((xh @ wq).reshape(T, nh, dh), positions, cfg.rope_base)
        k = ref.rope((xh @ wk).reshape(T, nkv, dh), positions, cfg.rope_base)
        v = (xh @ wv).reshape(T, nkv, dh)
        k_all = jax.lax.dynamic_update_slice(k_cache, k, (pos, 0, 0))
        v_all = jax.lax.dynamic_update_slice(v_cache, v, (pos, 0, 0))
        mask = kernels.make_block_mask(pos, T, S)
        o = kernels.block_attention(q, k_all, v_all, mask)
        h = x + o.reshape(T, nh * dh) @ wo
        return h, k, v

    def layer_attn(rms1, wq, wk, wv, wo, x, k_cache, v_cache, pos):
        """Split entry: attention sublayer only (ablation path)."""
        return _attn(rms1, wq, wk, wv, wo, x, k_cache, v_cache, pos)

    def ffn_dense(rms2, wg, wu, wd, h):
        """Split entry: dense FFN sublayer with residual."""
        xh = ref.rmsnorm(h, rms2, eps)
        return (h + kernels.ffn_dense(xh, wg, wu, wd, ftile=cfg.ftile),)

    def make_ffn_sparse_ext(K):
        def ffn_sparse_ext(rms2, wg, wu, wd, cw1, cw2, h, idx):
            """Split entry: sparse FFN at external top-K indices.
            Returns the sparse residual output and the compensator term
            separately so the harness can toggle compensation (Tab. 6)."""
            xh = ref.rmsnorm(h, rms2, eps)
            y = h + kernels.ffn_sparse(xh, wg, wu, wd, idx, ftile=cfg.ftile)
            comp = kernels.compensator(xh, cw1, cw2)
            return y, comp
        return ffn_sparse_ext

    def ffn_acts(rms2, wg, wu, h):
        """Split entry: GRIFFIN activation-norm statistic (oracle)."""
        xh = ref.rmsnorm(h, rms2, eps)
        return (kernels.ffn_neuron_scores(xh, wg, wu, ftile=cfg.ftile),)

    def predictor(rms2, pq, pw1, pw2, h):
        """Split entry: expert-predictor neuron scores."""
        xh = ref.rmsnorm(h, rms2, eps)
        return (kernels.predictor_scores(xh, pq, pw1, pw2, ftile=cfg.ftile),)

    def layer_dense(rms1, wq, wk, wv, wo, rms2, wg, wu, wd,
                    x, k_cache, v_cache, pos):
        """Fused entry: whole dense transformer layer (fast path)."""
        h, k, v = _attn(rms1, wq, wk, wv, wo, x, k_cache, v_cache, pos)
        xh = ref.rmsnorm(h, rms2, eps)
        y = h + kernels.ffn_dense(xh, wg, wu, wd, ftile=cfg.ftile)
        return y, k, v

    def make_layer_sparse(K):
        def layer_sparse(rms1, wq, wk, wv, wo, rms2, wg, wu, wd,
                         pq, pw1, pw2, cw1, cw2,
                         x, k_cache, v_cache, pos):
            """Fused entry: attention + predictor → top-K → gathered
            sparse FFN + error compensator (the FastForward fast path)."""
            h, k, v = _attn(rms1, wq, wk, wv, wo, x, k_cache, v_cache, pos)
            xh = ref.rmsnorm(h, rms2, eps)
            scores = kernels.predictor_scores(xh, pq, pw1, pw2,
                                              ftile=cfg.ftile)
            # top-K via argsort: xla_extension 0.5.1's HLO parser predates
            # the dedicated `topk` instruction (largest= attribute), so we
            # lower through `sort` instead of jax.lax.top_k.
            order = jnp.argsort(-scores)
            idx = jnp.sort(order[:K]).astype(jnp.int32)
            y = h + kernels.ffn_sparse(xh, wg, wu, wd, idx, ftile=cfg.ftile)
            y = y + kernels.compensator(xh, cw1, cw2)
            return y, k, v
        return layer_sparse

    return {
        "embed": embed,
        "lm_head": lm_head,
        "layer_attn": layer_attn,
        "layer_dense": layer_dense,
        "make_layer_sparse": make_layer_sparse,
        "ffn_dense": ffn_dense,
        "make_ffn_sparse_ext": make_ffn_sparse_ext,
        "ffn_acts": ffn_acts,
        "predictor": predictor,
    }


# Canonical per-layer weight roles in ABI order, per entry-point family.
LAYER_ROLES = ["rms1", "wq", "wk", "wv", "wo", "rms2", "wg", "wu", "wd"]
ATTN_ROLES = ["rms1", "wq", "wk", "wv", "wo"]
FFN_ROLES = ["rms2", "wg", "wu", "wd"]
PRED_ROLES = ["q", "w1", "w2"]
COMP_ROLES = ["w1", "w2"]


# ---------------------------------------------------------------------------
# Blockwise prefill in python (tests + calibration parity with the Rust
# engine; mirrors rust/src/engine/prefill.rs)
# ---------------------------------------------------------------------------


def blockwise_prefill_dense(params, cfg: ModelConfig, tokens):
    """Process a prompt block-by-block through the jnp path; returns the
    final hidden states [T, d] and the per-layer KV caches. Must equal
    forward_train on the same tokens (causality test)."""
    T = tokens.shape[0]
    assert T % cfg.block == 0
    n_blocks = T // cfg.block
    S = T
    d = cfg.d_model
    kc = [jnp.zeros((S, cfg.n_kv_heads, cfg.d_head)) for _ in params["layers"]]
    vc = [jnp.zeros((S, cfg.n_kv_heads, cfg.d_head)) for _ in params["layers"]]
    out = jnp.zeros((T, d))
    for b in range(n_blocks):
        pos = b * cfg.block
        blk = jax.lax.dynamic_slice(tokens, (pos,), (cfg.block,))
        x = params["embed"][blk]
        mask = kernels.make_block_mask(pos, cfg.block, S)
        for li, lp in enumerate(params["layers"]):
            h, k, v = attn_sublayer_jnp(lp, cfg, x, kc[li], vc[li], pos, mask)
            kc[li] = jax.lax.dynamic_update_slice(kc[li], k, (pos, 0, 0))
            vc[li] = jax.lax.dynamic_update_slice(vc[li], v, (pos, 0, 0))
            x = ffn_dense_sublayer_jnp(lp, cfg, h)
        out = jax.lax.dynamic_update_slice(out, x, (pos, 0))
    return out, kc, vc
