"""Build-time training: base LM, expert predictors, error compensators.

Runs once inside `make artifacts` (never on the request path). Three
stages, all on the pure-jnp model path for trace speed:

1. **Base LM** — AdamW on the synthetic corpus (next-byte prediction).
2. **Expert predictors** (paper §3.2) — weighted BCE against GRIFFIN
   activation-norm labels: top-50% neurons per block are positive, with
   exponentially decaying weights 32/16/8/4/2 over positive rank
   quintiles; negatives weigh 1.
3. **Error compensators** (paper §3.3) — layerwise distillation (MSE vs
   the dense FFN output) in two phases: oracle-mask warm start, then
   predictor-mask adaptation.
"""

from __future__ import annotations

import functools
import time
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .corpus import PAD, CorpusGen
from .kernels import ref


# ---------------------------------------------------------------------------
# AdamW (hand-rolled; no optax dependency)
# ---------------------------------------------------------------------------


def adamw_init(params):
    z = jax.tree.map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
    vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
    new = jax.tree.map(
        lambda p, mh, vh: p - lr * (mh / (jnp.sqrt(vh) + eps) + wd * p),
        params, mh, vh,
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Stage 1: base LM
# ---------------------------------------------------------------------------


def lm_loss(params, cfg, tokens):
    logits = M.forward_train(params, cfg, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets != PAD).astype(jnp.float32)  # don't learn padding
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def train_base(cfg: M.ModelConfig, *, steps=700, batch=12, seq=384,
               lr=3e-3, seed=0, log_every=25) -> Tuple[Dict, List[Dict]]:
    key = jax.random.PRNGKey(seed)
    params = M.init_params(key, cfg)
    opt = adamw_init(params)
    gen = CorpusGen(seed=seed + 1)

    @jax.jit
    def step(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(lm_loss)(params, cfg, tokens)
        params, opt = adamw_update(params, grads, opt, lr)
        return params, opt, loss

    log = []
    t0 = time.time()
    for i in range(steps):
        tokens = jnp.asarray(gen.mixed_batch(batch, seq + 1))
        cur_lr = lr * 0.5 * (1 + np.cos(np.pi * i / steps))
        params, opt, loss = step(params, opt, tokens, cur_lr)
        if i % log_every == 0 or i == steps - 1:
            entry = {"step": i, "loss": float(loss),
                     "elapsed_s": round(time.time() - t0, 1)}
            log.append(entry)
            print(f"[base] step {i:4d} loss {float(loss):.4f}")
    return params, log


# ---------------------------------------------------------------------------
# Stage 2: expert predictors (weighted BCE vs GRIFFIN labels)
# ---------------------------------------------------------------------------


def griffin_labels_and_weights(ffn_in, lp):
    """Labels/weights per paper §3.2 from one block's dense activations.

    ffn_in: [T, d] post-rms2 FFN input. Returns (y [f], w [f]).
    Top 50% of neurons by block activation norm → label 1; positive rank
    quintiles get weights 32/16/8/4/2; negatives weight 1.
    """
    scores = ref.ffn_neuron_scores(ffn_in, lp["wg"], lp["wu"])  # [f]
    f = scores.shape[0]
    order = jnp.argsort(-scores)                 # descending
    rank = jnp.argsort(order)                    # rank of each neuron
    y = (rank < f // 2).astype(jnp.float32)
    quint = rank // (f // 10)                    # positive quintiles 0..4
    wpos = 2.0 ** (5 - jnp.clip(quint, 0, 4))    # 32,16,8,4,2
    w = jnp.where(y > 0, wpos, 1.0)
    return y, w


def predictor_loss(pred_stack, ffn_in_blocks, labels, weights):
    """Weighted BCE over stacked layers. pred_stack leaves: [L, ...];
    ffn_in_blocks: [L, B, T, d]; labels/weights: [L, B, f]."""

    def layer_loss(pp, xs, ys, ws):
        def block_loss(x, y, w):
            s = ref.predictor_scores(x, pp["q"], pp["w1"], pp["w2"])
            p = jax.nn.log_sigmoid(s)
            q = jax.nn.log_sigmoid(-s)
            return jnp.sum(w * -(y * p + (1 - y) * q)) / jnp.sum(w)
        return jnp.mean(jax.vmap(block_loss)(xs, ys, ws))

    losses = jax.vmap(layer_loss)(pred_stack, ffn_in_blocks, labels, weights)
    return jnp.mean(losses)


def stack_layers(per_layer: List[Dict]) -> Dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)


def unstack_layers(stacked: Dict, n: int) -> List[Dict]:
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(n)]


def collect_ffn_inputs(params, cfg, gen: CorpusGen, n_blocks: int):
    """Sample corpus blocks and return per-layer FFN inputs [L, B, T, d]."""
    fwd = jax.jit(functools.partial(M.forward_ffn_inputs, params, cfg))
    outs = []
    for _ in range(n_blocks):
        toks = jnp.asarray(gen.mixed_batch(1, cfg.block)[0])
        _, ffn_in, _ = fwd(toks)
        outs.append(ffn_in)                      # [L, T, d]
    return jnp.stack(outs, axis=1)               # [L, B, T, d]


def train_predictors(params, cfg: M.ModelConfig, *, steps=250, batch=16,
                     lr=2e-3, seed=10) -> Tuple[List[Dict], List[Dict]]:
    key = jax.random.PRNGKey(seed)
    pred = stack_layers(M.init_predictor(key, cfg))
    opt = adamw_init(pred)
    gen = CorpusGen(seed=seed + 1)
    L = cfg.n_layers

    label_fn = jax.jit(
        lambda ffn_in: jax.vmap(                    # over layers
            lambda xs, lp: jax.vmap(
                lambda x: griffin_labels_and_weights(x, lp)
            )(xs),
            in_axes=(0, 0),
        )(ffn_in, stack_layers(params["layers"]))
    )

    @jax.jit
    def step(pred, opt, ffn_in, labels, weights, lr):
        loss, grads = jax.value_and_grad(predictor_loss)(
            pred, ffn_in, labels, weights)
        pred, opt = adamw_update(pred, grads, opt, lr, wd=0.0)
        return pred, opt, loss

    log = []
    for i in range(steps):
        ffn_in = collect_ffn_inputs(params, cfg, gen, batch)  # [L,B,T,d]
        labels, weights = label_fn(ffn_in)
        pred, opt, loss = step(pred, opt, ffn_in, labels, weights, lr)
        if i % 25 == 0 or i == steps - 1:
            log.append({"step": i, "loss": float(loss)})
            print(f"[pred] step {i:4d} wBCE {float(loss):.4f}")
    return unstack_layers(pred, L), log


def predictor_topk_overlap(params, pred, cfg, *, n_blocks=16, density=0.5,
                           seed=99) -> List[float]:
    """Eval: mean |predicted ∩ oracle| / K per layer (reported in
    EXPERIMENTS.md; the quality signal behind paper Table 7)."""
    gen = CorpusGen(seed=seed)
    K = int(cfg.d_ffn * density)
    ffn_in = collect_ffn_inputs(params, cfg, gen, n_blocks)  # [L,B,T,d]
    overlaps = []
    for li in range(cfg.n_layers):
        lp = params["layers"][li]
        pp = pred[li]
        tot = 0.0
        for b in range(n_blocks):
            x = ffn_in[li, b]
            oracle = np.argsort(
                -np.asarray(ref.ffn_neuron_scores(x, lp["wg"], lp["wu"])))[:K]
            predicted = np.argsort(
                -np.asarray(ref.predictor_scores(
                    x, pp["q"], pp["w1"], pp["w2"])))[:K]
            tot += len(set(oracle.tolist()) & set(predicted.tolist())) / K
        overlaps.append(tot / n_blocks)
    return overlaps


# ---------------------------------------------------------------------------
# Stage 3: error compensators (two-phase layerwise distillation)
# ---------------------------------------------------------------------------


def comp_loss(comp_stack, layer_stack, ffn_in, idx):
    """MSE between dense FFN output and sparse+compensated output.
    ffn_in: [L, B, T, d]; idx: [L, B, K] expert indices."""

    def layer_loss(cp, lp, xs, idxs):
        def block_loss(x, ix):
            dense = ref.ffn_dense(x, lp["wg"], lp["wu"], lp["wd"])
            sparse = ref.ffn_sparse(x, lp["wg"], lp["wu"], lp["wd"], ix)
            comp = ref.compensator(x, cp["w1"], cp["w2"])
            return jnp.mean((dense - (sparse + comp)) ** 2)
        return jnp.mean(jax.vmap(block_loss)(xs, idxs))

    return jnp.mean(
        jax.vmap(layer_loss)(comp_stack, layer_stack, ffn_in, idx))


def train_compensators(params, pred, cfg: M.ModelConfig, *, steps_a=150,
                       steps_b=150, batch=16, density=0.5, lr=2e-3,
                       seed=20) -> Tuple[List[Dict], List[Dict]]:
    key = jax.random.PRNGKey(seed)
    comp = stack_layers(M.init_compensator(key, cfg))
    opt = adamw_init(comp)
    gen = CorpusGen(seed=seed + 1)
    K = int(cfg.d_ffn * density)
    layer_stack = stack_layers(params["layers"])
    pred_stack = stack_layers(pred)

    @jax.jit
    def oracle_idx(ffn_in):
        def per(lp, xs):
            def one(x):
                s = ref.ffn_neuron_scores(x, lp["wg"], lp["wu"])
                _, ix = jax.lax.top_k(s, K)
                return jnp.sort(ix).astype(jnp.int32)
            return jax.vmap(one)(xs)
        return jax.vmap(per)(layer_stack, ffn_in)

    @jax.jit
    def pred_idx(ffn_in):
        def per(pp, xs):
            def one(x):
                s = ref.predictor_scores(x, pp["q"], pp["w1"], pp["w2"])
                _, ix = jax.lax.top_k(s, K)
                return jnp.sort(ix).astype(jnp.int32)
            return jax.vmap(one)(xs)
        return jax.vmap(per)(pred_stack, ffn_in)

    @jax.jit
    def step(comp, opt, ffn_in, idx, lr):
        loss, grads = jax.value_and_grad(comp_loss)(
            comp, layer_stack, ffn_in, idx)
        comp, opt = adamw_update(comp, grads, opt, lr, wd=0.0)
        return comp, opt, loss

    log = []
    for phase, steps, idx_fn in (
        ("oracle", steps_a, oracle_idx),
        ("predictor", steps_b, pred_idx),
    ):
        for i in range(steps):
            ffn_in = collect_ffn_inputs(params, cfg, gen, batch)
            idx = idx_fn(ffn_in)
            comp, opt, loss = step(comp, opt, ffn_in, idx, lr)
            if i % 25 == 0 or i == steps - 1:
                log.append({"phase": phase, "step": i, "loss": float(loss)})
                print(f"[comp/{phase}] step {i:4d} mse {float(loss):.6f}")
    return unstack_layers(comp, cfg.n_layers), log
