"""Layerwise sparsity calibration (paper §3.4, eq. 23 + Algorithm 1).

Computes, per layer, the total attention mass received by non-sink keys
(everything outside the first 128-token block) over a calibration set of
long synthetic prompts, then allocates per-layer density budgets with the
paper's greedy linear schedule. The schedule is quantized to the FFN
kernel's tile quantum so every per-layer K maps to a compiled artifact.

Algorithm 1 is re-implemented (and property-tested) in Rust
(rust/src/sparsity/schedule.rs); this module is the authoritative source
of the calibration *statistics* written into schedule.json.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from . import model as M
from .corpus import CorpusGen
from .kernels import ref


def attention_masses(params, cfg: M.ModelConfig, *, n_samples=8,
                     ctx_len=1024, seed=7) -> List[float]:
    """Per-layer mean attention mass received by non-sink tokens (eq. 23),
    accumulated block-by-block during prefill of calibration prompts."""
    gen = CorpusGen(seed=seed)
    L = cfg.n_layers
    masses = np.zeros(L, dtype=np.float64)

    @jax.jit
    def block_masses(params, tokens):
        """Prefill one prompt, returning per-layer non-sink attention mass."""
        T = tokens.shape[0]
        x = params["embed"][tokens]
        mask = kernels.make_block_mask(0, T, T)
        kz = jnp.zeros((T, cfg.n_kv_heads, cfg.d_head))
        out = []
        for lp in params["layers"]:
            xh = ref.rmsnorm(x, lp["rms1"], cfg.norm_eps)
            positions = jnp.arange(T, dtype=jnp.int32)
            q = ref.rope(
                (xh @ lp["wq"]).reshape(T, cfg.n_heads, cfg.d_head),
                positions, cfg.rope_base)
            k = ref.rope(
                (xh @ lp["wk"]).reshape(T, cfg.n_kv_heads, cfg.d_head),
                positions, cfg.rope_base)
            v = (xh @ lp["wv"]).reshape(T, cfg.n_kv_heads, cfg.d_head)
            out.append(
                ref.attention_mass_non_sink(q, k, mask, cfg.block))
            o = ref.block_attention(q, k, v, mask)
            h = x + o.reshape(T, cfg.n_heads * cfg.d_head) @ lp["wo"]
            x = M.ffn_dense_sublayer_jnp(lp, cfg, h)
        return jnp.stack(out)

    for _ in range(n_samples):
        toks = jnp.asarray(gen.tokens(ctx_len))
        masses += np.asarray(block_masses(params, toks), dtype=np.float64)
    # Normalize per head and sample (eq. 23 averages over |D| and H).
    masses /= n_samples * cfg.n_heads
    return masses.tolist()


def layerwise_schedule(scores: List[float], budget: float) -> List[float]:
    """Paper Algorithm 1 verbatim: greedy proportional allocation of the
    per-layer density budgets b_i ∈ (0, 1], clamped at 1.

    `budget` B is the mean target density (1 - sparsity); the returned
    list satisfies sum(b) <= B * L with equality unless everything
    saturates at 1."""
    L = len(scores)
    T = budget * L
    s_total = float(sum(scores))
    out = []
    for s in scores:
        b = min(1.0, s / s_total * T) if s_total > 0 else min(1.0, T / 1)
        T -= b
        s_total -= s
        out.append(b)
    return out


def quantize_densities(densities: List[float], d_ffn: int,
                       ftile: int) -> List[int]:
    """Round per-layer densities to K = multiples of the kernel tile,
    keeping every layer at least one tile wide."""
    return [
        int(np.clip(round(b * d_ffn / ftile), 1, d_ffn // ftile)) * ftile
        for b in densities
    ]


def build_schedule(params, cfg: M.ModelConfig, *,
                   sparsities=(0.3, 0.4, 0.5), n_samples=8,
                   ctx_len=1024, seed=7) -> Dict:
    """Full schedule.json payload: masses + per-budget layerwise and
    uniform K allocations."""
    masses = attention_masses(params, cfg, n_samples=n_samples,
                              ctx_len=ctx_len, seed=seed)
    schedules = {}
    for sp in sparsities:
        budget = 1.0 - sp
        dens = layerwise_schedule(masses, budget)
        schedules[f"{sp:.2f}"] = {
            "sparsity": sp,
            "layer_densities": dens,
            "layer_k": quantize_densities(dens, cfg.d_ffn, cfg.ftile),
            "uniform_k": quantize_densities(
                [budget] * cfg.n_layers, cfg.d_ffn, cfg.ftile),
        }
    return {
        "attention_masses": masses,
        "calibration": {"n_samples": n_samples, "ctx_len": ctx_len,
                        "sink_len": cfg.block, "seed": seed},
        "schedules": schedules,
    }
