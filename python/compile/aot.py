"""AOT compilation pipeline: train → calibrate → lower → serialize.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Emits into the artifacts directory:

* ``*.hlo.txt``       — HLO **text** per entry point (xla_extension 0.5.1
                        rejects jax≥0.5 serialized protos: 64-bit ids; the
                        text parser reassigns ids — see aot_recipe).
* ``weights.bin``     — all trained parameters, flat little-endian f32.
* ``manifest.json``   — model config, weight table (name→offset/shape),
                        executable ABI table (argument order!), K grid,
                        tokenizer spec.
* ``schedule.json``   — calibration masses + per-budget layer schedules.
* ``train_log.json``  — training curves + predictor quality for
                        EXPERIMENTS.md.

Python runs ONLY here; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import calibrate, train
from . import model as M
from .corpus import VOCAB

F32 = "f32"
I32 = "i32"


def to_hlo_text(lowered) -> str:
    """jax lowered → HLO text via stablehlo → XlaComputation."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


class ArtifactWriter:
    def __init__(self, out_dir: str, cfg: M.ModelConfig):
        self.out_dir = out_dir
        self.cfg = cfg
        self.weights: List[np.ndarray] = []
        self.weight_table: Dict[str, Dict] = {}
        self.executables: List[Dict] = []
        self.offset = 0
        os.makedirs(out_dir, exist_ok=True)

    # -- weights ---------------------------------------------------------
    def add_weight(self, name: str, arr) -> None:
        a = np.ascontiguousarray(np.asarray(arr), dtype=np.float32)
        self.weight_table[name] = {
            "offset": self.offset,
            "shape": list(a.shape),
            "dtype": F32,
        }
        self.weights.append(a)
        self.offset += a.nbytes

    def add_params(self, params, pred, comp) -> None:
        self.add_weight("embed", params["embed"])
        self.add_weight("final_norm", params["final_norm"])
        for li, lp in enumerate(params["layers"]):
            for role in M.LAYER_ROLES:
                self.add_weight(f"layers.{li}.{role}", lp[role])
        for li, pp in enumerate(pred):
            for role in M.PRED_ROLES:
                self.add_weight(f"pred.{li}.{role}", pp[role])
        for li, cp in enumerate(comp):
            for role in M.COMP_ROLES:
                self.add_weight(f"comp.{li}.{role}", cp[role])

    # -- executables -----------------------------------------------------
    def lower(self, name: str, fn, arg_specs: List[Dict]) -> None:
        """Lower `fn` at the shapes in arg_specs and record the ABI."""
        t0 = time.time()
        example = []
        for spec in arg_specs:
            shape = tuple(spec["shape"])
            dt = jnp.int32 if spec["dtype"] == I32 else jnp.float32
            example.append(jax.ShapeDtypeStruct(shape, dt))
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.executables.append(
            {"name": name, "file": fname, "args": arg_specs})
        print(f"  lowered {name:42s} {len(text)//1024:5d} KiB "
              f"{time.time()-t0:5.1f}s")

    def finish(self, schedule: Dict, train_log: Dict, k_grid: List[int],
               extra: Dict) -> None:
        blob = b"".join(a.tobytes() for a in self.weights)
        with open(os.path.join(self.out_dir, "weights.bin"), "wb") as f:
            f.write(blob)
        cfg = self.cfg
        manifest = {
            "schema_version": 1,
            "model": {
                "name": cfg.name, "vocab": cfg.vocab,
                "d_model": cfg.d_model, "n_layers": cfg.n_layers,
                "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads,
                "d_head": cfg.d_head, "d_ffn": cfg.d_ffn,
                "block": cfg.block, "ftile": cfg.ftile,
                "max_ctx": cfg.max_ctx, "buckets": cfg.buckets,
                "rope_base": cfg.rope_base, "norm_eps": cfg.norm_eps,
                "pred_r": cfg.pred_r, "comp_r": cfg.comp_r,
            },
            "tokenizer": {"kind": "byte", "vocab": VOCAB,
                          "pad": 256, "bos": 257, "eos": 258},
            "k_grid": k_grid,
            "weights_file": "weights.bin",
            "weights": self.weight_table,
            "executables": self.executables,
        }
        manifest.update(extra)
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        with open(os.path.join(self.out_dir, "schedule.json"), "w") as f:
            json.dump(schedule, f, indent=1)
        with open(os.path.join(self.out_dir, "train_log.json"), "w") as f:
            json.dump(train_log, f, indent=1)
        print(f"  weights.bin: {len(blob)//1024} KiB, "
              f"{len(self.executables)} executables")


# ---------------------------------------------------------------------------
# Arg-spec builders (the artifact ABI; mirrored by rust/src/runtime)
# ---------------------------------------------------------------------------


def w(role):             # per-layer transformer weight
    return {"kind": "layer_weight", "role": role}


def pw(role):            # per-layer predictor weight
    return {"kind": "pred_weight", "role": role}


def cw(role):            # per-layer compensator weight
    return {"kind": "comp_weight", "role": role}


def gw(name):            # global weight
    return {"kind": "weight", "name": name}


def inp(name, shape, dtype=F32):
    return {"kind": "input", "name": name, "shape": list(shape),
            "dtype": dtype}


def build_arg_specs(cfg: M.ModelConfig, weight_table: Dict) -> None:
    """Fill in shapes/dtypes for weight args from the weight table."""


def resolve_spec(spec: Dict, cfg: M.ModelConfig) -> Dict:
    """Attach concrete shape/dtype to weight arg specs (layer 0 as the
    exemplar — all layers share shapes)."""
    if spec["kind"] == "input":
        return spec
    shapes = {
        "rms1": [cfg.d_model], "rms2": [cfg.d_model],
        "wq": [cfg.d_model, cfg.n_heads * cfg.d_head],
        "wk": [cfg.d_model, cfg.n_kv_heads * cfg.d_head],
        "wv": [cfg.d_model, cfg.n_kv_heads * cfg.d_head],
        "wo": [cfg.n_heads * cfg.d_head, cfg.d_model],
        "wg": [cfg.d_model, cfg.d_ffn], "wu": [cfg.d_model, cfg.d_ffn],
        "wd": [cfg.d_ffn, cfg.d_model],
    }
    pred_shapes = {"q": [cfg.d_model], "w1": [cfg.d_model, cfg.pred_r],
                   "w2": [cfg.pred_r, cfg.d_ffn]}
    comp_shapes = {"w1": [cfg.d_model, cfg.comp_r],
                   "w2": [cfg.comp_r, cfg.d_model]}
    glob_shapes = {"embed": [cfg.vocab, cfg.d_model],
                   "final_norm": [cfg.d_model]}
    out = dict(spec)
    out["dtype"] = F32
    if spec["kind"] == "layer_weight":
        out["shape"] = shapes[spec["role"]]
    elif spec["kind"] == "pred_weight":
        out["shape"] = pred_shapes[spec["role"]]
    elif spec["kind"] == "comp_weight":
        out["shape"] = comp_shapes[spec["role"]]
    elif spec["kind"] == "weight":
        out["shape"] = glob_shapes[spec["name"]]
    return out


def lower_all(aw: ArtifactWriter, cfg: M.ModelConfig, k_grid: List[int],
              decode_k: List[int]) -> None:
    """Lower every entry point × shape variant."""
    ep = M.make_entry_points(cfg)
    d, nkv, dh = cfg.d_model, cfg.n_kv_heads, cfg.d_head
    B = cfg.block

    def rs(specs):
        return [resolve_spec(s, cfg) for s in specs]

    for T in (B, 1):
        aw.lower(f"embed_t{T}", ep["embed"], rs([
            gw("embed"), inp("tokens", [T], I32)]))
        aw.lower(f"lm_head_t{T}", ep["lm_head"], rs([
            gw("final_norm"), gw("embed"), inp("x", [T, d])]))

    layer_w = [w(r) for r in M.LAYER_ROLES]
    attn_w = [w(r) for r in M.ATTN_ROLES]
    ffn_w = [w(r) for r in M.FFN_ROLES]
    sparse_w = layer_w + [pw(r) for r in M.PRED_ROLES] + \
        [cw(r) for r in M.COMP_ROLES]

    for S in cfg.buckets:
        kv = [inp("k_cache", [S, nkv, dh]), inp("v_cache", [S, nkv, dh]),
              inp("pos", [], I32)]
        for T in (B, 1):
            aw.lower(f"layer_dense_t{T}_s{S}", ep["layer_dense"], rs(
                layer_w + [inp("x", [T, d])] + kv))
        aw.lower(f"layer_attn_t{B}_s{S}", ep["layer_attn"], rs(
            attn_w + [inp("x", [B, d])] + kv))
        for K in k_grid:
            aw.lower(f"layer_sparse_k{K}_t{B}_s{S}",
                     ep["make_layer_sparse"](K),
                     rs(sparse_w + [inp("x", [B, d])] + kv))
        for K in decode_k:
            aw.lower(f"layer_sparse_k{K}_t1_s{S}",
                     ep["make_layer_sparse"](K),
                     rs(sparse_w + [inp("x", [1, d])] + kv))

    # FFN-module-level entry points (split path: ablations, Fig. 6 benches)
    aw.lower(f"ffn_dense_t{B}", ep["ffn_dense"], rs(
        ffn_w + [inp("h", [B, d])]))
    for K in k_grid:
        aw.lower(f"ffn_sparse_ext_k{K}_t{B}", ep["make_ffn_sparse_ext"](K),
                 rs(ffn_w + [cw("w1"), cw("w2"), inp("h", [B, d]),
                             inp("idx", [K], I32)]))
    aw.lower(f"ffn_acts_t{B}", ep["ffn_acts"], rs(
        [w("rms2"), w("wg"), w("wu"), inp("h", [B, d])]))
    aw.lower(f"predictor_t{B}", ep["predictor"], rs(
        [w("rms2")] + [pw(r) for r in M.PRED_ROLES] + [inp("h", [B, d])]))


# ---------------------------------------------------------------------------
# Training cache
# ---------------------------------------------------------------------------


def save_cache(path, params, pred, comp):
    flat = {}
    flat["embed"] = np.asarray(params["embed"])
    flat["final_norm"] = np.asarray(params["final_norm"])
    for li, lp in enumerate(params["layers"]):
        for role in M.LAYER_ROLES:
            flat[f"layers.{li}.{role}"] = np.asarray(lp[role])
    for li, pp in enumerate(pred):
        for role in M.PRED_ROLES:
            flat[f"pred.{li}.{role}"] = np.asarray(pp[role])
    for li, cp in enumerate(comp):
        for role in M.COMP_ROLES:
            flat[f"comp.{li}.{role}"] = np.asarray(cp[role])
    np.savez(path, **flat)


def load_cache(path, cfg):
    z = np.load(path)
    params = {
        "embed": jnp.asarray(z["embed"]),
        "final_norm": jnp.asarray(z["final_norm"]),
        "layers": [
            {role: jnp.asarray(z[f"layers.{li}.{role}"])
             for role in M.LAYER_ROLES}
            for li in range(cfg.n_layers)
        ],
    }
    pred = [{role: jnp.asarray(z[f"pred.{li}.{role}"])
             for role in M.PRED_ROLES} for li in range(cfg.n_layers)]
    comp = [{role: jnp.asarray(z[f"comp.{li}.{role}"])
             for role in M.COMP_ROLES} for li in range(cfg.n_layers)]
    return params, pred, comp


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--model", default=os.environ.get("MODEL",
                                                      "ff-mini-128"))
    ap.add_argument("--base-steps", type=int, default=700)
    ap.add_argument("--pred-steps", type=int, default=200)
    ap.add_argument("--comp-steps", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--use-cache", action="store_true",
                    help="reuse cached trained weights if present")
    args = ap.parse_args()

    cfg = M.CONFIGS[args.model]
    cache = os.path.join(args.out_dir, "train_cache.npz")
    t0 = time.time()
    log: Dict[str, Any] = {"model": cfg.name}

    if args.use_cache and os.path.exists(cache):
        print(f"[aot] loading cached weights from {cache}")
        params, pred, comp = load_cache(cache, cfg)
        log["cached"] = True
    else:
        print(f"[aot] training base model {cfg.name}")
        params, base_log = train.train_base(
            cfg, steps=args.base_steps, seed=args.seed)
        print("[aot] training expert predictors")
        pred, pred_log = train.train_predictors(
            params, cfg, steps=args.pred_steps, seed=args.seed + 10)
        print("[aot] training error compensators")
        comp, comp_log = train.train_compensators(
            params, pred, cfg, steps_a=args.comp_steps,
            steps_b=args.comp_steps, seed=args.seed + 20)
        log.update({"base": base_log, "pred": pred_log, "comp": comp_log})
        os.makedirs(args.out_dir, exist_ok=True)
        save_cache(cache, params, pred, comp)

    print("[aot] predictor top-K overlap vs oracle")
    overlap = train.predictor_topk_overlap(params, pred, cfg)
    log["pred_topk_overlap@0.5"] = overlap
    print(f"  per-layer overlap: {[round(o, 3) for o in overlap]}")

    print("[aot] calibrating layerwise schedule")
    schedule = calibrate.build_schedule(params, cfg)
    k_grid = sorted({
        k
        for s in schedule["schedules"].values()
        for k in (s["layer_k"] + s["uniform_k"])
        if k < cfg.d_ffn
    })
    # Ensure the canonical 50%-uniform K is present for ablations.
    k50 = schedule["schedules"]["0.50"]["uniform_k"][0]
    decode_k = sorted({k for k in
                       schedule["schedules"]["0.50"]["layer_k"] +
                       [k50] if k < cfg.d_ffn})
    print(f"  k_grid={k_grid} decode_k={decode_k}")

    print("[aot] lowering entry points")
    aw = ArtifactWriter(args.out_dir, cfg)
    aw.add_params(params, pred, comp)
    lower_all(aw, cfg, k_grid, decode_k)
    aw.finish(schedule, log, k_grid, extra={"decode_k": decode_k})

    # Cross-language parity fixture: the Rust engine's dense blockwise
    # prefill must reproduce these logits (rust/tests/parity.rs).
    print("[aot] writing parity fixture")
    from .corpus import CorpusGen

    fx_tokens = CorpusGen(seed=1234).tokens(300)  # 2 blocks + 44-token tail
    logits = M.forward_train(params, cfg, jnp.asarray(fx_tokens)[None])[0]
    fixture = {
        "tokens": [int(t) for t in fx_tokens],
        "last_logits": [float(x) for x in np.asarray(logits[-1])],
    }
    with open(os.path.join(args.out_dir, "parity_fixture.json"), "w") as f:
        json.dump(fixture, f)
    print(f"[aot] done in {time.time()-t0:.0f}s → {args.out_dir}")


if __name__ == "__main__":
    main()
