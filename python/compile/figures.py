"""Paper Figures 4 & 5: blockwise attention-score distributions across
layers during prefill — the empirical motivation for the layerwise
sparsity schedule (§3.4).

For each layer, computes the sum of attention scores *received* by each
128-token block (excluding the first, sink-containing block) during
prefill of calibration prompts, then reports the per-layer histogram
(Fig. 4) and per-block means (Fig. 5).

Usage:  cd python && python -m compile.figures [--out ../artifacts/figures.json]
Runs at build time only (analysis of the trained model, like calibrate).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import kernels
from . import model as M
from .aot import load_cache
from .corpus import CorpusGen
from .kernels import ref


def blockwise_attention_mass(params, cfg: M.ModelConfig, tokens):
    """Per-layer, per-key-block received attention mass for one prompt.

    Returns [L, n_blocks] where entry (l, b) = sum over heads and queries
    of attention weight onto keys in block b at layer l.
    """
    T = tokens.shape[0]
    n_blocks = T // cfg.block
    x = params["embed"][tokens]
    mask = kernels.make_block_mask(0, T, T)
    out = np.zeros((cfg.n_layers, n_blocks))
    for li, lp in enumerate(params["layers"]):
        xh = ref.rmsnorm(x, lp["rms1"], cfg.norm_eps)
        positions = jnp.arange(T, dtype=jnp.int32)
        q = ref.rope(
            (xh @ lp["wq"]).reshape(T, cfg.n_heads, cfg.d_head),
            positions, cfg.rope_base)
        k = ref.rope(
            (xh @ lp["wk"]).reshape(T, cfg.n_kv_heads, cfg.d_head),
            positions, cfg.rope_base)
        v = (xh @ lp["wv"]).reshape(T, cfg.n_kv_heads, cfg.d_head)
        rep = cfg.n_heads // cfg.n_kv_heads
        kx = jnp.repeat(k, rep, axis=1)
        scores = jnp.einsum("thd,shd->hts", q, kx) / jnp.sqrt(
            jnp.asarray(cfg.d_head, jnp.float32))
        w = jax.nn.softmax(scores + mask[None], axis=-1)  # [H, T, S]
        per_key = jnp.sum(w, axis=(0, 1))                 # [S]
        out[li] = np.asarray(
            per_key.reshape(n_blocks, cfg.block).sum(axis=1))
        # continue the forward
        o = ref.block_attention(q, k, v, mask)
        h = x + o.reshape(T, cfg.n_heads * cfg.d_head) @ lp["wo"]
        x = M.ffn_dense_sublayer_jnp(lp, cfg, h)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/figures.json")
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--model", default=os.environ.get("MODEL",
                                                      "ff-mini-128"))
    ap.add_argument("--samples", type=int, default=6)
    ap.add_argument("--ctx", type=int, default=1024)
    args = ap.parse_args()

    cfg = M.CONFIGS[args.model]
    params, _, _ = load_cache(
        os.path.join(args.artifacts, "train_cache.npz"), cfg)
    gen = CorpusGen(seed=31)

    n_blocks = args.ctx // cfg.block
    masses = np.zeros((cfg.n_layers, n_blocks))
    for _ in range(args.samples):
        toks = jnp.asarray(gen.tokens(args.ctx))
        masses += blockwise_attention_mass(params, cfg, toks)
    masses /= args.samples

    # Fig. 4: distribution of per-block scores, excluding the sink block
    non_sink = masses[:, 1:]
    fig4 = {
        f"layer_{li}": {
            "per_block_mass": non_sink[li].tolist(),
            "min": float(non_sink[li].min()),
            "max": float(non_sink[li].max()),
        }
        for li in range(cfg.n_layers)
    }
    # Fig. 5: per-layer mean of non-sink block attention
    fig5 = {"mean_non_sink_mass_per_layer":
            non_sink.mean(axis=1).tolist(),
            "sink_block_mass_per_layer": masses[:, 0].tolist()}

    payload = {"model": cfg.name, "ctx": args.ctx,
               "samples": args.samples, "fig4": fig4, "fig5": fig5}
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)

    print(f"fig4/5 data → {args.out}")
    print("\nFig. 5 (mean non-sink attention mass per layer):")
    for li, v in enumerate(fig5["mean_non_sink_mass_per_layer"]):
        sink = fig5["sink_block_mass_per_layer"][li]
        bar = "#" * int(v / max(fig5["mean_non_sink_mass_per_layer"]) * 40)
        print(f"  layer {li}: {v:8.2f} {bar}   (sink block: {sink:8.2f})")
    print("\npaper: sink block dominates; non-sink mass varies by layer —")
    print("the signal Algorithm 1 allocates density against.")


if __name__ == "__main__":
    main()
