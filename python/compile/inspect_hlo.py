"""L2 perf tool: static inspection of the lowered HLO artifacts.

Verifies the structural perf properties DESIGN.md §8 claims for the L2
graphs — no unsupported custom-calls (the 0.5.1 parser would reject
them at load), no `topk` instructions (must lower through sort), bounded
artifact sizes, and a per-artifact op census (dot/while/gather counts)
that makes regressions visible in review.

Usage: cd python && python -m compile.inspect_hlo [--artifacts ../artifacts]
Also exercised by python/tests/test_artifacts.py.
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter
from typing import Dict


OP_RE = re.compile(r"=\s+(?:\([^)]*\)\s+)?[a-z0-9\[\],{}#@ ._\-]*?\b"
                   r"(dot|while|gather|sort|custom-call|topk|convolution|"
                   r"dynamic-update-slice|dynamic-slice)\b")


def census(text: str) -> Counter:
    counts: Counter = Counter()
    for m in OP_RE.finditer(text):
        counts[m.group(1)] += 1
    return counts


def inspect(artifacts: str) -> Dict[str, Counter]:
    with open(os.path.join(artifacts, "manifest.json")) as f:
        manifest = json.load(f)
    out = {}
    for e in manifest["executables"]:
        with open(os.path.join(artifacts, e["file"])) as f:
            out[e["name"]] = census(f.read())
    return out


def check(artifacts: str) -> list:
    """Return a list of violations (empty = clean)."""
    problems = []
    for name, c in inspect(artifacts).items():
        if c.get("topk"):
            problems.append(f"{name}: contains topk (0.5.1-incompatible)")
        if c.get("custom-call"):
            problems.append(f"{name}: contains custom-call "
                            f"(Mosaic leak? not loadable on CPU PJRT)")
        if c.get("convolution"):
            problems.append(f"{name}: unexpected convolution")
    return problems


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--artifacts", default="../artifacts")
    args = ap.parse_args()
    table = inspect(args.artifacts)
    print(f"{'artifact':<42} {'dot':>5} {'while':>6} {'gather':>7} "
          f"{'sort':>5} {'dus':>5}")
    for name in sorted(table):
        c = table[name]
        print(f"{name:<42} {c.get('dot', 0):>5} {c.get('while', 0):>6} "
              f"{c.get('gather', 0):>7} {c.get('sort', 0):>5} "
              f"{c.get('dynamic-update-slice', 0):>5}")
    problems = check(args.artifacts)
    if problems:
        print("\nVIOLATIONS:")
        for p in problems:
            print(f"  {p}")
        raise SystemExit(1)
    print(f"\n{len(table)} artifacts clean: no topk / custom-call / conv.")


if __name__ == "__main__":
    main()
