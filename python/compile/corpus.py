"""Synthetic training / calibration corpus (Minipile substitute).

A Zipfian char-gram language with long-range repeated motifs: documents
are built from a fixed vocabulary of pseudo-words sampled Zipf(1.2), with
sentence structure and periodic motif repetition so that (a) a small LM
can learn real structure in a few hundred steps and (b) attention has
genuine long-range mass (needed for the calibration statistic, eq. 23).

Tokenizer: byte-level with three specials. Mirrored exactly by
rust/src/tokenizer (round-trip tested on both sides).
"""

from __future__ import annotations

import numpy as np

PAD, BOS, EOS = 256, 257, 258
VOCAB = 384  # 256 bytes + specials, padded up for tidy matmul shapes


def encode(text: str) -> np.ndarray:
    """Byte-level encode (no specials appended)."""
    return np.frombuffer(text.encode("utf-8"), dtype=np.uint8).astype(np.int32)


def decode(tokens) -> str:
    bs = bytes(int(t) for t in tokens if 0 <= int(t) < 256)
    return bs.decode("utf-8", errors="replace")


class CorpusGen:
    """Deterministic synthetic corpus generator."""

    def __init__(self, seed: int = 0, n_words: int = 2048):
        self.rng = np.random.default_rng(seed)
        letters = "abcdefghijklmnopqrstuvwxyz"
        self.words = []
        for _ in range(n_words):
            n = int(self.rng.integers(2, 9))
            self.words.append(
                "".join(letters[i] for i in self.rng.integers(0, 26, n))
            )
        ranks = np.arange(1, n_words + 1, dtype=np.float64)
        p = ranks ** -1.2
        self.p = p / p.sum()

    def sentence(self) -> str:
        n = int(self.rng.integers(4, 13))
        idx = self.rng.choice(len(self.words), size=n, p=self.p)
        return " ".join(self.words[i] for i in idx) + "."

    def document(self, target_chars: int) -> str:
        """A document with a repeated motif every ~8 sentences, giving
        attention something long-range to lock onto."""
        motif = self.sentence()
        parts, total = [], 0
        i = 0
        while total < target_chars:
            s = motif if (i % 8 == 7) else self.sentence()
            parts.append(s)
            total += len(s) + 1
            i += 1
        return " ".join(parts)[:target_chars]

    def tokens(self, n: int) -> np.ndarray:
        """n tokens of corpus text (byte-encoded)."""
        return encode(self.document(n + 16))[:n]

    def batch(self, batch_size: int, seq_len: int) -> np.ndarray:
        return np.stack([self.tokens(seq_len) for _ in range(batch_size)])

    # -- task-formatted training examples --------------------------------
    # The LongBench substitute (rust/src/trace/longbench.rs) evaluates six
    # task formats; the base model must have seen those *formats* during
    # training (the analogue of LLaMA's pretraining coverage of QA /
    # few-shot / code shapes). Instances here are freshly sampled, so eval
    # tasks (different seed stream, generated in Rust) test generalization.

    def _word(self) -> str:
        return self.words[int(self.rng.integers(0, len(self.words)))]

    def task_example(self, target_chars: int) -> str:
        kind = int(self.rng.integers(0, 6))
        fill = lambda n: self.document(max(n, 8))  # noqa: E731
        if kind == 0:    # single-doc QA
            key, val = self._word(), self._word()
            body = max(target_chars - len(key) * 2 - len(val) * 2 - 60, 16)
            return (f"{fill(body // 2)} the {key} is {val}. "
                    f"{fill(body - body // 2)}\n"
                    f"question: what is the {key}?\nanswer: the {key} is {val}")
        if kind == 1:    # multi-doc QA
            pairs = [(self._word(), self._word()) for _ in range(3)]
            per = max(target_chars // 3 - 40, 16)
            docs = [
                f"document {i}: {fill(per)} the {k} is {v}."
                for i, (k, v) in enumerate(pairs)
            ]
            k, v = pairs[int(self.rng.integers(0, 3))]
            return ("\n".join(docs)
                    + f"\nquestion: what is the {k}?\nanswer: the {k} is {v}")
        if kind == 2:    # summarization
            topic = self._word()
            parts, total = [], 0
            while total < max(target_chars - 60, 32):
                s = self.sentence()
                if self.rng.random() < 0.5:
                    s = f"the {topic} {s}"
                parts.append(s)
                total += len(s) + 1
            return (" ".join(parts)
                    + f"\nsummary: this text is mostly about the {topic}")
        if kind == 3:    # few-shot mapping
            lines, total = [], 0
            while total < max(target_chars - 30, 32):
                w = self._word()
                line = f"{w} maps to {w}x."
                lines.append(line)
                total += len(line) + 1
            w = self._word()
            return " ".join(lines) + f"\n{w} maps to {w}x"
        if kind == 4:    # passkey retrieval
            pk = "".join(
                chr(97 + int(self.rng.integers(0, 26))) for _ in range(6))
            body = max(target_chars - 80, 16)
            return (f"{fill(body // 3)} the passkey is {pk}. remember it. "
                    f"{fill(body - body // 3)}\nthe passkey is {pk}")
        # kind == 5: bracket-balanced "code"
        out, depth = [], 0
        while sum(len(p) for p in out) < max(target_chars - 24, 16):
            if depth < 4 and (depth == 0 or self.rng.random() < 0.55):
                out.append(f"fn {self._word()}() {{ ")
                depth += 1
            else:
                out.append("} ")
                depth -= 1
        return "".join(out).rstrip() + " }" * depth

    def task_tokens(self, n: int) -> np.ndarray:
        toks = encode(self.task_example(n))
        if len(toks) >= n:
            return toks[:n]
        return np.concatenate(
            [toks, np.full(n - len(toks), PAD, dtype=np.int32)])

    def mixed_batch(self, batch_size: int, seq_len: int,
                    task_frac: float = 0.5) -> np.ndarray:
        """Training mixture: plain corpus + task-formatted examples."""
        rows = []
        for _ in range(batch_size):
            if self.rng.random() < task_frac:
                rows.append(self.task_tokens(seq_len))
            else:
                rows.append(self.tokens(seq_len))
        return np.stack(rows)
