"""Artifact-bundle consistency: manifest ABI vs emitted HLO files and
weights.bin. Skips when `make artifacts` has not been run."""

import json
import os
import re

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    with open(path) as f:
        return json.load(f)


def test_every_executable_file_exists(manifest):
    for e in manifest["executables"]:
        assert os.path.exists(os.path.join(ART, e["file"])), e["name"]


def test_weights_bin_covers_table(manifest):
    size = os.path.getsize(os.path.join(ART, manifest["weights_file"]))
    end = 0
    for name, w in manifest["weights"].items():
        n = int(np.prod(w["shape"])) if w["shape"] else 1
        assert w["offset"] % 4 == 0, name
        end = max(end, w["offset"] + 4 * n)
    assert end == size, f"table end {end} != blob size {size}"


def test_abi_param_count_matches_hlo(manifest):
    """Each HLO entry computation must declare exactly len(args) params."""
    for e in manifest["executables"][:8]:  # sample to keep test fast
        text = open(os.path.join(ART, e["file"])).read()
        m = re.search(r"ENTRY[^{]*\{(.*?)\n\}", text, re.S)
        assert m, e["name"]
        n_params = len(re.findall(r"parameter\((\d+)\)", m.group(1)))
        assert n_params == len(e["args"]), (
            f"{e['name']}: HLO has {n_params} params, ABI {len(e['args'])}"
        )


def test_schedule_consistency(manifest):
    with open(os.path.join(ART, "schedule.json")) as f:
        sched = json.load(f)
    L = manifest["model"]["n_layers"]
    assert len(sched["attention_masses"]) == L
    for key, s in sched["schedules"].items():
        assert len(s["layer_k"]) == L
        for k in s["layer_k"]:
            assert k % manifest["model"]["ftile"] == 0
            assert k <= manifest["model"]["d_ffn"]
        # every sub-d_ffn K is a compiled artifact
        for k in s["layer_k"]:
            if k < manifest["model"]["d_ffn"]:
                assert k in manifest["k_grid"], (key, k)


def test_k_grid_artifacts_exist(manifest):
    names = {e["name"] for e in manifest["executables"]}
    b = manifest["model"]["block"]
    for k in manifest["k_grid"]:
        for s in manifest["model"]["buckets"]:
            assert f"layer_sparse_k{k}_t{b}_s{s}" in names
        assert f"ffn_sparse_ext_k{k}_t{b}" in names
    for k in manifest["decode_k"]:
        for s in manifest["model"]["buckets"]:
            assert f"layer_sparse_k{k}_t1_s{s}" in names


def test_parity_fixture_shape(manifest):
    path = os.path.join(ART, "parity_fixture.json")
    if not os.path.exists(path):
        pytest.skip("fixture not emitted by this artifact build")
    with open(path) as f:
        fx = json.load(f)
    assert len(fx["last_logits"]) == manifest["model"]["vocab"]
    assert all(0 <= t < manifest["model"]["vocab"] for t in fx["tokens"])


def test_hlo_census_is_clean(manifest):
    """No topk / custom-call / convolution in any artifact (loadability
    + interpret-mode purity; see compile/inspect_hlo.py)."""
    from compile.inspect_hlo import check

    assert check(ART) == []
