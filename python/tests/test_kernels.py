"""L1 correctness: every Pallas kernel (interpret mode) vs its pure-jnp
oracle in ref.py — the core build-time correctness signal, swept over
shapes/K/seeds with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

RNG = np.random.default_rng(0)


def randn(*shape, scale=0.1):
    return jnp.asarray(
        RNG.standard_normal(shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# FFN kernels
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    t=st.sampled_from([1, 8, 128]),
    d=st.sampled_from([64, 128]),
    f_tiles=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ffn_dense_matches_ref(t, d, f_tiles, seed):
    rng = np.random.default_rng(seed)
    f = 64 * f_tiles
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    wu = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    wd = jnp.asarray(rng.standard_normal((f, d)).astype(np.float32) * 0.05)
    got = kernels.ffn_dense(x, wg, wu, wd, ftile=64)
    want = ref.ffn_dense(x, wg, wu, wd)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=12, deadline=None)
@given(
    k_tiles=st.integers(min_value=1, max_value=7),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ffn_sparse_matches_ref(k_tiles, seed):
    rng = np.random.default_rng(seed)
    t, d, f = 128, 128, 512
    k = 64 * k_tiles
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    wg = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    wu = jnp.asarray(rng.standard_normal((d, f)).astype(np.float32) * 0.05)
    wd = jnp.asarray(rng.standard_normal((f, d)).astype(np.float32) * 0.05)
    idx = jnp.asarray(
        np.sort(rng.permutation(f)[:k]).astype(np.int32))
    got = kernels.ffn_sparse(x, wg, wu, wd, idx, ftile=64)
    want = ref.ffn_sparse(x, wg, wu, wd, idx)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sparse_equals_dense_with_zeroed_neurons():
    """Invariant: sparse FFN over mask M == dense FFN with the complement
    neurons' down-projection rows zeroed (paper eq. 15-18)."""
    t, d, f, k = 32, 64, 256, 128
    x = randn(t, d, scale=1.0)
    wg, wu = randn(d, f, scale=0.05), randn(d, f, scale=0.05)
    wd = randn(f, d, scale=0.05)
    idx = jnp.asarray(np.sort(RNG.permutation(f)[:k]).astype(np.int32))
    sparse = kernels.ffn_sparse(x, wg, wu, wd, idx, ftile=64)
    mask = np.zeros((f, 1), np.float32)
    mask[np.asarray(idx)] = 1.0
    dense_masked = ref.ffn_dense(x, wg, wu, wd * jnp.asarray(mask))
    np.testing.assert_allclose(sparse, dense_masked, rtol=1e-4, atol=1e-5)


def test_neuron_scores_match_ref():
    x = randn(128, 128, scale=1.0)
    wg, wu = randn(128, 512, scale=0.05), randn(128, 512, scale=0.05)
    got = kernels.ffn_neuron_scores(x, wg, wu, ftile=64)
    want = ref.ffn_neuron_scores(x, wg, wu)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# Predictor + compensator kernels
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    r=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_predictor_matches_ref(r, seed):
    rng = np.random.default_rng(seed)
    t, d, f = 128, 128, 512
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    q = jnp.asarray(rng.standard_normal((d,)).astype(np.float32))
    w1 = jnp.asarray(rng.standard_normal((d, r)).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.standard_normal((r, f)).astype(np.float32) * 0.1)
    got = kernels.predictor_scores(x, q, w1, w2, ftile=64)
    want = ref.predictor_scores(x, q, w1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([1, 16, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_compensator_matches_ref(t, seed):
    rng = np.random.default_rng(seed)
    d, r = 128, 32
    x = jnp.asarray(rng.standard_normal((t, d)).astype(np.float32))
    w1 = jnp.asarray(rng.standard_normal((d, r)).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.standard_normal((r, d)).astype(np.float32) * 0.1)
    got = kernels.compensator(x, w1, w2)
    want = ref.compensator(x, w1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_zero_w2_compensator_is_noop():
    x = randn(16, 128, scale=1.0)
    w1 = randn(128, 32)
    w2 = jnp.zeros((32, 128))
    got = kernels.compensator(x, w1, w2)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


# ---------------------------------------------------------------------------
# Attention kernel
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    t=st.sampled_from([1, 128]),
    s_tiles=st.integers(min_value=1, max_value=8),
    nh=st.sampled_from([2, 4]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_block_attention_matches_ref(t, s_tiles, nh, seed):
    rng = np.random.default_rng(seed)
    s = 128 * s_tiles
    nkv, dh = nh // 2, 32
    pos = int(rng.integers(0, s - t + 1))
    q = jnp.asarray(rng.standard_normal((t, nh, dh)).astype(np.float32))
    k = np.zeros((s, nkv, dh), np.float32)
    v = np.zeros((s, nkv, dh), np.float32)
    k[: pos + t] = rng.standard_normal((pos + t, nkv, dh))
    v[: pos + t] = rng.standard_normal((pos + t, nkv, dh))
    mask = kernels.make_block_mask(pos, t, s)
    got = kernels.block_attention(q, jnp.asarray(k), jnp.asarray(v), mask)
    want = ref.block_attention(q, jnp.asarray(k), jnp.asarray(v), mask)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_mask_semantics():
    """Row t attends exactly to keys [0, pos+t]."""
    mask = np.asarray(kernels.make_block_mask(4, 3, 16))
    for t in range(3):
        attendable = (mask[t] == 0.0).nonzero()[0]
        assert attendable.max() == 4 + t
        assert (attendable == np.arange(4 + t + 1)).all()


def test_attention_rows_are_convex_combinations():
    """Output of attention lies in the convex hull of V rows: with all
    V rows equal, the output equals that row regardless of scores."""
    t, s, nh, nkv, dh = 8, 128, 4, 2, 16
    q = randn(t, nh, dh, scale=1.0)
    k = randn(s, nkv, dh, scale=1.0)
    v = jnp.ones((s, nkv, dh))
    mask = kernels.make_block_mask(s - t, t, s)
    out = kernels.block_attention(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(out), 1.0, rtol=1e-5)
