"""L2 correctness: model invariants, blockwise↔full-prompt equivalence,
schedule properties, AOT entry-point parity with the jnp model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import calibrate
from compile import model as M
from compile.corpus import PAD, CorpusGen, decode, encode
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(
    name="test-64", vocab=384, d_model=64, n_layers=2, n_heads=4,
    n_kv_heads=2, d_ffn=256, block=128, ftile=64, max_ctx=1024,
    pred_r=16, comp_r=16,
)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def test_param_shapes(params):
    assert params["embed"].shape == (384, 64)
    lp = params["layers"][0]
    assert lp["wq"].shape == (64, 64)
    assert lp["wk"].shape == (64, 32)   # GQA: 2 kv heads * 16
    assert lp["wg"].shape == (64, 256)
    assert len(params["layers"]) == 2


def test_forward_shapes(params):
    tokens = jnp.asarray(np.arange(32)[None, :] % 250)
    logits = M.forward_train(params, CFG, tokens)
    assert logits.shape == (1, 32, 384)
    assert bool(jnp.isfinite(logits).all())


def test_causality(params):
    """Changing a future token must not affect earlier logits."""
    rng = np.random.default_rng(1)
    a = rng.integers(0, 250, 64).astype(np.int32)
    b = a.copy()
    b[-1] = (b[-1] + 7) % 250
    la = M.forward_train(params, CFG, jnp.asarray(a)[None])[0]
    lb = M.forward_train(params, CFG, jnp.asarray(b)[None])[0]
    np.testing.assert_allclose(la[:-1], lb[:-1], rtol=1e-5, atol=1e-6)
    assert np.abs(np.asarray(la[-1] - lb[-1])).max() > 1e-4


def test_blockwise_prefill_equals_full_forward(params):
    """The engine's blockwise dataflow (KV-append per block) must equal a
    single full-sequence forward — the core correctness contract of the
    L3 prefill loop."""
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, 250, 256).astype(np.int32))
    blockwise, _, _ = M.blockwise_prefill_dense(params, CFG, tokens)
    # full forward, pre-lm-head hidden comparison via logits
    logits_full = M.forward_train(params, CFG, tokens[None])[0]
    x = ref.rmsnorm(blockwise, params["final_norm"], CFG.norm_eps)
    logits_block = x @ params["embed"].T
    np.testing.assert_allclose(
        np.asarray(logits_block), np.asarray(logits_full),
        rtol=5e-4, atol=5e-4)


def test_entry_point_layer_dense_matches_jnp(params):
    """AOT fused layer == jnp layer ops at a mid-prompt block position."""
    ep = M.make_entry_points(CFG)
    lp = params["layers"][0]
    rng = np.random.default_rng(3)
    S, T, pos = 512, 128, 128
    x = jnp.asarray(rng.standard_normal((T, CFG.d_model)).astype(np.float32))
    kc = np.zeros((S, CFG.n_kv_heads, CFG.d_head), np.float32)
    vc = np.zeros((S, CFG.n_kv_heads, CFG.d_head), np.float32)
    kc[:pos] = rng.standard_normal((pos, CFG.n_kv_heads, CFG.d_head))
    vc[:pos] = rng.standard_normal((pos, CFG.n_kv_heads, CFG.d_head))
    y, k_new, v_new = ep["layer_dense"](
        lp["rms1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
        lp["rms2"], lp["wg"], lp["wu"], lp["wd"],
        x, jnp.asarray(kc), jnp.asarray(vc), jnp.asarray(pos))
    # jnp path
    from compile import kernels
    mask = kernels.make_block_mask(pos, T, S)
    h, k_ref, v_ref = M.attn_sublayer_jnp(
        lp, CFG, x, jnp.asarray(kc), jnp.asarray(vc), pos, mask)
    y_ref = M.ffn_dense_sublayer_jnp(lp, CFG, h)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(k_new), np.asarray(k_ref),
                               rtol=1e-5, atol=1e-5)


def test_entry_point_sparse_oracle_consistency(params):
    """Fused sparse layer at K = d_ffn with a zero compensator must equal
    the dense layer (the mask covers every neuron)."""
    ep = M.make_entry_points(CFG)
    lp = params["layers"][0]
    pred = M.init_predictor(jax.random.PRNGKey(1), CFG)[0]
    comp = {"w1": jnp.zeros((CFG.d_model, CFG.comp_r)),
            "w2": jnp.zeros((CFG.comp_r, CFG.d_model))}
    rng = np.random.default_rng(4)
    S, T = 512, 128
    x = jnp.asarray(rng.standard_normal((T, CFG.d_model)).astype(np.float32))
    kz = jnp.zeros((S, CFG.n_kv_heads, CFG.d_head))
    sparse_full = ep["make_layer_sparse"](CFG.d_ffn)
    y_s, _, _ = sparse_full(
        lp["rms1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
        lp["rms2"], lp["wg"], lp["wu"], lp["wd"],
        pred["q"], pred["w1"], pred["w2"], comp["w1"], comp["w2"],
        x, kz, kz, jnp.asarray(0))
    y_d, _, _ = ep["layer_dense"](
        lp["rms1"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
        lp["rms2"], lp["wg"], lp["wu"], lp["wd"],
        x, kz, kz, jnp.asarray(0))
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=1e-4, atol=1e-4)


def test_sparse_error_decreases_with_k(params):
    """More experts → lower FFN approximation error (sanity on eq. 18)."""
    lp = params["layers"][0]
    rng = np.random.default_rng(5)
    x = jnp.asarray(
        rng.standard_normal((128, CFG.d_model)).astype(np.float32))
    dense = ref.ffn_dense(x, lp["wg"], lp["wu"], lp["wd"])
    scores = ref.ffn_neuron_scores(x, lp["wg"], lp["wu"])
    order = np.argsort(-np.asarray(scores))
    errs = []
    for k in (64, 128, 192, 256):
        idx = jnp.asarray(np.sort(order[:k]).astype(np.int32))
        sparse = ref.ffn_sparse(x, lp["wg"], lp["wu"], lp["wd"], idx)
        errs.append(float(jnp.mean((dense - sparse) ** 2)))
    assert errs == sorted(errs, reverse=True), errs
    assert errs[-1] < errs[0] * 0.6


# ---------------------------------------------------------------------------
# Schedule (Algorithm 1) — python twin of rust sparsity::schedule
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=32),
    budget=st.floats(min_value=0.05, max_value=0.95),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_alg1_budget_conservation(n, budget, seed):
    rng = np.random.default_rng(seed)
    scores = (rng.random(n) * 10 + 1e-6).tolist()
    b = calibrate.layerwise_schedule(scores, budget)
    assert len(b) == n
    assert all(0.0 <= x <= 1.0 + 1e-12 for x in b)
    total, target = sum(b), budget * n
    assert total <= target + 1e-9
    # Exact conservation holds when no layer hits the density-1 clamp;
    # with clamping the paper's greedy may under-allocate at the tail.
    if not any(x >= 1.0 - 1e-12 for x in b):
        assert abs(total - target) < 1e-6


def test_alg1_importance_ordering():
    b = calibrate.layerwise_schedule([5.0, 1.0, 1.0, 1.0], 0.5)
    assert b[0] > max(b[1:])


@settings(max_examples=30, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    ftile=st.sampled_from([32, 64, 128]),
)
def test_quantize_bounds(seed, ftile):
    rng = np.random.default_rng(seed)
    dens = rng.random(8).tolist()
    ks = calibrate.quantize_densities(dens, 512, ftile)
    assert all(ftile <= k <= 512 and k % ftile == 0 for k in ks)


# ---------------------------------------------------------------------------
# Corpus / tokenizer parity with the rust side
# ---------------------------------------------------------------------------


def test_byte_tokenizer_roundtrip():
    s = "hello wörld → 123"
    assert decode(encode(s)) == s


def test_corpus_deterministic():
    a = CorpusGen(seed=9).tokens(256)
    b = CorpusGen(seed=9).tokens(256)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < 256


def test_task_examples_parse():
    g = CorpusGen(seed=11)
    for _ in range(12):
        ex = g.task_example(300)
        assert 50 < len(ex) < 600
    b = g.mixed_batch(8, 128)
    assert b.shape == (8, 128)
    assert b.max() <= PAD
