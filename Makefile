# FastForward top-level targets.
#
#   make artifacts   train + AOT-lower the L2 model into rust/artifacts
#   make check       build, test, doc (missing-docs denied), fmt --check
#   make serve       run the server against the built artifacts
#   make serve-cpu   run the server on the pure-Rust CPU backend
#                    (no artifacts, no XLA bindings needed)
#   make bench-cpu   fig6/fig7/fig10/fig11/fig12/fig13/fig14/fig15
#                    wall-clock benches on the CPU backend; writes
#                    rust/BENCH_fig6_cpu.json,
#                    rust/BENCH_fig7_cpu.json,
#                    rust/BENCH_fig10_cpu.json,
#                    rust/BENCH_fig11_cpu.json,
#                    rust/BENCH_fig12_cpu.json,
#                    rust/BENCH_fig13_cpu.json,
#                    rust/BENCH_fig14_cpu.json and
#                    rust/BENCH_fig15_cpu.json

ARTIFACTS ?= rust/artifacts
REPLICAS  ?= 1

.PHONY: check artifacts serve serve-cpu bench-cpu clean

check:
	scripts/check.sh

artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS)

serve:
	cd rust && cargo run --release --features pjrt -- serve \
		--artifacts artifacts --replicas $(REPLICAS)

serve-cpu:
	cd rust && cargo run --release -- serve \
		--backend cpu --replicas $(REPLICAS)

bench-cpu:
	cd rust && cargo bench --bench fig6_ffn_speedup -- --backend cpu
	cd rust && cargo bench --bench fig7_e2e_speedup -- --backend cpu
	cd rust && cargo bench --bench fig10_continuous_batching -- --backend cpu
	cd rust && cargo bench --bench fig11_sparse_attention -- --backend cpu
	cd rust && cargo bench --bench fig12_kernel_tiers -- --backend cpu
	cd rust && cargo bench --bench fig13_quantized_weights -- --backend cpu
	cd rust && cargo bench --bench fig14_speculative_prefill -- --backend cpu
	cd rust && cargo bench --bench fig15_cluster_load -- --backend cpu

clean:
	cd rust && cargo clean
