# FastForward top-level targets.
#
#   make artifacts   train + AOT-lower the L2 model into rust/artifacts
#   make check       build, test, doc (missing-docs denied), fmt --check
#   make serve       run the server against the built artifacts
#   make serve-cpu   run the server on the pure-Rust CPU backend
#                    (no artifacts, no XLA bindings needed)

ARTIFACTS ?= rust/artifacts
REPLICAS  ?= 1

.PHONY: check artifacts serve serve-cpu clean

check:
	scripts/check.sh

artifacts:
	cd python && python3 -m compile.aot --out-dir ../$(ARTIFACTS)

serve:
	cd rust && cargo run --release --features pjrt -- serve \
		--artifacts artifacts --replicas $(REPLICAS)

serve-cpu:
	cd rust && cargo run --release -- serve \
		--backend cpu --replicas $(REPLICAS)

clean:
	cd rust && cargo clean
