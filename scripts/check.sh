#!/usr/bin/env bash
# Repo check gate: build, tests, doctests, clippy, examples, docs
# (missing-docs denied), CPU-backend smoke run, markdown link lint,
# formatting.
# Usage: scripts/check.sh [extra cargo args, e.g. --features pjrt]
set -euo pipefail
cd "$(dirname "$0")/../rust"

extra=("$@")

echo "==> cargo build --release"
cargo build --release "${extra[@]}"

echo "==> cargo test -q"
cargo test -q "${extra[@]}"

echo "==> backend conformance suite (FF_CPU_THREADS=1)"
FF_CPU_THREADS=1 cargo test -q --test backend_conformance "${extra[@]}"

echo "==> backend conformance suite (FF_CPU_THREADS=4)"
FF_CPU_THREADS=4 cargo test -q --test backend_conformance "${extra[@]}"

echo "==> backend conformance suite (FF_CPU_KERNEL=scalar)"
FF_CPU_KERNEL=scalar cargo test -q --test backend_conformance \
    "${extra[@]}"

echo "==> backend conformance suite (FF_CPU_KERNEL=simd)"
FF_CPU_KERNEL=simd cargo test -q --test backend_conformance \
    "${extra[@]}"

echo "==> backend conformance suite (FF_WEIGHT_PREC=int8)"
FF_WEIGHT_PREC=int8 cargo test -q --test backend_conformance \
    "${extra[@]}"

echo "==> one-block CPU perf smoke (sparse beats dense)"
cargo test -q --test perf_smoke one_block_sparse_beats_dense "${extra[@]}"

echo "==> batched-decode perf smoke (B=4 >= 1.3x sequential)"
cargo test -q --test perf_smoke batched_decode_beats_sequential \
    "${extra[@]}"

echo "==> block-sparse attention perf smoke (50% >= 1.15x dense)"
cargo test -q --test perf_smoke sparse_attention_beats_dense_at_t2048 \
    "${extra[@]}"

echo "==> SIMD kernel-tier perf smoke (dense prefill >= 1.2x scalar)"
cargo test -q --test perf_smoke simd_dense_prefill_beats_scalar_at_t512 \
    "${extra[@]}"

echo "==> int8 weight-tier perf smoke (dense prefill >= 1.2x simd-f32)"
cargo test -q --test perf_smoke int8_dense_prefill_beats_f32_at_t512 \
    "${extra[@]}"

echo "==> fig10 continuous-batching smoke (--smoke: B in {1,4})"
cargo bench --bench fig10_continuous_batching "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> fig11 sparse-attention smoke (--smoke: T in {512,1024})"
cargo bench --bench fig11_sparse_attention "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> fig12 kernel-tier smoke (--smoke: scalar/simd/bf16 at T=256)"
cargo bench --bench fig12_kernel_tiers "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> fig13 quantized-weight smoke (--smoke: f32/bf16/int8 at T=256)"
cargo bench --bench fig13_quantized_weights "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> token-pruning perf smoke (keep=0.5 >= 1.2x dense-length)"
cargo test -q --test perf_smoke \
    token_pruned_prefill_beats_dense_length_at_t512 "${extra[@]}"

echo "==> fig14 speculative-prefill smoke (--smoke: keep in {1.0,0.5})"
cargo bench --bench fig14_speculative_prefill "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> cluster-affinity perf smoke (affinity >= 1.3x random ttft p50)"
cargo test -q --test perf_smoke cluster_affinity_beats_random_dispatch \
    "${extra[@]}"

echo "==> fig15 cluster-load smoke (--smoke: affinity/random/chaos, 2 workers)"
cargo bench --bench fig15_cluster_load "${extra[@]}" -- \
    --backend cpu --smoke

echo "==> cargo test --doc"
cargo test --doc -q "${extra[@]}"

echo "==> cargo clippy --all-targets (warnings denied)"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets --quiet "${extra[@]}" -- -D warnings
else
    echo "    (clippy not installed — skipped)"
fi

echo "==> cargo build --examples"
cargo build --release --examples "${extra[@]}"

echo "==> quickstart smoke run (--backend cpu: no artifacts needed)"
FF_BACKEND=cpu cargo run --release --quiet "${extra[@]}" \
    --example quickstart

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet "${extra[@]}"

echo "==> markdown link lint (README.md, docs/*.md)"
if command -v python3 >/dev/null 2>&1; then
    python3 ../scripts/lint_links.py
else
    echo "    (python3 not installed — skipped)"
fi

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "    (rustfmt not installed — skipped)"
fi

echo "==> all checks passed"
