#!/usr/bin/env bash
# Repo check gate: build, tests, docs (missing-docs denied), formatting.
# Usage: scripts/check.sh [extra cargo args, e.g. --features pjrt]
set -euo pipefail
cd "$(dirname "$0")/../rust"

extra=("$@")

echo "==> cargo build --release"
cargo build --release "${extra[@]}"

echo "==> cargo test -q"
cargo test -q "${extra[@]}"

echo "==> cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet "${extra[@]}"

echo "==> cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "    (rustfmt not installed — skipped)"
fi

echo "==> all checks passed"
