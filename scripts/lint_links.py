#!/usr/bin/env python3
"""Dead-relative-link lint for the repo's markdown.

Scans README.md and docs/*.md for [text](target) links and verifies
that every relative target (optionally with a #fragment) exists on
disk, resolved against the file containing the link. External links
(http/https/mailto) are skipped. Exits non-zero listing every dead
link.
"""
import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")

def links_in(path: Path):
    text = path.read_text(encoding="utf-8")
    # strip fenced code blocks so example snippets aren't linted
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    for m in LINK_RE.finditer(text):
        yield m.group(1)

def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    dead = []
    for f in files:
        if not f.exists():
            continue
        for target in links_in(f):
            if target.startswith(SKIP_PREFIXES):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (f.parent / rel).exists():
                dead.append(f"{f.relative_to(root)}: dead link -> {target}")
    if dead:
        print("\n".join(dead))
        return 1
    print(f"link lint: {len(files)} files OK")
    return 0

if __name__ == "__main__":
    sys.exit(main())
