//! Figure 14: speculative prefill — quality vs speedup as the token
//! keep ratio falls.
//!
//! For each keep ratio r, the bench runs the longbench-sim suite with
//! `--token-keep-ratio r` on the synthetic CPU model: the low-rank FFN
//! predictor scores every prompt token in one cheap pass, the top-K
//! survive (sink + local bands always kept, `fastforward::sparsity::
//! tokens`), and only the survivors go through the main prefill. The
//! sweep reports, per ratio:
//!
//! * the likelihood score average and its relative gap vs r = 1.0
//!   (the paper's accuracy axis),
//! * the greedy-overlap score on the needle tasks (full runs only),
//! * mean prefill wall-clock and its speedup vs r = 1.0.
//!
//! r = 1.0 is bit-identical to the unpruned path by construction (the
//! conformance tier pins that), so it doubles as the dense baseline.
//! Needs no artifacts and emits `BENCH_fig14_cpu.json`.
//!
//! Flags: `--smoke` for the quick check.sh gate (two ratios, smaller
//! task set, no generation pass).

mod common;

use fastforward::engine::SparsityConfig;
use fastforward::eval::{self, EvalSpec};
use fastforward::testing;
use fastforward::util::cli::Args;

struct Point {
    keep: f64,
    avg: f64,
    rel_gap_pct: f64,
    overlap_avg: f64,
    mean_ttft_ms: f64,
    speedup: f64,
}

fn main() {
    common::header(
        "Figure 14",
        "speculative prefill: quality vs speedup over token keep ratio",
    );
    let args = Args::parse_env();
    let smoke = args.has("smoke");
    let keeps: &[f64] = if smoke {
        &[1.0, 0.5]
    } else {
        &[1.0, 0.75, 0.5, 0.25]
    };
    let spec = if smoke {
        EvalSpec {
            tasks_per_group: 2,
            prompt_chars: 512,
            with_generation: false,
            ..EvalSpec::default()
        }
    } else {
        EvalSpec {
            with_generation: true,
            max_gen_tokens: 12,
            ..EvalSpec::default()
        }
    };
    println!(
        "backend: cpu (synthetic model), longbench-sim {} tasks/group, \
         {} prompt chars{}",
        spec.tasks_per_group,
        spec.prompt_chars,
        if smoke { ", smoke mode" } else { "" }
    );

    let engine = testing::cpu_engine();
    let tasks = eval::build_tasks(&spec);
    let mut points: Vec<Point> = Vec::new();
    println!(
        "{:>6} {:>8} {:>10} {:>10} {:>10} {:>9}",
        "keep", "avg", "gap %", "overlap", "ttft ms", "speedup"
    );
    for &keep in keeps {
        let mut cfg = SparsityConfig::dense();
        cfg.token_keep_ratio = Some(keep);
        let r = eval::evaluate(&engine, &tasks, &cfg, &spec).unwrap();
        let base = points.first();
        let rel_gap = base.map_or(0.0, |b| {
            if b.avg == 0.0 {
                0.0
            } else {
                (r.average - b.avg) / b.avg * 100.0
            }
        });
        let speedup =
            base.map_or(1.0, |b| b.mean_ttft_ms / r.mean_ttft_ms);
        let overlap_avg = if r.group_overlap.is_empty() {
            0.0
        } else {
            r.group_overlap.values().sum::<f64>()
                / r.group_overlap.len() as f64
        };
        println!(
            "{keep:>6.2} {:>8.2} {rel_gap:>+10.2} {overlap_avg:>10.2} \
             {:>10.2} {speedup:>8.2}x",
            r.average, r.mean_ttft_ms
        );
        points.push(Point {
            keep,
            avg: r.average,
            rel_gap_pct: rel_gap,
            overlap_avg,
            mean_ttft_ms: r.mean_ttft_ms,
            speedup,
        });
    }

    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"keep\":{},\"avg\":{:.4},\"rel_gap_pct\":{:.4},\
                 \"overlap_avg\":{:.4},\"mean_ttft_ms\":{:.3},\
                 \"speedup\":{:.4}}}",
                p.keep, p.avg, p.rel_gap_pct, p.overlap_avg,
                p.mean_ttft_ms, p.speedup
            )
        })
        .collect();
    common::write_bench_json(
        "BENCH_fig14_cpu.json",
        &format!(
            "{{\"figure\":\"fig14_speculative_prefill\",\
             \"backend\":\"cpu\",\"smoke\":{smoke},\"points\":[{}]}}\n",
            rows.join(",")
        ),
    );

    if let Some(p) = points.iter().find(|p| p.keep == 0.5) {
        println!(
            "acceptance: keep=0.5 prefill faster than unpruned → \
             {:.2}x {}",
            p.speedup,
            if p.speedup > 1.0 { "PASS" } else { "MISS" }
        );
    }
}
