//! Paper Figure 1: TTFT vs context length, dense vs 50% FFN sparsity.
//!
//! Measured on the real engine (ff-mini artifacts, XLA-CPU) for contexts
//! up to the artifact max, then projected to the paper's 1K–32K range
//! for the LLaMA-8B shape via the FLOP cost model with a roofline
//! constant calibrated from the measured dense runs.

mod common;

use fastforward::cost::{CostModel, Roofline};
use fastforward::engine::SparsityConfig;
use fastforward::util::stats;

fn main() {
    common::header("Figure 1", "TTFT vs context length, dense vs sparse-50%");
    let Some(engine) = common::engine() else { return };
    let max_ctx = engine.manifest().model.max_ctx;
    let ctxs: Vec<usize> =
        [256usize, 512, 1024, 2048, 4096].into_iter()
            .filter(|&c| c <= max_ctx)
            .collect();

    let dense_cfg = SparsityConfig::dense();
    let sparse_cfg = SparsityConfig::fastforward(0.5);

    println!("\n-- measured (ff-mini artifacts, XLA-CPU interpret kernels) --");
    println!("{:>8} {:>14} {:>14} {:>9}", "ctx", "dense ms", "sparse50 ms",
             "speedup");
    let mut dense_ms = Vec::new();
    for &ctx in &ctxs {
        let prompt = common::prompt_tokens(ctx, 11);
        let d = stats::bench(
            &format!("fig1/dense/ctx{ctx}"),
            1,
            3,
            || {
                engine.prefill(&prompt, &dense_cfg).unwrap();
            },
        );
        let s = stats::bench(
            &format!("fig1/sparse50/ctx{ctx}"),
            1,
            3,
            || {
                engine.prefill(&prompt, &sparse_cfg).unwrap();
            },
        );
        println!(
            "{ctx:>8} {:>14.1} {:>14.1} {:>8.2}x",
            d * 1e3,
            s * 1e3,
            d / s
        );
        dense_ms.push((ctx, d));
    }

    // Dispatch-cost accounting (perf evidence for EXPERIMENTS.md §Perf)
    let st = engine.rt.stats();
    let total = st.upload_time + st.execute_time + st.download_time;
    println!(
        "\ndispatch accounting over {} executions: upload {:.1}% | execute {:.1}% | download {:.1}% (compile {:.2}s)",
        st.executions,
        100.0 * st.upload_time.as_secs_f64() / total.as_secs_f64(),
        100.0 * st.execute_time.as_secs_f64() / total.as_secs_f64(),
        100.0 * st.download_time.as_secs_f64() / total.as_secs_f64(),
        st.compile_time.as_secs_f64(),
    );

    // Roofline calibration: effective FLOP/s of the dense path.
    let local = CostModel::from_cfg(&engine.manifest().model);
    let (ctx0, secs0) = *dense_ms.last().unwrap();
    let roof = Roofline {
        flops_per_sec: local.dense_prefill(ctx0).total() / secs0,
    };
    println!(
        "\ncalibrated roofline: {:.2} GFLOP/s (dense prefill @ ctx {ctx0})",
        roof.flops_per_sec / 1e9
    );

    println!("\n-- projected TTFT, LLaMA-3.1-8B shape (paper Fig. 1 axis) --");
    println!("{:>8} {:>14} {:>14} {:>9}", "ctx", "dense s", "sparse50 s",
             "speedup");
    let m8 = CostModel::llama8b();
    for ctx in [1024usize, 2048, 4096, 8192, 16384, 32768] {
        let dense = m8.dense_prefill(ctx).total();
        let ks: Vec<f64> = vec![0.5 * m8.d_ffn; m8.n_layers];
        let sparse = m8.prefill_flops(ctx, &ks, true, true, true).total();
        println!(
            "{ctx:>8} {:>14.2} {:>14.2} {:>8.2}x",
            roof.project(dense),
            roof.project(sparse),
            dense / sparse
        );
    }
    println!("\npaper: sparse TTFT < dense across 1K-32K, gap peaks mid-context");
}
