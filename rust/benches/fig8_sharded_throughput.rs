//! Figure 8 (repo extension): aggregate throughput and TTFT of the
//! replica-sharded executor pool, plus prefix-cache reuse on a
//! shared-document (RAG-style) workload.
//!
//! Part A — sharding: a synthetic multi-client closed-loop workload
//! (unique prompts) is pushed through the full router → pool → engine
//! stack at 1, 2 and 4 replicas; requests/sec and TTFT percentiles are
//! reported per pool size, with speedup vs the single-replica baseline.
//!
//! Part B — prefix reuse: every client shares one long document prefix
//! (the paper's RAG/LongBench motivation). The same workload runs with
//! the prefix cache disabled and enabled; the engine's block-execution
//! counter verifies that cache hits actually skip prefill blocks.

mod common;

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use fastforward::batcher::BatcherConfig;
use fastforward::engine::SparsityConfig;
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::pool::ExecutorPool;
use fastforward::router::{LoadEstimator, Response, Router};
use fastforward::util::stats::Summary;

struct Outcome {
    reqs_per_s: f64,
    ttft_p50_ms: f64,
    ttft_p95_ms: f64,
    blocks_executed: u64,
    blocks_reused: u64,
    prefix_hits: u64,
}

struct Scenario {
    replicas: usize,
    clients: usize,
    reqs_per_client: usize,
    /// Tokens of shared document prefix (0 = fully unique prompts).
    shared_prefix_tokens: usize,
    /// Unique suffix tokens per request.
    suffix_tokens: usize,
    prefix_cache_bytes: usize,
}

fn run(dir: &PathBuf, block: usize, sc: &Scenario) -> Outcome {
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        256,
        4096,
        4096, // generous: admission pressure is not under test here
        block,
        metrics.clone(),
        sc.replicas,
        LoadEstimator::new(block),
        sc.prefix_cache_bytes,
    ));
    let pool = ExecutorPool::spawn_from_artifacts(
        router.clone(),
        BatcherConfig {
            max_active: 4,
            prefill_block_budget: 4,
            ..Default::default()
        },
        dir.clone(),
    );

    let doc = common::prompt_tokens(sc.shared_prefix_tokens.max(1), 4242);
    let t0 = Instant::now();
    let workers: Vec<_> = (0..sc.clients)
        .map(|c| {
            let router = router.clone();
            let doc = doc.clone();
            let sc_reqs = sc.reqs_per_client;
            let shared = sc.shared_prefix_tokens;
            let suffix = sc.suffix_tokens;
            std::thread::spawn(move || {
                let mut ttfts = Vec::with_capacity(sc_reqs);
                for i in 0..sc_reqs {
                    let shared_doc: &[i32] =
                        if shared > 0 { &doc } else { &[] };
                    let prompt = common::arrivals::client_prompt(
                        shared_doc,
                        suffix,
                        common::arrivals::client_seed(c, i),
                    );
                    let (tx, rx) = channel();
                    router
                        .submit(
                            prompt,
                            4,
                            SparsityConfig::fastforward(0.5),
                            tx,
                        )
                        .expect("admission");
                    let resp =
                        Response::collect(&rx).expect("response");
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    ttfts.push(resp.ttft_ms);
                }
                ttfts
            })
        })
        .collect();

    let mut ttft = Summary::new();
    let mut total = 0usize;
    for w in workers {
        for t in w.join().unwrap() {
            ttft.add(t);
            total += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    router.close();
    pool.join().expect("pool drains cleanly");
    let (hits, _misses, reused) = metrics.prefix_counters();
    Outcome {
        reqs_per_s: total as f64 / wall,
        ttft_p50_ms: ttft.percentile(50.0),
        ttft_p95_ms: ttft.percentile(95.0),
        blocks_executed: metrics.blocks_executed(),
        blocks_reused: reused,
        prefix_hits: hits,
    }
}

fn main() {
    common::header(
        "Figure 8",
        "sharded executor throughput + prefix-aware KV reuse",
    );
    let Some(dir) = fastforward::test_artifacts_dir() else { return };
    let block = Manifest::load(&dir).expect("manifest").model.block;

    // ---- Part A: throughput vs replica count (unique prompts) ----------
    println!("\n-- A. aggregate throughput vs replicas (unique prompts) --");
    println!(
        "{:>9} {:>10} {:>12} {:>12} {:>9}",
        "replicas", "req/s", "ttft p50", "ttft p95", "speedup"
    );
    let mut base = None;
    for replicas in [1usize, 2, 4] {
        let o = run(
            &dir,
            block,
            &Scenario {
                replicas,
                clients: 2 * replicas,
                reqs_per_client: 4,
                shared_prefix_tokens: 0,
                suffix_tokens: 3 * block + block / 2,
                prefix_cache_bytes: 0,
            },
        );
        let baseline = *base.get_or_insert(o.reqs_per_s);
        println!(
            "{replicas:>9} {:>10.2} {:>10.1}ms {:>10.1}ms {:>8.2}x",
            o.reqs_per_s,
            o.ttft_p50_ms,
            o.ttft_p95_ms,
            o.reqs_per_s / baseline
        );
    }
    println!(
        "(acceptance: >= 1.5x aggregate throughput at --replicas 4 vs 1)"
    );

    // ---- Part B: prefix reuse on a shared-document workload ------------
    println!("\n-- B. shared-prefix (RAG) workload, 2 replicas --");
    println!(
        "{:>14} {:>10} {:>12} {:>10} {:>10} {:>8}",
        "prefix cache", "req/s", "ttft p50", "executed", "reused", "hits"
    );
    for (label, bytes) in [("off", 0usize), ("on (128MiB)", 128 << 20)] {
        let o = run(
            &dir,
            block,
            &Scenario {
                replicas: 2,
                clients: 4,
                reqs_per_client: 4,
                shared_prefix_tokens: 3 * block,
                suffix_tokens: block / 2,
                prefix_cache_bytes: bytes,
            },
        );
        println!(
            "{label:>14} {:>10.2} {:>10.1}ms {:>10} {:>10} {:>8}",
            o.reqs_per_s,
            o.ttft_p50_ms,
            o.blocks_executed,
            o.blocks_reused,
            o.prefix_hits
        );
        // 16 requests x 3 full prompt blocks each
        let total_prompt_blocks = 16 * 3u64;
        if bytes > 0 {
            assert!(
                o.blocks_reused > 0,
                "shared-prefix workload must hit the prefix cache"
            );
            assert!(
                o.blocks_executed < total_prompt_blocks / 2,
                "cache hits must skip prefill blocks \
                 (executed {} of {total_prompt_blocks} prompt blocks)",
                o.blocks_executed
            );
        } else {
            assert_eq!(
                o.blocks_executed, total_prompt_blocks,
                "cold run must execute every prompt block"
            );
        }
    }
    println!(
        "\n(prefix hits adopt cached KV for whole 128-token blocks; only\n\
         the uncached suffix is prefilled — the engine block counter\n\
         above is the ground truth that compute was actually skipped)"
    );
}
