#![allow(dead_code)] // each bench uses a subset of these helpers

//! Shared bench plumbing (criterion substitute — the offline vendored
//! crate set has no criterion; util::stats::bench provides warmup + reps
//! with mean/σ/percentile reporting).

pub mod arrivals;

use std::sync::Arc;

use fastforward::engine::Engine;
use fastforward::manifest::Manifest;
use fastforward::runtime::Runtime;
use fastforward::tokenizer::Tokenizer;
use fastforward::trace::WordBank;
use fastforward::util::rng::Rng;
use fastforward::weights::WeightStore;

pub fn engine() -> Option<Engine> {
    let dir = fastforward::test_artifacts_dir()?;
    let m = Arc::new(Manifest::load(&dir).unwrap());
    let w = Arc::new(WeightStore::load(&m).unwrap());
    let rt = Arc::new(Runtime::new(m, w).unwrap());
    Some(Engine::new(rt))
}

/// Whether `--backend cpu` was passed: the bench then runs the
/// deterministic synthetic reference model on the fast CPU backend
/// (no artifacts needed) and emits a `BENCH_*_cpu.json` artifact.
pub fn cpu_mode() -> bool {
    fastforward::util::cli::Args::parse_env().str("backend", "") == "cpu"
}

/// Write a machine-readable bench artifact next to the bench's stdout
/// report (`make bench-cpu` collects these).
pub fn write_bench_json(path: &str, body: &str) {
    match std::fs::write(path, body) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("[bench] failed to write {path}: {e}"),
    }
}

pub fn prompt_tokens(len_tokens: usize, seed: u64) -> Vec<i32> {
    let mut rng = Rng::new(seed);
    let bank = WordBank::new(&mut rng, 256);
    let text = bank.filler(&mut rng, len_tokens);
    let mut toks = Tokenizer::new(384).encode(&text);
    toks.truncate(len_tokens);
    while toks.len() < len_tokens {
        toks.push(b' ' as i32);
    }
    toks
}

/// Standard bench header naming the paper artifact being reproduced.
pub fn header(id: &str, what: &str) {
    println!("\n=== {id}: {what} ===");
}
