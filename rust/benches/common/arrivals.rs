//! Seeded arrival-trace + prompt-recipe generators shared by the
//! load-driven benches (fig8 sharded throughput, fig9 SLO latency,
//! fig15 cluster load). One definition, so every bench's "open-loop
//! Poisson at rate λ with a heavy-tail mix and a mid-trace burst" means
//! exactly the same thing.

use fastforward::util::rng::Rng;

/// A shared-document prompt: `doc` (possibly empty) followed by a
/// seeded unique suffix of `suffix_tokens` tokens — the RAG-style
/// recipe every multi-client bench uses. Callers derive `seed` from
/// (client, request) so suffixes never collide across the fleet.
pub fn client_prompt(doc: &[i32], suffix_tokens: usize, seed: u64)
                     -> Vec<i32> {
    let mut p = doc.to_vec();
    p.extend(super::prompt_tokens(suffix_tokens, seed));
    p
}

/// Seed formula for per-(client, request) prompt suffixes: distinct
/// strides per client keep streams disjoint while staying reproducible
/// run-to-run.
pub fn client_seed(client: usize, req: usize) -> u64 {
    1 + client as u64 * 7919 + req as u64
}

/// `n` cumulative Poisson arrival offsets (milliseconds from trace
/// start) at `rate_per_s`: exponential inter-arrivals via inverse-CDF
/// (`-ln(1-u)/λ`), seeded — the memoryless open-loop baseline.
pub fn poisson_arrivals_ms(rng: &mut Rng, n: usize, rate_per_s: f64)
                           -> Vec<f64> {
    assert!(rate_per_s > 0.0, "arrival rate must be positive");
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.f64().min(1.0 - 1e-12);
            t += -(1.0 - u).ln() / rate_per_s * 1e3;
            t
        })
        .collect()
}

/// `n` cumulative arrival offsets (ms) with Pareto (heavy-tail)
/// inter-arrivals at mean rate `rate_per_s`: most gaps are short, a few
/// are very long — the bursty regime that stresses queues harder than
/// Poisson at the same average rate. `alpha` > 1 controls tail weight
/// (smaller = heavier; 1.5 is a reasonable default).
pub fn heavy_tail_arrivals_ms(rng: &mut Rng, n: usize, rate_per_s: f64,
                              alpha: f64) -> Vec<f64> {
    assert!(rate_per_s > 0.0, "arrival rate must be positive");
    assert!(alpha > 1.0, "Pareto needs alpha > 1 for a finite mean");
    // Pareto(x_m, alpha) has mean x_m * alpha / (alpha - 1); pick x_m so
    // the mean inter-arrival equals 1/rate.
    let mean = 1.0 / rate_per_s;
    let x_m = mean * (alpha - 1.0) / alpha;
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            let u = rng.f64().min(1.0 - 1e-12);
            t += x_m / (1.0 - u).powf(1.0 / alpha) * 1e3;
            t
        })
        .collect()
}

/// Inject a synchronized burst into a sorted arrival trace: `burst_n`
/// extra arrivals all landing at `at_frac` of the trace's span
/// (thundering-herd moment). Returns the combined sorted trace.
pub fn with_burst(mut arrivals_ms: Vec<f64>, at_frac: f64,
                  burst_n: usize) -> Vec<f64> {
    let span = arrivals_ms.last().copied().unwrap_or(0.0);
    let at = span * at_frac.clamp(0.0, 1.0);
    arrivals_ms.extend(std::iter::repeat(at).take(burst_n));
    arrivals_ms.sort_by(|a, b| a.total_cmp(b));
    arrivals_ms
}
