//! Figure 15 (repo extension): prefix-affinity cluster dispatch vs
//! random placement over real `serve` worker processes.
//!
//! The harness spawns two `fastforward serve --backend cpu` workers
//! (each with a prefix cache deliberately sized to hold only its *own*
//! affine share of the document set), fronts them with an in-process
//! [`fastforward::cluster::ClusterFront`], and drives a trace-driven
//! open-loop workload of shared-document (RAG-style) prompts:
//!
//! * **affinity vs random** — the same seeded Poisson arrival trace is
//!   replayed against consistent-hash prefix-affinity dispatch and
//!   against uniform-random placement (fresh workers each, so caches
//!   start cold). Affinity keeps each document on one worker, so after
//!   one cold prefill per document every request adopts cached KV;
//!   random placement spreads every document across both workers, whose
//!   caches cannot hold the full set — LRU thrash, repeated cold
//!   prefills, inflated TTFT. Reported: TTFT p50/p99, shed counts, and
//!   the cluster-wide prefix hit rate scraped from the workers' own
//!   `/metrics`.
//! * **chaos** — a heavy-tail (Pareto) arrival trace with a
//!   thundering-herd burst, during which worker 0 is SIGKILLed
//!   mid-trace. Acceptance: every request resolves (ok + shed + failed
//!   == total, failures bounded by the in-flight cap, no hangs) while
//!   the health checker + backplane retry re-route the dead worker's
//!   arc to the survivor.
//!
//! The document set is pre-balanced: doc texts are chosen so the
//! routing ring assigns exactly half to each worker, making the
//! cache-sizing argument deterministic rather than dependent on a lucky
//! ring split. Needs no artifacts; emits `BENCH_fig15_cpu.json`.
//!
//! Flags: `--backend cpu` (required), `--smoke` for the quick check.sh
//! gate (shorter trace).

mod common;

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use fastforward::cluster::{http_get, http_post, ClusterConfig,
                           ClusterFront, DispatchMode};
use fastforward::metrics::Metrics;
use fastforward::testing::{ascii_doc_text, balanced_cluster_docs,
                           WorkerProc};
use fastforward::util::json::{self, Json};
use fastforward::util::rng::Rng;
use fastforward::util::stats::Summary;

/// Prefill block size of the default synthetic model.
const BLOCK: usize = 128;
/// Full blocks per shared document (512 tokens).
const DOC_BLOCKS: usize = 4;
/// Shared documents (4 affine to each of the 2 workers).
const DOCS: usize = 8;
/// Unique suffix bytes (= tokens) per request.
const SUFFIX_BYTES: usize = 32;
const DECODE_TOKENS: usize = 4;
const WORKERS: usize = 2;

/// Worker flags: one replica, 2 CPU lanes, and a 3 MiB prefix cache =
/// 24 cached blocks — its affine share (4 docs × 4 blocks = 16) plus
/// slack, but well under the full set (8 docs × 4 = 32 blocks), so
/// random placement thrashes while affinity stays warm.
const WORKER_FLAGS: &[&str] = &[
    "--replicas", "1", "--cpu-threads", "2", "--queue", "256",
    "--prefix-cache-mb", "3",
];

fn cluster_cfg(dispatch: DispatchMode) -> ClusterConfig {
    ClusterConfig {
        dispatch,
        block: BLOCK,
        key_blocks: DOC_BLOCKS,
        vocab: 384,
        max_inflight: 8,
        health_interval: Duration::from_millis(100),
        fail_threshold: 2,
        connect_timeout: Duration::from_millis(500),
        proxy_read_timeout: Duration::from_secs(30),
        ..ClusterConfig::default()
    }
}

struct Outcome {
    ok: usize,
    shed: usize,
    failed: usize,
    ttft: Summary,
    /// Cluster-wide prefix hit rate summed over live workers' /metrics.
    hit_rate: f64,
    /// Fraction of dispatches that landed on the affine worker.
    affine_frac: f64,
}

/// First sample of a metric series in Prometheus text exposition.
fn scrape_metric(text: &str, name: &str) -> f64 {
    text.lines()
        .find(|l| {
            l.strip_prefix(name)
                .map(|rest| rest.starts_with(' '))
                .unwrap_or(false)
        })
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0.0)
}

/// Replay `arrivals_ms` (one request per entry, doc `i % DOCS` + unique
/// suffix) against a fresh 2-worker cluster under `dispatch`. With
/// `chaos_at = Some(i)`, worker 0 is killed when request `i`'s arrival
/// time passes.
fn run_scenario(bin: &str, dispatch: DispatchMode, arrivals_ms: &[f64],
                docs: &[String], chaos_at: Option<usize>) -> Outcome {
    let w0 = WorkerProc::spawn(bin, WORKER_FLAGS);
    let w1 = WorkerProc::spawn(bin, WORKER_FLAGS);
    let worker_addrs = vec![w0.addr().to_string(), w1.addr().to_string()];

    let metrics = Arc::new(Metrics::new());
    let front = ClusterFront::new(worker_addrs.clone(),
                                  cluster_cfg(dispatch), metrics);
    let (front_addr, front_handle) =
        front.clone().spawn("127.0.0.1:0").expect("front binds");
    let front_addr = front_addr.to_string();

    let t0 = Instant::now();
    let w0 = Arc::new(Mutex::new(w0));
    let killer = chaos_at.map(|i| {
        let at = Duration::from_micros((arrivals_ms[i] * 1e3) as u64);
        let w0 = w0.clone();
        std::thread::spawn(move || {
            let gone = t0.elapsed();
            if at > gone {
                std::thread::sleep(at - gone);
            }
            w0.lock().unwrap().kill();
            eprintln!("[chaos] worker 0 killed at {:?}", t0.elapsed());
        })
    });

    let clients: Vec<_> = arrivals_ms
        .iter()
        .enumerate()
        .map(|(i, &at_ms)| {
            let at = Duration::from_micros((at_ms * 1e3) as u64);
            let addr = front_addr.clone();
            let prompt = format!(
                "{}{}",
                docs[i % DOCS],
                ascii_doc_text(500_000 + i as u64, SUFFIX_BYTES)
            );
            std::thread::spawn(move || {
                let gone = t0.elapsed();
                if at > gone {
                    std::thread::sleep(at - gone);
                }
                let body = Json::obj(vec![
                    ("prompt", Json::Str(prompt)),
                    ("max_tokens", Json::Num(DECODE_TOKENS as f64)),
                ])
                .to_string();
                match http_post(&addr, "/generate", &body,
                                Duration::from_secs(60)) {
                    Ok((200, b)) => {
                        let ttft = json::parse(&b).ok().and_then(|j| {
                            j.get("ttft_ms").and_then(|v| v.as_f64())
                        });
                        match ttft {
                            Some(t) => (0u8, t),
                            None => (2, 0.0),
                        }
                    }
                    Ok((429, _)) | Ok((503, _)) => (1, 0.0),
                    Ok(_) | Err(_) => (2, 0.0),
                }
            })
        })
        .collect();

    let (mut ok, mut shed, mut failed) = (0usize, 0usize, 0usize);
    let mut ttft = Summary::new();
    for c in clients {
        match c.join().expect("client thread") {
            (0, t) => {
                ok += 1;
                ttft.add(t);
            }
            (1, _) => shed += 1,
            _ => failed += 1,
        }
    }
    if let Some(k) = killer {
        let _ = k.join();
    }

    // cluster-wide prefix reuse, straight from the workers' own
    // counters (dead workers are skipped — their hits already happened)
    let (mut hits, mut misses) = (0.0f64, 0.0f64);
    for addr in &worker_addrs {
        if let Ok((200, text)) =
            http_get(addr, "/metrics", Duration::from_secs(2))
        {
            hits += scrape_metric(&text, "ff_prefix_hits_total");
            misses += scrape_metric(&text, "ff_prefix_misses_total");
        }
    }
    let hit_rate = if hits + misses > 0.0 {
        hits / (hits + misses)
    } else {
        0.0
    };
    let (affine, fallback, random) = front.metrics.cluster_dispatches();
    let total_disp = (affine + fallback + random).max(1);
    let affine_frac = affine as f64 / total_disp as f64;

    front.stop();
    let _ = front_handle.join();
    w0.lock().unwrap().kill();
    Outcome { ok, shed, failed, ttft, hit_rate, affine_frac }
}

fn main() {
    common::header(
        "Figure 15",
        "prefix-affinity cluster dispatch vs random, 2 worker processes",
    );
    if !common::cpu_mode() {
        println!("fig15 drives real `serve --backend cpu` worker \
                  processes; rerun with --backend cpu");
        return;
    }
    let args = fastforward::util::cli::Args::parse_env();
    let smoke = args.has("smoke");
    let n_requests = if smoke { 36 } else { 120 };
    let bin = env!("CARGO_BIN_EXE_fastforward");
    let cfg = cluster_cfg(DispatchMode::Affinity);
    let docs =
        balanced_cluster_docs(&cfg, WORKERS, DOCS, DOC_BLOCKS * BLOCK);

    // Calibrate the offered rate off one cold end-to-end request, so
    // the trace sits between the warm (affinity) and cold (random)
    // service capacities on any machine.
    let calib = WorkerProc::spawn(bin, WORKER_FLAGS);
    let body = Json::obj(vec![
        ("prompt", Json::Str(format!("{}{}", docs[0],
                                     ascii_doc_text(999, SUFFIX_BYTES)))),
        ("max_tokens", Json::Num(DECODE_TOKENS as f64)),
    ])
    .to_string();
    let t = Instant::now();
    let (status, _) = http_post(calib.addr(), "/generate", &body,
                                Duration::from_secs(60))
        .expect("calibration request");
    assert_eq!(status, 200, "calibration request must succeed");
    let t_cold = t.elapsed().as_secs_f64().max(1e-3);
    drop(calib);
    let rate_per_s =
        (0.7 * WORKERS as f64 / t_cold).clamp(0.5, 500.0);
    println!(
        "cold request {:.1} ms → offered rate {:.1} req/s \
         ({n_requests} requests{})",
        t_cold * 1e3,
        rate_per_s,
        if smoke { ", smoke" } else { "" }
    );

    let poisson = common::arrivals::poisson_arrivals_ms(
        &mut Rng::new(7), n_requests, rate_per_s);
    let bursty = common::arrivals::with_burst(
        common::arrivals::heavy_tail_arrivals_ms(
            &mut Rng::new(9), n_requests, rate_per_s, 1.5),
        0.6,
        8,
    );

    println!(
        "\n{:>16} {:>5} {:>5} {:>7} {:>11} {:>11} {:>9} {:>8}",
        "scenario", "ok", "shed", "failed", "ttft p50", "ttft p99",
        "hit rate", "affine"
    );
    let mut rows: Vec<String> = Vec::new();
    let mut report = |label: &str, o: &Outcome| {
        println!(
            "{label:>16} {:>5} {:>5} {:>7} {:>9.1}ms {:>9.1}ms \
             {:>8.1}% {:>7.1}%",
            o.ok, o.shed, o.failed,
            o.ttft.percentile(50.0), o.ttft.percentile(99.0),
            o.hit_rate * 100.0, o.affine_frac * 100.0
        );
        rows.push(format!(
            "{{\"scenario\":\"{label}\",\"ok\":{},\"shed\":{},\
             \"failed\":{},\"ttft_p50_ms\":{:.3},\"ttft_p99_ms\":{:.3},\
             \"prefix_hit_rate\":{:.4},\"affine_frac\":{:.4}}}",
            o.ok, o.shed, o.failed,
            o.ttft.percentile(50.0), o.ttft.percentile(99.0),
            o.hit_rate, o.affine_frac
        ));
    };

    let aff = run_scenario(bin, DispatchMode::Affinity, &poisson,
                           &docs, None);
    report("affinity", &aff);
    let rnd = run_scenario(bin, DispatchMode::Random, &poisson,
                           &docs, None);
    report("random", &rnd);
    let chaos = run_scenario(bin, DispatchMode::Affinity, &bursty,
                             &docs, Some(bursty.len() * 2 / 5));
    report("affinity+chaos", &chaos);

    let speedup = if aff.ttft.percentile(50.0) > 0.0 {
        rnd.ttft.percentile(50.0) / aff.ttft.percentile(50.0)
    } else {
        0.0
    };
    common::write_bench_json(
        "BENCH_fig15_cpu.json",
        &format!(
            "{{\"figure\":\"fig15_cluster_load\",\"backend\":\"cpu\",\
             \"smoke\":{smoke},\"workers\":{WORKERS},\
             \"offered_rate_per_s\":{rate_per_s:.2},\
             \"affinity_ttft_p50_speedup\":{speedup:.3},\
             \"scenarios\":[{}]}}\n",
            rows.join(",")
        ),
    );

    // ---- acceptance -----------------------------------------------------
    let total = bursty.len();
    assert_eq!(
        chaos.ok + chaos.shed + chaos.failed, total,
        "chaos trace lost requests"
    );
    assert!(
        chaos.failed <= 2 * 8,
        "chaos failures ({}) exceed the in-flight bound",
        chaos.failed
    );
    assert!(
        chaos.ok >= total / 2,
        "chaos trace completed only {}/{total} requests",
        chaos.ok
    );
    assert!(
        aff.hit_rate > rnd.hit_rate,
        "affinity cluster-wide prefix hit rate ({:.1}%) must beat \
         random ({:.1}%)",
        aff.hit_rate * 100.0,
        rnd.hit_rate * 100.0
    );
    println!(
        "\nacceptance: affinity TTFT p50 speedup vs random {speedup:.2}x \
         {}; chaos {}/{total} ok, {} shed, {} failed, none lost",
        if speedup >= 1.3 { "PASS (>= 1.3x)" } else { "MISS (< 1.3x)" },
        chaos.ok, chaos.shed, chaos.failed
    );
}
