//! Figure 9 (repo extension): interactive latency under batch load —
//! the SLO-aware scheduler's reason to exist.
//!
//! A closed-loop batch workload (long prefills, batch class) saturates
//! the executor while an interactive client submits short requests and
//! measures TTFT and inter-token latency from its own event stream.
//! The sweep crosses batch load (0 / N clients) with SLO scheduling on
//! vs off (`BatcherConfig::slo`), at 0.5 sparsity throughout.
//!
//! With SLO scheduling off, a long batch prefill sits between an
//! interactive arrival and its first token (TTFT inflation ~ one full
//! prefill) and between consecutive decode rounds (ITL inflation ~ the
//! whole block budget). With it on, batch prefill is preempted for
//! interactive prefill and trickles at `decode_first_budget` during
//! interactive decode — p95 TTFT/ITL should stay near the unloaded
//! baseline, paid for with batch throughput.

mod common;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Instant;

use fastforward::batcher::BatcherConfig;
use fastforward::engine::SparsityConfig;
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::pool::ExecutorPool;
use fastforward::router::{LoadEstimator, Response, Router, SloClass,
                          SubmitOpts, TokenEvent};
use fastforward::util::stats::Summary;

const INTERACTIVE_REQUESTS: usize = 6;
const INTERACTIVE_DECODE: usize = 8;
const BATCH_PREFILL_BLOCKS: usize = 12;

struct Outcome {
    ttft: Summary,
    itl: Summary,
    batch_reqs: usize,
    preemptions: u64,
}

fn run(dir: &std::path::PathBuf, block: usize, slo: bool,
       batch_clients: usize) -> Outcome {
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        256,
        4096,
        4096,
        block,
        metrics.clone(),
        1,
        LoadEstimator::new(block),
        0, // prefix reuse off: measure scheduling, not caching
    ));
    let pool = ExecutorPool::spawn_from_artifacts(
        router.clone(),
        BatcherConfig {
            max_active: 4,
            prefill_block_budget: 4,
            decode_first_budget: 1,
            max_batch: 8,
            slo,
        },
        dir.clone(),
    );

    // closed-loop batch load: each client keeps one long prefill in
    // flight until told to stop
    let stop = Arc::new(AtomicBool::new(false));
    let batch_workers: Vec<_> = (0..batch_clients)
        .map(|c| {
            let router = router.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut done = 0usize;
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let prompt = common::arrivals::client_prompt(
                        &[],
                        BATCH_PREFILL_BLOCKS * block,
                        common::arrivals::client_seed(c, i),
                    );
                    let (tx, rx) = channel();
                    if router
                        .submit_with(
                            prompt,
                            2,
                            SparsityConfig::fastforward(0.5),
                            SubmitOpts {
                                class: SloClass::Batch,
                                ..Default::default()
                            },
                            tx,
                        )
                        .is_err()
                    {
                        break;
                    }
                    match Response::collect(&rx) {
                        Some(r) if r.error.is_none() => done += 1,
                        _ => break,
                    }
                    i += 1;
                }
                done
            })
        })
        .collect();

    // interactive client: short prompts, latency measured off its own
    // event stream (exactly what an SSE consumer experiences)
    let mut ttft = Summary::new();
    let mut itl = Summary::new();
    for i in 0..INTERACTIVE_REQUESTS {
        std::thread::sleep(std::time::Duration::from_millis(50));
        let prompt =
            common::prompt_tokens(2 * block + 32, 5000 + i as u64);
        let (tx, rx) = channel();
        router
            .submit(
                prompt,
                INTERACTIVE_DECODE,
                SparsityConfig::fastforward(0.5),
                tx,
            )
            .expect("interactive admission");
        let mut last: Option<Instant> = None;
        loop {
            match rx.recv().expect("event stream") {
                TokenEvent::First { ttft_ms, .. } => {
                    ttft.add(ttft_ms);
                    last = Some(Instant::now());
                }
                TokenEvent::Token { .. } => {
                    let now = Instant::now();
                    if let Some(prev) = last {
                        itl.add((now - prev).as_secs_f64() * 1e3);
                    }
                    last = Some(now);
                }
                TokenEvent::Done(r) => {
                    assert!(r.error.is_none(), "{:?}", r.error);
                    break;
                }
            }
        }
    }

    stop.store(true, Ordering::Relaxed);
    let batch_reqs: usize = batch_workers
        .into_iter()
        .map(|w| w.join().unwrap())
        .sum();
    router.close();
    pool.join().expect("pool drains cleanly");
    Outcome {
        ttft,
        itl,
        batch_reqs,
        preemptions: metrics.preemptions(),
    }
}

fn main() {
    common::header(
        "Figure 9",
        "interactive p95 TTFT/ITL vs batch load, SLO scheduling on/off",
    );
    let Some(dir) = fastforward::test_artifacts_dir() else { return };
    let block = Manifest::load(&dir).expect("manifest").model.block;

    println!(
        "\n{:>5} {:>7} {:>12} {:>12} {:>12} {:>10} {:>11}",
        "slo", "batch", "ttft p50", "ttft p95", "itl p95",
        "batch req", "preemptions"
    );
    for batch_clients in [0usize, 2] {
        for slo in [false, true] {
            let o = run(&dir, block, slo, batch_clients);
            println!(
                "{:>5} {:>7} {:>10.1}ms {:>10.1}ms {:>10.1}ms {:>10} \
                 {:>11}",
                if slo { "on" } else { "off" },
                batch_clients,
                o.ttft.percentile(50.0),
                o.ttft.percentile(95.0),
                o.itl.percentile(95.0),
                o.batch_reqs,
                o.preemptions
            );
            if slo && batch_clients > 0 {
                assert!(
                    o.preemptions >= 1,
                    "SLO scheduling under batch load must preempt \
                     (got {} preemptions)",
                    o.preemptions
                );
            }
            if !slo {
                assert_eq!(
                    o.preemptions, 0,
                    "preemption must be off with --no-slo"
                );
            }
        }
    }
    println!(
        "\n(interactive TTFT/ITL are measured on the client's own event\n\
         stream; with SLO scheduling on, batch prefill is preempted for\n\
         interactive prefill and trickles during interactive decode —\n\
         the batch req column is the throughput cost of that choice)"
    );
}
