//! Figure 11: block-sparse attention — prefill speedup vs context
//! length on the CPU backend.
//!
//! For each context length T, the bench prefills a T-token prompt on
//! the attention-heavy synthetic model two ways:
//!
//! * **dense** — the original attention path (every causal key), and
//! * **block-sparse** — `--attn-sparsity` drop of the optional causal
//!   key blocks per query block per head, keeping the mandatory
//!   sink + local band (`fastforward::sparsity::attn`).
//!
//! Attention cost grows O(T²) while the dropped fraction of key blocks
//! approaches the configured drop, so the speedup *rises with context
//! length* — the shape this figure pins. The model and prefill driver
//! are shared with the tier-1 perf gate (`fastforward::testing::
//! attn_bench_*`), so the gate and this bench always measure the same
//! thing. Needs no artifacts and emits `BENCH_fig11_cpu.json`.
//!
//! Flags: `--drop A` block drop fraction (default 0.5), `--smoke` for
//! the quick check.sh gate (T ∈ {512, 1024}). Acceptance (full run):
//! T=2048 block-sparse prefill ≥ 1.15× dense — the same bar
//! `tests/perf_smoke.rs` gates in tier-1.

mod common;

use std::time::Instant;

use fastforward::engine::Engine;
use fastforward::testing;
use fastforward::util::cli::Args;

struct Point {
    len: usize,
    dense_ms: f64,
    sparse_ms: f64,
}

fn measure(engine: &Engine, len: usize, drop: f64) -> Point {
    let dense_cfg = testing::attn_bench_cfg(None);
    let sparse_cfg = testing::attn_bench_cfg(Some(drop));
    let dense_run = || testing::attn_bench_prefill(engine, len,
                                                   &dense_cfg);
    let sparse_run = || testing::attn_bench_prefill(engine, len,
                                                    &sparse_cfg);

    // warmup, then best-of-2 wall clock per path
    dense_run();
    sparse_run();
    let best = |f: &dyn Fn()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..2 {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    Point {
        len,
        dense_ms: best(&dense_run) * 1e3,
        sparse_ms: best(&sparse_run) * 1e3,
    }
}

fn main() {
    common::header(
        "Figure 11",
        "block-sparse attention: prefill speedup vs context length",
    );
    let args = Args::parse_env();
    let smoke = args.has("smoke");
    let drop = args.f64("drop", 0.5);
    let lens: &[usize] = if smoke {
        &[512, 1024]
    } else {
        &[256, 512, 1024, 2048]
    };
    println!(
        "backend: cpu (synthetic attention-heavy model), block drop \
         {drop:.2}{}",
        if smoke { ", smoke mode" } else { "" }
    );

    let engine =
        Engine::synthetic_cpu(&testing::attn_bench_spec()).unwrap();
    let mut points = Vec::new();
    println!("{:>6} {:>12} {:>12} {:>10}", "T", "dense ms",
             "sparse ms", "speedup");
    for &len in lens {
        let p = measure(&engine, len, drop);
        println!(
            "{:>6} {:>12.1} {:>12.1} {:>9.2}x",
            p.len,
            p.dense_ms,
            p.sparse_ms,
            p.dense_ms / p.sparse_ms
        );
        points.push(p);
    }

    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"len\":{},\"dense_ms\":{:.2},\"sparse_ms\":{:.2},\
                 \"speedup\":{:.4}}}",
                p.len,
                p.dense_ms,
                p.sparse_ms,
                p.dense_ms / p.sparse_ms
            )
        })
        .collect();
    common::write_bench_json(
        "BENCH_fig11_cpu.json",
        &format!(
            "{{\"figure\":\"fig11_sparse_attention\",\
             \"backend\":\"cpu\",\"drop\":{drop},\"smoke\":{smoke},\
             \"points\":[{}]}}\n",
            rows.join(",")
        ),
    );

    if let Some(p) = points.iter().find(|p| p.len == 2048) {
        let speedup = p.dense_ms / p.sparse_ms;
        println!(
            "acceptance: T=2048 block-sparse ≥ 1.15x dense → {:.2}x {}",
            speedup,
            if speedup >= 1.15 { "PASS" } else { "MISS" }
        );
    }
}
