//! Paper Figure 7: end-to-end compute-bound prefill speedup as a
//! function of context size for sparsity ∈ {30, 40, 50}%.
//!
//! Two reproductions:
//!  (a) measured wall-clock speedup of the real engine on the ff-mini
//!      artifacts (contexts up to the artifact max), and
//!  (b) the compute-bound (FLOP-ratio) curves for the paper's LLaMA
//!      1B/3B/8B shapes across 256–64K tokens — the exact quantity the
//!      paper plots, including the dense first/last blocks and the
//!      predictor/compensator overheads.

mod common;

use fastforward::cost::CostModel;
use fastforward::engine::SparsityConfig;
use fastforward::util::stats;

fn main() {
    common::header("Figure 7", "e2e compute-bound prefill speedup vs context");
    let Some(engine) = common::engine() else { return };
    let max_ctx = engine.manifest().model.max_ctx;

    println!("\n-- measured wall-clock speedup (ff-mini artifacts) --");
    println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "30%", "40%", "50%");
    for ctx in [512usize, 1024, 2048, 4096] {
        if ctx > max_ctx {
            break;
        }
        let prompt = common::prompt_tokens(ctx, 21);
        let dense = stats::bench(
            &format!("fig7/dense/ctx{ctx}"),
            1,
            3,
            || {
                engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
            },
        );
        print!("{ctx:>8}");
        for sp in [0.3, 0.4, 0.5] {
            let cfg = SparsityConfig::fastforward(sp);
            let s = stats::bench(
                &format!("fig7/sp{:.0}/ctx{ctx}", sp * 100.0),
                1,
                3,
                || {
                    engine.prefill(&prompt, &cfg).unwrap();
                },
            );
            print!(" {:>9.2}x", dense / s);
        }
        println!();
    }

    println!("\n-- compute-bound speedup, paper model shapes --");
    for (name, m) in [
        ("Llama-3.2-1B", CostModel::llama1b()),
        ("Llama-3.2-3B", CostModel::llama3b()),
        ("Llama-3.1-8B", CostModel::llama8b()),
    ] {
        println!("\n{name}:");
        println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "30%", "40%", "50%");
        let mut peak50 = (0usize, 0.0f64);
        for ctx in
            [256usize, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536]
        {
            print!("{ctx:>8}");
            for sp in [0.3, 0.4, 0.5] {
                let dens = vec![1.0 - sp; m.n_layers];
                let s = m.speedup(ctx, &dens, true, true);
                if sp == 0.5 && s > peak50.1 {
                    peak50 = (ctx, s);
                }
                print!(" {:>9.2}x", s);
            }
            println!();
        }
        println!(
            "  peak @50%: {:.2}x at ctx {} (paper: up to 1.45x, peak 2-8K)",
            peak50.1, peak50.0
        );
    }
}
