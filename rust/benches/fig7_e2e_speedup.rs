//! Paper Figure 7: end-to-end compute-bound prefill speedup as a
//! function of context size for sparsity ∈ {30, 40, 50}%.
//!
//! Two reproductions:
//!  (a) measured wall-clock speedup of the real engine — on the
//!      ff-mini artifacts by default, or with `--backend cpu` on the
//!      synthetic reference model over the fast tiled/parallel CPU
//!      backend (no artifacts needed; emits `BENCH_fig7_cpu.json`).
//!      The CPU mode disables the compensator: the reference
//!      compensator recomputes every dropped neuron exactly (dense
//!      cost by construction, see runtime/cpu.rs), while the paper's
//!      trained low-rank compensator is a negligible overhead — the
//!      nc path is the faithful compute profile.
//!  (b) the compute-bound (FLOP-ratio) curves for the paper's LLaMA
//!      1B/3B/8B shapes across 256–64K tokens — the exact quantity the
//!      paper plots, including the dense first/last blocks and the
//!      predictor/compensator overheads.

mod common;

use fastforward::cost::CostModel;
use fastforward::engine::SparsityConfig;
use fastforward::util::stats;

fn main() {
    common::header("Figure 7", "e2e compute-bound prefill speedup vs context");
    let cpu = common::cpu_mode();
    let engine = if cpu {
        println!("backend: cpu (synthetic reference model)");
        Some(fastforward::testing::cpu_engine())
    } else {
        common::engine()
    };
    let Some(engine) = engine else { return };
    let max_ctx = engine.manifest().model.max_ctx;

    let sparse_cfg = |sp: f64| {
        let mut cfg = SparsityConfig::fastforward(sp);
        if cpu {
            cfg.compensator = false; // see module docs
        }
        cfg
    };

    println!(
        "\n-- measured wall-clock speedup ({}) --",
        if cpu { "synthetic model, cpu backend" } else { "ff-mini artifacts" }
    );
    println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "30%", "40%", "50%");
    let mut json_rows: Vec<(usize, Vec<f64>)> = Vec::new();
    for ctx in [512usize, 1024, 2048, 4096] {
        if ctx > max_ctx {
            break;
        }
        let prompt = common::prompt_tokens(ctx, 21);
        let dense = stats::bench(
            &format!("fig7/dense/ctx{ctx}"),
            1,
            3,
            || {
                engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
            },
        );
        print!("{ctx:>8}");
        let mut speedups = Vec::new();
        for sp in [0.3, 0.4, 0.5] {
            let cfg = sparse_cfg(sp);
            let s = stats::bench(
                &format!("fig7/sp{:.0}/ctx{ctx}", sp * 100.0),
                1,
                3,
                || {
                    engine.prefill(&prompt, &cfg).unwrap();
                },
            );
            speedups.push(dense / s);
            print!(" {:>9.2}x", dense / s);
        }
        println!();
        json_rows.push((ctx, speedups));
    }
    if cpu {
        let mut body = String::from("{\n  \"figure\": \"fig7\",\n");
        body += "  \"backend\": \"cpu\",\n";
        body += &format!(
            "  \"model\": \"{}\",\n",
            engine.manifest().model.name
        );
        body += "  \"sparsities\": [0.3, 0.4, 0.5],\n  \"rows\": [\n";
        for (i, (ctx, sp)) in json_rows.iter().enumerate() {
            body += &format!(
                "    {{\"ctx\": {ctx}, \"speedups\": \
                 [{:.4}, {:.4}, {:.4}]}}{}\n",
                sp[0],
                sp[1],
                sp[2],
                if i + 1 == json_rows.len() { "" } else { "," }
            );
        }
        body += "  ]\n}\n";
        common::write_bench_json("BENCH_fig7_cpu.json", &body);
    }

    println!("\n-- compute-bound speedup, paper model shapes --");
    for (name, m) in [
        ("Llama-3.2-1B", CostModel::llama1b()),
        ("Llama-3.2-3B", CostModel::llama3b()),
        ("Llama-3.1-8B", CostModel::llama8b()),
    ] {
        println!("\n{name}:");
        println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "30%", "40%", "50%");
        let mut peak50 = (0usize, 0.0f64);
        for ctx in
            [256usize, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536]
        {
            print!("{ctx:>8}");
            for sp in [0.3, 0.4, 0.5] {
                let dens = vec![1.0 - sp; m.n_layers];
                let s = m.speedup(ctx, &dens, true, true);
                if sp == 0.5 && s > peak50.1 {
                    peak50 = (ctx, s);
                }
                print!(" {:>9.2}x", s);
            }
            println!();
        }
        println!(
            "  peak @50%: {:.2}x at ctx {} (paper: up to 1.45x, peak 2-8K)",
            peak50.1, peak50.0
        );
    }
}
