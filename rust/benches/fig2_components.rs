//! Paper Figure 2: per-component time of a transformer block across
//! context lengths (attention vs FFN vs the rest).
//!
//! Measured with the split-path executables (layer_attn / ffn_dense) on
//! the real artifacts, plus the cost model's FLOP shares for the
//! LLaMA-8B shape the paper profiles.

mod common;

use fastforward::cost::CostModel;
use fastforward::engine::SparsityConfig;
use fastforward::runtime::Input;
use fastforward::util::stats;

fn main() {
    common::header("Figure 2",
                   "per-component block time across context lengths");
    let Some(engine) = common::engine() else { return };
    let m = engine.manifest().model.clone();
    let rt = engine.rt.clone();
    let (block, d) = (m.block, m.d_model);

    println!("\n-- measured per-block split timing (layer 0, ff-mini) --");
    println!("{:>8} {:>12} {:>12} {:>10}", "cache", "attn ms", "ffn ms",
             "ffn share");
    let x = vec![0.05f32; block * d];
    for &s in &m.buckets {
        let kc = vec![0f32; s * m.n_kv_heads * m.d_head];
        let pos = [(s - block) as i32];
        let attn = stats::bench(
            &format!("fig2/layer_attn/s{s}"),
            2,
            5,
            || {
                rt.run(
                    &format!("layer_attn_t{block}_s{s}"),
                    0,
                    &[
                        ("x", Input::F32(&x, vec![block, d])),
                        ("k_cache",
                         Input::F32(&kc, vec![s, m.n_kv_heads, m.d_head])),
                        ("v_cache",
                         Input::F32(&kc, vec![s, m.n_kv_heads, m.d_head])),
                        ("pos", Input::I32(&pos, vec![])),
                    ],
                )
                .unwrap();
            },
        );
        let ffn = stats::bench(&format!("fig2/ffn_dense/s{s}"), 2, 5, || {
            rt.run(
                &format!("ffn_dense_t{block}"),
                0,
                &[("h", Input::F32(&x, vec![block, d]))],
            )
            .unwrap();
        });
        println!(
            "{s:>8} {:>12.3} {:>12.3} {:>9.1}%",
            attn * 1e3,
            ffn * 1e3,
            100.0 * ffn / (attn + ffn)
        );
    }

    // whole-prefill component split from the engine timing breakdown
    println!("\n-- measured whole-prefill breakdown (dense) --");
    println!("{:>8} {:>10} {:>10} {:>10}", "ctx", "embed ms", "layers ms",
             "lm_head ms");
    for ctx in [512usize, 1024, 2048, 4096] {
        if ctx > m.max_ctx {
            break;
        }
        let prompt = common::prompt_tokens(ctx, 3);
        let _ = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
        let pre = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
        println!(
            "{ctx:>8} {:>10.1} {:>10.1} {:>10.2}",
            pre.timing.embed.as_secs_f64() * 1e3,
            pre.timing.layers.as_secs_f64() * 1e3,
            pre.timing.lm_head.as_secs_f64() * 1e3
        );
    }

    println!("\n-- FLOP shares, LLaMA-3.1-8B shape (paper Fig. 2 axis) --");
    println!("{:>8} {:>12} {:>12} {:>12} {:>10}", "ctx", "attn-proj%",
             "attn-mix%", "ffn%", "crossover");
    let m8 = CostModel::llama8b();
    let xover = m8.attn_ffn_crossover();
    for ctx in [1024usize, 2048, 4096, 8192, 16384, 32768, 65536] {
        let c = m8.dense_prefill(ctx);
        let t = c.total();
        let proj: f64 = c.per_layer.iter().map(|l| l.attn_proj).sum();
        let mix: f64 = c.per_layer.iter().map(|l| l.attn_mix).sum();
        println!(
            "{ctx:>8} {:>11.1}% {:>11.1}% {:>11.1}% {:>10}",
            100.0 * proj / t,
            100.0 * mix / t,
            100.0 * c.ffn() / t,
            if ctx >= xover { "attn>ffn" } else { "" }
        );
    }
    println!("\nattention/FFN crossover: {xover} tokens (paper §2.3: ~28K for 8B)");
}
