//! Figure 13: quantized weight storage — dense prefill throughput and
//! resident memory of the f32 / bf16 / int8 weight tiers on the SIMD
//! kernels.
//!
//! For each context length T, the bench prefills a T-token prompt on
//! the FFN-heavy synthetic model (the tier-1 perf-gate regime: dense
//! FFN matmuls dominate) under three engine configurations, all on
//! `--cpu-kernel simd`:
//!
//! * **simd-f32** — f32 weight panels (the baseline tier),
//! * **simd-bf16** — raw bf16 panels widened to f32 in-register,
//!   halving the weight-read bytes (`--weight-precision bf16`),
//! * **simd-int8** — int8 codes + per-column-tile f32 scales
//!   dequantized in-register, quartering the weight-read bytes
//!   (`--weight-precision int8`).
//!
//! Reported as tokens/s plus each tier's resident weight bytes
//! (`WeightStore::resident_bytes`) and the process RSS after engine
//! construction — the memory story is half the point of load-time
//! quantization. Needs no artifacts and emits `BENCH_fig13_cpu.json`.
//!
//! Flags: `--smoke` for the quick check.sh gate (T = 256 only).
//! Acceptance (full run): simd-int8 ≥ 1.2× simd-f32 tokens/s at
//! T = 512 — the same bar `tests/perf_smoke.rs` gates in tier-1.

mod common;

use std::time::Instant;

use fastforward::engine::Engine;
use fastforward::manifest::SyntheticSpec;
use fastforward::runtime::{CpuKernel, CpuOptions};
use fastforward::util::cli::Args;
use fastforward::weights::{WeightPrecision, WeightStore};

/// FFN-heavy bench model (same regime as the tier-1 perf gates).
fn bench_spec(precision: WeightPrecision) -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-quant-weights".to_string(),
        n_layers: 2,
        d_ffn: 1024,
        max_ctx: 1024,
        buckets: vec![512, 1024],
        weight_precision: precision,
        ..SyntheticSpec::default()
    }
}

fn tier_engine(precision: WeightPrecision) -> Engine {
    Engine::synthetic_cpu_with(
        &bench_spec(precision),
        CpuOptions {
            threads: 0,
            reference: false,
            kernel: Some(CpuKernel::Simd),
        },
    )
    .expect("synthetic tier engine")
}

/// Resident bytes of a standalone store seeded like the bench engine's
/// (the engine shares one `Arc`'d store; this measures the same thing
/// without reaching into engine internals).
fn store_bytes(precision: WeightPrecision) -> usize {
    let spec = bench_spec(precision);
    let manifest = fastforward::manifest::Manifest::synthetic(&spec);
    WeightStore::seeded_with(&manifest, spec.seed, precision)
        .resident_bytes()
}

/// Process resident set size from /proc/self/status (kB → bytes);
/// `None` off Linux or if the field is missing.
fn process_rss_bytes() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    let kb: usize =
        line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// Best-of-2 dense prefill wall-clock → tokens/s.
fn tokens_per_s(engine: &Engine, len: usize) -> f64 {
    let toks = common::prompt_tokens(len, 0xF16_13);
    let cfg = fastforward::engine::SparsityConfig::dense();
    engine.prefill(&toks, &cfg).unwrap(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        engine.prefill(&toks, &cfg).unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    len as f64 / best
}

fn main() {
    common::header(
        "Figure 13",
        "quantized weight tiers: dense prefill tokens/s + resident \
         bytes (simd-f32 / simd-bf16 / simd-int8)",
    );
    let args = Args::parse_env();
    let smoke = args.has("smoke");
    let lens: &[usize] = if smoke { &[256] } else { &[256, 512, 1024] };
    println!(
        "backend: cpu (synthetic FFN-heavy model){}",
        if smoke { ", smoke mode" } else { "" }
    );

    let precisions = [
        ("simd-f32", WeightPrecision::F32),
        ("simd-bf16", WeightPrecision::Bf16),
        ("simd-int8", WeightPrecision::Int8),
    ];
    let tiers: Vec<(&str, WeightPrecision, Engine)> = precisions
        .iter()
        .map(|&(name, p)| (name, p, tier_engine(p)))
        .collect();

    println!("{:>10} {:>16} {:>14}", "tier", "weight bytes", "RSS");
    let mut mem_rows = Vec::new();
    for &(name, p, _) in &tiers {
        let bytes = store_bytes(p);
        let rss = process_rss_bytes();
        println!(
            "{:>10} {:>14.1}MB {:>14}",
            name,
            bytes as f64 / (1024.0 * 1024.0),
            rss.map_or("n/a".to_string(),
                       |r| format!("{:.1}MB", r as f64 / 1048576.0)),
        );
        mem_rows.push(format!(
            "{{\"tier\":\"{name}\",\"weight_bytes\":{bytes},\
             \"rss_bytes\":{}}}",
            rss.map_or("null".to_string(), |r| r.to_string())
        ));
    }

    println!("{:>6} {:>14} {:>14} {:>14}", "T", tiers[0].0, tiers[1].0,
             tiers[2].0);
    let mut rows = Vec::new();
    let mut int8_vs_f32_at_512 = None;
    for &len in lens {
        let tps: Vec<f64> =
            tiers.iter().map(|(_, _, e)| tokens_per_s(e, len)).collect();
        println!(
            "{:>6} {:>12.0}/s {:>12.0}/s {:>12.0}/s",
            len, tps[0], tps[1], tps[2]
        );
        if len == 512 {
            int8_vs_f32_at_512 = Some(tps[2] / tps[0]);
        }
        rows.push(format!(
            "{{\"len\":{len},\"simd_f32_tps\":{:.1},\
             \"simd_bf16_tps\":{:.1},\"simd_int8_tps\":{:.1}}}",
            tps[0], tps[1], tps[2]
        ));
    }

    common::write_bench_json(
        "BENCH_fig13_cpu.json",
        &format!(
            "{{\"figure\":\"fig13_quantized_weights\",\
             \"backend\":\"cpu\",\"smoke\":{smoke},\
             \"memory\":[{}],\"points\":[{}]}}\n",
            mem_rows.join(","),
            rows.join(",")
        ),
    );

    if let Some(ratio) = int8_vs_f32_at_512 {
        println!(
            "acceptance: T=512 simd-int8 ≥ 1.2x simd-f32 → {:.2}x {}",
            ratio,
            if ratio >= 1.2 { "PASS" } else { "MISS" }
        );
    }
}
