//! Figure 12: CPU kernel tiers — dense prefill throughput of the
//! scalar-f32, simd-f32 and simd-bf16 kernel tiers.
//!
//! For each context length T, the bench prefills a T-token prompt on
//! the FFN-heavy synthetic model (the tier-1 perf-gate regime: dense
//! FFN matmuls dominate) under three engine configurations:
//!
//! * **scalar-f32** — the sequential-order fast path, bit-identical to
//!   the reference oracle (`--cpu-kernel scalar`),
//! * **simd-f32** — lane-chunked/register-tiled kernels, gated by the
//!   ULP tolerance tier (`--cpu-kernel simd`), and
//! * **simd-bf16** — the same kernels streaming raw bf16 weight panels
//!   with f32 accumulation (`--weight-precision bf16`), halving the
//!   weight-read bytes.
//!
//! Reported as tokens/s so tiers compare directly across lengths. The
//! roofline note in docs/ARCHITECTURE.md §2.4 explains what each step
//! up should buy; this bench is how those wins are *measured*, not
//! assumed. Needs no artifacts and emits `BENCH_fig12_cpu.json`.
//!
//! Flags: `--smoke` for the quick check.sh gate (T = 256 only).
//! Acceptance (full run): simd-f32 ≥ 1.2× scalar-f32 tokens/s at
//! T = 512 — the same bar `tests/perf_smoke.rs` gates in tier-1.

mod common;

use std::time::Instant;

use fastforward::engine::Engine;
use fastforward::manifest::SyntheticSpec;
use fastforward::runtime::{CpuKernel, CpuOptions};
use fastforward::util::cli::Args;
use fastforward::weights::WeightPrecision;

/// FFN-heavy bench model (same regime as the tier-1 perf gates).
fn bench_spec(precision: WeightPrecision) -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-kernel-tiers".to_string(),
        n_layers: 2,
        d_ffn: 1024,
        max_ctx: 1024,
        buckets: vec![512, 1024],
        weight_precision: precision,
        ..SyntheticSpec::default()
    }
}

fn tier_engine(kernel: CpuKernel, precision: WeightPrecision) -> Engine {
    Engine::synthetic_cpu_with(
        &bench_spec(precision),
        CpuOptions { threads: 0, reference: false, kernel: Some(kernel) },
    )
    .expect("synthetic tier engine")
}

/// Best-of-2 dense prefill wall-clock → tokens/s.
fn tokens_per_s(engine: &Engine, len: usize) -> f64 {
    let toks = common::prompt_tokens(len, 0xF16_12);
    let cfg = fastforward::engine::SparsityConfig::dense();
    engine.prefill(&toks, &cfg).unwrap(); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let t0 = Instant::now();
        engine.prefill(&toks, &cfg).unwrap();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    len as f64 / best
}

fn main() {
    common::header(
        "Figure 12",
        "CPU kernel tiers: dense prefill tokens/s \
         (scalar-f32 / simd-f32 / simd-bf16)",
    );
    let args = Args::parse_env();
    let smoke = args.has("smoke");
    let lens: &[usize] = if smoke { &[256] } else { &[256, 512, 1024] };
    println!(
        "backend: cpu (synthetic FFN-heavy model){}",
        if smoke { ", smoke mode" } else { "" }
    );

    let tiers = [
        ("scalar-f32",
         tier_engine(CpuKernel::Scalar, WeightPrecision::F32)),
        ("simd-f32",
         tier_engine(CpuKernel::Simd, WeightPrecision::F32)),
        ("simd-bf16",
         tier_engine(CpuKernel::Simd, WeightPrecision::Bf16)),
    ];
    println!("{:>6} {:>14} {:>14} {:>14}", "T", tiers[0].0, tiers[1].0,
             tiers[2].0);
    let mut rows = Vec::new();
    let mut simd_vs_scalar_at_512 = None;
    for &len in lens {
        let tps: Vec<f64> =
            tiers.iter().map(|(_, e)| tokens_per_s(e, len)).collect();
        println!(
            "{:>6} {:>12.0}/s {:>12.0}/s {:>12.0}/s",
            len, tps[0], tps[1], tps[2]
        );
        if len == 512 {
            simd_vs_scalar_at_512 = Some(tps[1] / tps[0]);
        }
        rows.push(format!(
            "{{\"len\":{len},\"scalar_f32_tps\":{:.1},\
             \"simd_f32_tps\":{:.1},\"simd_bf16_tps\":{:.1}}}",
            tps[0], tps[1], tps[2]
        ));
    }

    common::write_bench_json(
        "BENCH_fig12_cpu.json",
        &format!(
            "{{\"figure\":\"fig12_kernel_tiers\",\"backend\":\"cpu\",\
             \"smoke\":{smoke},\"points\":[{}]}}\n",
            rows.join(",")
        ),
    );

    if let Some(ratio) = simd_vs_scalar_at_512 {
        println!(
            "acceptance: T=512 simd-f32 ≥ 1.2x scalar-f32 → {:.2}x {}",
            ratio,
            if ratio >= 1.2 { "PASS" } else { "MISS" }
        );
    }
}
