//! Paper Tables 2 & 3: longbench-sim accuracy under FFN sparsity.
//!
//! Table 2: prefill sparsity at 0/30/40/50% (full FastForward config:
//!   trained predictor + compensator + dense first/last + layerwise).
//! Table 3: 50% sparsity applied in BOTH prefill and generation.
//!
//! Env knobs: FF_TASKS (tasks/group, default 3), FF_PROMPT_CHARS
//! (default 1024).

mod common;

use fastforward::engine::SparsityConfig;
use fastforward::eval::mmlu::evaluate_mmlu;
use fastforward::eval::{self, EvalSpec};

fn main() {
    common::header("Tables 2-3", "longbench-sim accuracy under FFN sparsity");
    let Some(engine) = common::engine() else { return };
    let spec = EvalSpec {
        tasks_per_group: std::env::var("FF_TASKS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3),
        prompt_chars: std::env::var("FF_PROMPT_CHARS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1024),
        seed: 17,
        with_generation: false,
        max_gen_tokens: 16,
    };
    println!(
        "({} tasks/group × 6 groups, ~{} prompt tokens, teacher-forced\n\
         likelihood score ×100; paper metric is task accuracy — shapes,\n\
         not absolute values, are the reproduction target)",
        spec.tasks_per_group, spec.prompt_chars
    );

    let tasks = eval::build_tasks(&spec);
    println!("\n-- Table 2: prefill FFN sparsity --");
    println!("{}", eval::TABLE_HEADER);
    let dense = eval::evaluate(&engine, &tasks, &SparsityConfig::dense(),
                               &spec)
        .unwrap();
    println!("{}", eval::format_row("dense (0%)", &dense, 0.0));
    for sp in [0.3, 0.4, 0.5] {
        let cfg = SparsityConfig::fastforward(sp);
        let r = eval::evaluate(&engine, &tasks, &cfg, &spec).unwrap();
        println!(
            "{}",
            eval::format_row(
                &format!("{:.0}%", sp * 100.0),
                &r,
                r.rel_gap_pct(dense.average)
            )
        );
    }
    println!("paper Table 2 (8B): -3.09% @30, -4.75% @40, -5.99% @50");

    println!("\n-- Table 3: sparsity in prefill AND generation --");
    println!("{}", eval::TABLE_HEADER);
    println!("{}", eval::format_row("dense (0%)", &dense, 0.0));
    let mut both = SparsityConfig::fastforward(0.5);
    both.sparse_decode = true;
    let r = eval::evaluate(&engine, &tasks, &both, &spec).unwrap();
    println!(
        "{}",
        eval::format_row("sparse 50% (prefill+gen)", &r,
                         r.rel_gap_pct(dense.average))
    );

    // MMLU column of Table 3 (mmlu-sim, 4-way multiple choice)
    let n_mc = spec.tasks_per_group * 4;
    let mc_dense = evaluate_mmlu(&engine, n_mc, spec.prompt_chars / 2, 5,
                                 &SparsityConfig::dense())
        .unwrap();
    let mc_sparse =
        evaluate_mmlu(&engine, n_mc, spec.prompt_chars / 2, 5, &both)
            .unwrap();
    println!(
        "mmlu-sim ({n_mc} items):      dense {:.1}%   sparse-50 {:.1}%   \
         (random floor 25%)",
        mc_dense.accuracy, mc_sparse.accuracy
    );
    println!("paper Table 3 (8B): LB 49.76→46.92, MMLU 67.84→67.17");
}
