//! Figure 10: continuous batching — batched decode throughput vs
//! batch size on the CPU backend.
//!
//! For each batch size B, the bench prefills B short sequences on the
//! FFN-heavy synthetic model, then decodes them two ways:
//!
//! * **sequential** — one `Engine::decode_step` per sequence per token
//!   (the pre-batching execution path: B passes over the layer
//!   weights per decode round), and
//! * **batched** — one `DecodeBatch::step` per round (all B rows fold
//!   into one shared pass over the weights).
//!
//! Both paths produce bit-identical logits (the backend conformance
//! suite pins that), so the comparison is purely wall-clock: aggregate
//! decoded tokens per second. The model and both decode drivers are
//! shared with the tier-1 perf gate (`fastforward::testing::
//! decode_bench_*`), so the gate and this bench always measure the
//! same thing. Needs no artifacts and emits `BENCH_fig10_cpu.json`.
//!
//! Flags: `--steps N` decode rounds per measurement (default 24),
//! `--smoke` for the quick check.sh gate (B ∈ {1, 4}, 6 rounds).
//! Acceptance (full run): B=4 aggregate throughput ≥ 1.3× B=1
//! sequential — the same bar `tests/perf_smoke.rs` gates in tier-1.

mod common;

use std::time::Instant;

use fastforward::engine::Engine;
use fastforward::testing;
use fastforward::util::cli::Args;

struct Point {
    b: usize,
    seq_tps: f64,
    batch_tps: f64,
}

fn measure(engine: &Engine, b: usize, steps: usize) -> Point {
    let seqs = testing::decode_bench_seqs(engine, b);
    let tokens = (b * steps) as f64;
    let seq_run = || testing::decode_bench_sequential(engine, &seqs,
                                                      steps);
    let batch_run =
        || testing::decode_bench_batched(engine, &seqs, steps, b);

    // warmup, then best-of-2 wall clock per path
    seq_run();
    batch_run();
    let best = |f: &dyn Fn()| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..2 {
            let t0 = Instant::now();
            f();
            best = best.min(t0.elapsed().as_secs_f64());
        }
        best
    };
    Point {
        b,
        seq_tps: tokens / best(&seq_run),
        batch_tps: tokens / best(&batch_run),
    }
}

fn main() {
    common::header(
        "Figure 10",
        "continuous batching: batched decode throughput vs batch size",
    );
    let args = Args::parse_env();
    let smoke = args.has("smoke");
    let steps = args.usize("steps", if smoke { 6 } else { 24 });
    let batch_sizes: &[usize] =
        if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    println!(
        "backend: cpu (synthetic FFN-heavy model), {steps} decode \
         rounds per point{}",
        if smoke { ", smoke mode" } else { "" }
    );

    let engine =
        Engine::synthetic_cpu(&testing::decode_bench_spec()).unwrap();
    let mut points = Vec::new();
    println!("{:>4} {:>14} {:>14} {:>10}", "B", "seq tok/s",
             "batched tok/s", "speedup");
    for &b in batch_sizes {
        let p = measure(&engine, b, steps);
        println!(
            "{:>4} {:>14.1} {:>14.1} {:>9.2}x",
            p.b,
            p.seq_tps,
            p.batch_tps,
            p.batch_tps / p.seq_tps
        );
        points.push(p);
    }

    let rows: Vec<String> = points
        .iter()
        .map(|p| {
            format!(
                "{{\"b\":{},\"seq_tps\":{:.2},\"batch_tps\":{:.2},\
                 \"speedup\":{:.4}}}",
                p.b,
                p.seq_tps,
                p.batch_tps,
                p.batch_tps / p.seq_tps
            )
        })
        .collect();
    common::write_bench_json(
        "BENCH_fig10_cpu.json",
        &format!(
            "{{\"figure\":\"fig10_continuous_batching\",\
             \"backend\":\"cpu\",\"steps\":{steps},\"smoke\":{smoke},\
             \"points\":[{}]}}\n",
            rows.join(",")
        ),
    );

    if let Some(p4) = points.iter().find(|p| p.b == 4) {
        let speedup = p4.batch_tps / p4.seq_tps;
        println!(
            "acceptance: B=4 batched ≥ 1.3x sequential → {:.2}x {}",
            speedup,
            if speedup >= 1.3 { "PASS" } else { "MISS" }
        );
    }
}
