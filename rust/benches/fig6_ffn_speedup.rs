//! Paper Figure 6: FFN-module speedup at 50% sparsity (module-level,
//! custom kernels). Measures the dense FFN executable vs the gathered
//! sparse FFN executable (+ predictor overhead) per 128-token block on
//! the real artifacts, sweeping every compiled K.

mod common;

use fastforward::runtime::Input;
use fastforward::sparsity::masks::top_k_indices;
use fastforward::util::stats;

fn main() {
    common::header("Figure 6",
                   "FFN module speedup vs dense at each compiled K");
    let Some(engine) = common::engine() else { return };
    let m = engine.manifest().model.clone();
    let k_grid = engine.manifest().k_grid.clone();
    let rt = engine.rt.clone();
    let (block, d, f) = (m.block, m.d_model, m.d_ffn);
    let h = vec![0.07f32; block * d];

    let dense = stats::bench("fig6/ffn_dense", 3, 10, || {
        rt.run(
            &format!("ffn_dense_t{block}"),
            0,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    });

    // predictor overhead measured separately (runs once per block)
    let pred = stats::bench("fig6/predictor", 3, 10, || {
        rt.run(
            &format!("predictor_t{block}"),
            0,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    });

    println!(
        "\n{:>6} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "K", "density", "sparse ms", "+pred ms", "speedup", "ideal"
    );
    for &k in &k_grid {
        let scores: Vec<f32> = (0..f).map(|i| (i * 37 % 101) as f32).collect();
        let idx = top_k_indices(&scores, k);
        let sparse = stats::bench(&format!("fig6/ffn_sparse_k{k}"), 3, 10, || {
            rt.run(
                &format!("ffn_sparse_ext_k{k}_t{block}"),
                0,
                &[
                    ("h", Input::F32(&h, vec![block, d])),
                    ("idx", Input::I32(&idx, vec![idx.len()])),
                ],
            )
            .unwrap();
        });
        let total = sparse + pred;
        println!(
            "{k:>6} {:>9.2} {:>12.3} {:>12.3} {:>9.2}x {:>9.2}x",
            k as f64 / f as f64,
            sparse * 1e3,
            total * 1e3,
            dense / total,
            f as f64 / k as f64
        );
    }
    println!(
        "\ndense module: {:.3} ms | predictor overhead: {:.3} ms per block",
        dense * 1e3,
        pred * 1e3
    );
    println!("paper Fig. 6: module speedup approaches (but stays under) the\n\
              ideal 1/density bound due to gather + predictor overheads");
}
