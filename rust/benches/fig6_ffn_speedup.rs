//! Paper Figure 6: FFN-module speedup at 50% sparsity (module-level,
//! custom kernels). Measures the dense FFN executable vs the gathered
//! sparse FFN executable (+ predictor overhead) per 128-token block,
//! sweeping every compiled K.
//!
//! Two modes:
//!
//! * default — the real AOT artifacts on PJRT (skips when absent),
//!   measuring `ffn_sparse_ext` (the compensated module).
//! * `--backend cpu` — the synthetic reference model on the fast
//!   tiled/parallel CPU backend, measuring `ffn_sparse_nc` (the
//!   sub-dense gathered module; the reference compensator computes
//!   every dropped neuron's true activation — dense cost by
//!   construction — so the paper's wall-clock claim is carried by the
//!   nc kernels, see runtime/cpu.rs). Emits `BENCH_fig6_cpu.json`.
//!   Acceptance: ≥1.15× at 50% sparsity.

mod common;

use fastforward::runtime::Input;
use fastforward::sparsity::masks::top_k_indices;
use fastforward::util::stats;

fn main() {
    common::header("Figure 6",
                   "FFN module speedup vs dense at each compiled K");
    let cpu = common::cpu_mode();
    let engine = if cpu {
        println!("backend: cpu (synthetic reference model, \
                  sub-dense ffn_sparse_nc kernels)");
        fastforward::testing::cpu_engine()
    } else {
        let Some(engine) = common::engine() else { return };
        engine
    };
    let m = engine.manifest().model.clone();
    let k_grid = engine.manifest().k_grid.clone();
    let rt = engine.rt.clone();
    let (block, d, f) = (m.block, m.d_model, m.d_ffn);
    let h = vec![0.07f32; block * d];

    let dense = stats::bench("fig6/ffn_dense", 3, 10, || {
        rt.run(
            &format!("ffn_dense_t{block}"),
            0,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    });

    // predictor overhead measured separately (runs once per block)
    let pred = stats::bench("fig6/predictor", 3, 10, || {
        rt.run(
            &format!("predictor_t{block}"),
            0,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    });

    let sparse_exe = |k: usize| {
        if cpu {
            format!("ffn_sparse_nc_k{k}_t{block}")
        } else {
            format!("ffn_sparse_ext_k{k}_t{block}")
        }
    };

    let mut rows = Vec::new();
    println!(
        "\n{:>6} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "K", "density", "sparse ms", "+pred ms", "speedup", "ideal"
    );
    for &k in &k_grid {
        let scores: Vec<f32> = (0..f).map(|i| (i * 37 % 101) as f32).collect();
        let idx = top_k_indices(&scores, k);
        let sparse = stats::bench(&format!("fig6/ffn_sparse_k{k}"), 3, 10, || {
            rt.run(
                &sparse_exe(k),
                0,
                &[
                    ("h", Input::F32(&h, vec![block, d])),
                    ("idx", Input::I32(&idx, vec![idx.len()])),
                ],
            )
            .unwrap();
        });
        let total = sparse + pred;
        let speedup = dense / total;
        println!(
            "{k:>6} {:>9.2} {:>12.3} {:>12.3} {:>9.2}x {:>9.2}x",
            k as f64 / f as f64,
            sparse * 1e3,
            total * 1e3,
            speedup,
            f as f64 / k as f64
        );
        rows.push((k, sparse, speedup));
    }
    println!(
        "\ndense module: {:.3} ms | predictor overhead: {:.3} ms per block",
        dense * 1e3,
        pred * 1e3
    );
    if cpu {
        let at_50 = rows
            .iter()
            .find(|(k, _, _)| *k == f / 2)
            .map(|&(_, _, s)| s);
        if let Some(s) = at_50 {
            println!(
                "50% sparsity (K={}): {s:.2}x vs dense (target >= 1.15x)",
                f / 2
            );
        }
        let mut body = String::from("{\n  \"figure\": \"fig6\",\n");
        body += "  \"backend\": \"cpu\",\n";
        body += &format!("  \"model\": \"{}\",\n", m.name);
        body += &format!("  \"d_ffn\": {f},\n  \"block\": {block},\n");
        body += &format!("  \"dense_ms\": {:.6},\n", dense * 1e3);
        body += &format!("  \"predictor_ms\": {:.6},\n", pred * 1e3);
        if let Some(s) = at_50 {
            body += &format!("  \"speedup_at_50\": {s:.4},\n");
        }
        body += "  \"rows\": [\n";
        for (i, (k, sparse, speedup)) in rows.iter().enumerate() {
            body += &format!(
                "    {{\"k\": {k}, \"density\": {:.4}, \
                 \"sparse_ms\": {:.6}, \"speedup\": {speedup:.4}}}{}\n",
                *k as f64 / f as f64,
                sparse * 1e3,
                if i + 1 == rows.len() { "" } else { "," }
            );
        }
        body += "  ]\n}\n";
        common::write_bench_json("BENCH_fig6_cpu.json", &body);
    }
    println!("paper Fig. 6: module speedup approaches (but stays under) the\n\
              ideal 1/density bound due to gather + predictor overheads");
}
