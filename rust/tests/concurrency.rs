//! Seeded-RNG randomized traffic through the CpuBackend executor pool
//! (always-on: no artifacts, no `pjrt` feature — docs/TESTING.md).
//!
//! Four waves of randomized interactive/batch requests — mixed prompt
//! lengths, dense and sparse configs, shared prefixes, and random
//! client disconnects — against a two-replica pool. Invariants:
//!
//! * **No lost terminals:** every submitted request receives exactly
//!   one `TokenEvent::Done` (success or "cancelled"), never a hang.
//! * **No KV leaks:** after drain, the only resident pages are the
//!   prefix cache's own accounted entries.
//! * **Queue-metric monotonicity:** per-class queue-delay sample counts
//!   never decrease, and end between the number of successful requests
//!   and the number submitted (each request is sampled at most once,
//!   at first admission).
//!
//! Plus the continuous-batching scheduler regressions: an interactive
//! prefill arriving under a full decode batch joins within the next
//! tick instead of waiting for the batch to drain, and the
//! `ff_batch_occupancy` metric is monotone in offered load.

use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

use fastforward::batcher::BatcherConfig;
use fastforward::engine::SparsityConfig;
use fastforward::metrics::Metrics;
use fastforward::pool::ExecutorPool;
use fastforward::router::{CancelToken, LoadEstimator, Response, Router,
                          SloClass, SubmitOpts, TokenEvent};
use fastforward::runtime::BackendKind;
use fastforward::util::rng::Rng;

struct Pending {
    id: u64,
    rx: Receiver<TokenEvent>,
    cancel: CancelToken,
}

#[test]
fn randomized_traffic_loses_no_done_events_and_leaks_no_kv() {
    let probe = fastforward::testing::cpu_engine();
    let block = probe.block();
    let max_ctx = probe.manifest().model.max_ctx;
    drop(probe);

    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        64,
        max_ctx,
        512,
        block,
        metrics.clone(),
        2,
        LoadEstimator::new(block),
        8 << 20,
    ));
    let pool = ExecutorPool::spawn_backend(
        router.clone(),
        BatcherConfig {
            max_active: 4,
            prefill_block_budget: 2,
            decode_first_budget: 1,
            max_batch: 8,
            slo: true,
        },
        BackendKind::Cpu,
        None,
    );

    let mut rng = Rng::new(0xC0FFEE);
    let mut pending: Vec<Pending> = Vec::new();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    let mut prev = (0usize, 0usize);
    for _wave in 0..4 {
        for _ in 0..6 {
            let len = 1 + rng.range(0, 3 * block);
            // ~1/3 of prompts share a deterministic prefix family so
            // the prefix cache sees hits, inserts and evictions while
            // cancellations fire around it
            let prompt: Vec<i32> = if rng.bool(0.33) {
                (0..len).map(|i| ((i * 7) % 250) as i32).collect()
            } else {
                (0..len).map(|_| rng.range(0, 250) as i32).collect()
            };
            let cancel = CancelToken::new();
            let opts = SubmitOpts {
                class: if rng.bool(0.5) {
                    SloClass::Interactive
                } else {
                    SloClass::Batch
                },
                deadline_ms: None,
                cancel: cancel.clone(),
            };
            let cfg = if rng.bool(0.5) {
                SparsityConfig::fastforward(0.5)
            } else {
                SparsityConfig::dense()
            };
            let (tx, rx) = channel();
            match router.submit_with(prompt, rng.range(0, 5), cfg, opts, tx)
            {
                Ok(id) => {
                    submitted += 1;
                    pending.push(Pending { id, rx, cancel });
                }
                Err(_) => rejected += 1, // backpressure is a valid outcome
            }
        }
        // random client disconnects: queued, active, or already-finished
        // requests alike (cancel-after-done must be a harmless no-op)
        for p in &pending {
            if rng.bool(0.2) {
                p.cancel.cancel();
            }
        }
        std::thread::sleep(Duration::from_millis(
            rng.range(5, 40) as u64
        ));
        // per-class queue metrics are monotone while traffic flows
        let now = (
            metrics.queue_delay_samples(SloClass::Interactive),
            metrics.queue_delay_samples(SloClass::Batch),
        );
        assert!(
            now.0 >= prev.0 && now.1 >= prev.1,
            "queue-delay sample counts went backwards: {now:?} < {prev:?}"
        );
        prev = now;
    }

    // every submitted request terminates with exactly one Done
    let mut ok = 0usize;
    let mut cancelled = 0usize;
    for p in pending {
        let resp =
            Response::collect_timeout(&p.rx, Duration::from_secs(300))
                .expect("every request must receive a terminal Done");
        assert_eq!(resp.id, p.id, "response routed to the wrong request");
        match &resp.error {
            None => ok += 1,
            Some(e) if e.contains("cancelled") => cancelled += 1,
            Some(e) => panic!("unexpected failure: {e}"),
        }
        // and the channel carries nothing after Done
        assert!(
            p.rx.try_recv().is_err(),
            "events after the terminal Done"
        );
    }
    assert_eq!(ok + cancelled, submitted);
    assert!(ok > 0, "the randomized run completed no requests at all");
    eprintln!(
        "[concurrency] submitted {submitted}, ok {ok}, cancelled \
         {cancelled}, rejected {rejected}"
    );

    router.close();
    pool.join().unwrap();

    // KV accounting: only prefix-cache residency may remain (page_size
    // == block, so each cached block entry accounts for exactly one
    // page)
    assert_eq!(
        router.kv_pool.lock().unwrap().used_pages(),
        router.prefix_cache.lock().unwrap().entry_count(),
        "KV pages leaked after drain"
    );

    // sample-count bookends: every successful request was admitted
    // (sampled once); nothing is sampled more than once per request
    let total = metrics.queue_delay_samples(SloClass::Interactive)
        + metrics.queue_delay_samples(SloClass::Batch);
    assert!(
        total >= ok,
        "successful requests must have been sampled: {total} < {ok}"
    );
    assert!(
        total <= submitted,
        "requests sampled more than once: {total} > {submitted}"
    );
    assert!(
        metrics.cancelled() >= cancelled as u64,
        "cancellations must be visible in metrics"
    );
    // the run decoded through batched passes
    assert!(
        metrics.batch_steps() > 0,
        "randomized traffic must exercise the batched step path"
    );
}

// ---------------------------------------------------------------------------
// Continuous-batching scheduler regressions
// ---------------------------------------------------------------------------

/// One single-replica pool over the synthetic CPU model.
fn one_replica_pool(
    max_active: usize,
) -> (Arc<Router>, ExecutorPool, Arc<Metrics>) {
    let probe = fastforward::testing::cpu_engine();
    let block = probe.block();
    let max_ctx = probe.manifest().model.max_ctx;
    drop(probe);
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        64,
        max_ctx,
        512,
        block,
        metrics.clone(),
        1,
        LoadEstimator::new(block),
        0,
    ));
    let pool = ExecutorPool::spawn_backend(
        router.clone(),
        BatcherConfig {
            max_active,
            prefill_block_budget: 2,
            decode_first_budget: 1,
            max_batch: 8,
            slo: true,
        },
        BackendKind::Cpu,
        None,
    );
    (router, pool, metrics)
}

/// Under a full decode batch of long batch-class generations, an
/// arriving interactive prefill must join the very next tick — it
/// completes while the decode batch is still running, instead of
/// waiting for the batch to drain.
#[test]
fn interactive_prefill_joins_under_full_decode_batch() {
    let (router, pool, _metrics) = one_replica_pool(8);

    // three decode-heavy batch-class requests fill the decode batch
    let batch_rxs: Vec<Receiver<TokenEvent>> = (0..3)
        .map(|i| {
            let (tx, rx) = channel();
            router
                .submit_with(
                    vec![(10 + i) as i32; 8],
                    48,
                    SparsityConfig::dense(),
                    SubmitOpts {
                        class: SloClass::Batch,
                        deadline_ms: None,
                        cancel: CancelToken::new(),
                    },
                    tx,
                )
                .unwrap();
            rx
        })
        .collect();
    // wait until every batch request is decoding (First emitted)
    for rx in &batch_rxs {
        loop {
            match rx.recv_timeout(Duration::from_secs(120)).unwrap() {
                TokenEvent::First { .. } => break,
                TokenEvent::Done(resp) => {
                    panic!("batch request finished too early: {resp:?}")
                }
                TokenEvent::Token { .. } => {}
            }
        }
    }

    // now an interactive request arrives: short prompt, two tokens
    let (tx, rx) = channel();
    router
        .submit(vec![7; 8], 2, SparsityConfig::dense(), tx)
        .unwrap();
    let resp = Response::collect_timeout(&rx, Duration::from_secs(120))
        .expect("interactive request must complete");
    assert!(resp.error.is_none(), "{:?}", resp.error);

    // no starvation: at least one 48-token batch generation is still
    // in flight when the interactive request is already done
    let still_running = batch_rxs.iter().any(|rx| {
        loop {
            match rx.try_recv() {
                Ok(TokenEvent::Done(_)) => return false,
                Ok(_) => continue,
                Err(_) => return true, // no Done yet
            }
        }
    });
    assert!(
        still_running,
        "interactive request should finish while the decode batch is \
         still running (it must join mid-batch, not after the drain)"
    );

    for rx in &batch_rxs {
        let resp =
            Response::collect_timeout(rx, Duration::from_secs(300))
                .expect("batch request completes");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    router.close();
    pool.join().unwrap();
}

/// Seeded randomized load sweep: mean batch occupancy must be monotone
/// in offered load — more co-active requests fold more rows per pass.
#[test]
fn batch_occupancy_is_monotone_in_offered_load() {
    let mut rng = Rng::new(0xBA7C4);
    let mut occupancy_at = |n_requests: usize| -> f64 {
        let (router, pool, metrics) = one_replica_pool(8);
        let rxs: Vec<Receiver<TokenEvent>> = (0..n_requests)
            .map(|_| {
                // randomized content, fixed decode-heavy shape so the
                // members stay co-active
                let prompt: Vec<i32> = (0..8)
                    .map(|_| rng.range(1, 250) as i32)
                    .collect();
                let (tx, rx) = channel();
                router
                    .submit(
                        prompt,
                        20 + rng.range(0, 4),
                        SparsityConfig::dense(),
                        tx,
                    )
                    .unwrap();
                rx
            })
            .collect();
        for rx in &rxs {
            let resp =
                Response::collect_timeout(rx, Duration::from_secs(300))
                    .expect("request completes");
            assert!(resp.error.is_none(), "{:?}", resp.error);
        }
        router.close();
        pool.join().unwrap();
        assert!(metrics.batch_steps() > 0, "no batched passes ran");
        metrics.batch_occupancy_mean()
    };

    let low = occupancy_at(1);
    let mid = occupancy_at(4);
    let high = occupancy_at(8);
    eprintln!(
        "[concurrency] occupancy mean: load 1 → {low:.2}, load 4 → \
         {mid:.2}, load 8 → {high:.2}"
    );
    assert!(
        (low - 1.0).abs() < 1e-9,
        "a lone request always runs occupancy-1 passes: {low}"
    );
    assert!(
        mid >= low && high >= mid,
        "occupancy must be monotone in offered load: {low:.2} → \
         {mid:.2} → {high:.2}"
    );
    assert!(
        high > 1.5,
        "eight co-active decode-heavy requests should fold multiple \
         rows per pass: {high:.2}"
    );
}
