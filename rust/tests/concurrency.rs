//! Seeded-RNG randomized traffic through the CpuBackend executor pool
//! (always-on: no artifacts, no `pjrt` feature — docs/TESTING.md).
//!
//! Four waves of randomized interactive/batch requests — mixed prompt
//! lengths, dense and sparse configs, shared prefixes, and random
//! client disconnects — against a two-replica pool. Invariants:
//!
//! * **No lost terminals:** every submitted request receives exactly
//!   one `TokenEvent::Done` (success or "cancelled"), never a hang.
//! * **No KV leaks:** after drain, the only resident pages are the
//!   prefix cache's own accounted entries.
//! * **Queue-metric monotonicity:** per-class queue-delay sample counts
//!   never decrease, and end between the number of successful requests
//!   and the number submitted (each request is sampled at most once,
//!   at first admission).

use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::Duration;

use fastforward::batcher::BatcherConfig;
use fastforward::engine::SparsityConfig;
use fastforward::metrics::Metrics;
use fastforward::pool::ExecutorPool;
use fastforward::router::{CancelToken, LoadEstimator, Response, Router,
                          SloClass, SubmitOpts, TokenEvent};
use fastforward::runtime::BackendKind;
use fastforward::util::rng::Rng;

struct Pending {
    id: u64,
    rx: Receiver<TokenEvent>,
    cancel: CancelToken,
}

#[test]
fn randomized_traffic_loses_no_done_events_and_leaks_no_kv() {
    let probe = fastforward::testing::cpu_engine();
    let block = probe.block();
    let max_ctx = probe.manifest().model.max_ctx;
    drop(probe);

    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        64,
        max_ctx,
        512,
        block,
        metrics.clone(),
        2,
        LoadEstimator::new(block),
        8 << 20,
    ));
    let pool = ExecutorPool::spawn_backend(
        router.clone(),
        BatcherConfig {
            max_active: 4,
            prefill_block_budget: 2,
            decode_first_budget: 1,
            slo: true,
        },
        BackendKind::Cpu,
        None,
    );

    let mut rng = Rng::new(0xC0FFEE);
    let mut pending: Vec<Pending> = Vec::new();
    let mut submitted = 0usize;
    let mut rejected = 0usize;
    let mut prev = (0usize, 0usize);
    for _wave in 0..4 {
        for _ in 0..6 {
            let len = 1 + rng.range(0, 3 * block);
            // ~1/3 of prompts share a deterministic prefix family so
            // the prefix cache sees hits, inserts and evictions while
            // cancellations fire around it
            let prompt: Vec<i32> = if rng.bool(0.33) {
                (0..len).map(|i| ((i * 7) % 250) as i32).collect()
            } else {
                (0..len).map(|_| rng.range(0, 250) as i32).collect()
            };
            let cancel = CancelToken::new();
            let opts = SubmitOpts {
                class: if rng.bool(0.5) {
                    SloClass::Interactive
                } else {
                    SloClass::Batch
                },
                deadline_ms: None,
                cancel: cancel.clone(),
            };
            let cfg = if rng.bool(0.5) {
                SparsityConfig::fastforward(0.5)
            } else {
                SparsityConfig::dense()
            };
            let (tx, rx) = channel();
            match router.submit_with(prompt, rng.range(0, 5), cfg, opts, tx)
            {
                Ok(id) => {
                    submitted += 1;
                    pending.push(Pending { id, rx, cancel });
                }
                Err(_) => rejected += 1, // backpressure is a valid outcome
            }
        }
        // random client disconnects: queued, active, or already-finished
        // requests alike (cancel-after-done must be a harmless no-op)
        for p in &pending {
            if rng.bool(0.2) {
                p.cancel.cancel();
            }
        }
        std::thread::sleep(Duration::from_millis(
            rng.range(5, 40) as u64
        ));
        // per-class queue metrics are monotone while traffic flows
        let now = (
            metrics.queue_delay_samples(SloClass::Interactive),
            metrics.queue_delay_samples(SloClass::Batch),
        );
        assert!(
            now.0 >= prev.0 && now.1 >= prev.1,
            "queue-delay sample counts went backwards: {now:?} < {prev:?}"
        );
        prev = now;
    }

    // every submitted request terminates with exactly one Done
    let mut ok = 0usize;
    let mut cancelled = 0usize;
    for p in pending {
        let resp =
            Response::collect_timeout(&p.rx, Duration::from_secs(300))
                .expect("every request must receive a terminal Done");
        assert_eq!(resp.id, p.id, "response routed to the wrong request");
        match &resp.error {
            None => ok += 1,
            Some(e) if e.contains("cancelled") => cancelled += 1,
            Some(e) => panic!("unexpected failure: {e}"),
        }
        // and the channel carries nothing after Done
        assert!(
            p.rx.try_recv().is_err(),
            "events after the terminal Done"
        );
    }
    assert_eq!(ok + cancelled, submitted);
    assert!(ok > 0, "the randomized run completed no requests at all");
    eprintln!(
        "[concurrency] submitted {submitted}, ok {ok}, cancelled \
         {cancelled}, rejected {rejected}"
    );

    router.close();
    pool.join().unwrap();

    // KV accounting: only prefix-cache residency may remain (page_size
    // == block, so each cached block entry accounts for exactly one
    // page)
    assert_eq!(
        router.kv_pool.lock().unwrap().used_pages(),
        router.prefix_cache.lock().unwrap().entry_count(),
        "KV pages leaked after drain"
    );

    // sample-count bookends: every successful request was admitted
    // (sampled once); nothing is sampled more than once per request
    let total = metrics.queue_delay_samples(SloClass::Interactive)
        + metrics.queue_delay_samples(SloClass::Batch);
    assert!(
        total >= ok,
        "successful requests must have been sampled: {total} < {ok}"
    );
    assert!(
        total <= submitted,
        "requests sampled more than once: {total} > {submitted}"
    );
    assert!(
        metrics.cancelled() >= cancelled as u64,
        "cancellations must be visible in metrics"
    );
}
