//! Backend-equivalence conformance suite: the fast tiled/parallel
//! `CpuBackend` against the sequential scalar
//! `CpuBackend::reference()` oracle.
//!
//! **Why bit-identical and not ≤1e-6:** every fast kernel partitions
//! *output elements* across tiles/threads and accumulates each
//! element's reduction in exactly the naive order (ascending reduction
//! index) — parallelism decides *who* computes an element, never the
//! sequence of f32 additions behind it. A ≤1e-6 tolerance would be the
//! right bound if tiling split reductions (it does not, by design), so
//! this suite asserts the stronger property: logits and KV rows are
//! **bit-identical** across `threads ∈ {1, 4}` and against the
//! reference, for dense, 50% and 87.5% sparsity, with and without the
//! compensator, across prefill block boundaries (tail-only prompts,
//! exact-block prompts, block+1, multi-block + ragged tail).
//!
//! The block-sparse attention axis rides the same contract: a drop of
//! 0.0 (all causal key blocks kept) must equal the dense path bit for
//! bit, standalone and inside B=3 mixed batches, and genuinely sparse
//! drops (0.5, sink+local-only) must be deterministic and identical
//! between the fast backend and the reference at every thread count.
//!
//! **Kernel tiers.** Everything above is the *scalar* tier's bitwise
//! contract. The SIMD kernel tier (`--cpu-kernel simd` /
//! `FF_CPU_KERNEL=simd`) re-associates reductions (lane-chunked
//! accumulation in `lane_dot`), so it is gated against the same
//! sequential oracle under the relaxed budget of
//! [`testing::simd_spec`] — abs/rel tensor tolerance plus the
//! statistical guards (logit argmax agreement, KV rel-L2 drift) — and
//! must still be **deterministic and thread-invariant bitwise against
//! itself**: lane folding is a pure function of the operands, never of
//! the thread count. The reduced-precision storage tiers ride the
//! SIMD kernels over their own resident representation and are gated
//! against the f32-weight oracle: bf16 (`--weight-precision bf16`,
//! raw u16 panels widened in-register) under [`testing::bf16_spec`],
//! int8 (`--weight-precision int8`, symmetric-absmax codes +
//! per-column-tile scales dequantized in-register) under
//! [`testing::int8_spec`]. Both must also stay deterministic and
//! thread/batch-invariant bitwise against themselves.
//!
//! Also hosts the `Rc → Arc` migration regressions: `Manifest` /
//! `WeightStore` are `Send + Sync`, and `ExecutorPool`'s backend
//! factory shares one weight-store allocation across replicas instead
//! of re-seeding per replica.

use fastforward::engine::{argmax, DecodeBatch, Engine, PrefillSession,
                          SparsityConfig};
use fastforward::kvcache::SeqKvCache;
use fastforward::manifest::SyntheticSpec;
use fastforward::pool::ExecutorPool;
use fastforward::runtime::{BackendKind, CpuKernel};
use fastforward::sparsity::masks::ExpertSource;
use fastforward::testing;
use fastforward::tokenizer::Tokenizer;

fn corpus_prompt(len: usize) -> Vec<i32> {
    let mut rng = fastforward::util::rng::Rng::new(4242);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let text = bank.filler(&mut rng, len);
    let mut toks = Tokenizer::new(384).encode(&text);
    toks.truncate(len);
    while toks.len() < len {
        toks.push(b' ' as i32);
    }
    toks
}

/// Uniform-allocation sparse config at arbitrary sparsity (the
/// layerwise schedule only ships 0.30/0.40/0.50 budgets), with every
/// block sparse so the sparse kernels are actually exercised.
fn uniform_cfg(sparsity: f64, compensator: bool) -> SparsityConfig {
    SparsityConfig {
        sparsity: Some(sparsity),
        layerwise: false,
        dense_first: false,
        dense_last: false,
        compensator,
        source: ExpertSource::Trained,
        sparse_decode: false,
        attn_sparsity: None,
        token_keep_ratio: None,
    }
}

fn configs() -> Vec<(&'static str, SparsityConfig)> {
    vec![
        ("dense", SparsityConfig::dense()),
        // the paper's full method: layerwise schedule + compensator
        ("fastforward-50", SparsityConfig::fastforward(0.5)),
        // 50% through the sub-dense nc fast path (no compensator)
        ("uniform-50-nc", uniform_cfg(0.5, false)),
        // 87.5% sparsity (K = d_ffn/8), nc fast path
        ("uniform-87.5-nc", uniform_cfg(0.875, false)),
    ]
}

fn assert_prefill_bit_identical(want: &fastforward::engine::PrefillResult,
                                got: &fastforward::engine::PrefillResult,
                                what: &str) {
    assert_eq!(want.last_logits.len(), got.last_logits.len(), "{what}");
    for i in 0..want.last_logits.len() {
        assert_eq!(
            want.last_logits[i].to_bits(),
            got.last_logits[i].to_bits(),
            "{what}: logit {i} differs ({} vs {})",
            want.last_logits[i],
            got.last_logits[i]
        );
    }
    let n = want.cache.len * want.cache.row_elems();
    assert_eq!(want.cache.len, got.cache.len, "{what}: KV length");
    for l in 0..want.cache.n_layers {
        assert_eq!(
            want.cache.k[l][..n],
            got.cache.k[l][..n],
            "{what}: layer {l} K rows differ"
        );
        assert_eq!(
            want.cache.v[l][..n],
            got.cache.v[l][..n],
            "{what}: layer {l} V rows differ"
        );
    }
}

/// The conformance matrix: fast backend at `threads ∈ {1, 4}` vs the
/// sequential reference, across sparsity levels and prompt lengths
/// straddling the 128-token prefill block boundaries.
#[test]
fn fast_backend_matches_reference_bit_identically() {
    let reference = testing::cpu_engine_reference();
    // Explicitly-pinned thread counts, plus the env-resolved default —
    // scripts/check.sh runs this suite under FF_CPU_THREADS=1 and =4,
    // and the "env" engine is what makes those two runs exercise the
    // production thread-resolution path (`--cpu-threads` serving goes
    // through the same resolver). Under FF_CPU_KERNEL=simd the env
    // engine lands on the SIMD tier, where bit-identity is not the
    // contract — `env_kernel_engine_matches_reference_at_its_tier`
    // gates it there instead.
    let mut fasts: Vec<(String, Engine)> = vec![
        ("threads=1".to_string(), testing::cpu_engine_threads(1)),
        ("threads=4".to_string(), testing::cpu_engine_threads(4)),
    ];
    if CpuKernel::from_env() == CpuKernel::Scalar {
        fasts.push(("threads=env".to_string(), testing::cpu_engine()));
    }
    let block = reference.block();
    // tail-only, block+1, and 2 blocks + ragged tail
    let lens = [40, block + 1, 2 * block + 44];
    for (name, cfg) in configs() {
        for &len in &lens {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            for (threads, fast) in &fasts {
                let got = fast.prefill(&prompt, &cfg).unwrap();
                assert_prefill_bit_identical(
                    &want,
                    &got,
                    &format!("{name} len={len} {threads}"),
                );
            }
        }
    }
}

/// Exact-block-boundary prompt (no ragged tail) under the full method.
#[test]
fn exact_block_boundary_matches_reference() {
    let reference = testing::cpu_engine_reference();
    let fast = testing::cpu_engine_threads(4);
    let prompt = corpus_prompt(2 * reference.block());
    for (name, cfg) in configs() {
        let want = reference.prefill(&prompt, &cfg).unwrap();
        let got = fast.prefill(&prompt, &cfg).unwrap();
        assert_prefill_bit_identical(&want, &got,
                                     &format!("{name} exact-2-blocks"));
    }
}

/// Decode steps (T=1 dispatch shapes, incl. the sparse nc decode path)
/// agree bit-for-bit too.
#[test]
fn decode_matches_reference_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let fast = testing::cpu_engine_threads(4);
    let mut cfg = uniform_cfg(0.5, false);
    cfg.sparse_decode = true;
    let prompt = corpus_prompt(150);
    let mut a = reference.prefill(&prompt, &cfg).unwrap();
    let mut b = fast.prefill(&prompt, &cfg).unwrap();
    let mut la = a.last_logits.clone();
    let mut lb = b.last_logits.clone();
    let mut pos = prompt.len();
    for step in 0..4 {
        let ta = fastforward::engine::argmax(&la) as i32;
        let tb = fastforward::engine::argmax(&lb) as i32;
        assert_eq!(ta, tb, "decode step {step}: argmax diverged");
        la = reference.decode_step(ta, pos, &mut a.cache, &cfg).unwrap();
        lb = fast.decode_step(tb, pos, &mut b.cache, &cfg).unwrap();
        for i in 0..la.len() {
            assert_eq!(
                la[i].to_bits(),
                lb[i].to_bits(),
                "decode step {step}: logit {i} differs"
            );
        }
        pos += 1;
    }
}

/// Fast and reference runtimes share one numeric fingerprint: they are
/// the *same* numeric backend (bit-identical), so prefix-cache KV is
/// interchangeable between them and across thread counts.
#[test]
fn fast_and_reference_share_numeric_fingerprint() {
    let reference = testing::cpu_engine_reference();
    let f1 = testing::cpu_engine_threads(1);
    let f4 = testing::cpu_engine_threads(4);
    assert_eq!(
        reference.rt.numeric_fingerprint(),
        f1.rt.numeric_fingerprint()
    );
    assert_eq!(
        f1.rt.numeric_fingerprint(),
        f4.rt.numeric_fingerprint()
    );
    let cfg = SparsityConfig::fastforward(0.5);
    assert_eq!(reference.prefix_seed(&cfg), f4.prefix_seed(&cfg));
}

// ---------------------------------------------------------------------------
// StepBatch / continuous-batching bit-identity
// ---------------------------------------------------------------------------

/// Per-sequence trace of one run: the logits after prefill and after
/// every decode step, plus the final KV cache.
type SeqTrace = (Vec<Vec<f32>>, SeqKvCache);

/// The sequential oracle: each sequence prefills and decodes entirely
/// on its own, one engine dispatch at a time.
fn run_sequential(engine: &Engine, seqs: &[(Vec<i32>, SparsityConfig)],
                  decode_steps: usize) -> Vec<SeqTrace> {
    seqs.iter()
        .map(|(prompt, cfg)| {
            let pre = engine.prefill(prompt, cfg).unwrap();
            let mut hist = vec![pre.last_logits.clone()];
            let mut cache = pre.cache;
            let mut logits = pre.last_logits;
            let mut pos = prompt.len();
            for _ in 0..decode_steps {
                let tok = argmax(&logits) as i32;
                logits = engine
                    .decode_step(tok, pos, &mut cache, cfg)
                    .unwrap();
                pos += 1;
                hist.push(logits.clone());
            }
            (hist, cache)
        })
        .collect()
}

/// The continuous-batching path: every sequence prefills chunk-by-chunk
/// *while* already-finished sequences decode in the same mixed steps
/// ([`DecodeBatch::step`] → `Engine::step_batch`), then the batch keeps
/// decoding lockstep until every member did `decode_steps` tokens.
fn run_batched(engine: &Engine, seqs: &[(Vec<i32>, SparsityConfig)],
               decode_steps: usize, max_batch: usize) -> Vec<SeqTrace> {
    let mut db = DecodeBatch::new(engine.clone());
    let mut sessions: Vec<Option<PrefillSession>> = seqs
        .iter()
        .map(|(p, c)| {
            Some(
                PrefillSession::new(engine.clone(), p.clone(), c.clone())
                    .unwrap(),
            )
        })
        .collect();
    let n = seqs.len();
    let mut ids: Vec<Option<usize>> = vec![None; n];
    let mut hist: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
    let mut steps_done = vec![0usize; n];
    let mut finals: Vec<Option<SeqKvCache>> =
        (0..n).map(|_| None).collect();
    loop {
        // stage one decode token per member still owing steps
        let mut any_staged = false;
        for i in 0..n {
            if let Some(id) = ids[i] {
                if steps_done[i] < decode_steps {
                    let tok = argmax(db.logits(id)) as i32;
                    db.feed(id, tok);
                    any_staged = true;
                }
            }
        }
        // at most one prefill chunk rides along
        let chunk_i = sessions.iter().position(|s| s.is_some());
        if !any_staged && chunk_i.is_none() {
            break;
        }
        {
            let chunk = chunk_i.and_then(|i| sessions[i].as_mut());
            let stats = db.step(chunk, max_batch);
            assert!(
                stats.failures.is_empty(),
                "batched step failed: {:?}",
                stats.failures
            );
        }
        // collect the stepped members' fresh logits
        for i in 0..n {
            if let Some(id) = ids[i] {
                if steps_done[i] < decode_steps {
                    steps_done[i] += 1;
                    hist[i].push(db.logits(id).to_vec());
                    if steps_done[i] == decode_steps {
                        finals[i] = Some(db.leave(id));
                        ids[i] = None;
                    }
                }
            }
        }
        // a finished prefill joins the decode batch
        if let Some(i) = chunk_i {
            if sessions[i].as_ref().unwrap().done() {
                let session = sessions[i].take().unwrap();
                let pre = session.finish().unwrap();
                hist[i].push(pre.last_logits.clone());
                if decode_steps == 0 {
                    finals[i] = Some(pre.cache);
                } else {
                    ids[i] = Some(db.join(
                        pre.cache,
                        seqs[i].0.len(),
                        pre.last_logits,
                        seqs[i].1.clone(),
                    ));
                }
            }
        }
    }
    hist.into_iter()
        .zip(finals)
        .map(|(h, c)| (h, c.expect("sequence never finished")))
        .collect()
}

fn assert_traces_bit_identical(want: &[SeqTrace], got: &[SeqTrace],
                               what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: sequence count");
    for (i, ((wh, wc), (gh, gc))) in
        want.iter().zip(got.iter()).enumerate()
    {
        assert_eq!(wh.len(), gh.len(), "{what}: seq {i} step count");
        for (step, (wl, gl)) in wh.iter().zip(gh.iter()).enumerate() {
            assert_eq!(wl.len(), gl.len());
            for j in 0..wl.len() {
                assert_eq!(
                    wl[j].to_bits(),
                    gl[j].to_bits(),
                    "{what}: seq {i} step {step} logit {j} differs \
                     ({} vs {})",
                    wl[j],
                    gl[j]
                );
            }
        }
        assert_eq!(wc.len, gc.len, "{what}: seq {i} KV length");
        let elems = wc.len * wc.row_elems();
        for l in 0..wc.n_layers {
            assert_eq!(
                wc.k[l][..elems],
                gc.k[l][..elems],
                "{what}: seq {i} layer {l} K rows differ"
            );
            assert_eq!(
                wc.v[l][..elems],
                gc.v[l][..elems],
                "{what}: seq {i} layer {l} V rows differ"
            );
        }
    }
}

/// Mixed prompts + configs for the batched runs: a tail-only dense
/// sequence, the paper's full method, and a sub-dense nc config that
/// also decodes sparsely — so one batch mixes dense rows, fused
/// compensated rows and gathered nc rows at once.
fn batch_seqs(block: usize) -> Vec<(Vec<i32>, SparsityConfig)> {
    let mut nc = uniform_cfg(0.5, false);
    nc.sparse_decode = true;
    vec![
        (corpus_prompt(40), SparsityConfig::dense()),
        (corpus_prompt(block + 1), SparsityConfig::fastforward(0.5)),
        (corpus_prompt(2 * block + 44), nc),
    ]
}

/// The tentpole invariant: B ∈ {1, 3} mixed prefill-chunk/decode
/// batches produce logits and KV bit-identical to running the same
/// sequences one at a time on the sequential reference oracle — at
/// explicit thread counts 1 and 4, and whether all rows fit one pass
/// (`max_batch = 4`) or the step must split passes (`max_batch = 2`).
#[test]
fn step_batch_matches_sequential_reference_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let block = reference.block();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];

    // B = 3, mixed configs
    let seqs = batch_seqs(block);
    let want = run_sequential(&reference, &seqs, 4);
    for (name, fast) in &fasts {
        for max_batch in [4, 2] {
            let got = run_batched(fast, &seqs, 4, max_batch);
            assert_traces_bit_identical(
                &want,
                &got,
                &format!("B=3 {name} max_batch={max_batch}"),
            );
        }
    }

    // B = 1 degenerates to the sequential path under the batched entry
    let solo = vec![(
        corpus_prompt(block + 9),
        SparsityConfig::fastforward(0.5),
    )];
    let want = run_sequential(&reference, &solo, 3);
    for (name, fast) in &fasts {
        let got = run_batched(fast, &solo, 3, 4);
        assert_traces_bit_identical(&want, &got,
                                    &format!("B=1 {name}"));
    }
}

/// The batched entry on the *reference* backend itself (sequential
/// per-row dispatch inside `execute_batch`) also matches the
/// reference's one-at-a-time path — the default-ABI semantics.
#[test]
fn step_batch_on_reference_backend_matches_itself() {
    let reference = testing::cpu_engine_reference();
    let seqs = batch_seqs(reference.block());
    let want = run_sequential(&reference, &seqs, 2);
    let got = run_batched(&reference, &seqs, 2, 4);
    assert_traces_bit_identical(&want, &got, "reference step-batch");
}

// ---------------------------------------------------------------------------
// Block-sparse attention conformance axis
// ---------------------------------------------------------------------------

/// Dense-FFN config with block-sparse attention at `drop` — `0.0`
/// keeps every causal key block (the oracle case: bit-identical to
/// dense by the accumulation-order contract), `1.0` keeps only the
/// mandatory sink + local band.
fn attn_cfg(drop: f64) -> SparsityConfig {
    let mut cfg = SparsityConfig::dense();
    cfg.attn_sparsity = Some(drop);
    cfg
}

/// Prompt lengths straddling the attention-block (64) and prefill-block
/// (128) boundaries: tail-only lengths around one attention block, one
/// exact prefill block (two attention blocks), and multi-block +
/// ragged tail.
fn attn_lens(ab: usize, block: usize) -> [usize; 5] {
    [ab - 1, ab, ab + 1, block, 2 * block + 44]
}

/// The attention oracle contract: `attn_sparsity = 0.0` routes through
/// the block-sparse machinery at full coverage, and must reproduce the
/// dense path **bit-identically** (logits + KV) — on the reference
/// oracle and on the fast backend at threads ∈ {1, 4}, standalone,
/// with and without FFN sparsity riding along.
#[test]
fn attn_all_blocks_matches_dense_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    let ab = reference.manifest().model.attn_block;
    let block = reference.block();
    for &len in &attn_lens(ab, block) {
        let prompt = corpus_prompt(len);
        let dense = reference
            .prefill(&prompt, &SparsityConfig::dense())
            .unwrap();
        let full = reference.prefill(&prompt, &attn_cfg(0.0)).unwrap();
        assert_prefill_bit_identical(
            &dense,
            &full,
            &format!("attn=0.0 reference len={len}"),
        );
        for (threads, fast) in &fasts {
            let got = fast.prefill(&prompt, &attn_cfg(0.0)).unwrap();
            assert_prefill_bit_identical(
                &dense,
                &got,
                &format!("attn=0.0 {threads} len={len}"),
            );
        }
        // composed with FFN sparsity: attn=0.0 on top of the paper's
        // full method must equal the method with dense attention
        let ff = SparsityConfig::fastforward(0.5);
        let mut ff_attn = ff.clone();
        ff_attn.attn_sparsity = Some(0.0);
        let want = reference.prefill(&prompt, &ff).unwrap();
        for (threads, fast) in &fasts {
            let got = fast.prefill(&prompt, &ff_attn).unwrap();
            assert_prefill_bit_identical(
                &want,
                &got,
                &format!("ff50+attn=0.0 {threads} len={len}"),
            );
        }
    }
}

/// Genuinely sparse attention (50% drop, and sink+local-only) agrees
/// bit-for-bit between the fast backend at threads ∈ {1, 4} and the
/// sequential reference, and is deterministic across repeated runs —
/// block selection happens sequentially before any row-parallel work,
/// so thread count can never reach it.
#[test]
fn attn_sparse_matches_reference_and_is_deterministic() {
    let reference = testing::cpu_engine_reference();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    let ab = reference.manifest().model.attn_block;
    let block = reference.block();
    for &drop in &[0.5, 1.0] {
        for &len in &attn_lens(ab, block) {
            let prompt = corpus_prompt(len);
            let cfg = attn_cfg(drop);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            let again = reference.prefill(&prompt, &cfg).unwrap();
            assert_prefill_bit_identical(
                &want,
                &again,
                &format!("attn={drop} reference rerun len={len}"),
            );
            for (threads, fast) in &fasts {
                let got = fast.prefill(&prompt, &cfg).unwrap();
                assert_prefill_bit_identical(
                    &want,
                    &got,
                    &format!("attn={drop} {threads} len={len}"),
                );
                let got2 = fast.prefill(&prompt, &cfg).unwrap();
                assert_prefill_bit_identical(
                    &got,
                    &got2,
                    &format!("attn={drop} {threads} rerun len={len}"),
                );
            }
        }
    }
}

/// Mixed prompts + configs exercising the attention axis inside one
/// batch: an all-blocks (oracle) row, the paper's method with 50%
/// attention drop on top, and a plain dense row.
fn attn_batch_seqs(block: usize) -> Vec<(Vec<i32>, SparsityConfig)> {
    let mut ff = SparsityConfig::fastforward(0.5);
    ff.attn_sparsity = Some(0.5);
    vec![
        (corpus_prompt(2 * block + 44), attn_cfg(0.0)),
        (corpus_prompt(block + 1), ff),
        (corpus_prompt(40), SparsityConfig::dense()),
    ]
}

/// B = 3 mixed prefill-chunk/decode batches with attention-sparse rows
/// keep the bit-identity guarantee: batched == sequential reference,
/// at threads ∈ {1, 4}, and the all-blocks row inside the batch equals
/// a standalone dense run of the same prompt.
#[test]
fn attn_sparse_step_batch_matches_sequential_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let block = reference.block();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    let seqs = attn_batch_seqs(block);
    let want = run_sequential(&reference, &seqs, 3);
    for (name, fast) in &fasts {
        let got = run_batched(fast, &seqs, 3, 4);
        assert_traces_bit_identical(
            &want,
            &got,
            &format!("attn B=3 {name}"),
        );
    }
    // the attn=0.0 member is indistinguishable from dense end to end
    let dense_solo = vec![(seqs[0].0.clone(), SparsityConfig::dense())];
    let dense = run_sequential(&reference, &dense_solo, 3);
    assert_traces_bit_identical(
        &dense,
        &want[0..1],
        "attn=0.0 batch member vs standalone dense",
    );
}

// ---------------------------------------------------------------------------
// Speculative-prefill token-pruning axis
// ---------------------------------------------------------------------------

/// Dense config with speculative token pruning at `keep` — `1.0` must
/// be *the* unpruned path (no scoring pass runs at all), and `< 1.0`
/// prunes the prompt before the main prefill.
fn keep_cfg(keep: f64) -> SparsityConfig {
    let mut cfg = SparsityConfig::dense();
    cfg.token_keep_ratio = Some(keep);
    cfg
}

/// The tentpole gate: `token_keep_ratio = 1.0` is **bit-identical**
/// (logits + KV) to leaving the knob unset — on the reference oracle
/// and at threads ∈ {1, 4} — for dense and the paper's full method,
/// across prompt lengths straddling the prefill-block boundary. The
/// identity holds by construction (the resolver returns the unpruned
/// path before any scoring code runs), and this test is what keeps it
/// that way.
#[test]
fn token_keep_one_matches_unpruned_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    let block = reference.block();
    for (name, base) in [
        ("dense", SparsityConfig::dense()),
        ("fastforward-50", SparsityConfig::fastforward(0.5)),
    ] {
        let mut keep1 = base.clone();
        keep1.token_keep_ratio = Some(1.0);
        assert_eq!(
            base.prefill_fingerprint(),
            keep1.prefill_fingerprint(),
            "{name}: keep=1.0 must share the unpruned KV fingerprint"
        );
        for &len in &[40, block + 1, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &base).unwrap();
            let got = reference.prefill(&prompt, &keep1).unwrap();
            assert_prefill_bit_identical(
                &want,
                &got,
                &format!("{name} keep=1.0 reference len={len}"),
            );
            for (threads, fast) in &fasts {
                let got = fast.prefill(&prompt, &keep1).unwrap();
                assert_prefill_bit_identical(
                    &want,
                    &got,
                    &format!("{name} keep=1.0 {threads} len={len}"),
                );
            }
        }
    }
}

/// keep = 1.0 inside mixed B ∈ {1, 3} prefill-chunk/decode batches:
/// batched equals the unpruned sequential reference bit for bit at
/// threads ∈ {1, 4} and both batch shapes.
#[test]
fn token_keep_one_step_batch_matches_sequential_bit_identically() {
    let reference = testing::cpu_engine_reference();
    let block = reference.block();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    // the unpruned oracle...
    let base = batch_seqs(block);
    let want = run_sequential(&reference, &base, 3);
    // ...against the same sequences with keep=1.0 set explicitly
    let seqs: Vec<(Vec<i32>, SparsityConfig)> = base
        .iter()
        .map(|(p, c)| {
            let mut c = c.clone();
            c.token_keep_ratio = Some(1.0);
            (p.clone(), c)
        })
        .collect();
    for (name, fast) in &fasts {
        let got = run_batched(fast, &seqs, 3, 4);
        assert_traces_bit_identical(
            &want,
            &got,
            &format!("keep=1.0 B=3 {name}"),
        );
    }
    // B = 1
    let solo_base =
        vec![(corpus_prompt(block + 9), SparsityConfig::fastforward(0.5))];
    let want = run_sequential(&reference, &solo_base, 3);
    let mut solo = solo_base.clone();
    solo[0].1.token_keep_ratio = Some(1.0);
    for (name, fast) in &fasts {
        let got = run_batched(fast, &solo, 3, 4);
        assert_traces_bit_identical(
            &want,
            &got,
            &format!("keep=1.0 B=1 {name}"),
        );
    }
}

/// Genuinely pruned prefill (keep ∈ {0.5, 0.25}) is deterministic
/// across reruns and **thread-invariant bitwise**: scoring and
/// selection run sequentially on the dispatching thread, so threads
/// ∈ {1, 4} and the reference oracle agree on the keep-set, the
/// compacted KV and the logits. The keep-map invariants (count,
/// mandatory bands, ascending order) are checked on the engine's
/// actual output, not just the pure selection function.
#[test]
fn pruned_prefill_is_deterministic_and_thread_invariant() {
    use fastforward::sparsity::tokens::{LOCAL_TOKENS, SINK_TOKENS};
    let reference = testing::cpu_engine_reference();
    let fasts = [
        ("threads=1", testing::cpu_engine_threads(1)),
        ("threads=4", testing::cpu_engine_threads(4)),
    ];
    let block = reference.block();
    for &keep in &[0.5, 0.25] {
        for &len in &[block + 1, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let cfg = keep_cfg(keep);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            let expect = ((keep * len as f64).ceil() as usize)
                .clamp(SINK_TOKENS + LOCAL_TOKENS, len);
            assert_eq!(
                want.cache.len, expect,
                "keep={keep} len={len}: pruned KV length"
            );
            let map = want
                .keep_map
                .as_ref()
                .expect("pruned prefill must report its keep-map");
            assert_eq!(map.len(), expect);
            assert!(
                map.windows(2).all(|w| w[0] < w[1]),
                "keep-map not strictly ascending"
            );
            for i in 0..SINK_TOKENS {
                assert!(map.contains(&(i as u32)), "sink {i} dropped");
            }
            for i in len - LOCAL_TOKENS..len {
                assert!(map.contains(&(i as u32)), "local {i} dropped");
            }
            let again = reference.prefill(&prompt, &cfg).unwrap();
            assert_eq!(want.keep_map, again.keep_map);
            assert_prefill_bit_identical(
                &want,
                &again,
                &format!("keep={keep} reference rerun len={len}"),
            );
            for (threads, fast) in &fasts {
                let got = fast.prefill(&prompt, &cfg).unwrap();
                assert_eq!(
                    want.keep_map, got.keep_map,
                    "keep={keep} {threads} len={len}: keep-set differs"
                );
                assert_prefill_bit_identical(
                    &want,
                    &got,
                    &format!("keep={keep} {threads} len={len}"),
                );
            }
        }
    }
}

/// Prefix-cache isolation of the pruning axis: distinct keep ratios
/// carry distinct KV fingerprints (pruned KV never crosses
/// configurations), while `Some(1.0)` and `None` deliberately share
/// one — their KV is bit-identical, so sharing is sound and keeps the
/// cache warm across the flag's two unpruned spellings.
#[test]
fn token_keep_fingerprints_isolate_pruned_kv() {
    let dense = SparsityConfig::dense();
    assert_eq!(
        dense.prefill_fingerprint(),
        keep_cfg(1.0).prefill_fingerprint()
    );
    assert_ne!(
        dense.prefill_fingerprint(),
        keep_cfg(0.5).prefill_fingerprint()
    );
    assert_ne!(
        keep_cfg(0.5).prefill_fingerprint(),
        keep_cfg(0.25).prefill_fingerprint()
    );
}

// ---------------------------------------------------------------------------
// SIMD / bf16 kernel tiers: tolerance-gated conformance
// ---------------------------------------------------------------------------

/// The tier matrix: every FFN-sparsity config the bitwise suite runs,
/// plus the block-sparse attention axis (standalone and composed with
/// the paper's full method) — relaxed tiers must hold everywhere the
/// bitwise tier does.
fn tier_configs() -> Vec<(&'static str, SparsityConfig)> {
    let mut v = configs();
    v.push(("attn-50", attn_cfg(0.5)));
    v.push(("attn-sink-local", attn_cfg(1.0)));
    let mut ff_attn = SparsityConfig::fastforward(0.5);
    ff_attn.attn_sparsity = Some(0.5);
    v.push(("ff50+attn50", ff_attn));
    v
}

/// Check one prefill result against a [`testing::ConformanceSpec`]:
/// logits under the tier's tolerance + argmax guard, every KV layer
/// under the tier's tolerance + rel-L2 drift bound.
fn assert_prefill_within(spec: &testing::ConformanceSpec,
                         want: &fastforward::engine::PrefillResult,
                         got: &fastforward::engine::PrefillResult,
                         what: &str) {
    spec.check_logits(
        &format!("{what}: logits"),
        &want.last_logits,
        &got.last_logits,
    );
    assert_eq!(want.cache.len, got.cache.len, "{what}: KV length");
    let n = want.cache.len * want.cache.row_elems();
    for l in 0..want.cache.n_layers {
        spec.check_kv(
            &format!("{what}: layer {l} K"),
            &want.cache.k[l][..n],
            &got.cache.k[l][..n],
        );
        spec.check_kv(
            &format!("{what}: layer {l} V"),
            &want.cache.v[l][..n],
            &got.cache.v[l][..n],
        );
    }
}

/// Trace comparison under a tier spec (the tolerance-gated analogue of
/// [`assert_traces_bit_identical`]).
fn assert_traces_within(spec: &testing::ConformanceSpec,
                        want: &[SeqTrace], got: &[SeqTrace],
                        what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: sequence count");
    for (i, ((wh, wc), (gh, gc))) in
        want.iter().zip(got.iter()).enumerate()
    {
        assert_eq!(wh.len(), gh.len(), "{what}: seq {i} step count");
        for (step, (wl, gl)) in wh.iter().zip(gh.iter()).enumerate() {
            spec.check_logits(
                &format!("{what}: seq {i} step {step} logits"),
                wl,
                gl,
            );
        }
        assert_eq!(wc.len, gc.len, "{what}: seq {i} KV length");
        let elems = wc.len * wc.row_elems();
        for l in 0..wc.n_layers {
            spec.check_kv(
                &format!("{what}: seq {i} layer {l} K"),
                &wc.k[l][..elems],
                &gc.k[l][..elems],
            );
            spec.check_kv(
                &format!("{what}: seq {i} layer {l} V"),
                &wc.v[l][..elems],
                &gc.v[l][..elems],
            );
        }
    }
}

/// The SIMD kernel tier against the sequential scalar oracle, under
/// [`testing::simd_spec`], across the full matrix: every FFN/attention
/// config × prompt lengths straddling the prefill-block boundary ×
/// threads ∈ {1, 4}.
#[test]
fn simd_tier_matches_reference_within_budget() {
    let reference = testing::cpu_engine_reference();
    let spec = testing::simd_spec();
    let block = reference.block();
    let lens = [40, block + 1, 2 * block + 44];
    let simds = [
        ("threads=1", testing::cpu_engine_simd(1)),
        ("threads=4", testing::cpu_engine_simd(4)),
    ];
    for (name, cfg) in tier_configs() {
        for &len in &lens {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            for (threads, simd) in &simds {
                let got = simd.prefill(&prompt, &cfg).unwrap();
                assert_prefill_within(
                    &spec,
                    &want,
                    &got,
                    &format!("simd {name} len={len} {threads}"),
                );
            }
        }
    }
}

/// SIMD self-consistency: the tier is deterministic and
/// **thread-invariant bitwise** — lane-chunked accumulation is a pure
/// function of the operands, so threads ∈ {1, 4} must agree on every
/// bit even though the tier is not bit-identical to the scalar oracle.
#[test]
fn simd_tier_is_thread_invariant_bitwise() {
    let t1 = testing::cpu_engine_simd(1);
    let t4 = testing::cpu_engine_simd(4);
    let block = t1.block();
    let mut ff_attn = SparsityConfig::fastforward(0.5);
    ff_attn.attn_sparsity = Some(0.5);
    let cfgs = [
        ("dense", SparsityConfig::dense()),
        ("fastforward-50", SparsityConfig::fastforward(0.5)),
        ("attn-50", attn_cfg(0.5)),
        ("ff50+attn50", ff_attn),
    ];
    for (name, cfg) in &cfgs {
        for &len in &[40, block + 1, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let a = t1.prefill(&prompt, cfg).unwrap();
            let b = t4.prefill(&prompt, cfg).unwrap();
            assert_prefill_bit_identical(
                &a,
                &b,
                &format!("simd {name} len={len} t1 vs t4"),
            );
            let again = t4.prefill(&prompt, cfg).unwrap();
            assert_prefill_bit_identical(
                &b,
                &again,
                &format!("simd {name} len={len} rerun"),
            );
        }
    }
}

/// Mixed prefill-chunk/decode batches on the SIMD tier: batched equals
/// the SIMD engine's own sequential path **bitwise** (batching never
/// changes accumulation order), and both stay within the tier budget
/// of the scalar oracle.
#[test]
fn simd_step_batch_is_batch_invariant_and_within_budget() {
    let reference = testing::cpu_engine_reference();
    let spec = testing::simd_spec();
    let seqs = batch_seqs(reference.block());
    let want = run_sequential(&reference, &seqs, 3);
    for threads in [1usize, 4] {
        let simd = testing::cpu_engine_simd(threads);
        let solo = run_sequential(&simd, &seqs, 3);
        let got = run_batched(&simd, &seqs, 3, 4);
        assert_traces_bit_identical(
            &solo,
            &got,
            &format!("simd B=3 threads={threads} batched vs solo"),
        );
        assert_traces_within(
            &spec,
            &want,
            &got,
            &format!("simd B=3 threads={threads} vs oracle"),
        );
    }
}

/// The bf16 storage tier (SIMD kernels streaming raw bf16 panels,
/// f32 accumulation) against the **f32-weight** oracle, under
/// [`testing::bf16_spec`]: the budget is set by the one-time weight
/// rounding, and the argmax guard keeps the rounded model ranking
/// tokens like the oracle.
#[test]
fn bf16_tier_matches_f32_reference_within_budget() {
    let reference = testing::cpu_engine_reference();
    let spec = testing::bf16_spec();
    let block = reference.block();
    let bf16s = [
        ("threads=1", testing::cpu_engine_bf16_simd(1)),
        ("threads=4", testing::cpu_engine_bf16_simd(4)),
    ];
    for (name, cfg) in tier_configs() {
        for &len in &[40, block + 1, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            for (threads, bf16) in &bf16s {
                let got = bf16.prefill(&prompt, &cfg).unwrap();
                assert_prefill_within(
                    &spec,
                    &want,
                    &got,
                    &format!("bf16 {name} len={len} {threads}"),
                );
            }
        }
    }
    // and the tier is deterministic + thread-invariant against itself
    let prompt = corpus_prompt(block + 1);
    let cfg = SparsityConfig::fastforward(0.5);
    let a = bf16s[0].1.prefill(&prompt, &cfg).unwrap();
    let b = bf16s[1].1.prefill(&prompt, &cfg).unwrap();
    assert_prefill_bit_identical(&a, &b, "bf16 t1 vs t4");
}

/// The int8 storage tier (SIMD kernels streaming int8 codes +
/// per-column-tile scales, dequantized in-register, f32 accumulation)
/// against the **f32-weight** oracle, under [`testing::int8_spec`]:
/// the budget is set by the one-time symmetric-absmax quantization,
/// and the argmax + KV-norm guards keep the quantized model ranking
/// tokens and shaping caches like the oracle — across the full
/// config × length × thread matrix.
#[test]
fn int8_tier_matches_f32_reference_within_budget() {
    let reference = testing::cpu_engine_reference();
    let spec = testing::int8_spec();
    let block = reference.block();
    let int8s = [
        ("threads=1", testing::cpu_engine_int8_simd(1)),
        ("threads=4", testing::cpu_engine_int8_simd(4)),
    ];
    for (name, cfg) in tier_configs() {
        for &len in &[40, block + 1, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            for (threads, int8) in &int8s {
                let got = int8.prefill(&prompt, &cfg).unwrap();
                assert_prefill_within(
                    &spec,
                    &want,
                    &got,
                    &format!("int8 {name} len={len} {threads}"),
                );
            }
        }
    }
    // and the tier is deterministic + thread-invariant against itself
    let prompt = corpus_prompt(block + 1);
    let cfg = SparsityConfig::fastforward(0.5);
    let a = int8s[0].1.prefill(&prompt, &cfg).unwrap();
    let b = int8s[1].1.prefill(&prompt, &cfg).unwrap();
    assert_prefill_bit_identical(&a, &b, "int8 t1 vs t4");
    let again = int8s[1].1.prefill(&prompt, &cfg).unwrap();
    assert_prefill_bit_identical(&b, &again, "int8 t4 rerun");
}

/// Mixed prefill-chunk/decode batches on the int8 tier: batched equals
/// the int8 engine's own sequential path **bitwise** (batching never
/// changes the dequantize-and-fold order), and both stay within the
/// tier budget of the f32-weight oracle.
#[test]
fn int8_step_batch_is_batch_invariant_and_within_budget() {
    let reference = testing::cpu_engine_reference();
    let spec = testing::int8_spec();
    let seqs = batch_seqs(reference.block());
    let want = run_sequential(&reference, &seqs, 3);
    for threads in [1usize, 4] {
        let int8 = testing::cpu_engine_int8_simd(threads);
        let solo = run_sequential(&int8, &seqs, 3);
        let got = run_batched(&int8, &seqs, 3, 4);
        assert_traces_bit_identical(
            &solo,
            &got,
            &format!("int8 B=3 threads={threads} batched vs solo"),
        );
        assert_traces_within(
            &spec,
            &want,
            &got,
            &format!("int8 B=3 threads={threads} vs oracle"),
        );
    }
}

/// The env-resolved engine (what `cargo test` under
/// `FF_CPU_KERNEL=...` actually builds — scripts/check.sh runs this
/// suite both ways) is gated at whichever tier the env selects:
/// bitwise on scalar, [`testing::simd_spec`] on simd.
#[test]
fn env_kernel_engine_matches_reference_at_its_tier() {
    let reference = testing::cpu_engine_reference();
    let env = testing::cpu_engine();
    let kernel = CpuKernel::from_env();
    let block = reference.block();
    for (name, cfg) in tier_configs() {
        for &len in &[40, 2 * block + 44] {
            let prompt = corpus_prompt(len);
            let want = reference.prefill(&prompt, &cfg).unwrap();
            let got = env.prefill(&prompt, &cfg).unwrap();
            match kernel {
                CpuKernel::Scalar => assert_prefill_bit_identical(
                    &want,
                    &got,
                    &format!("env=scalar {name} len={len}"),
                ),
                CpuKernel::Simd => assert_prefill_within(
                    &testing::simd_spec(),
                    &want,
                    &got,
                    &format!("env=simd {name} len={len}"),
                ),
            }
        }
    }
}

/// KV-cache safety across tiers: the SIMD, bf16 and int8 tiers carry
/// distinct numeric fingerprints, so prefix-cache KV computed on one
/// tier is never silently adopted by another — while the scalar fast
/// path still shares the reference fingerprint (bit-identical ⇒
/// interchangeable).
#[test]
fn relaxed_tiers_have_distinct_numeric_fingerprints() {
    let reference = testing::cpu_engine_reference();
    let scalar = testing::cpu_engine_threads(1);
    let simd = testing::cpu_engine_simd(1);
    let bf16 = testing::cpu_engine_bf16_simd(1);
    let int8 = testing::cpu_engine_int8_simd(1);
    assert_eq!(
        reference.rt.numeric_fingerprint(),
        scalar.rt.numeric_fingerprint(),
        "scalar fast path shares the reference fingerprint"
    );
    assert_ne!(
        scalar.rt.numeric_fingerprint(),
        simd.rt.numeric_fingerprint(),
        "simd tier must not adopt scalar KV"
    );
    assert_ne!(
        scalar.rt.numeric_fingerprint(),
        bf16.rt.numeric_fingerprint(),
        "bf16 tier must not adopt scalar KV"
    );
    assert_ne!(
        simd.rt.numeric_fingerprint(),
        bf16.rt.numeric_fingerprint(),
        "bf16 tier must not adopt f32-simd KV"
    );
    assert_ne!(
        scalar.rt.numeric_fingerprint(),
        int8.rt.numeric_fingerprint(),
        "int8 tier must not adopt scalar KV"
    );
    assert_ne!(
        simd.rt.numeric_fingerprint(),
        int8.rt.numeric_fingerprint(),
        "int8 tier must not adopt f32-simd KV"
    );
    assert_ne!(
        bf16.rt.numeric_fingerprint(),
        int8.rt.numeric_fingerprint(),
        "int8 tier must not adopt bf16 KV"
    );
}

// ---------------------------------------------------------------------------
// Rc→Arc migration regressions
// ---------------------------------------------------------------------------

fn assert_send_sync<T: Send + Sync>() {}

/// The types the executor pool now shares across replica threads must
/// stay `Send + Sync` (this is a compile-time assertion).
#[test]
fn shared_model_state_is_send_sync() {
    assert_send_sync::<fastforward::manifest::Manifest>();
    assert_send_sync::<fastforward::weights::WeightStore>();
}

/// Regression for the per-replica re-seeding `spawn_backend` used to
/// do: every engine the factory builds must share the *same*
/// manifest/weight allocation (no re-seed, no re-load) and therefore
/// the same numeric fingerprint.
#[test]
fn pool_factory_shares_one_weight_set_across_replicas() {
    let factory =
        ExecutorPool::shared_backend_factory(BackendKind::Cpu, None)
            .unwrap();
    let a = factory().unwrap();
    let b = factory().unwrap();
    assert_eq!(
        a.rt.numeric_fingerprint(),
        b.rt.numeric_fingerprint(),
        "replicas must serve identical numerics"
    );
    assert!(
        std::sync::Arc::ptr_eq(&a.rt.manifest, &b.rt.manifest),
        "replicas must share one manifest allocation, not re-seed"
    );
    // and the factory-built engine matches a hand-built one numerically
    // (the factory honors FF_WEIGHT_PREC, so the hand-built spec must
    // resolve the same storage precision for the fingerprints to agree)
    let spec = SyntheticSpec {
        weight_precision: fastforward::weights::WeightPrecision::from_env(),
        ..SyntheticSpec::default()
    };
    let hand = Engine::synthetic_cpu(&spec).unwrap();
    assert_eq!(
        a.rt.numeric_fingerprint(),
        hand.rt.numeric_fingerprint()
    );
}

/// Invalid backend/artifact combinations fail at factory construction
/// with a clear error (spawn_backend then degrades every replica to an
/// answered error instead of hanging).
#[test]
fn factory_rejects_invalid_backend_combinations() {
    let err = match ExecutorPool::shared_backend_factory(
        BackendKind::Cpu,
        Some(std::path::PathBuf::from("/no/such/bundle")),
    ) {
        Ok(_) => panic!("cpu + artifacts must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("synthetic reference model"), "{err}");
    let err = match ExecutorPool::shared_backend_factory(
        BackendKind::Pjrt,
        None,
    ) {
        Ok(_) => panic!("pjrt without artifacts must be rejected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("artifact directory"), "{err}");
}
