//! Streaming + SLO-scheduling integration tests.
//!
//! Host-only:
//! * SSE wire format: event ordering and framing over a real TCP
//!   connection, with the executor side played by a stub thread.
//!
//! Engine-backed — always-on (docs/TESTING.md): the stack runs on real
//! artifacts + PJRT when present, the deterministic CpuBackend
//! otherwise:
//! * streamed tokens reassemble to exactly the one-shot response;
//! * a mid-stream client disconnect cancels the session and the KV
//!   pool returns to zero used pages;
//! * a batch-class long prefill is preempted for an interactive
//!   request (observable via `ff_preemptions_total`), and the
//!   interactive request finishes first.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastforward::batcher::{Batcher, BatcherConfig};
use fastforward::engine::SparsityConfig;
use fastforward::metrics::Metrics;
use fastforward::router::{Response, Router, SloClass, SubmitOpts,
                          TokenEvent};
use fastforward::server::{Lifecycle, Server, DEFAULT_HEADER_TIMEOUT};
use fastforward::testing;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::json;

// ---------------------------------------------------------------------------
// helpers
// ---------------------------------------------------------------------------

/// One parsed SSE frame.
#[derive(Debug)]
struct Frame {
    event: String,
    data: json::Json,
}

/// Split an SSE body into (event, data) frames.
fn parse_sse(body: &str) -> Vec<Frame> {
    let mut frames = Vec::new();
    for chunk in body.split("\n\n").filter(|c| !c.trim().is_empty()) {
        let mut event = String::new();
        let mut data = String::new();
        for line in chunk.lines() {
            if let Some(v) = line.strip_prefix("event: ") {
                event = v.to_string();
            } else if let Some(v) = line.strip_prefix("data: ") {
                data = v.to_string();
            }
        }
        assert!(!event.is_empty(), "frame without event name: {chunk:?}");
        frames.push(Frame {
            event,
            data: json::parse(&data)
                .unwrap_or_else(|e| panic!("bad frame json {data:?}: {e}")),
        });
    }
    frames
}

fn post_raw(addr: &str, path: &str, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

/// Bind an ephemeral port, then hand the address to a Server (which
/// re-binds; the tiny race is acceptable in tests).
fn spawn_server(server: Arc<Server>) -> String {
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener);
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let _ = server.serve(&addr2);
    });
    std::thread::sleep(Duration::from_millis(200));
    addr
}

/// The single-replica engine stack over whichever backend this machine
/// supports, plus the model limits tests need for sizing prompts.
struct Stack {
    router: Arc<Router>,
    handle: std::thread::JoinHandle<()>,
    max_ctx: usize,
}

fn start_stack(cfg: BatcherConfig) -> Stack {
    let probe = testing::test_engine();
    let block = probe.block();
    let max_ctx = probe.manifest().model.max_ctx;
    drop(probe);
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new(64, max_ctx, 512, block, metrics));
    let r2 = router.clone();
    let handle = std::thread::spawn(move || {
        Batcher::new(testing::test_engine(), r2, cfg).run().unwrap();
    });
    Stack {
        router,
        handle,
        max_ctx,
    }
}

fn prompt_text(n: usize) -> String {
    let mut rng = fastforward::util::rng::Rng::new(5);
    let bank = fastforward::trace::WordBank::new(&mut rng, 64);
    bank.filler(&mut rng, n)
}

// ---------------------------------------------------------------------------
// host-only: SSE wire format
// ---------------------------------------------------------------------------

#[test]
fn sse_event_ordering_and_framing() {
    let metrics = Arc::new(Metrics::new());
    let router =
        Arc::new(Router::new(16, 4096, 256, 128, metrics.clone()));

    // Stub executor: echoes each prompt token back as one Token event,
    // exercising the full event protocol without an engine.
    let r2 = router.clone();
    let exec = std::thread::spawn(move || {
        while let Some(req) = r2.pop_blocking() {
            let _ = req.events.send(TokenEvent::First {
                ttft_ms: 1.5,
                reused_blocks: 0,
            });
            let mut text = String::new();
            for &t in &req.prompt {
                let piece = ((t as u8) as char).to_string();
                text.push_str(&piece);
                let _ = req.events.send(TokenEvent::Token {
                    token: t,
                    text: piece,
                });
            }
            let mut done = Response::failed(req.id, String::new());
            done.error = None;
            done.text = text;
            done.tokens = req.prompt.len();
            done.ttft_ms = 1.5;
            let _ = req.events.send(TokenEvent::Done(done));
        }
    });

    let server = Arc::new(Server {
        router: router.clone(),
        metrics,
        tokenizer: Tokenizer::new(384),
        default_sparsity: None,
        default_attn_sparsity: None,
        default_token_keep: None,
        lifecycle: Lifecycle::new(),
        header_timeout: DEFAULT_HEADER_TIMEOUT,
    });
    let addr = spawn_server(server);

    let raw = post_raw(
        &addr,
        "/generate",
        r#"{"prompt": "abc", "max_tokens": 4, "stream": true}"#,
    );
    let (head, body) = raw.split_once("\r\n\r\n").expect("header split");
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(
        head.contains("Content-Type: text/event-stream"),
        "SSE content type: {head}"
    );

    let frames = parse_sse(body);
    assert_eq!(frames.len(), 2 + 3, "first + 3 tokens + done");
    assert_eq!(frames[0].event, "first");
    assert!(frames[0].data.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        frames[0].data.get("reused_blocks").unwrap().as_usize(),
        Some(0)
    );
    let mut streamed = String::new();
    for f in &frames[1..4] {
        assert_eq!(f.event, "token");
        assert!(f.data.get("token").unwrap().as_usize().is_some());
        streamed.push_str(f.data.get("text").unwrap().as_str().unwrap());
    }
    let done = frames.last().unwrap();
    assert_eq!(done.event, "done");
    assert_eq!(done.data.get("text").unwrap().as_str(), Some("abc"));
    assert_eq!(
        streamed, "abc",
        "token texts concatenate to the final text"
    );
    assert_eq!(done.data.get("error").unwrap(), &json::Json::Null);

    // non-streaming requests on the same server still get plain JSON
    let raw = post_raw(&addr, "/generate", r#"{"prompt": "xy"}"#);
    let (head, body) = raw.split_once("\r\n\r\n").unwrap();
    assert!(head.starts_with("HTTP/1.1 200"), "{head}");
    assert!(head.contains("application/json"), "{head}");
    let j = json::parse(body).unwrap();
    assert_eq!(j.get("text").unwrap().as_str(), Some("xy"));

    // unknown SLO class is a 400, not a silent default
    let raw = post_raw(
        &addr,
        "/generate",
        r#"{"prompt": "x", "class": "warp-speed"}"#,
    );
    assert!(raw.starts_with("HTTP/1.1 400"), "{raw}");

    router.close();
    exec.join().unwrap();
}

// ---------------------------------------------------------------------------
// engine-backed (always-on)
// ---------------------------------------------------------------------------

#[test]
fn streamed_tokens_match_oneshot_exactly() {
    let stack = start_stack(BatcherConfig {
        max_active: 4,
        prefill_block_budget: 2,
        ..Default::default()
    });
    let router = stack.router.clone();
    let tok = Tokenizer::new(384);
    let prompt = tok.encode(&prompt_text(400));
    let cfg = SparsityConfig::fastforward(0.5);

    // one-shot: drain the stream to the terminal response only
    let (tx, rx) = channel();
    router
        .submit(prompt.clone(), 8, cfg.clone(), tx)
        .expect("admit");
    let oneshot = Response::collect_timeout(&rx, Duration::from_secs(120))
        .expect("one-shot response");
    assert!(oneshot.error.is_none(), "{:?}", oneshot.error);

    // streamed: same prompt, same config — collect every event
    let (tx, rx) = channel();
    router.submit(prompt, 8, cfg, tx).expect("admit");
    let mut saw_first = false;
    let mut ids = Vec::new();
    let mut text_pieces = String::new();
    let streamed_done = loop {
        match rx.recv_timeout(Duration::from_secs(120)).expect("event") {
            TokenEvent::First { ttft_ms, .. } => {
                assert!(!saw_first, "exactly one First event");
                assert!(ttft_ms > 0.0);
                saw_first = true;
            }
            TokenEvent::Token { token, text } => {
                assert!(saw_first, "tokens only after First");
                ids.push(token);
                text_pieces.push_str(&text);
            }
            TokenEvent::Done(resp) => break resp,
        }
    };
    assert!(streamed_done.error.is_none(), "{:?}", streamed_done.error);

    // bit-identical: same token count, same final text, and the
    // streamed ids decode to exactly the one-shot text
    assert_eq!(streamed_done.tokens, oneshot.tokens);
    assert_eq!(streamed_done.text, oneshot.text);
    assert_eq!(ids.len(), streamed_done.tokens);
    assert_eq!(tok.decode(&ids), oneshot.text);
    // incremental pieces reassemble the text (a trailing *incomplete*
    // multi-byte character may legitimately stay buffered)
    assert!(
        oneshot.text.starts_with(&text_pieces)
            && oneshot.text.len() - text_pieces.len() < 4,
        "pieces {text_pieces:?} vs {:?}",
        oneshot.text
    );

    // ITL samples were recorded for the interactive class
    if streamed_done.tokens > 1 {
        let (p50, _) = router.metrics.itl_p50_p95(SloClass::Interactive);
        assert!(p50 > 0.0, "ITL histogram populated");
        assert!(router.metrics.export().contains(
            "ff_itl_ms_p50{class=\"interactive\"}"
        ));
    }

    router.close();
    stack.handle.join().unwrap();
    assert_eq!(router.kv_pool.lock().unwrap().used_pages(), 0);
}

#[test]
fn disconnect_mid_stream_releases_kv_pages() {
    let stack = start_stack(BatcherConfig {
        max_active: 4,
        prefill_block_budget: 2,
        ..Default::default()
    });
    let router = stack.router.clone();
    let server = Arc::new(Server {
        router: router.clone(),
        metrics: router.metrics.clone(),
        tokenizer: Tokenizer::new(384),
        default_sparsity: Some(0.5),
        default_attn_sparsity: None,
        default_token_keep: None,
        lifecycle: Lifecycle::new(),
        header_timeout: DEFAULT_HEADER_TIMEOUT,
    });
    let addr = spawn_server(server);

    // start a long streamed generation, then vanish after the first
    // token frame
    let body = format!(
        r#"{{"prompt": "{}", "max_tokens": 400, "stream": true}}"#,
        prompt_text(150).replace('"', " ")
    );
    {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.write_all(
            format!(
                "POST /generate HTTP/1.1\r\nHost: x\r\n\
                 Content-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut seen = String::new();
        let mut buf = [0u8; 1024];
        let deadline = Instant::now() + Duration::from_secs(120);
        loop {
            let n = s.read(&mut buf).expect("read stream");
            assert!(n > 0, "server closed before first token");
            seen.push_str(&String::from_utf8_lossy(&buf[..n]));
            if seen.contains("event: token") {
                break;
            }
            assert!(Instant::now() < deadline, "no token frame");
        }
        // drop the connection mid-stream
    }

    // the executor must notice, cancel the session and release its KV
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let used = router.kv_pool.lock().unwrap().used_pages();
        if used == 0 && router.metrics.cancelled() >= 1 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "KV not reclaimed after disconnect: {used} pages used, \
             {} cancelled",
            router.metrics.cancelled()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(
        router.metrics.stream_disconnects() >= 1,
        "disconnect was observed by the server"
    );

    router.close();
    stack.handle.join().unwrap();
}

#[test]
fn interactive_preempts_batch_prefill() {
    let stack = start_stack(BatcherConfig {
        max_active: 4,
        prefill_block_budget: 2,
        decode_first_budget: 1,
        max_batch: 8,
        slo: true,
    });
    let router = stack.router.clone();
    let tok = Tokenizer::new(384);

    // batch-class long prefill: as long as the context bound allows
    // (the acceptance scenario's "16K-token" prefill scaled to the
    // test model's max_ctx)
    let batch_len = stack.max_ctx.saturating_sub(64).min(3400);
    let mut batch_prompt = tok.encode(&prompt_text(batch_len));
    batch_prompt.truncate(batch_len);
    let (btx, brx) = channel();
    router
        .submit_with(
            batch_prompt,
            4,
            SparsityConfig::fastforward(0.5),
            SubmitOpts {
                class: SloClass::Batch,
                ..Default::default()
            },
            btx,
        )
        .expect("admit batch");

    // give the executor a moment to admit it and start prefilling
    // (short enough that the CPU reference backend cannot race through
    // the whole batch prefill before the interactive request lands)
    std::thread::sleep(Duration::from_millis(50));

    // interactive short request arrives mid-prefill
    let (itx, irx) = channel();
    let t0 = Instant::now();
    router
        .submit(
            tok.encode(&prompt_text(180)),
            6,
            SparsityConfig::fastforward(0.5),
            itx,
        )
        .expect("admit interactive");
    let interactive = Response::collect_timeout(
        &irx,
        Duration::from_secs(300),
    )
    .expect("interactive response");
    let interactive_wall = t0.elapsed();
    assert!(interactive.error.is_none(), "{:?}", interactive.error);

    // the batch request must still be running when the interactive one
    // finished (it was preempted, not merely outrun)
    let mut batch_done_already = false;
    while let Ok(ev) = brx.try_recv() {
        if matches!(ev, TokenEvent::Done(_)) {
            batch_done_already = true;
        }
    }
    assert!(
        !batch_done_already,
        "batch prefill should still be in flight"
    );
    assert!(
        router.metrics.preemptions() >= 1,
        "preemption must be observable via ff_preemptions_total"
    );
    assert!(
        router.metrics.export().contains("ff_preemptions_total"),
        "metric exported"
    );

    // and the batch request still completes afterwards
    let batch = Response::collect_timeout(&brx, Duration::from_secs(600))
        .expect("batch response");
    assert!(batch.error.is_none(), "{:?}", batch.error);
    assert!(
        interactive.ttft_ms < batch.e2e_ms,
        "interactive TTFT {} must beat the batch request's e2e {}",
        interactive.ttft_ms,
        batch.e2e_ms
    );
    eprintln!(
        "[slo] interactive ttft {:.1} ms (wall {:.1} ms) vs batch e2e \
         {:.1} ms, {} preemptions",
        interactive.ttft_ms,
        interactive_wall.as_secs_f64() * 1e3,
        batch.e2e_ms,
        router.metrics.preemptions()
    );

    router.close();
    stack.handle.join().unwrap();
    assert_eq!(router.kv_pool.lock().unwrap().used_pages(), 0);
}
