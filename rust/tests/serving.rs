//! Serving-stack integration: router → batcher → engine over a thread,
//! exercising admission, chunked prefill interleaving, decode rounds,
//! metrics, and KV page accounting. Skips without artifacts.

use std::sync::mpsc::channel;
use std::sync::Arc;

use fastforward::batcher::{Batcher, BatcherConfig};
use fastforward::engine::{Engine, SparsityConfig};
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::router::{Response, Router, TokenEvent};
use fastforward::runtime::Runtime;
use fastforward::tokenizer::Tokenizer;
use fastforward::weights::WeightStore;

fn start_stack(max_active: usize) -> Option<(Arc<Router>, std::thread::JoinHandle<()>)> {
    let dir = fastforward::test_artifacts_dir()?;
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new(64, 4096, 512, 128, metrics));
    let r2 = router.clone();
    let handle = std::thread::spawn(move || {
        let m = Arc::new(Manifest::load(&dir).unwrap());
        let w = Arc::new(WeightStore::load(&m).unwrap());
        let rt = Arc::new(Runtime::new(m, w).unwrap());
        let engine = Engine::new(rt);
        Batcher::new(
            engine,
            r2,
            BatcherConfig {
                max_active,
                prefill_block_budget: 2,
                ..Default::default()
            },
        )
        .run()
        .unwrap();
    });
    Some((router, handle))
}

/// Same stack shape as [`start_stack`] but on a synthetic CPU engine,
/// so lock-poisoning regressions are exercised even where no trained
/// artifacts are installed.
fn start_synthetic_stack(
    max_active: usize,
) -> (Arc<Router>, std::thread::JoinHandle<()>) {
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new(64, 2048, 512, 128, metrics));
    let r2 = router.clone();
    let handle = std::thread::spawn(move || {
        Batcher::new(
            fastforward::testing::cpu_engine(),
            r2,
            BatcherConfig {
                max_active,
                prefill_block_budget: 2,
                ..Default::default()
            },
        )
        .run()
        .unwrap();
    });
    (router, handle)
}

fn prompt_text(n: usize) -> String {
    let mut rng = fastforward::util::rng::Rng::new(5);
    let bank = fastforward::trace::WordBank::new(&mut rng, 64);
    bank.filler(&mut rng, n)
}

#[test]
fn serves_concurrent_requests_with_ttft() {
    let Some((router, handle)) = start_stack(4) else { return };
    let tok = Tokenizer::new(384);
    let mut rxs = Vec::new();
    for i in 0..5 {
        let (tx, rx) = channel::<TokenEvent>();
        let text = prompt_text(180 + i * 160);
        router
            .submit(
                tok.encode(&text),
                6,
                if i % 2 == 0 {
                    SparsityConfig::fastforward(0.5)
                } else {
                    SparsityConfig::dense()
                },
                tx,
            )
            .unwrap();
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = Response::collect_timeout(
            &rx,
            std::time::Duration::from_secs(120),
        )
        .expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.ttft_ms > 0.0);
        assert!(resp.tokens <= 6);
    }
    // metrics recorded
    assert_eq!(router.metrics.requests_completed(), 5);
    let (p50, _) = router.metrics.ttft_p50_p95();
    assert!(p50 > 0.0);
    // KV pages are released by the batcher's retire step, which runs
    // just after the response send — drain the executor before checking.
    router.close();
    handle.join().unwrap();
    assert_eq!(router.kv_pool.lock().unwrap().used_pages(), 0);
}

/// Regression: a panic while holding `kv_pool` / `prefix_cache` used
/// to poison the mutexes and turn every subsequent admission into a
/// `PoisonError` unwrap panic — one bad request killed the whole
/// serving stack. The hot paths now recover the guard
/// (`util::sync::lock_recover`), so requests submitted *after* the
/// poisoning must still be admitted, complete cleanly, and leave the
/// page accounting drained.
#[test]
fn poisoned_shared_locks_do_not_cascade_into_failures() {
    let (router, handle) = start_synthetic_stack(2);
    let tok = Tokenizer::new(384);

    // healthy request before the injected fault
    let (tx, rx) = channel::<TokenEvent>();
    router
        .submit(tok.encode(&prompt_text(160)), 4,
                SparsityConfig::dense(), tx)
        .unwrap();
    let resp =
        Response::collect_timeout(&rx, std::time::Duration::from_secs(120))
            .expect("pre-fault response");
    assert!(resp.error.is_none(), "{:?}", resp.error);

    // inject a panic while holding each shared lock
    for poison in [true, false] {
        let r = router.clone();
        let t = std::thread::spawn(move || {
            let _g = if poison {
                Ok(r.kv_pool.lock().unwrap())
            } else {
                Err(r.prefix_cache.lock().unwrap())
            };
            panic!("injected panic while holding a shared router lock");
        });
        assert!(t.join().is_err(), "injector thread must panic");
    }
    assert!(router.kv_pool.lock().is_err(), "kv_pool not poisoned");
    assert!(
        router.prefix_cache.lock().is_err(),
        "prefix_cache not poisoned"
    );

    // requests after the fault still run to completion
    let mut rxs = Vec::new();
    for i in 0..3 {
        let (tx, rx) = channel::<TokenEvent>();
        router
            .submit(
                tok.encode(&prompt_text(140 + i * 90)),
                4,
                if i % 2 == 0 {
                    SparsityConfig::dense()
                } else {
                    SparsityConfig::fastforward(0.5)
                },
                tx,
            )
            .expect("admission must survive poisoned locks");
        rxs.push(rx);
    }
    for rx in rxs {
        let resp = Response::collect_timeout(
            &rx,
            std::time::Duration::from_secs(120),
        )
        .expect("post-fault response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.tokens <= 4);
    }

    router.close();
    handle.join().unwrap();
    let pool = fastforward::util::sync::lock_recover(&router.kv_pool);
    assert_eq!(pool.used_pages(), 0, "page accounting leaked");
}

#[test]
fn backpressure_rejects_oversize() {
    let Some((router, handle)) = start_stack(2) else { return };
    let (tx, _rx) = channel::<TokenEvent>();
    let err = router
        .submit(vec![65; 5000], 10, SparsityConfig::dense(), tx)
        .unwrap_err();
    assert!(matches!(
        err,
        fastforward::router::Reject::PromptTooLong { .. }
    ));
    router.close();
    handle.join().unwrap();
}
