//! End-to-end integration tests, in two tiers (docs/TESTING.md):
//!
//! * **Always-on numeric tier** — runs on every machine via
//!   [`fastforward::testing::test_engine`]: real artifacts + PJRT when
//!   present, the deterministic pure-Rust `CpuBackend` otherwise. These
//!   tests assert *weight-agnostic* invariants: sparse FFN at
//!   `K == d_ffn` matches dense to 1e-5, the FFN partitions additively,
//!   the compensator shrinks sparse error, session stepping equals
//!   one-shot prefill, the layerwise schedule's density budget is
//!   achieved end to end, and two CpuBackend runs are byte-identical.
//! * **Artifact tier** — skips without `make artifacts` + `--features
//!   pjrt`: assertions about *trained-weight* quality (python parity,
//!   fidelity bounds, ablation orderings).

use fastforward::engine::{PrefillSession, SparsityConfig};
use fastforward::runtime::Input;
use fastforward::sparsity::masks::ExpertSource;
use fastforward::sparsity::schedule as alg1;
use fastforward::testing;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::json;
use fastforward::util::rng::Rng;

fn corpus_prompt(len: usize) -> Vec<i32> {
    // deterministic pseudo-text prompt (tokenizer byte ids of a-z/space)
    let mut rng = fastforward::util::rng::Rng::new(99);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let text = bank.filler(&mut rng, len);
    Tokenizer::new(384).encode(&text)
}

// ---------------------------------------------------------------------------
// always-on numeric tier
// ---------------------------------------------------------------------------

/// Blockwise prefill through the session API must agree with the one-shot
/// engine prefill (same executables, incremental scheduling).
#[test]
fn session_stepping_equals_oneshot() {
    let engine = testing::test_engine();
    let prompt = corpus_prompt(300);
    let cfg = SparsityConfig::fastforward(0.5);
    let oneshot = engine.prefill(&prompt, &cfg).unwrap();
    let mut s =
        PrefillSession::new(engine.clone(), prompt.clone(), cfg).unwrap();
    let mut steps = 0;
    while !s.done() {
        s.step().unwrap();
        steps += 1;
    }
    let block = engine.block();
    assert_eq!(steps, 300 / block + 300 % block);
    let stepped = s.finish().unwrap();
    for (a, b) in oneshot
        .last_logits
        .iter()
        .zip(stepped.last_logits.iter())
    {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

/// Dense-first/last + tail handling: a prompt under one block must run
/// entirely dense (via tail steps) under every config.
#[test]
fn short_prompts_work_all_configs() {
    let engine = testing::test_engine();
    let prompt = corpus_prompt(40);
    for cfg in [
        SparsityConfig::dense(),
        SparsityConfig::fastforward(0.5),
        {
            let mut c = SparsityConfig::fastforward(0.5);
            c.source = ExpertSource::Oracle;
            c
        },
    ] {
        let pre = engine.prefill(&prompt, &cfg).unwrap();
        assert_eq!(pre.timing.blocks, 0);
        assert_eq!(pre.timing.tail_tokens, 40);
        assert!(pre.last_logits.iter().all(|x| x.is_finite()));
    }
}

/// KV caches returned by prefill support decode continuation.
#[test]
fn prefill_then_decode_runs() {
    let engine = testing::test_engine();
    let prompt = corpus_prompt(200);
    let cfg = SparsityConfig::fastforward(0.5);
    let mut pre = engine.prefill(&prompt, &cfg).unwrap();
    let mut pos = prompt.len();
    let mut logits = pre.last_logits.clone();
    for _ in 0..8 {
        let tok = fastforward::engine::argmax(&logits) as i32;
        logits = engine
            .decode_step(tok, pos, &mut pre.cache, &cfg)
            .unwrap();
        pos += 1;
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}

/// Bucket growth mid-prompt: a prompt crossing the first bucket boundary
/// must produce finite and reproducible logits.
#[test]
fn bucket_growth_is_transparent() {
    let engine = testing::test_engine();
    let m_buckets = engine.manifest().model.buckets.clone();
    let len = m_buckets[0] + 130; // crosses into the second bucket
    let prompt = corpus_prompt(len);
    let a = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let b = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    assert!(a.last_logits.iter().all(|x| x.is_finite()));
    for (x, y) in a.last_logits.iter().zip(b.last_logits.iter()) {
        assert_eq!(x, y, "prefill must be deterministic");
    }
}

/// The combined fast path end to end: FFN sparsity 0.5 *and* block-
/// sparse attention 0.5 together (the CLI's `--sparsity 0.5
/// --attn-sparsity 0.5`). Prefill is deterministic and finite, decode
/// continues over the sparse-prefilled KV, prefix-cache adoption under
/// the combined config is numerically invisible, and cached KV never
/// crosses attention configurations.
#[test]
fn combined_ffn_and_attention_sparsity_end_to_end() {
    use fastforward::kvcache::{PagedAllocator, PrefixCache};
    let engine = testing::cpu_engine();
    let block = engine.block();
    let mut cfg = SparsityConfig::fastforward(0.5);
    cfg.attn_sparsity = Some(0.5);
    let prompt = corpus_prompt(3 * block + 21);

    let cold = engine.prefill(&prompt, &cfg).unwrap();
    assert_eq!(cold.timing.blocks, 3);
    assert!(cold.last_logits.iter().all(|x| x.is_finite()));
    let again = engine.prefill(&prompt, &cfg).unwrap();
    assert_eq!(
        cold.last_logits, again.last_logits,
        "combined sparse prefill must be deterministic"
    );

    // decode rides the combined-sparse KV
    let mut pre = engine.prefill(&prompt, &cfg).unwrap();
    let mut pos = prompt.len();
    let mut logits = pre.last_logits.clone();
    for _ in 0..4 {
        let tok = fastforward::engine::argmax(&logits) as i32;
        logits = engine
            .decode_step(tok, pos, &mut pre.cache, &cfg)
            .unwrap();
        pos += 1;
        assert!(logits.iter().all(|x| x.is_finite()));
    }

    // prefix-cache adoption under the combined config is invisible
    let mut alloc = PagedAllocator::new(1024, block);
    let mut pc = PrefixCache::new(block, 256 << 20);
    let seed = engine.prefix_seed(&cfg);
    let inserted =
        pc.insert(seed, &prompt, usize::MAX, &cold.cache, &mut alloc);
    assert_eq!(inserted, 3);
    let mut warm =
        PrefillSession::new(engine.clone(), prompt.clone(), cfg.clone())
            .unwrap();
    let hit = pc.acquire(seed, &prompt).expect("prefix hit");
    warm.adopt_prefix(hit.tokens, |cache| hit.copy_into(cache))
        .unwrap();
    pc.release(&hit);
    while !warm.done() {
        warm.step().unwrap();
    }
    let warm = warm.finish().unwrap();
    assert_eq!(warm.timing.blocks, 0, "cached blocks must not re-run");
    assert_eq!(warm.timing.adopted_blocks, 3);
    assert_eq!(
        warm.last_logits, cold.last_logits,
        "adoption under the combined config must be bit-identical"
    );

    // the same prompt under the same FFN sparsity but *dense* attention
    // must not see the attention-sparse KV (fingerprint separation)
    let dense_attn = SparsityConfig::fastforward(0.5);
    assert!(
        pc.acquire(engine.prefix_seed(&dense_attn), &prompt).is_none(),
        "KV must never cross attention configurations"
    );
}

/// The crown-jewel exactness invariant: the fused sparse layer at
/// `K == d_ffn` (every expert selected, nothing dropped, compensator
/// over an empty set) must reproduce the dense layer to 1e-5 — outputs
/// *and* the KV rows it writes.
#[test]
fn sparse_full_k_matches_dense_layer() {
    // reference-backend contract: pinned to the CPU engine, where the
    // compensator is exactly zero over an empty dropped set
    let engine = testing::cpu_engine();
    let rt = &engine.rt;
    let m = rt.manifest.clone();
    let mm = &m.model;
    let (block, d, nkv, dh, f) =
        (mm.block, mm.d_model, mm.n_kv_heads, mm.d_head, mm.d_ffn);
    assert!(m.k_grid.contains(&f), "synthetic grid includes K=d_ffn");
    let s = mm.buckets[0];
    let mut rng = Rng::new(31);
    let x: Vec<f32> = (0..block * d)
        .map(|_| (rng.normal() * 0.3) as f32)
        .collect();
    let kc = vec![0f32; s * nkv * dh];
    let pos = [0i32];
    let run = |exe: &str| {
        rt.run(
            exe,
            0,
            &[
                ("x", Input::F32(&x, vec![block, d])),
                ("k_cache", Input::F32(&kc, vec![s, nkv, dh])),
                ("v_cache", Input::F32(&kc, vec![s, nkv, dh])),
                ("pos", Input::I32(&pos, vec![])),
            ],
        )
        .unwrap()
    };
    let dense = run(&format!("layer_dense_t{block}_s{s}"));
    let sparse = run(&format!("layer_sparse_k{f}_t{block}_s{s}"));
    let mut max_err = 0f32;
    for (a, b) in dense[0].data.iter().zip(sparse[0].data.iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err < 1e-5,
        "sparse(K=d_ffn) diverges from dense: max abs err {max_err}"
    );
    // the attention half is literally the same computation
    assert_eq!(dense[1].data, sparse[1].data, "k_new must match");
    assert_eq!(dense[2].data, sparse[2].data, "v_new must match");
}

/// Same invariant through the split ablation pipeline: the external-index
/// sparse FFN over *all* indices equals the dense FFN, and its
/// compensator term is exactly zero.
#[test]
fn ffn_sparse_ext_full_index_set_matches_dense() {
    let engine = testing::cpu_engine();
    let rt = &engine.rt;
    let mm = &rt.manifest.model;
    let (block, d, f) = (mm.block, mm.d_model, mm.d_ffn);
    let mut rng = Rng::new(32);
    let h: Vec<f32> = (0..block * d)
        .map(|_| (rng.normal() * 0.5) as f32)
        .collect();
    let dense = rt
        .run(
            &format!("ffn_dense_t{block}"),
            1,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    let all_idx: Vec<i32> = (0..f as i32).collect();
    let sparse = rt
        .run(
            &format!("ffn_sparse_ext_k{f}_t{block}"),
            1,
            &[
                ("h", Input::F32(&h, vec![block, d])),
                ("idx", Input::I32(&all_idx, vec![f])),
            ],
        )
        .unwrap();
    let mut max_err = 0f32;
    for (a, b) in dense[0].data.iter().zip(sparse[0].data.iter()) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-5, "full-index sparse FFN err {max_err}");
    assert!(
        sparse[1].data.iter().all(|&c| c == 0.0),
        "compensator must be exactly zero when nothing is dropped"
    );
}

/// The sparse FFN is a *partition* of the dense one: contributions of an
/// index set and of its complement sum back to the dense output.
#[test]
fn ffn_partitions_additively() {
    let engine = testing::cpu_engine();
    let rt = &engine.rt;
    let mm = &rt.manifest.model;
    let (block, d, f) = (mm.block, mm.d_model, mm.d_ffn);
    let k = f / 2;
    let mut rng = Rng::new(33);
    let h: Vec<f32> = (0..block * d)
        .map(|_| (rng.normal() * 0.5) as f32)
        .collect();
    // split the experts into evens and odds — maximally interleaved
    let evens: Vec<i32> = (0..f as i32).step_by(2).collect();
    let odds: Vec<i32> = (1..f as i32).step_by(2).collect();
    let run_ffn = |idx: &[i32]| {
        rt.run(
            &format!("ffn_sparse_ext_k{k}_t{block}"),
            2,
            &[
                ("h", Input::F32(&h, vec![block, d])),
                ("idx", Input::I32(idx, vec![idx.len()])),
            ],
        )
        .unwrap()
    };
    let a = run_ffn(&evens);
    let b = run_ffn(&odds);
    let dense = rt
        .run(
            &format!("ffn_dense_t{block}"),
            2,
            &[("h", Input::F32(&h, vec![block, d]))],
        )
        .unwrap();
    for i in 0..block * d {
        // (h + y_evens) + (h + y_odds) - h == h + y_dense
        let sum = a[0].data[i] + b[0].data[i] - h[i];
        let want = dense[0].data[i];
        assert!(
            (sum - want).abs() < 1e-3,
            "partition additivity broken at {i}: {sum} vs {want}"
        );
    }
}

/// The compensator's contract, asserted layer-by-layer: adding the
/// compensation term strictly shrinks the sparse FFN's error against
/// dense (and therefore can never hurt).
#[test]
fn compensator_shrinks_sparse_ffn_error() {
    let engine = testing::cpu_engine();
    let rt = &engine.rt;
    let mm = &rt.manifest.model;
    let (block, d, f) = (mm.block, mm.d_model, mm.d_ffn);
    let k = f / 2;
    let mut rng = Rng::new(34);
    let h: Vec<f32> = (0..block * d)
        .map(|_| (rng.normal() * 0.5) as f32)
        .collect();
    let idx: Vec<i32> = (0..k as i32).collect();
    for layer in 0..mm.n_layers {
        let dense = rt
            .run(
                &format!("ffn_dense_t{block}"),
                layer,
                &[("h", Input::F32(&h, vec![block, d]))],
            )
            .unwrap();
        let sparse = rt
            .run(
                &format!("ffn_sparse_ext_k{k}_t{block}"),
                layer,
                &[
                    ("h", Input::F32(&h, vec![block, d])),
                    ("idx", Input::I32(&idx, vec![k])),
                ],
            )
            .unwrap();
        let l2 = |with_comp: bool| -> f64 {
            let mut acc = 0f64;
            for i in 0..block * d {
                let got = sparse[0].data[i]
                    + if with_comp { sparse[1].data[i] } else { 0.0 };
                let e = (dense[0].data[i] - got) as f64;
                acc += e * e;
            }
            acc.sqrt()
        };
        let (without, with) = (l2(false), l2(true));
        assert!(
            with <= without * 0.95 + 1e-6,
            "layer {layer}: compensator did not shrink the error \
             ({with} vs {without})"
        );
    }
}

/// Algorithm 1 + quantizer, end to end through the engine: the per-layer
/// K schedule the engine actually dispatches achieves the requested
/// density budget (within one ftile), allocates sparsely somewhere, and
/// the executed block mix honors dense_first/dense_last.
#[test]
fn schedule_density_budget_achieved_end_to_end() {
    let engine = testing::test_engine();
    let mm = engine.manifest().model.clone();
    for sp in [0.3, 0.4, 0.5] {
        let cfg = SparsityConfig::fastforward(sp);
        let ks = engine.layer_ks(&cfg).unwrap();
        assert_eq!(ks.len(), mm.n_layers);
        let achieved = alg1::achieved_density(&ks, mm.d_ffn);
        let slack = mm.ftile as f64 / mm.d_ffn as f64;
        assert!(
            achieved <= (1.0 - sp) + slack + 1e-9,
            "sparsity {sp}: achieved density {achieved} exceeds budget"
        );
        assert!(
            ks.iter().any(|&k| k < mm.d_ffn),
            "sparsity {sp}: schedule never sparsifies"
        );
    }
    // block-aligned prompt: first + last blocks dense, interior sparse
    let blocks = 5;
    let prompt = corpus_prompt(blocks * mm.block);
    let pre = engine
        .prefill(&prompt, &SparsityConfig::fastforward(0.5))
        .unwrap();
    assert_eq!(pre.timing.blocks, blocks);
    assert_eq!(pre.timing.tail_tokens, 0);
    assert_eq!(
        pre.timing.dense_blocks, 2,
        "dense_first + dense_last exactly"
    );
}

/// Acceptance invariant: two independent CpuBackend engines (and two
/// consecutive runs of the same engine) produce *byte-identical* logits
/// for the same trace — dense and sparse.
#[test]
fn cpu_backend_prefill_is_byte_identical_across_runs() {
    let a = testing::cpu_engine();
    let b = testing::cpu_engine();
    let prompt = corpus_prompt(300);
    for cfg in [SparsityConfig::dense(), SparsityConfig::fastforward(0.5)]
    {
        let ra = a.prefill(&prompt, &cfg).unwrap();
        let ra2 = a.prefill(&prompt, &cfg).unwrap();
        let rb = b.prefill(&prompt, &cfg).unwrap();
        assert_eq!(ra.last_logits.len(), rb.last_logits.len());
        for i in 0..ra.last_logits.len() {
            assert_eq!(
                ra.last_logits[i].to_bits(),
                ra2.last_logits[i].to_bits(),
                "same engine, consecutive runs: logit {i} differs"
            );
            assert_eq!(
                ra.last_logits[i].to_bits(),
                rb.last_logits[i].to_bits(),
                "independent engines: logit {i} differs"
            );
        }
        // the KV the decode phase reads is identical too
        let n = ra.cache.len * ra.cache.row_elems();
        for l in 0..ra.cache.n_layers {
            assert_eq!(ra.cache.k[l][..n], rb.cache.k[l][..n]);
            assert_eq!(ra.cache.v[l][..n], rb.cache.v[l][..n]);
        }
    }
}

/// All expert sources execute and produce finite logits on the
/// reference backend (trained-weight *orderings* are asserted in the
/// artifact tier below).
#[test]
fn all_expert_sources_execute() {
    let engine = testing::test_engine();
    let prompt = corpus_prompt(3 * engine.block());
    for source in [
        ExpertSource::Trained,
        ExpertSource::Oracle,
        ExpertSource::FirstBlockStatic,
        ExpertSource::Cats,
    ] {
        let mut cfg = SparsityConfig::fastforward(0.5);
        cfg.source = source;
        let pre = engine.prefill(&prompt, &cfg).unwrap();
        assert!(
            pre.last_logits.iter().all(|x| x.is_finite()),
            "{source:?} produced non-finite logits"
        );
    }
}

// ---------------------------------------------------------------------------
// artifact tier (trained-weight assertions; skip without artifacts)
// ---------------------------------------------------------------------------

/// The Rust engine's blockwise dense prefill must reproduce the logits
/// computed by the python model on the same tokens (parity fixture
/// emitted by aot.py) — the strongest cross-language correctness signal.
#[test]
fn dense_prefill_matches_python_fixture() {
    let Some(engine) = testing::artifact_engine() else { return };
    let dir = fastforward::test_artifacts_dir().unwrap();
    let Ok(text) = std::fs::read_to_string(dir.join("parity_fixture.json"))
    else {
        eprintln!("[skip] no parity fixture");
        return;
    };
    let j = json::parse(&text).unwrap();
    let tokens: Vec<i32> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let want: Vec<f64> = j.get("last_logits").unwrap().f64_vec().unwrap();

    let pre = engine.prefill(&tokens, &SparsityConfig::dense()).unwrap();
    assert_eq!(pre.last_logits.len(), want.len());
    let mut max_abs = 0f64;
    let mut max_rel = 0f64;
    for (g, w) in pre.last_logits.iter().zip(want.iter()) {
        let abs = (*g as f64 - w).abs();
        max_abs = max_abs.max(abs);
        max_rel = max_rel.max(abs / (1.0 + w.abs()));
    }
    assert!(
        max_rel < 5e-3,
        "python/rust logits diverge: max_abs={max_abs} max_rel={max_rel}"
    );
}

/// Sparse prefill degrades logits bounded-ly: cosine similarity of the
/// last-position logits vs dense stays high (the whole point of the
/// predictor + compensator), and higher sparsity moves it further.
/// Trained-weight fidelity — artifact tier.
#[test]
fn sparsity_error_is_bounded_and_monotone() {
    let Some(engine) = testing::artifact_engine() else { return };
    let prompt = corpus_prompt(700);

    let dense = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let cos = |a: &[f32], b: &[f32]| {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        dot / (na * nb)
    };
    let mut sims = Vec::new();
    for sp in [0.3, 0.5] {
        let sparse = engine
            .prefill(&prompt, &SparsityConfig::fastforward(sp))
            .unwrap();
        sims.push(cos(&dense.last_logits, &sparse.last_logits));
    }
    assert!(sims[0] > 0.95, "30% sparsity cos sim too low: {}", sims[0]);
    assert!(sims[1] > 0.80, "50% sparsity cos sim too low: {}", sims[1]);
    assert!(
        sims[0] >= sims[1] - 0.02,
        "more sparsity should not increase fidelity: {sims:?}"
    );
}

/// All Table-7 expert sources run and produce finite outputs; the oracle
/// should track dense at least as well as the static baseline.
/// Trained-weight ordering — artifact tier.
#[test]
fn expert_source_ablation_ordering() {
    let Some(engine) = testing::artifact_engine() else { return };
    let prompt = corpus_prompt(700);
    let dense = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let l2 = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum::<f64>()
            .sqrt()
    };
    let mut errs = std::collections::BTreeMap::new();
    for (name, source) in [
        ("oracle", ExpertSource::Oracle),
        ("trained", ExpertSource::Trained),
        ("static", ExpertSource::FirstBlockStatic),
    ] {
        let mut cfg = SparsityConfig::fastforward(0.5);
        cfg.source = source;
        cfg.compensator = false; // isolate the selector (paper Tab. 7)
        let pre = engine.prefill(&prompt, &cfg).unwrap();
        assert!(pre.last_logits.iter().all(|x| x.is_finite()));
        errs.insert(name, l2(&dense.last_logits, &pre.last_logits));
    }
    assert!(
        errs["oracle"] <= errs["static"] * 1.5,
        "oracle should not be much worse than static: {errs:?}"
    );
}

/// Rust Algorithm-1 twin reproduces the python-computed schedule.json
/// (artifact tier: the synthetic manifest's schedule is *generated* by
/// the twin, so only real artifacts make this non-circular).
#[test]
fn rust_schedule_matches_python_schedule() {
    let Some(dir) = fastforward::test_artifacts_dir() else { return };
    let m = fastforward::manifest::Manifest::load(&dir).unwrap();
    for (_, b) in &m.schedule.budgets {
        let dens = alg1::layerwise_schedule(
            &m.schedule.attention_masses,
            1.0 - b.sparsity,
        );
        for (got, want) in dens.iter().zip(b.layer_densities.iter()) {
            assert!(
                (got - want).abs() < 1e-9,
                "alg1 twin drift: {got} vs {want}"
            );
        }
        let ks = alg1::quantize_densities(&dens, m.model.d_ffn,
                                          m.model.ftile);
        assert_eq!(&ks, &b.layer_k);
    }
}
