//! End-to-end integration tests over the real AOT artifacts. Every test
//! skips cleanly when `make artifacts` has not been run.

use std::rc::Rc;

use fastforward::engine::{Engine, PrefillSession, SparsityConfig};
use fastforward::manifest::Manifest;
use fastforward::runtime::Runtime;
use fastforward::sparsity::masks::ExpertSource;
use fastforward::sparsity::schedule as alg1;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::json;
use fastforward::weights::WeightStore;

fn engine() -> Option<Engine> {
    let dir = fastforward::test_artifacts_dir()?;
    let m = Rc::new(Manifest::load(&dir).unwrap());
    let w = Rc::new(WeightStore::load(&m).unwrap());
    let rt = Rc::new(Runtime::new(m, w).unwrap());
    Some(Engine::new(rt))
}

fn corpus_prompt(len: usize) -> Vec<i32> {
    // deterministic pseudo-text prompt (tokenizer byte ids of a-z/space)
    let mut rng = fastforward::util::rng::Rng::new(99);
    let bank = fastforward::trace::WordBank::new(&mut rng, 128);
    let text = bank.filler(&mut rng, len);
    Tokenizer::new(384).encode(&text)
}

/// The Rust engine's blockwise dense prefill must reproduce the logits
/// computed by the python model on the same tokens (parity fixture
/// emitted by aot.py) — the strongest cross-language correctness signal.
#[test]
fn dense_prefill_matches_python_fixture() {
    let Some(engine) = engine() else { return };
    let dir = fastforward::test_artifacts_dir().unwrap();
    let Ok(text) = std::fs::read_to_string(dir.join("parity_fixture.json"))
    else {
        eprintln!("[skip] no parity fixture");
        return;
    };
    let j = json::parse(&text).unwrap();
    let tokens: Vec<i32> = j
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let want: Vec<f64> = j.get("last_logits").unwrap().f64_vec().unwrap();

    let pre = engine.prefill(&tokens, &SparsityConfig::dense()).unwrap();
    assert_eq!(pre.last_logits.len(), want.len());
    let mut max_abs = 0f64;
    let mut max_rel = 0f64;
    for (g, w) in pre.last_logits.iter().zip(want.iter()) {
        let abs = (*g as f64 - w).abs();
        max_abs = max_abs.max(abs);
        max_rel = max_rel.max(abs / (1.0 + w.abs()));
    }
    assert!(
        max_rel < 5e-3,
        "python/rust logits diverge: max_abs={max_abs} max_rel={max_rel}"
    );
}

/// Blockwise prefill through the session API must agree with the one-shot
/// engine prefill (same executables, incremental scheduling).
#[test]
fn session_stepping_equals_oneshot() {
    let Some(engine) = engine() else { return };
    let prompt = corpus_prompt(300);
    let cfg = SparsityConfig::fastforward(0.5);
    let oneshot = engine.prefill(&prompt, &cfg).unwrap();
    let mut s =
        PrefillSession::new(engine.clone(), prompt.clone(), cfg).unwrap();
    let mut steps = 0;
    while !s.done() {
        s.step().unwrap();
        steps += 1;
    }
    assert_eq!(steps, 300 / 128 + 300 % 128);
    let stepped = s.finish().unwrap();
    for (a, b) in oneshot
        .last_logits
        .iter()
        .zip(stepped.last_logits.iter())
    {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

/// Sparse prefill degrades logits bounded-ly: cosine similarity of the
/// last-position logits vs dense stays high (the whole point of the
/// predictor + compensator), and higher sparsity moves it further.
#[test]
fn sparsity_error_is_bounded_and_monotone() {
    let Some(engine) = engine() else { return };
    let prompt = corpus_prompt(700);

    let dense = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let cos = |a: &[f32], b: &[f32]| {
        let dot: f32 = a.iter().zip(b).map(|(x, y)| x * y).sum();
        let na: f32 = a.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = b.iter().map(|x| x * x).sum::<f32>().sqrt();
        dot / (na * nb)
    };
    let mut sims = Vec::new();
    for sp in [0.3, 0.5] {
        let sparse = engine
            .prefill(&prompt, &SparsityConfig::fastforward(sp))
            .unwrap();
        sims.push(cos(&dense.last_logits, &sparse.last_logits));
    }
    assert!(sims[0] > 0.95, "30% sparsity cos sim too low: {}", sims[0]);
    assert!(sims[1] > 0.80, "50% sparsity cos sim too low: {}", sims[1]);
    assert!(
        sims[0] >= sims[1] - 0.02,
        "more sparsity should not increase fidelity: {sims:?}"
    );
}

/// Dense-first/last + tail handling: a prompt under one block must run
/// entirely dense (via tail steps) under every config.
#[test]
fn short_prompts_work_all_configs() {
    let Some(engine) = engine() else { return };
    let prompt = corpus_prompt(40);
    for cfg in [
        SparsityConfig::dense(),
        SparsityConfig::fastforward(0.5),
        {
            let mut c = SparsityConfig::fastforward(0.5);
            c.source = ExpertSource::Oracle;
            c
        },
    ] {
        let pre = engine.prefill(&prompt, &cfg).unwrap();
        assert_eq!(pre.timing.blocks, 0);
        assert_eq!(pre.timing.tail_tokens, 40);
        assert!(pre.last_logits.iter().all(|x| x.is_finite()));
    }
}

/// All Table-7 expert sources run and produce finite outputs; the oracle
/// should track dense at least as well as the static baseline.
#[test]
fn expert_source_ablation_ordering() {
    let Some(engine) = engine() else { return };
    let prompt = corpus_prompt(700);
    let dense = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let l2 = |a: &[f32], b: &[f32]| -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| ((x - y) * (x - y)) as f64)
            .sum::<f64>()
            .sqrt()
    };
    let mut errs = std::collections::BTreeMap::new();
    for (name, source) in [
        ("oracle", ExpertSource::Oracle),
        ("trained", ExpertSource::Trained),
        ("static", ExpertSource::FirstBlockStatic),
    ] {
        let mut cfg = SparsityConfig::fastforward(0.5);
        cfg.source = source;
        cfg.compensator = false; // isolate the selector (paper Tab. 7)
        let pre = engine.prefill(&prompt, &cfg).unwrap();
        assert!(pre.last_logits.iter().all(|x| x.is_finite()));
        errs.insert(name, l2(&dense.last_logits, &pre.last_logits));
    }
    assert!(
        errs["oracle"] <= errs["static"] * 1.5,
        "oracle should not be much worse than static: {errs:?}"
    );
}

/// KV caches returned by prefill support decode continuation.
#[test]
fn prefill_then_decode_runs() {
    let Some(engine) = engine() else { return };
    let prompt = corpus_prompt(200);
    let cfg = SparsityConfig::fastforward(0.5);
    let mut pre = engine.prefill(&prompt, &cfg).unwrap();
    let mut pos = prompt.len();
    let mut logits = pre.last_logits.clone();
    for _ in 0..8 {
        let tok = fastforward::engine::argmax(&logits) as i32;
        logits = engine
            .decode_step(tok, pos, &mut pre.cache, &cfg)
            .unwrap();
        pos += 1;
        assert!(logits.iter().all(|x| x.is_finite()));
    }
}

/// Rust Algorithm-1 twin reproduces the python-computed schedule.json.
#[test]
fn rust_schedule_matches_python_schedule() {
    let Some(dir) = fastforward::test_artifacts_dir() else { return };
    let m = Manifest::load(&dir).unwrap();
    for (_, b) in &m.schedule.budgets {
        let dens = alg1::layerwise_schedule(
            &m.schedule.attention_masses,
            1.0 - b.sparsity,
        );
        for (got, want) in dens.iter().zip(b.layer_densities.iter()) {
            assert!(
                (got - want).abs() < 1e-9,
                "alg1 twin drift: {got} vs {want}"
            );
        }
        let ks = alg1::quantize_densities(&dens, m.model.d_ffn,
                                          m.model.ftile);
        assert_eq!(&ks, &b.layer_k);
    }
}

/// Bucket growth mid-prompt: a prompt crossing the first bucket boundary
/// must produce the same logits as one prefilled after manual inspection
/// (finite + consistent with session restart).
#[test]
fn bucket_growth_is_transparent() {
    let Some(engine) = engine() else { return };
    let m_buckets = engine.manifest().model.buckets.clone();
    let len = m_buckets[0] + 130; // crosses into the second bucket
    let prompt = corpus_prompt(len);
    let a = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    let b = engine.prefill(&prompt, &SparsityConfig::dense()).unwrap();
    assert!(a.last_logits.iter().all(|x| x.is_finite()));
    for (x, y) in a.last_logits.iter().zip(b.last_logits.iter()) {
        assert_eq!(x, y, "prefill must be deterministic");
    }
}
