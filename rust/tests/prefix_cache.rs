//! Prefix-cache correctness: adoption must be numerically invisible.
//!
//! * A session that adopts cached KV blocks produces **bit-identical**
//!   last-position logits to an uncached prefill of the same prompt
//!   (same executables, same inputs — both backends are deterministic).
//! * Adoption actually skips compute: the engine's block-execution
//!   counter (`PrefillTiming::blocks`) stays at zero for a fully-cached
//!   prefix while `adopted_blocks` covers it.
//! * The full pooled stack reuses a prefix across replicas and reports
//!   it in `Response::reused_blocks`.
//!
//! Always-on (docs/TESTING.md): runs against real artifacts + PJRT when
//! present, the deterministic CpuBackend otherwise.

use std::sync::mpsc::channel;
use std::sync::Arc;

use fastforward::batcher::BatcherConfig;
use fastforward::engine::{Engine, PrefillSession, SparsityConfig};
use fastforward::kvcache::{PagedAllocator, PrefixCache};
use fastforward::metrics::Metrics;
use fastforward::router::{LoadEstimator, Response, Router};
use fastforward::testing;

fn prompt_tokens(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = fastforward::util::rng::Rng::new(seed);
    let bank = fastforward::trace::WordBank::new(&mut rng, 64);
    let mut t = fastforward::tokenizer::Tokenizer::new(384)
        .encode(&bank.filler(&mut rng, n + 64));
    t.truncate(n);
    t
}

fn assert_adoption_bit_identical(engine: &Engine, cfg: &SparsityConfig) {
    let block = engine.block();
    let prompt = prompt_tokens(3 * block + block / 2, 11);
    let cold = engine.prefill(&prompt, cfg).unwrap();
    assert_eq!(cold.timing.blocks, 3);
    assert_eq!(cold.timing.adopted_blocks, 0);

    let mut alloc = PagedAllocator::new(1024, block);
    let mut pc = PrefixCache::new(block, 256 << 20);
    // the production seed: config ⊕ model ⊕ backend
    let seed = engine.prefix_seed(cfg);
    let inserted =
        pc.insert(seed, &prompt, usize::MAX, &cold.cache, &mut alloc);
    assert_eq!(inserted, 3);

    let mut warm =
        PrefillSession::new(engine.clone(), prompt.clone(), cfg.clone())
            .unwrap();
    let hit = pc.acquire(seed, &prompt).expect("prefix hit");
    assert_eq!(hit.tokens, 3 * block);
    warm.adopt_prefix(hit.tokens, |cache| hit.copy_into(cache))
        .unwrap();
    pc.release(&hit);
    while !warm.done() {
        warm.step().unwrap();
    }
    let warm = warm.finish().unwrap();

    // engine block-execution counter: nothing re-prefilled
    assert_eq!(warm.timing.blocks, 0, "cached blocks must not re-execute");
    assert_eq!(warm.timing.adopted_blocks, 3);
    assert_eq!(warm.timing.tail_tokens, cold.timing.tail_tokens);

    // bit-identical logits and hidden state
    assert_eq!(
        warm.last_logits, cold.last_logits,
        "adopted-prefix logits must be bit-identical to uncached prefill"
    );
    assert_eq!(warm.last_hidden, cold.last_hidden);
    // and the KV the decode phase will read matches exactly
    for l in 0..cold.cache.n_layers {
        let n = cold.cache.len * cold.cache.row_elems();
        assert_eq!(warm.cache.k[l][..n], cold.cache.k[l][..n]);
        assert_eq!(warm.cache.v[l][..n], cold.cache.v[l][..n]);
    }
}

#[test]
fn adoption_is_bit_identical_dense() {
    let engine = testing::test_engine();
    assert_adoption_bit_identical(&engine, &SparsityConfig::dense());
}

#[test]
fn adoption_is_bit_identical_sparse() {
    let engine = testing::test_engine();
    assert_adoption_bit_identical(
        &engine,
        &SparsityConfig::fastforward(0.5),
    );
}

#[test]
fn configs_never_share_prefixes() {
    let engine = testing::test_engine();
    let block = engine.block();
    let prompt = prompt_tokens(2 * block + 7, 13);
    let dense = SparsityConfig::dense();
    let sparse = SparsityConfig::fastforward(0.5);
    let cold = engine.prefill(&prompt, &dense).unwrap();

    let mut alloc = PagedAllocator::new(256, block);
    let mut pc = PrefixCache::new(block, 64 << 20);
    pc.insert(
        engine.prefix_seed(&dense),
        &prompt,
        usize::MAX,
        &cold.cache,
        &mut alloc,
    );
    assert!(
        pc.acquire(engine.prefix_seed(&sparse), &prompt).is_none(),
        "sparse prefill must not adopt dense KV"
    );
    assert!(pc
        .acquire(engine.prefix_seed(&dense), &prompt)
        .is_some());
}

/// The prefix seed commits to the *backend and model*, not just the
/// sparsity configuration: KV computed by a different model/backend
/// combination is invisible, even under an identical config.
#[test]
fn prefix_seed_is_backend_and_model_aware() {
    let engine = testing::cpu_engine();
    let other = Engine::synthetic_cpu(&fastforward::manifest::SyntheticSpec {
        name: "ff-ref-other".to_string(),
        ..Default::default()
    })
    .unwrap();
    let cfg = SparsityConfig::fastforward(0.5);
    assert_eq!(engine.prefix_seed(&cfg), testing::cpu_engine().prefix_seed(&cfg));
    assert_ne!(
        engine.prefix_seed(&cfg),
        other.prefix_seed(&cfg),
        "different model identity must produce a different seed"
    );
    assert_ne!(
        engine.prefix_seed(&cfg),
        engine.prefix_seed(&SparsityConfig::dense()),
        "different config must produce a different seed"
    );
}

/// Full stack: two replicas, shared prefix cache. The second request
/// (same prompt) adopts the prefix the first one computed — regardless
/// of which replica each lands on — and produces the same text.
#[test]
fn pooled_stack_reuses_prefixes_across_replicas() {
    let probe = testing::test_engine();
    let block = probe.block();
    let max_ctx = probe.manifest().model.max_ctx;
    drop(probe);
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new_pooled(
        32,
        max_ctx,
        1024,
        block,
        metrics.clone(),
        2,
        LoadEstimator::new(block),
        64 << 20,
    ));
    let pool = testing::spawn_test_pool(
        router.clone(),
        BatcherConfig::default(),
    );

    let prompt = prompt_tokens(3 * block + 40, 21);
    let run = |label: &str| -> Response {
        let (tx, rx) = channel();
        router
            .submit(prompt.clone(), 6, SparsityConfig::fastforward(0.5), tx)
            .unwrap();
        let resp = Response::collect_timeout(
            &rx,
            std::time::Duration::from_secs(300),
        )
        .expect(label);
        assert!(resp.error.is_none(), "{label}: {:?}", resp.error);
        resp
    };

    let first = run("first request");
    assert_eq!(first.reused_blocks, 0, "cold request adopts nothing");
    let second = run("second request");
    assert_eq!(
        second.reused_blocks, 3,
        "identical prompt must adopt all three cached blocks"
    );
    assert_eq!(
        second.text, first.text,
        "prefix adoption must not change the generation"
    );

    let (hits, _misses, reused) = metrics.prefix_counters();
    assert_eq!(hits, 1);
    assert_eq!(reused, 3);
    // executed blocks: 3 cold + 0 warm
    assert_eq!(metrics.blocks_executed(), 3);

    router.close();
    pool.join().unwrap();
    assert_eq!(router.kv_pool.lock().unwrap().used_pages(),
               router.prefix_cache.lock().unwrap().entry_count(),
               "only prefix-cache residency may remain after drain");
}
