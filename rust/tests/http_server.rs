//! HTTP server integration: boots the full serve stack on an ephemeral
//! port and exercises /generate, /metrics, /healthz with a raw TCP
//! client. Skips without artifacts.

use std::io::{Read, Write};
use std::net::TcpStream;

use std::sync::Arc;

use fastforward::batcher::{Batcher, BatcherConfig};
use fastforward::engine::Engine;
use fastforward::manifest::Manifest;
use fastforward::metrics::Metrics;
use fastforward::router::Router;
use fastforward::runtime::Runtime;
use fastforward::server::{Lifecycle, Server, DEFAULT_HEADER_TIMEOUT};
use fastforward::tokenizer::Tokenizer;
use fastforward::util::json;
use fastforward::weights::WeightStore;

fn http(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(req.as_bytes()).unwrap();
    let mut out = String::new();
    s.read_to_string(&mut out).unwrap();
    out
}

fn post(addr: &str, path: &str, body: &str) -> String {
    http(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn get(addr: &str, path: &str) -> String {
    http(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

#[test]
fn full_http_stack() {
    let Some(dir) = fastforward::test_artifacts_dir() else { return };
    let metrics = Arc::new(Metrics::new());
    let router = Arc::new(Router::new(16, 4096, 256, 128, metrics.clone()));

    // executor thread
    let r2 = router.clone();
    let d2 = dir.clone();
    let exec = std::thread::spawn(move || {
        let m = Arc::new(Manifest::load(&d2).unwrap());
        let w = Arc::new(WeightStore::load(&m).unwrap());
        let rt = Arc::new(Runtime::new(m, w).unwrap());
        Batcher::new(Engine::new(rt), r2, BatcherConfig::default())
            .run()
            .unwrap();
    });

    // server on an ephemeral port (bind first to learn the port)
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // Server re-binds; tiny race is acceptable in tests
    let server = Arc::new(Server {
        router: router.clone(),
        metrics: metrics.clone(),
        tokenizer: Tokenizer::new(384),
        default_sparsity: Some(0.5),
        default_attn_sparsity: None,
        default_token_keep: None,
        lifecycle: Lifecycle::new(),
        header_timeout: DEFAULT_HEADER_TIMEOUT,
    });
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let _ = server.serve(&addr2);
    });
    std::thread::sleep(std::time::Duration::from_millis(200));

    // healthz
    let h = get(&addr, "/healthz");
    assert!(h.starts_with("HTTP/1.1 200"), "{h}");

    // generate (sparse default)
    let resp = post(
        &addr,
        "/generate",
        r#"{"prompt": "the cat sat on the mat and the", "max_tokens": 4}"#,
    );
    assert!(resp.starts_with("HTTP/1.1 200"), "{resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap();
    let j = json::parse(body).unwrap();
    assert!(j.get("ttft_ms").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(j.get("error").unwrap(), &json::Json::Null);

    // bad json → 400
    let bad = post(&addr, "/generate", "{nope");
    assert!(bad.starts_with("HTTP/1.1 400"), "{bad}");

    // oversized prompt → 400 with reason
    let huge = format!(
        r#"{{"prompt": "{}", "max_tokens": 4}}"#,
        "a".repeat(5000)
    );
    let rej = post(&addr, "/generate", &huge);
    assert!(rej.starts_with("HTTP/1.1 400"), "{rej}");

    // metrics reflect the completed request
    let m = get(&addr, "/metrics");
    assert!(m.contains("ff_requests_completed 1"), "{m}");

    // unknown path → 404
    assert!(get(&addr, "/nope").starts_with("HTTP/1.1 404"));

    router.close();
    exec.join().unwrap();
}
