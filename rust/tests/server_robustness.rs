//! HTTP front-end robustness: request-size caps, slow-loris deadlines
//! and the drain lifecycle — host-only (stub executor, no artifacts),
//! over real TCP connections so the wire behavior is what's asserted.
//!
//! * bodies larger than `MAX_BODY_BYTES` are refused with 413 from the
//!   `Content-Length` header alone — before the server reads (or
//!   allocates for) a single body byte;
//! * a connection that stalls mid-header is answered 408 and closed
//!   within `Server::header_timeout`, so idle sockets can't pin
//!   connection threads forever;
//! * `POST /admin/drain` flips `/healthz` and `/readyz` to 503 and
//!   refuses new `/generate` work while `/metrics` stays observable;
//! * `/readyz` (the cluster health-checker's probe) goes 503 when every
//!   replica is dead, while `/healthz` liveness stays 200.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use fastforward::metrics::Metrics;
use fastforward::router::{Response, Router, TokenEvent};
use fastforward::server::{Lifecycle, Server, DEFAULT_HEADER_TIMEOUT,
                          MAX_BODY_BYTES};
use fastforward::tokenizer::Tokenizer;

/// Stub stack: a real `Server` over a real `Router`, with the executor
/// side played by a thread that echoes each prompt token — the full
/// HTTP surface with no engine.
struct Stub {
    router: Arc<Router>,
    exec: std::thread::JoinHandle<()>,
    addr: String,
}

fn start_stub(header_timeout: Duration) -> Stub {
    let metrics = Arc::new(Metrics::new());
    let router =
        Arc::new(Router::new(16, 4096, 256, 128, metrics.clone()));
    let r2 = router.clone();
    let exec = std::thread::spawn(move || {
        while let Some(req) = r2.pop_blocking() {
            let mut done = Response::failed(req.id, String::new());
            done.error = None;
            done.text = "ok".to_string();
            done.tokens = 1;
            let _ = req.events.send(TokenEvent::Done(done));
        }
    });
    let server = Arc::new(Server {
        router: router.clone(),
        metrics,
        tokenizer: Tokenizer::new(384),
        default_sparsity: None,
        default_attn_sparsity: None,
        default_token_keep: None,
        lifecycle: Lifecycle::new(),
        header_timeout,
    });
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // reserve-release: the server re-binds momentarily
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let _ = server.serve(&addr2);
    });
    std::thread::sleep(Duration::from_millis(200));
    Stub { router, exec, addr }
}

impl Stub {
    fn shutdown(self) {
        self.router.close();
        self.exec.join().unwrap();
    }
}

fn request(addr: &str, raw: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(raw.as_bytes()).unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    out
}

fn get(addr: &str, path: &str) -> String {
    request(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n"))
}

fn post(addr: &str, path: &str, body: &str) -> String {
    request(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: x\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

#[test]
fn oversized_body_is_rejected_413_before_read() {
    let stub = start_stub(DEFAULT_HEADER_TIMEOUT);
    // claim a body one byte over the cap but never send it: the 413
    // must come from the Content-Length header alone
    let t0 = Instant::now();
    let raw = request(
        &stub.addr,
        &format!(
            "POST /generate HTTP/1.1\r\nHost: x\r\n\
             Content-Length: {}\r\n\r\n",
            MAX_BODY_BYTES + 1
        ),
    );
    assert!(raw.starts_with("HTTP/1.1 413"), "{raw}");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "413 must not wait for the body"
    );
    // a request at the boundary still parses (and fails later on JSON,
    // not on size) — the cap is exclusive of valid maximum-size bodies
    let raw = post(&stub.addr, "/generate", "{\"prompt\":\"hi\"}");
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    stub.shutdown();
}

#[test]
fn stalled_headers_time_out_408() {
    let stub = start_stub(Duration::from_millis(300));
    let t0 = Instant::now();
    let mut s = TcpStream::connect(&stub.addr).unwrap();
    // a slow-loris client: half a request line, then silence
    s.write_all(b"POST /generate HTTP/1.1\r\nContent-Le").unwrap();
    let mut out = String::new();
    let _ = s.read_to_string(&mut out);
    let waited = t0.elapsed();
    assert!(out.starts_with("HTTP/1.1 408"), "{out}");
    assert!(
        waited >= Duration::from_millis(250),
        "timed out suspiciously early ({waited:?})"
    );
    assert!(
        waited < Duration::from_secs(10),
        "stalled connection held its thread for {waited:?}"
    );
    // the connection thread is free again: a well-formed request on a
    // fresh connection works immediately
    let raw = post(&stub.addr, "/generate", "{\"prompt\":\"hi\"}");
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");
    stub.shutdown();
}

#[test]
fn drain_flips_health_and_refuses_new_work() {
    let stub = start_stub(DEFAULT_HEADER_TIMEOUT);
    assert!(get(&stub.addr, "/healthz").starts_with("HTTP/1.1 200"));
    assert!(get(&stub.addr, "/readyz").starts_with("HTTP/1.1 200"));

    let raw = post(&stub.addr, "/admin/drain", "");
    assert!(raw.starts_with("HTTP/1.1 200"), "{raw}");

    // load balancers and the cluster health-checker both see 503 now
    let health = get(&stub.addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 503"), "{health}");
    assert!(health.contains("draining"), "{health}");
    assert!(get(&stub.addr, "/readyz").starts_with("HTTP/1.1 503"));

    // new work is refused...
    let gen = post(&stub.addr, "/generate", "{\"prompt\":\"hi\"}");
    assert!(gen.starts_with("HTTP/1.1 503"), "{gen}");
    assert!(gen.contains("draining"), "{gen}");

    // ...but observability survives the drain
    let metrics = get(&stub.addr, "/metrics");
    assert!(metrics.starts_with("HTTP/1.1 200"), "{metrics}");
    assert!(metrics.contains("ff_"), "{metrics}");
    stub.shutdown();
}

#[test]
fn readyz_requires_a_live_replica() {
    let stub = start_stub(DEFAULT_HEADER_TIMEOUT);
    assert!(get(&stub.addr, "/readyz").starts_with("HTTP/1.1 200"));
    stub.router.replica(0).mark_dead("executor crashed");
    // alive (the process runs) but not ready (nothing can serve)
    assert!(get(&stub.addr, "/healthz").starts_with("HTTP/1.1 200"));
    let ready = get(&stub.addr, "/readyz");
    assert!(ready.starts_with("HTTP/1.1 503"), "{ready}");
    assert!(ready.contains("no replicas accepting"), "{ready}");
    stub.shutdown();
}
