//! Cluster front-tier integration: consistent-hash prefix-affinity
//! dispatch, quota/shed admission, health-checked lifecycle and the
//! backplane retry — all over real loopback TCP, with the workers
//! played by in-process `Server` stacks (stub executors, no engine) so
//! every case is deterministic and artifact-free. The multi-*process*
//! version of this surface is the fig15 bench and the
//! `cluster_affinity_beats_random_dispatch` perf gate.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastforward::cluster::policy::HashRing;
use fastforward::cluster::{http_get, http_post, ClusterConfig,
                           ClusterFront, DispatchMode};
use fastforward::metrics::Metrics;
use fastforward::router::{Response, Router, TokenEvent};
use fastforward::server::{Lifecycle, Server, DEFAULT_HEADER_TIMEOUT};
use fastforward::testing;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::json;

const TIMEOUT: Duration = Duration::from_secs(10);

/// An in-process worker: a real `Server` whose executor is a stub
/// thread, plus a served-request counter so dispatch tests can see
/// which worker took what.
struct StubWorker {
    router: Arc<Router>,
    exec: std::thread::JoinHandle<()>,
    addr: String,
    served: Arc<AtomicUsize>,
}

fn start_worker() -> StubWorker {
    let metrics = Arc::new(Metrics::new());
    let router =
        Arc::new(Router::new(64, 4096, 256, 128, metrics.clone()));
    let served = Arc::new(AtomicUsize::new(0));
    let (r2, s2) = (router.clone(), served.clone());
    let exec = std::thread::spawn(move || {
        while let Some(req) = r2.pop_blocking() {
            s2.fetch_add(1, Ordering::AcqRel);
            let mut done = Response::failed(req.id, String::new());
            done.error = None;
            done.text = "ok".to_string();
            done.tokens = 1;
            let _ = req.events.send(TokenEvent::Done(done));
        }
    });
    let server = Arc::new(Server {
        router: router.clone(),
        metrics,
        tokenizer: Tokenizer::new(384),
        default_sparsity: None,
        default_attn_sparsity: None,
        default_token_keep: None,
        lifecycle: Lifecycle::new(),
        header_timeout: DEFAULT_HEADER_TIMEOUT,
    });
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    drop(listener); // reserve-release: the server re-binds momentarily
    let addr2 = addr.clone();
    std::thread::spawn(move || {
        let _ = server.serve(&addr2);
    });
    fastforward::cluster::wait_ready(&addr, Duration::from_secs(30))
        .expect("stub worker ready");
    StubWorker { router, exec, addr, served }
}

impl StubWorker {
    fn served(&self) -> usize {
        self.served.load(Ordering::Acquire)
    }

    fn shutdown(self) {
        self.router.close();
        self.exec.join().unwrap();
    }
}

fn cfg(dispatch: DispatchMode) -> ClusterConfig {
    ClusterConfig {
        dispatch,
        connect_timeout: Duration::from_millis(500),
        proxy_read_timeout: Duration::from_secs(10),
        ..ClusterConfig::default()
    }
}

fn front_over(workers: &[&StubWorker], cfg: ClusterConfig)
              -> (Arc<ClusterFront>, String) {
    let front = ClusterFront::new(
        workers.iter().map(|w| w.addr.clone()).collect(),
        cfg,
        Arc::new(Metrics::new()),
    );
    let (addr, _handle) =
        front.clone().spawn("127.0.0.1:0").expect("front binds");
    (front, addr.to_string())
}

fn gen_body(prompt: &str) -> String {
    format!("{{\"prompt\":\"{prompt}\",\"max_tokens\":2}}")
}

#[test]
fn front_proxies_generate_and_streams_end_to_end() {
    let w0 = start_worker();
    let w1 = start_worker();
    let (front, addr) =
        front_over(&[&w0, &w1], cfg(DispatchMode::Affinity));

    // one-shot JSON passes through the backplane byte-for-byte
    let (status, body) =
        http_post(&addr, "/generate", &gen_body("hello cluster"),
                  TIMEOUT)
            .unwrap();
    assert_eq!(status, 200, "{body}");
    let j = json::parse(&body).expect("proxied json");
    assert_eq!(j.get("text").and_then(|t| t.as_str()), Some("ok"));

    // an SSE stream proxies identically (Connection: close framing)
    let (status, body) = http_post(
        &addr,
        "/generate",
        "{\"prompt\":\"abc\",\"max_tokens\":2,\"stream\":true}",
        TIMEOUT,
    )
    .unwrap();
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("event: done"), "SSE frames survive: {body}");

    // front health + metrics surface
    let (status, _) = http_get(&addr, "/healthz", TIMEOUT).unwrap();
    assert_eq!(status, 200);
    let (status, _) = http_get(&addr, "/readyz", TIMEOUT).unwrap();
    assert_eq!(status, 200);
    let (status, metrics) = http_get(&addr, "/metrics", TIMEOUT).unwrap();
    assert_eq!(status, 200);
    assert!(metrics.contains("ff_cluster_dispatch_total"), "{metrics}");

    let (affine, fallback, random) = front.metrics.cluster_dispatches();
    assert_eq!(affine + fallback, 2, "both requests were dispatched");
    assert_eq!(random, 0);
    assert_eq!(w0.served() + w1.served(), 2);
    front.stop();
    w0.shutdown();
    w1.shutdown();
}

#[test]
fn affinity_pins_documents_and_random_does_not_starve() {
    let w0 = start_worker();
    let w1 = start_worker();
    let base = cfg(DispatchMode::Affinity);
    let (front, addr) = front_over(&[&w0, &w1], base.clone());

    // pre-balanced docs: 2 pin to each worker by construction
    let docs = testing::balanced_cluster_docs(&base, 2, 4,
                                              base.key_blocks * 128);
    // same document repeated → same worker every time
    for _ in 0..3 {
        let (status, _) =
            http_post(&addr, "/generate", &gen_body(&docs[0]), TIMEOUT)
                .unwrap();
        assert_eq!(status, 200);
    }
    let pinned = [w0.served(), w1.served()];
    assert!(
        pinned == [3, 0] || pinned == [0, 3],
        "one document must pin to exactly one worker, got {pinned:?}"
    );

    // the full balanced set touches both workers
    for d in &docs {
        let (status, _) =
            http_post(&addr, "/generate", &gen_body(d), TIMEOUT)
                .unwrap();
        assert_eq!(status, 200);
    }
    assert!(w0.served() > 0 && w1.served() > 0,
            "balanced docs must reach both workers");
    let (affine, fallback, _) = front.metrics.cluster_dispatches();
    assert_eq!(affine, 7, "unloaded cluster routes everything affine");
    assert_eq!(fallback, 0);
    front.stop();
    w0.shutdown();
    w1.shutdown();
}

#[test]
fn tenant_quota_sheds_with_429() {
    let w0 = start_worker();
    let (front, addr) = front_over(
        &[&w0],
        ClusterConfig {
            quota_rps: 0.001, // refill ~never within the test
            quota_burst: 2.0,
            ..cfg(DispatchMode::Affinity)
        },
    );

    let body = "{\"prompt\":\"hi\",\"tenant\":\"hot\"}";
    for _ in 0..2 {
        let (status, _) =
            http_post(&addr, "/generate", body, TIMEOUT).unwrap();
        assert_eq!(status, 200, "burst allowance admits");
    }
    let (status, resp) =
        http_post(&addr, "/generate", body, TIMEOUT).unwrap();
    assert_eq!(status, 429, "{resp}");
    assert!(resp.contains("over quota"), "{resp}");

    // quotas are per-tenant: another tenant is unaffected
    let (status, _) = http_post(
        &addr,
        "/generate",
        "{\"prompt\":\"hi\",\"tenant\":\"cold\"}",
        TIMEOUT,
    )
    .unwrap();
    assert_eq!(status, 200);

    let (_, metrics) = http_get(&addr, "/metrics", TIMEOUT).unwrap();
    assert!(
        metrics.contains("ff_cluster_quota_rejects_total 1"),
        "{metrics}"
    );
    assert!(
        metrics.contains("ff_cluster_sheds_total{code=\"429\"} 1"),
        "{metrics}"
    );
    front.stop();
    w0.shutdown();
}

#[test]
fn health_checker_routes_around_dead_worker() {
    let w0 = start_worker();
    let dead = testing::free_addr(); // reserved, nobody listening
    let base = cfg(DispatchMode::Affinity);
    let fail_threshold = base.fail_threshold;
    let (front, addr) = front_over_addrs(
        vec![w0.addr.clone(), dead],
        base,
    );

    // drive the checker deterministically instead of sleeping
    for _ in 0..fail_threshold {
        front.probe_workers();
    }
    assert!(front.workers()[0].healthy());
    assert!(!front.workers()[1].healthy(), "dead worker marked");

    // ≥1 routable worker → the front stays ready, and every request
    // lands on the survivor regardless of its affine key
    let (status, _) = http_get(&addr, "/readyz", TIMEOUT).unwrap();
    assert_eq!(status, 200);
    for i in 0..4 {
        let (status, _) = http_post(
            &addr,
            "/generate",
            &gen_body(&format!("doc number {i}")),
            TIMEOUT,
        )
        .unwrap();
        assert_eq!(status, 200);
    }
    assert_eq!(w0.served(), 4);

    // kill the survivor too: the front reports unready and sheds 503
    w0.router.replica(0).mark_dead("gone");
    for _ in 0..fail_threshold {
        front.probe_workers();
    }
    let (status, _) = http_get(&addr, "/readyz", TIMEOUT).unwrap();
    assert_eq!(status, 503);
    let (status, resp) =
        http_post(&addr, "/generate", &gen_body("x"), TIMEOUT).unwrap();
    assert_eq!(status, 503, "{resp}");
    front.stop();
    w0.shutdown();
}

/// [`front_over`] for raw addresses (dead-worker cases where no
/// `StubWorker` exists).
fn front_over_addrs(addrs: Vec<String>, cfg: ClusterConfig)
                    -> (Arc<ClusterFront>, String) {
    let front = ClusterFront::new(addrs, cfg, Arc::new(Metrics::new()));
    let (addr, _handle) =
        front.clone().spawn("127.0.0.1:0").expect("front binds");
    (front, addr.to_string())
}

#[test]
fn backplane_retry_recovers_from_unprobed_death() {
    // worker 0 is dead but still *believed* healthy (no probe has run):
    // the kill-restart window. A request whose affine worker is the
    // dead one must be retried on the survivor, not failed.
    let live = start_worker();
    let dead = testing::free_addr();
    // keep the background checker out of the way: this test *wants*
    // the stale-health window, and a slow machine must not let probes
    // retire worker 0 before the request arrives
    let base = ClusterConfig {
        health_interval: Duration::from_secs(60),
        fail_threshold: 1000,
        ..cfg(DispatchMode::Affinity)
    };

    // find a prompt whose ring slot is worker 0 (the dead one)
    let ring = HashRing::new(2, base.vnodes);
    let tok = Tokenizer::new(base.vocab);
    let prompt = (0..64u64)
        .map(|i| testing::ascii_doc_text(7000 + i, base.key_blocks * 128))
        .find(|p| {
            let key = fastforward::kvcache::routing_key(
                base.routing_seed,
                &tok.encode(p),
                base.block,
                base.key_blocks,
            );
            ring.assign(key, |_| true) == Some(0)
        })
        .expect("some doc keys to slot 0");

    let (front, addr) =
        front_over_addrs(vec![dead, live.addr.clone()], base);
    let (status, body) =
        http_post(&addr, "/generate", &gen_body(&prompt), TIMEOUT)
            .unwrap();
    assert_eq!(status, 200, "retry must recover: {body}");
    assert_eq!(live.served(), 1);
    assert!(!front.workers()[0].healthy(),
            "connect failure is a death signal — no probe needed");

    let (_, metrics) = http_get(&addr, "/metrics", TIMEOUT).unwrap();
    assert!(metrics.contains("ff_cluster_retries_total 1"), "{metrics}");
    assert!(
        metrics.contains("ff_cluster_backplane_errors_total 1"),
        "{metrics}"
    );
    front.stop();
    live.shutdown();
}

#[test]
fn draining_worker_is_retired_by_probes() {
    let w0 = start_worker();
    let w1 = start_worker();
    let base = cfg(DispatchMode::Affinity);
    let fail_threshold = base.fail_threshold;
    let (front, addr) = front_over(&[&w0, &w1], base.clone());

    // drain worker 1 (the operator runbook: POST /admin/drain, wait for
    // the front to retire it, then stop the process)
    let (status, _) =
        http_post(&w1.addr, "/admin/drain", "", TIMEOUT).unwrap();
    assert_eq!(status, 200);
    for _ in 0..fail_threshold {
        front.probe_workers();
    }
    assert!(!front.workers()[1].healthy(), "draining worker retired");

    // all traffic — including worker 1's affine documents — now flows
    // to worker 0, with zero client-visible errors
    let docs = testing::balanced_cluster_docs(&base, 2, 4,
                                              base.key_blocks * 128);
    for d in &docs {
        let (status, _) =
            http_post(&addr, "/generate", &gen_body(d), TIMEOUT)
                .unwrap();
        assert_eq!(status, 200);
    }
    assert_eq!(w0.served(), 4);
    assert_eq!(w1.served(), 0);
    front.stop();
    w0.shutdown();
    w1.shutdown();
}
