//! Seeded fuzz tests for the block-sparse attention path: ragged
//! prompt lengths around the attention-block and prefill-block
//! boundaries, random drop levels, and decode-after-sparse-prefill.
//!
//! Complements the conformance suite (`backend_conformance.rs`), which
//! pins the bit-identity oracle at fixed lengths: here the lengths and
//! drops are drawn from a seeded generator, so every run explores the
//! same adversarial neighbourhood of the boundary arithmetic —
//! off-by-one prompt tails, chunks whose last attention block is
//! clamped by the causal frontier, and decode steps stacked on KV that
//! a sparse prefill produced.
//!
//! Every suite draws from `testing::fuzz_seed`: failure messages carry
//! the RNG seed, and exporting `FF_TEST_SEED=<seed>` replays exactly
//! that case deterministically.

use fastforward::engine::{argmax, Engine, SparsityConfig};
use fastforward::testing;
use fastforward::tokenizer::Tokenizer;
use fastforward::util::rng::Rng;

fn fuzz_prompt(rng: &mut Rng, len: usize) -> Vec<i32> {
    let bank = fastforward::trace::WordBank::new(rng, 128);
    let text = bank.filler(rng, len);
    let mut toks = Tokenizer::new(384).encode(&text);
    toks.truncate(len);
    while toks.len() < len {
        toks.push(b' ' as i32);
    }
    toks
}

fn attn_cfg(drop: f64) -> SparsityConfig {
    let mut cfg = SparsityConfig::dense();
    cfg.attn_sparsity = Some(drop);
    cfg
}

/// Lengths clustered around multiples of the attention block size,
/// ±2 — the seams where pooling, causal clamping and the ragged tail
/// hand over to each other.
fn boundary_len(rng: &mut Rng, ab: usize, max_ctx: usize) -> usize {
    let m = rng.range(1, (max_ctx / ab).min(8));
    let jitter = rng.range_i64(-2, 3);
    ((m * ab) as i64 + jitter).clamp(1, max_ctx as i64) as usize
}

/// Random drops at random boundary-straddling lengths: every logit and
/// every KV row of a sparse-attention prefill is finite. The sink +
/// local band guarantees a non-empty softmax support for every query
/// row, so no NaN can enter through an empty reduction.
#[test]
fn fuzz_sparse_prefill_is_finite() {
    let engine = testing::cpu_engine();
    let m = engine.manifest().model.clone();
    let seed = testing::fuzz_seed(0xA77_F022);
    let mut rng = Rng::new(seed);
    for _ in 0..12 {
        let len = boundary_len(&mut rng, m.attn_block, m.max_ctx);
        let drop = rng.f64();
        let prompt = fuzz_prompt(&mut rng, len);
        let pre = engine.prefill(&prompt, &attn_cfg(drop)).unwrap();
        assert!(
            pre.last_logits.iter().all(|v| v.is_finite()),
            "non-finite logit at len={len} drop={drop:.3} — replay \
             with FF_TEST_SEED={seed:#x}"
        );
        let elems = pre.cache.len * pre.cache.row_elems();
        for l in 0..pre.cache.n_layers {
            assert!(
                pre.cache.k[l][..elems].iter().all(|v| v.is_finite())
                    && pre.cache.v[l][..elems]
                        .iter()
                        .all(|v| v.is_finite()),
                "non-finite KV at layer {l} len={len} drop={drop:.3} \
                 — replay with FF_TEST_SEED={seed:#x}"
            );
        }
    }
}

/// Decode over all-blocks-sparse-prefilled KV is bit-identical to
/// decode over dense-prefilled KV: with `attn_sparsity = 0.0` the
/// prefill KV is dense KV (accumulation-order contract), and decode
/// steps are always dense-attention, so the whole decode trajectory
/// must coincide — at fuzzed boundary lengths.
#[test]
fn fuzz_decode_after_full_coverage_prefill_matches_dense() {
    let engine = testing::cpu_engine();
    let m = engine.manifest().model.clone();
    let seed = testing::fuzz_seed(0xA77_D0DE);
    let mut rng = Rng::new(seed);
    let dense_cfg = SparsityConfig::dense();
    let full_cfg = attn_cfg(0.0);
    for _ in 0..6 {
        let len = boundary_len(&mut rng, m.attn_block, m.max_ctx / 2);
        let prompt = fuzz_prompt(&mut rng, len);
        let mut a = engine.prefill(&prompt, &dense_cfg).unwrap();
        let mut b = engine.prefill(&prompt, &full_cfg).unwrap();
        let mut la = a.last_logits.clone();
        let mut lb = b.last_logits.clone();
        let mut pos = len;
        for step in 0..3 {
            for j in 0..la.len() {
                assert_eq!(
                    la[j].to_bits(),
                    lb[j].to_bits(),
                    "len={len} step {step}: logit {j} diverged — \
                     replay with FF_TEST_SEED={seed:#x}"
                );
            }
            let tok = argmax(&la) as i32;
            la = engine
                .decode_step(tok, pos, &mut a.cache, &dense_cfg)
                .unwrap();
            lb = engine
                .decode_step(tok, pos, &mut b.cache, &full_cfg)
                .unwrap();
            pos += 1;
        }
    }
}

/// Decode after a *genuinely* sparse prefill stays finite and
/// deterministic: two identical prefill+decode trajectories agree bit
/// for bit (selection is sequential and seeded only by the data).
#[test]
fn fuzz_decode_after_sparse_prefill_is_deterministic() {
    let engine = testing::cpu_engine();
    let m = engine.manifest().model.clone();
    let seed = testing::fuzz_seed(0xA77_5EED);
    let mut rng = Rng::new(seed);
    for _ in 0..4 {
        let len = boundary_len(&mut rng, m.attn_block, m.max_ctx / 2);
        let drop = 0.25 + rng.f64() * 0.75;
        let prompt = fuzz_prompt(&mut rng, len);
        let cfg = attn_cfg(drop);
        let run = |engine: &Engine| -> Vec<Vec<f32>> {
            let mut pre = engine.prefill(&prompt, &cfg).unwrap();
            let mut logits = pre.last_logits.clone();
            let mut pos = len;
            let mut hist = vec![logits.clone()];
            for _ in 0..3 {
                let tok = argmax(&logits) as i32;
                logits = engine
                    .decode_step(tok, pos, &mut pre.cache, &cfg)
                    .unwrap();
                pos += 1;
                hist.push(logits.clone());
            }
            hist
        };
        let first = run(&engine);
        let second = run(&engine);
        for (step, (wa, wb)) in
            first.iter().zip(second.iter()).enumerate()
        {
            for j in 0..wa.len() {
                assert!(
                    wa[j].is_finite(),
                    "len={len} drop={drop:.3} step {step}: non-finite \
                     — replay with FF_TEST_SEED={seed:#x}"
                );
                assert_eq!(
                    wa[j].to_bits(),
                    wb[j].to_bits(),
                    "len={len} drop={drop:.3} step {step}: logit {j} \
                     not deterministic — replay with \
                     FF_TEST_SEED={seed:#x}"
                );
            }
        }
    }
}
