//! Deterministic perf smoke tests (tier-1): on the CPU backend, sparse
//! prefill must be measurably faster than dense prefill — the paper's
//! headline claim, checkable on any machine with no artifacts.
//!
//! Methodology: an FFN-dominated synthetic model (d_ffn ≫ d_model, two
//! layers — paper models are FFN-bound at prefill, §1), fixed seeds and
//! prompts, best-of-N wall-clock per configuration, and a *generous*
//! threshold far under the compute-bound ratio (~1.4× at 50% here), so
//! scheduler noise cannot flake the gate. The sparse config disables
//! the compensator: the reference compensator recomputes every dropped
//! neuron exactly (dense cost by construction — see runtime/cpu.rs),
//! whereas the paper's trained low-rank compensator is a negligible
//! overhead; the nc path is the faithful compute profile.
//!
//! Skipped with a message on single-core machines, where wall-clock
//! smoke timing is at the scheduler's mercy.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use fastforward::engine::{Engine, SparsityConfig};
use fastforward::manifest::SyntheticSpec;
use fastforward::runtime::{CpuKernel, CpuOptions};
use fastforward::sparsity::masks::ExpertSource;
use fastforward::testing;

/// libtest runs the tests of this binary on parallel threads by
/// default; two wall-clock gates timing each other's CPU load would
/// flake. Every perf test holds this gate for its full duration so the
/// measurements never overlap.
static GATE: Mutex<()> = Mutex::new(());

fn hold_gate() -> std::sync::MutexGuard<'static, ()> {
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// FFN-heavy bench model: dense FFN work (3·d·d_ffn per token per
/// layer) dominates attention, as in the paper's compute regime.
fn perf_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-1k".to_string(),
        n_layers: 2,
        d_ffn: 1024,
        max_ctx: 1024,
        buckets: vec![512, 1024],
        ..SyntheticSpec::default()
    }
}

/// Uniform 50% sparsity, every block sparse, no compensator (see
/// module docs), trained low-rank predictor.
fn sparse_cfg() -> SparsityConfig {
    SparsityConfig {
        sparsity: Some(0.5),
        layerwise: false,
        dense_first: false,
        dense_last: false,
        compensator: false,
        source: ExpertSource::Trained,
        sparse_decode: false,
        attn_sparsity: None,
        token_keep_ratio: None,
    }
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i % 250) as i32 + 1).collect()
}

fn best_of(n: usize, mut f: impl FnMut()) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..n {
        let t0 = Instant::now();
        f();
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Wall-clock gates need a minimum core count; on smaller machines each
/// gate reports itself SKIPPED by name — an explicit line per gate, so
/// a CI log shows exactly which perf claims went unmeasured instead of
/// a silently green run.
fn skip_under_cores(gate: &str, need: usize) -> bool {
    let n = cores();
    if n >= need {
        return false;
    }
    eprintln!(
        "[perf] {gate}: SKIPPED ({n} cores) — needs >= {need} for \
         stable wall-clock timing"
    );
    true
}

/// The single-process gates' threshold: ≥ 2 cores.
fn skip_few_cores(gate: &str) -> bool {
    skip_under_cores(gate, 2)
}

fn measure_speedup(engine: &Engine, len: usize, reps: usize) -> f64 {
    let toks = prompt(len);
    let dense_cfg = SparsityConfig::dense();
    let cfg = sparse_cfg();
    // warmup both paths (thread pool spin-up, op-cache fill)
    engine.prefill(&toks, &dense_cfg).unwrap();
    engine.prefill(&toks, &cfg).unwrap();
    let dense = best_of(reps, || {
        engine.prefill(&toks, &dense_cfg).unwrap();
    });
    let sparse = best_of(reps, || {
        engine.prefill(&toks, &cfg).unwrap();
    });
    eprintln!(
        "[perf] len={len}: dense {:.1} ms, sparse(50%, nc) {:.1} ms, \
         speedup {:.2}x",
        dense * 1e3,
        sparse * 1e3,
        dense / sparse
    );
    dense / sparse
}

/// The acceptance gate: 50% sparse prefill ≥ 1.15× faster than dense
/// at T = 512 (compute-bound expectation ≈ 1.4×).
#[test]
fn sparse_prefill_beats_dense_at_t512() {
    let _gate = hold_gate();
    if skip_few_cores("sparse_prefill_beats_dense_at_t512") {
        return;
    }
    let engine = Engine::synthetic_cpu(&perf_spec()).unwrap();
    let speedup = measure_speedup(&engine, 512, 2);
    assert!(
        speedup >= 1.15,
        "50% sparse prefill speedup {speedup:.2}x < 1.15x at T=512 \
         (paper claims up to 1.45x; compute-bound expectation here \
         ~1.4x)"
    );
}

/// The continuous-batching gate: B=4 batched decode must deliver ≥1.3×
/// the aggregate tokens/s of decoding the same four sequences one at a
/// time (B=1 sequential), on the FFN-heavy decode-bench model (~12 MiB
/// of weights per token pass — `testing::decode_bench_spec`, shared
/// with the fig10 bench). The batched step is bit-identical to
/// sequential decode (conformance suite), so this is purely a
/// throughput claim: one pass over the weights for 4 rows instead of
/// 4 passes.
#[test]
fn batched_decode_beats_sequential() {
    let _gate = hold_gate();
    if skip_few_cores("batched_decode_beats_sequential") {
        return;
    }
    const B: usize = 4;
    const STEPS: usize = 16;
    let engine =
        Engine::synthetic_cpu(&testing::decode_bench_spec()).unwrap();
    let seqs = testing::decode_bench_seqs(&engine, B);

    let seq_run = || testing::decode_bench_sequential(&engine, &seqs,
                                                      STEPS);
    let batch_run =
        || testing::decode_bench_batched(&engine, &seqs, STEPS, B);
    // warmup both paths (thread pool spin-up, op-cache fill)
    seq_run();
    batch_run();
    let t_seq = best_of(2, seq_run);
    let t_batch = best_of(2, batch_run);
    let speedup = t_seq / t_batch;
    eprintln!(
        "[perf] batched decode B={B}, {STEPS} steps: sequential {:.1} \
         ms, batched {:.1} ms, aggregate speedup {:.2}x",
        t_seq * 1e3,
        t_batch * 1e3,
        speedup
    );
    assert!(
        speedup >= 1.3,
        "batched decode speedup {speedup:.2}x < 1.3x at B={B} \
         (one weight pass should serve all {B} rows)"
    );
}

/// The block-sparse attention gate: at T = 2048 on the attention-heavy
/// bench model (`testing::attn_bench_spec`, shared with the fig11
/// bench), a 50% drop of optional key blocks must prefill ≥ 1.15×
/// faster than dense attention. At this length attention is ~85% of
/// the prefill compute and 50% drop visits under half the key blocks,
/// so the compute-bound expectation is ≈ 1.5× — generous margin for
/// the gate, per the module's methodology.
#[test]
fn sparse_attention_beats_dense_at_t2048() {
    let _gate = hold_gate();
    if skip_few_cores("sparse_attention_beats_dense_at_t2048") {
        return;
    }
    const LEN: usize = 2048;
    let engine =
        Engine::synthetic_cpu(&testing::attn_bench_spec()).unwrap();
    let dense_cfg = testing::attn_bench_cfg(None);
    let sparse_cfg = testing::attn_bench_cfg(Some(0.5));
    // warmup both paths (thread pool spin-up, op-cache fill)
    testing::attn_bench_prefill(&engine, LEN, &dense_cfg);
    testing::attn_bench_prefill(&engine, LEN, &sparse_cfg);
    let dense = best_of(2, || {
        testing::attn_bench_prefill(&engine, LEN, &dense_cfg)
    });
    let sparse = best_of(2, || {
        testing::attn_bench_prefill(&engine, LEN, &sparse_cfg)
    });
    let speedup = dense / sparse;
    eprintln!(
        "[perf] attn len={LEN}: dense {:.1} ms, block-sparse(50%) \
         {:.1} ms, speedup {:.2}x",
        dense * 1e3,
        sparse * 1e3,
        speedup
    );
    assert!(
        speedup >= 1.15,
        "50% block-sparse attention prefill speedup {speedup:.2}x < \
         1.15x at T={LEN} (compute-bound expectation ~1.5x)"
    );
}

/// One-block variant (T = 128) — the quick gate scripts/check.sh runs;
/// a single block is almost pure FFN, so the margin is wide.
#[test]
fn one_block_sparse_beats_dense() {
    let _gate = hold_gate();
    if skip_few_cores("one_block_sparse_beats_dense") {
        return;
    }
    let engine = Engine::synthetic_cpu(&perf_spec()).unwrap();
    let speedup = measure_speedup(&engine, 128, 3);
    assert!(
        speedup >= 1.10,
        "one-block 50% sparse speedup {speedup:.2}x < 1.10x"
    );
}

/// The speculative-prefill gate: keep=0.5 token pruning at T = 512 on
/// the FFN-heavy bench model must prefill ≥ 1.2× faster than the
/// dense-length path. Pruning halves the tokens the main prefill
/// visits (2 blocks instead of 4), and the scoring pass is one cheap
/// low-rank predictor evaluation per block — the compute-bound
/// expectation is ≈ 1.9×, so the 1.2× bar leaves the usual generous
/// margin. Everything else (FFN density, attention) stays dense so the
/// measurement isolates the token-pruning axis.
#[test]
fn token_pruned_prefill_beats_dense_length_at_t512() {
    let _gate = hold_gate();
    if skip_few_cores("token_pruned_prefill_beats_dense_length_at_t512") {
        return;
    }
    let engine = Engine::synthetic_cpu(&perf_spec()).unwrap();
    let toks = prompt(512);
    let dense_cfg = SparsityConfig::dense();
    let mut keep_cfg = SparsityConfig::dense();
    keep_cfg.token_keep_ratio = Some(0.5);
    // warmup both paths (thread pool spin-up, op-cache fill)
    engine.prefill(&toks, &dense_cfg).unwrap();
    engine.prefill(&toks, &keep_cfg).unwrap();
    let dense = best_of(2, || {
        engine.prefill(&toks, &dense_cfg).unwrap();
    });
    let pruned = best_of(2, || {
        engine.prefill(&toks, &keep_cfg).unwrap();
    });
    let speedup = dense / pruned;
    eprintln!(
        "[perf] token pruning len=512: dense-length {:.1} ms, keep=0.5 \
         {:.1} ms, speedup {:.2}x",
        dense * 1e3,
        pruned * 1e3,
        speedup
    );
    assert!(
        speedup >= 1.2,
        "keep=0.5 speculative prefill speedup {speedup:.2}x < 1.2x at \
         T=512 (half the tokens + one cheap scoring pass; \
         compute-bound expectation ~1.9x)"
    );
}

/// The SIMD kernel-tier gate: dense prefill at T = 512 on the
/// FFN-heavy bench model must run ≥ 1.2× faster on `--cpu-kernel simd`
/// than on the scalar tier. The win comes from alias-free register
/// tiling in the matmul and lane-chunked reductions elsewhere — it is
/// *measured* here, not assumed (docs/ARCHITECTURE.md roofline note).
#[test]
fn simd_dense_prefill_beats_scalar_at_t512() {
    let _gate = hold_gate();
    if skip_few_cores("simd_dense_prefill_beats_scalar_at_t512") {
        return;
    }
    let kernel_engine = |kernel: CpuKernel| {
        Engine::synthetic_cpu_with(
            &perf_spec(),
            CpuOptions {
                threads: 0,
                reference: false,
                kernel: Some(kernel),
            },
        )
        .unwrap()
    };
    let scalar = kernel_engine(CpuKernel::Scalar);
    let simd = kernel_engine(CpuKernel::Simd);
    let toks = prompt(512);
    let cfg = SparsityConfig::dense();
    // warmup both tiers (thread pool spin-up, op-cache fill)
    scalar.prefill(&toks, &cfg).unwrap();
    simd.prefill(&toks, &cfg).unwrap();
    let t_scalar = best_of(2, || {
        scalar.prefill(&toks, &cfg).unwrap();
    });
    let t_simd = best_of(2, || {
        simd.prefill(&toks, &cfg).unwrap();
    });
    let speedup = t_scalar / t_simd;
    eprintln!(
        "[perf] kernel tiers len=512: scalar {:.1} ms, simd {:.1} ms, \
         speedup {:.2}x",
        t_scalar * 1e3,
        t_simd * 1e3,
        speedup
    );
    assert!(
        speedup >= 1.2,
        "simd dense prefill speedup {speedup:.2}x < 1.2x at T=512 \
         (register-tiled matmul + lane-chunked reductions)"
    );
}

/// The int8 weight-tier gate: dense prefill at T = 512 on the
/// FFN-heavy bench model must run ≥ 1.2× faster streaming int8 weight
/// panels (`--weight-precision int8`, SIMD kernels) than streaming f32
/// panels on the same SIMD kernels. The tiled matmuls are
/// memory-bandwidth-bound at this shape, so quartering the weight-read
/// bytes (1 code byte + amortized per-tile scale vs 4 bytes) should
/// comfortably clear the bar even after the in-register dequantize.
#[test]
fn int8_dense_prefill_beats_f32_at_t512() {
    let _gate = hold_gate();
    if skip_few_cores("int8_dense_prefill_beats_f32_at_t512") {
        return;
    }
    let precision_engine = |precision| {
        let spec = SyntheticSpec {
            weight_precision: precision,
            ..perf_spec()
        };
        Engine::synthetic_cpu_with(
            &spec,
            CpuOptions {
                threads: 0,
                reference: false,
                kernel: Some(CpuKernel::Simd),
            },
        )
        .unwrap()
    };
    let f32e =
        precision_engine(fastforward::weights::WeightPrecision::F32);
    let int8e =
        precision_engine(fastforward::weights::WeightPrecision::Int8);
    let toks = prompt(512);
    let cfg = SparsityConfig::dense();
    // warmup both tiers (thread pool spin-up, op-cache fill)
    f32e.prefill(&toks, &cfg).unwrap();
    int8e.prefill(&toks, &cfg).unwrap();
    let t_f32 = best_of(2, || {
        f32e.prefill(&toks, &cfg).unwrap();
    });
    let t_int8 = best_of(2, || {
        int8e.prefill(&toks, &cfg).unwrap();
    });
    let speedup = t_f32 / t_int8;
    eprintln!(
        "[perf] weight tiers len=512: simd-f32 {:.1} ms, simd-int8 \
         {:.1} ms, speedup {:.2}x",
        t_f32 * 1e3,
        t_int8 * 1e3,
        speedup
    );
    assert!(
        speedup >= 1.2,
        "int8 dense prefill speedup {speedup:.2}x < 1.2x at T=512 \
         (quartered weight-read bytes on bandwidth-bound matmuls)"
    );
}

/// The cluster-affinity gate: on a 2-worker cluster serving a
/// shared-document workload whose full working set overflows any one
/// worker's prefix cache but whose *per-worker affine share* fits,
/// consistent-hash prefix-affinity dispatch must deliver ≥ 1.3× lower
/// TTFT p50 than uniform-random placement.
///
/// Mechanism under test (docs/ARCHITECTURE.md §3): affinity pins each
/// document to one worker, so after a single cold prefill per document
/// every request adopts cached KV and prefills only its 32-token
/// suffix; random placement cycles all 8 documents (32 KV blocks)
/// through both 24-block caches — LRU thrash, repeated 4½-block cold
/// prefills. The compute-bound expectation is ~4×; 1.3× leaves the
/// module's usual generous margin. Closed-loop (4 clients, no arrival
/// trace) so the measurement can't be confounded by queueing; the
/// open-loop + chaos version of this claim is the fig15 bench.
#[test]
fn cluster_affinity_beats_random_dispatch() {
    let _gate = hold_gate();
    // two worker processes × 2 lanes + front + clients
    if skip_under_cores("cluster_affinity_beats_random_dispatch", 4) {
        return;
    }
    use fastforward::cluster::{http_post, ClusterConfig, ClusterFront,
                               DispatchMode};
    use fastforward::metrics::Metrics;
    use fastforward::util::json;

    const DOCS: usize = 8;
    const DOC_BLOCKS: usize = 4; // × 128-token blocks = 512-byte docs
    const CLIENTS: usize = 4;
    const REQS: usize = 10;
    let base = ClusterConfig {
        block: 128,
        key_blocks: DOC_BLOCKS,
        vocab: 384,
        max_inflight: 8,
        connect_timeout: std::time::Duration::from_millis(500),
        proxy_read_timeout: std::time::Duration::from_secs(30),
        ..ClusterConfig::default()
    };
    let docs =
        testing::balanced_cluster_docs(&base, 2, DOCS, DOC_BLOCKS * 128);
    let bin = env!("CARGO_BIN_EXE_fastforward");

    // per-worker cache = 24 blocks: affine share (16) fits, full
    // working set (32) doesn't — see the sizing argument above
    let worker_flags: &[&str] = &[
        "--replicas", "1", "--cpu-threads", "2", "--queue", "256",
        "--prefix-cache-mb", "3",
    ];
    let run = |dispatch: DispatchMode| -> f64 {
        let w0 = testing::WorkerProc::spawn(bin, worker_flags);
        let w1 = testing::WorkerProc::spawn(bin, worker_flags);
        let front = ClusterFront::new(
            vec![w0.addr().to_string(), w1.addr().to_string()],
            ClusterConfig { dispatch, ..base.clone() },
            Arc::new(Metrics::new()),
        );
        let (addr, handle) =
            front.clone().spawn("127.0.0.1:0").expect("front binds");
        let addr = addr.to_string();
        let clients: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let addr = addr.clone();
                let docs = docs.clone();
                std::thread::spawn(move || {
                    let mut ttfts = Vec::with_capacity(REQS);
                    for i in 0..REQS {
                        let prompt = format!(
                            "{}{}",
                            docs[(c * REQS + i) % DOCS],
                            testing::ascii_doc_text(
                                900_000 + (c * REQS + i) as u64,
                                32,
                            )
                        );
                        let body = format!(
                            "{{\"prompt\":\"{prompt}\",\
                             \"max_tokens\":4}}"
                        );
                        let (status, resp) = http_post(
                            &addr,
                            "/generate",
                            &body,
                            std::time::Duration::from_secs(60),
                        )
                        .expect("cluster request");
                        assert_eq!(status, 200, "unexpected shed: {resp}");
                        let ttft = json::parse(&resp)
                            .expect("response json")
                            .get("ttft_ms")
                            .and_then(|v| v.as_f64())
                            .expect("ttft_ms in response");
                        ttfts.push(ttft);
                    }
                    ttfts
                })
            })
            .collect();
        let mut all = fastforward::util::stats::Summary::new();
        for c in clients {
            for t in c.join().expect("client thread") {
                all.add(t);
            }
        }
        front.stop();
        let _ = handle.join();
        all.percentile(50.0)
    };

    let p50_affinity = run(DispatchMode::Affinity);
    let p50_random = run(DispatchMode::Random);
    let speedup = p50_random / p50_affinity.max(1e-9);
    eprintln!(
        "[perf] cluster dispatch, {DOCS} docs x {DOC_BLOCKS} blocks, \
         {} reqs: affinity ttft p50 {p50_affinity:.1} ms, random \
         {p50_random:.1} ms, speedup {speedup:.2}x",
        CLIENTS * REQS
    );
    assert!(
        speedup >= 1.3,
        "prefix-affinity dispatch ttft p50 speedup {speedup:.2}x < \
         1.3x vs random on 2 workers (warm suffix-only prefill vs \
         LRU-thrashed cold prefills; compute-bound expectation ~4x)"
    );
}
