//! Artifact manifest: the ABI contract between python/compile/aot.py and
//! the Rust runtime. Parses manifest.json + schedule.json and exposes the
//! model config, weight table, executable argument specs and sparsity
//! schedules.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Model hyperparameters (mirrors python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    /// Model name, e.g. "ff-mini-128".
    pub name: String,
    /// LM-head vocabulary size (byte tokenizer padded for tidy shapes).
    pub vocab: usize,
    /// Residual stream width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention query heads.
    pub n_heads: usize,
    /// KV heads (GQA).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// FFN hidden width (the dimension sparsity selects over).
    pub d_ffn: usize,
    /// Prefill block size in tokens (paper §3.1: 128).
    pub block: usize,
    /// FFN kernel tile: every compiled K is a multiple of this.
    pub ftile: usize,
    /// Maximum context length any request may use.
    pub max_ctx: usize,
    /// Compiled KV-bucket sizes, ascending.
    pub buckets: Vec<usize>,
    /// Key/query block size for block-sparse attention (must divide
    /// `block`; pre-attention-sparsity bundles default to 64).
    pub attn_block: usize,
}

/// One weight's location in weights.bin.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    /// Byte offset into weights.bin (f32-aligned).
    pub offset: usize,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

impl WeightEntry {
    /// Number of f32 elements (min 1 for scalars).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Kinds of executable arguments (the dispatch ABI).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgKind {
    /// Global weight, e.g. "embed".
    Weight(String),
    /// Per-layer transformer weight role, e.g. "wq".
    LayerWeight(String),
    /// Per-layer expert-predictor weight role.
    PredWeight(String),
    /// Per-layer compensator weight role.
    CompWeight(String),
    /// Runtime input (x, k_cache, pos, idx, ...).
    Input(String),
}

/// One argument slot of an executable's ABI.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// How the slot is filled at dispatch time.
    pub kind: ArgKind,
    /// Expected tensor shape.
    pub shape: Vec<usize>,
    /// Whether the slot carries i32 data (f32 otherwise).
    pub is_i32: bool,
}

/// One AOT-lowered executable in the artifact bundle.
#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    /// Manifest name, e.g. "layer_dense_t128_s512".
    pub name: String,
    /// HLO-text file relative to the artifact dir.
    pub file: String,
    /// Argument slots in positional order.
    pub args: Vec<ArgSpec>,
}

/// Per-sparsity-budget schedule (paper Algorithm 1 output).
#[derive(Debug, Clone)]
pub struct BudgetSchedule {
    /// Target sparsity level (e.g. 0.5).
    pub sparsity: f64,
    /// Per-layer density budgets b_i from Algorithm 1.
    pub layer_densities: Vec<f64>,
    /// Per-layer K (quantized to the compiled grid).
    pub layer_k: Vec<usize>,
    /// Uniform-allocation comparison K per layer (Table 4 ablation).
    pub uniform_k: Vec<usize>,
}

/// Calibration outputs shipped with the artifacts.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-layer attention mass (the Algorithm 1 importance signal).
    pub attention_masses: Vec<f64>,
    /// Schedules keyed by sparsity ("0.30", "0.40", "0.50").
    pub budgets: BTreeMap<String, BudgetSchedule>,
}

/// The parsed artifact manifest: the ABI contract between
/// python/compile/aot.py and the Rust runtime.
#[derive(Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model hyperparameters.
    pub model: ModelCfg,
    /// Absolute path to weights.bin.
    pub weights_file: PathBuf,
    /// Weight table keyed by name.
    pub weights: BTreeMap<String, WeightEntry>,
    /// Executable specs keyed by name.
    pub executables: BTreeMap<String, ExecutableSpec>,
    /// Compiled sparse-K grid for prefill blocks.
    pub k_grid: Vec<usize>,
    /// Compiled sparse-K grid for T=1 decode steps.
    pub decode_k: Vec<usize>,
    /// Compiled attention drop levels in percent (the `a{pct}`
    /// executable variants). Empty when the bundle ships no
    /// attention-sparse executables — `--attn-sparsity` then fails
    /// fast instead of silently running dense.
    pub attn_grid: Vec<usize>,
    /// Calibrated sparsity schedules.
    pub schedule: Schedule,
}

/// Parameters of the synthetic, artifact-free model description built
/// by [`Manifest::synthetic`] — the manifest the deterministic
/// [`crate::runtime::CpuBackend`] runs against when no AOT bundle is on
/// disk (always-on numeric tests, `--backend cpu` serving).
#[derive(Debug, Clone)]
pub struct SyntheticSpec {
    /// Model name (feeds [`Manifest::fingerprint`]).
    pub name: String,
    /// LM-head vocabulary (≥ 259 to cover the byte tokenizer specials).
    pub vocab: usize,
    /// Residual stream width (must equal `n_heads * d_head`).
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention query heads.
    pub n_heads: usize,
    /// KV heads (GQA; must divide `n_heads`).
    pub n_kv_heads: usize,
    /// Per-head dimension (even, for RoPE pairs).
    pub d_head: usize,
    /// FFN hidden width (the dimension sparsity selects over).
    pub d_ffn: usize,
    /// Prefill block size in tokens.
    pub block: usize,
    /// FFN kernel tile: every K in the grid is a multiple of this.
    pub ftile: usize,
    /// Maximum context length (== the largest bucket).
    pub max_ctx: usize,
    /// KV bucket sizes, ascending.
    pub buckets: Vec<usize>,
    /// Key/query block size for block-sparse attention (must divide
    /// `block`).
    pub attn_block: usize,
    /// Rank of the low-rank expert predictor (`pred.{l}.wd` is
    /// `[d_model, pred_rank]`, `pred.{l}.wu` is `[pred_rank, d_ffn]`).
    /// The paper's predictors are small networks whose overhead is a
    /// fraction of one FFN matmul — modelling them full-rank would make
    /// the predictor as expensive as the FFN it prunes and hide the
    /// sparse speedup entirely.
    pub pred_rank: usize,
    /// Seed for [`crate::weights::WeightStore::seeded`].
    pub seed: u64,
    /// Storage precision of the seeded weights
    /// ([`crate::weights::WeightStore::seeded_with`]): `F32` is the
    /// bitwise-gated default; `Bf16` rounds every weight to bfloat16
    /// (f32 accumulation), conformance-gated at the relaxed tolerance
    /// tier (`testing::bf16_spec`); `Int8` stores symmetric-absmax
    /// codes + per-column-tile f32 scales, dequantized in-register and
    /// gated by `testing::int8_spec`.
    pub weight_precision: crate::weights::WeightPrecision,
}

impl Default for SyntheticSpec {
    /// The reference test model: small enough that a full prefill is
    /// fast on the interpreter, structured exactly like the paper's
    /// models (GQA, 128-token blocks, tiled K grid).
    fn default() -> Self {
        SyntheticSpec {
            name: "ff-ref-64".to_string(),
            vocab: 384,
            d_model: 64,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            d_head: 16,
            d_ffn: 256,
            block: 128,
            ftile: 32,
            max_ctx: 2048,
            buckets: vec![256, 512, 1024, 2048],
            attn_block: 64,
            pred_rank: 16,
            seed: 0xF057_F0A4,
            weight_precision: crate::weights::WeightPrecision::F32,
        }
    }
}

/// Attention drop levels (percent of optional key blocks dropped) the
/// synthetic manifest compiles `a{pct}` executable variants for.
/// 0 = full coverage through the sparse machinery (bit-identical to
/// dense), 100 = sink + local band only.
pub const SYNTHETIC_ATTN_GRID: [usize; 5] = [0, 25, 50, 75, 100];

impl Manifest {
    /// Parse manifest.json + schedule.json from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} (run `make artifacts`)"))?;
        let j = json::parse(&text).context("parsing manifest.json")?;

        let m = j.req("model")?;
        let model = ModelCfg {
            name: m.req("name")?.as_str().unwrap_or("?").to_string(),
            vocab: req_usize(m, "vocab")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_heads: req_usize(m, "n_heads")?,
            n_kv_heads: req_usize(m, "n_kv_heads")?,
            d_head: req_usize(m, "d_head")?,
            d_ffn: req_usize(m, "d_ffn")?,
            block: req_usize(m, "block")?,
            ftile: req_usize(m, "ftile")?,
            max_ctx: req_usize(m, "max_ctx")?,
            buckets: m.req("buckets")?.usize_vec()?,
            // pre-attention-sparsity bundles omit the field
            attn_block: m
                .get("attn_block")
                .and_then(|v| v.as_usize())
                .unwrap_or(64),
        };

        let mut weights = BTreeMap::new();
        for (name, w) in j
            .req("weights")?
            .as_obj()
            .ok_or_else(|| anyhow!("weights not an object"))?
        {
            weights.insert(
                name.clone(),
                WeightEntry {
                    offset: req_usize(w, "offset")?,
                    shape: w.req("shape")?.usize_vec()?,
                },
            );
        }

        let mut executables = BTreeMap::new();
        for e in j
            .req("executables")?
            .as_arr()
            .ok_or_else(|| anyhow!("executables not an array"))?
        {
            let name = e.req("name")?.as_str().unwrap().to_string();
            let file = e.req("file")?.as_str().unwrap().to_string();
            let mut args = Vec::new();
            for a in e.req("args")?.as_arr().unwrap() {
                let kind = match a.req("kind")?.as_str().unwrap() {
                    "weight" => {
                        ArgKind::Weight(a.req("name")?.as_str().unwrap().into())
                    }
                    "layer_weight" => ArgKind::LayerWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "pred_weight" => ArgKind::PredWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "comp_weight" => ArgKind::CompWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "input" => {
                        ArgKind::Input(a.req("name")?.as_str().unwrap().into())
                    }
                    other => anyhow::bail!("unknown arg kind {other}"),
                };
                args.push(ArgSpec {
                    kind,
                    shape: a.req("shape")?.usize_vec()?,
                    is_i32: a.req("dtype")?.as_str() == Some("i32"),
                });
            }
            executables.insert(
                name.clone(),
                ExecutableSpec { name, file, args },
            );
        }

        let schedule = load_schedule(&dir.join("schedule.json"))?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            weights_file: dir.join(
                j.req("weights_file")?.as_str().unwrap_or("weights.bin"),
            ),
            model,
            weights,
            executables,
            k_grid: j.req("k_grid")?.usize_vec()?,
            decode_k: j.req("decode_k")?.usize_vec()?,
            // AOT bundles without attention-sparse executables ship no
            // attn_grid; the engine rejects `--attn-sparsity` for them
            attn_grid: match j.get("attn_grid") {
                Some(v) => v.usize_vec()?,
                None => Vec::new(),
            },
            schedule,
        })
    }

    /// Build a complete in-memory manifest for the synthetic reference
    /// model: weight table (sequential offsets), executable specs for
    /// every name the engine can dispatch, the tiled K grids, and a
    /// sparsity schedule computed by the same Algorithm-1 twin the
    /// engine uses (`0.30` / `0.40` / `0.50` budgets).
    ///
    /// The result has no backing files: pair it with
    /// [`crate::weights::WeightStore::seeded`] and the `cpu` backend.
    pub fn synthetic(spec: &SyntheticSpec) -> Manifest {
        use crate::sparsity::schedule::{layerwise_schedule,
                                        quantize_densities};
        assert_eq!(spec.d_model, spec.n_heads * spec.d_head,
                   "d_model must equal n_heads * d_head");
        assert_eq!(spec.n_heads % spec.n_kv_heads, 0,
                   "n_kv_heads must divide n_heads");
        assert_eq!(spec.d_head % 2, 0, "d_head must be even (RoPE)");
        assert_eq!(spec.d_ffn % spec.ftile, 0,
                   "d_ffn must be a multiple of ftile");
        assert!(spec.vocab >= 259,
                "vocab must cover the byte-tokenizer specials (>= 259)");
        assert!(spec.pred_rank > 0 && spec.pred_rank <= spec.d_ffn,
                "pred_rank must be in [1, d_ffn]");
        assert!(spec.attn_block > 0 && spec.block % spec.attn_block == 0,
                "attn_block must divide the prefill block");
        let (d, f) = (spec.d_model, spec.d_ffn);
        let (nh, nkv, dh) = (spec.n_heads, spec.n_kv_heads, spec.d_head);

        // --- weight table (offsets assigned in insertion order) ---
        let mut weights = BTreeMap::new();
        let mut off = 0usize;
        let mut add_w = |weights: &mut BTreeMap<String, WeightEntry>,
                         name: String, shape: Vec<usize>| {
            let numel = shape.iter().product::<usize>().max(1);
            weights.insert(name, WeightEntry { offset: off, shape });
            off += numel * 4;
        };
        add_w(&mut weights, "embed".into(), vec![spec.vocab, d]);
        add_w(&mut weights, "final_rms".into(), vec![d]);
        add_w(&mut weights, "lm_head".into(), vec![d, spec.vocab]);
        for l in 0..spec.n_layers {
            add_w(&mut weights, format!("layers.{l}.rms1"), vec![d]);
            add_w(&mut weights, format!("layers.{l}.wq"),
                  vec![d, nh * dh]);
            add_w(&mut weights, format!("layers.{l}.wk"),
                  vec![d, nkv * dh]);
            add_w(&mut weights, format!("layers.{l}.wv"),
                  vec![d, nkv * dh]);
            add_w(&mut weights, format!("layers.{l}.wo"),
                  vec![nh * dh, d]);
            add_w(&mut weights, format!("layers.{l}.rms2"), vec![d]);
            add_w(&mut weights, format!("layers.{l}.w_gate"), vec![d, f]);
            add_w(&mut weights, format!("layers.{l}.w_up"), vec![d, f]);
            add_w(&mut weights, format!("layers.{l}.w_down"), vec![f, d]);
            add_w(&mut weights, format!("pred.{l}.wd"),
                  vec![d, spec.pred_rank]);
            add_w(&mut weights, format!("pred.{l}.wu"),
                  vec![spec.pred_rank, f]);
            add_w(&mut weights, format!("comp.{l}.alpha"), vec![f]);
        }

        // --- K grids: every tile multiple up to and including d_ffn ---
        let k_grid: Vec<usize> =
            (1..=f / spec.ftile).map(|i| i * spec.ftile).collect();
        let decode_k = k_grid.clone();

        // --- executable specs for every dispatchable name ---
        let lay = |role: &str| ArgKind::LayerWeight(role.to_string());
        let farg = |kind: ArgKind, shape: Vec<usize>| ArgSpec {
            kind,
            shape,
            is_i32: false,
        };
        let iarg = |name: &str, shape: Vec<usize>| ArgSpec {
            kind: ArgKind::Input(name.to_string()),
            shape,
            is_i32: true,
        };
        let xarg = |name: &str, shape: Vec<usize>| ArgSpec {
            kind: ArgKind::Input(name.to_string()),
            shape,
            is_i32: false,
        };
        let attn_weights = |args: &mut Vec<ArgSpec>| {
            args.push(farg(lay("rms1"), vec![d]));
            args.push(farg(lay("wq"), vec![d, nh * dh]));
            args.push(farg(lay("wk"), vec![d, nkv * dh]));
            args.push(farg(lay("wv"), vec![d, nkv * dh]));
            args.push(farg(lay("wo"), vec![nh * dh, d]));
        };
        let ffn_weights = |args: &mut Vec<ArgSpec>| {
            args.push(farg(lay("rms2"), vec![d]));
            args.push(farg(lay("w_gate"), vec![d, f]));
            args.push(farg(lay("w_up"), vec![d, f]));
            args.push(farg(lay("w_down"), vec![f, d]));
        };
        let r = spec.pred_rank;
        let pred_weights = |args: &mut Vec<ArgSpec>| {
            args.push(farg(ArgKind::PredWeight("wd".into()), vec![d, r]));
            args.push(farg(ArgKind::PredWeight("wu".into()), vec![r, f]));
        };
        let layer_inputs = |args: &mut Vec<ArgSpec>, t: usize, s: usize| {
            args.push(xarg("x", vec![t, d]));
            args.push(xarg("k_cache", vec![s, nkv, dh]));
            args.push(xarg("v_cache", vec![s, nkv, dh]));
            args.push(iarg("pos", vec![]));
        };

        let mut executables = BTreeMap::new();
        let mut add_x = |name: String, args: Vec<ArgSpec>| {
            executables.insert(
                name.clone(),
                ExecutableSpec {
                    file: format!("{name}.hlo"),
                    name,
                    args,
                },
            );
        };
        for t in [spec.block, 1] {
            add_x(
                format!("embed_t{t}"),
                vec![
                    farg(ArgKind::Weight("embed".into()),
                         vec![spec.vocab, d]),
                    iarg("tokens", vec![t]),
                ],
            );
            add_x(
                format!("lm_head_t{t}"),
                vec![
                    farg(ArgKind::Weight("final_rms".into()), vec![d]),
                    farg(ArgKind::Weight("lm_head".into()),
                         vec![d, spec.vocab]),
                    xarg("x", vec![t, d]),
                ],
            );
        }
        for &s in &spec.buckets {
            for t in [spec.block, 1] {
                // Attention-sparse `a{pct}` variants exist only for
                // full prefill blocks: T=1 steps (ragged tail, decode)
                // always run dense attention. `None` is the original
                // dense-attention name; `Some(0)` is a distinct name —
                // the sparse machinery at full coverage, bit-identical
                // to `None` by the accumulation-order contract.
                let mut a_levels: Vec<Option<usize>> = vec![None];
                if t == spec.block {
                    a_levels.extend(
                        SYNTHETIC_ATTN_GRID.iter().map(|&p| Some(p)),
                    );
                }
                for a in a_levels {
                    let aseg = a
                        .map(|p| format!("a{p}_"))
                        .unwrap_or_default();
                    let mut args = Vec::new();
                    attn_weights(&mut args);
                    ffn_weights(&mut args);
                    layer_inputs(&mut args, t, s);
                    add_x(format!("layer_dense_{aseg}t{t}_s{s}"), args);
                    for &k in &k_grid {
                        // fused sparse layer, exact compensator inside
                        let mut args = Vec::new();
                        attn_weights(&mut args);
                        ffn_weights(&mut args);
                        pred_weights(&mut args);
                        args.push(farg(
                            ArgKind::CompWeight("alpha".into()),
                            vec![f],
                        ));
                        layer_inputs(&mut args, t, s);
                        add_x(
                            format!(
                                "layer_sparse_{aseg}k{k}_t{t}_s{s}"
                            ),
                            args,
                        );
                        // fused sparse layer, no compensator: the
                        // backend may skip dropped-neuron activations
                        // entirely — the genuinely-sub-dense compute
                        // profile of the paper's kernels (synthetic
                        // manifests only; AOT bundles do not ship this
                        // variant and the engine falls back to the
                        // split pipeline)
                        let mut args = Vec::new();
                        attn_weights(&mut args);
                        ffn_weights(&mut args);
                        pred_weights(&mut args);
                        layer_inputs(&mut args, t, s);
                        add_x(
                            format!(
                                "layer_sparse_nc_{aseg}k{k}_t{t}_s{s}"
                            ),
                            args,
                        );
                    }
                }
            }
            let mut args = Vec::new();
            attn_weights(&mut args);
            layer_inputs(&mut args, spec.block, s);
            add_x(format!("layer_attn_t{}_s{s}", spec.block), args);
        }
        let t = spec.block;
        {
            let mut args = vec![farg(lay("rms2"), vec![d])];
            pred_weights(&mut args);
            args.push(xarg("h", vec![t, d]));
            add_x(format!("predictor_t{t}"), args);
        }
        add_x(
            format!("ffn_acts_t{t}"),
            vec![
                farg(lay("rms2"), vec![d]),
                farg(lay("w_gate"), vec![d, f]),
                farg(lay("w_up"), vec![d, f]),
                xarg("h", vec![t, d]),
            ],
        );
        {
            let mut args = Vec::new();
            ffn_weights(&mut args);
            args.push(xarg("h", vec![t, d]));
            add_x(format!("ffn_dense_t{t}"), args);
        }
        for &k in &k_grid {
            let mut args = Vec::new();
            ffn_weights(&mut args);
            args.push(farg(ArgKind::CompWeight("alpha".into()), vec![f]));
            args.push(xarg("h", vec![t, d]));
            args.push(iarg("idx", vec![k]));
            add_x(format!("ffn_sparse_ext_k{k}_t{t}"), args);
            // external-index sparse FFN without the compensator output
            // (only selected neurons are ever touched)
            let mut args = Vec::new();
            ffn_weights(&mut args);
            args.push(xarg("h", vec![t, d]));
            args.push(iarg("idx", vec![k]));
            add_x(format!("ffn_sparse_nc_k{k}_t{t}"), args);
        }

        // --- calibrated schedule via the Algorithm-1 twin ---
        let masses: Vec<f64> = (0..spec.n_layers)
            .map(|l| 1.0 / (1.0 + 0.35 * l as f64))
            .collect();
        let mut budgets = BTreeMap::new();
        for sp in [0.3f64, 0.4, 0.5] {
            let dens = layerwise_schedule(&masses, 1.0 - sp);
            let layer_k = quantize_densities(&dens, f, spec.ftile);
            let uniform = layerwise_schedule(
                &vec![1.0; spec.n_layers],
                1.0 - sp,
            );
            let uniform_k = quantize_densities(&uniform, f, spec.ftile);
            budgets.insert(
                format!("{sp:.2}"),
                BudgetSchedule {
                    sparsity: sp,
                    layer_densities: dens,
                    layer_k,
                    uniform_k,
                },
            );
        }

        Manifest {
            dir: PathBuf::new(),
            model: ModelCfg {
                name: spec.name.clone(),
                vocab: spec.vocab,
                d_model: d,
                n_layers: spec.n_layers,
                n_heads: nh,
                n_kv_heads: nkv,
                d_head: dh,
                d_ffn: f,
                block: spec.block,
                ftile: spec.ftile,
                max_ctx: spec.max_ctx,
                buckets: spec.buckets.clone(),
                attn_block: spec.attn_block,
            },
            weights_file: PathBuf::new(),
            weights,
            executables,
            k_grid,
            decode_k,
            attn_grid: SYNTHETIC_ATTN_GRID.to_vec(),
            schedule: Schedule {
                attention_masses: masses,
                budgets,
            },
        }
    }

    /// Stable 64-bit fingerprint of the model identity (name + every
    /// dimension that shapes the numerics). Combined with the
    /// weight-value fingerprint and the backend label in
    /// [`crate::runtime::Runtime::numeric_fingerprint`] so the prefix
    /// cache never mixes KV across models, weight sets, or backends.
    pub fn fingerprint(&self) -> u64 {
        use crate::util::hash;
        let mut h = hash::mix(
            hash::BASIS,
            hash::fnv1a(self.model.name.as_bytes()),
        );
        for v in [
            self.model.vocab,
            self.model.d_model,
            self.model.n_layers,
            self.model.n_heads,
            self.model.n_kv_heads,
            self.model.d_head,
            self.model.d_ffn,
            self.model.block,
            self.model.ftile,
            self.model.attn_block,
        ] {
            h = hash::mix(h, v as u64);
        }
        h
    }

    /// Whether the manifest ships an executable named `name` — the
    /// capability probe the engine uses to pick fused fast paths that
    /// only synthetic manifests provide (e.g. `layer_sparse_nc_*`).
    pub fn has_executable(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Resolve a weight-arg to a concrete weight name for `layer`.
    pub fn resolve_weight_name(&self, kind: &ArgKind, layer: usize) -> Option<String> {
        match kind {
            ArgKind::Weight(name) => Some(name.clone()),
            ArgKind::LayerWeight(role) => Some(format!("layers.{layer}.{role}")),
            ArgKind::PredWeight(role) => Some(format!("pred.{layer}.{role}")),
            ArgKind::CompWeight(role) => Some(format!("comp.{layer}.{role}")),
            ArgKind::Input(_) => None,
        }
    }

    /// Smallest KV bucket that can hold `len` positions.
    pub fn bucket_for(&self, len: usize) -> Result<usize> {
        self.model
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .ok_or_else(|| {
                anyhow!(
                    "context {len} exceeds max bucket {:?}",
                    self.model.buckets.last()
                )
            })
    }

    /// The schedule entry for a sparsity level (key like "0.50").
    pub fn budget(&self, sparsity: f64) -> Result<&BudgetSchedule> {
        let key = format!("{sparsity:.2}");
        self.schedule
            .budgets
            .get(&key)
            .ok_or_else(|| anyhow!("no schedule for sparsity {key}"))
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("{key} not a usize"))
}

fn load_schedule(path: &Path) -> Result<Schedule> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path:?}"))?;
    let j = json::parse(&text)?;
    let mut budgets = BTreeMap::new();
    for (key, s) in j.req("schedules")?.as_obj().unwrap() {
        budgets.insert(
            key.clone(),
            BudgetSchedule {
                sparsity: s.req("sparsity")?.as_f64().unwrap(),
                layer_densities: s.req("layer_densities")?.f64_vec()?,
                layer_k: s.req("layer_k")?.usize_vec()?,
                uniform_k: s.req("uniform_k")?.usize_vec()?,
            },
        );
    }
    Ok(Schedule {
        attention_masses: j.req("attention_masses")?.f64_vec()?,
        budgets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Manifest loading against real artifacts (skips if absent).
    #[test]
    fn loads_real_manifest() {
        let dir = crate::test_artifacts_dir();
        let Some(dir) = dir else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.d_model >= 64);
        assert_eq!(m.model.d_head * m.model.n_heads, m.model.d_model);
        assert!(m.weights.contains_key("embed"));
        assert!(m.weights.contains_key("layers.0.wq"));
        assert!(m
            .executables
            .keys()
            .any(|k| k.starts_with("layer_dense_t128")));
        // every executable's file exists
        for e in m.executables.values() {
            assert!(m.dir.join(&e.file).exists(), "{} missing", e.file);
        }
        // schedules cover the paper's sparsity levels
        for sp in [0.3, 0.4, 0.5] {
            let b = m.budget(sp).unwrap();
            assert_eq!(b.layer_k.len(), m.model.n_layers);
            assert!(b.layer_k.iter().all(|&k| k <= m.model.d_ffn));
        }
    }

    /// The synthetic manifest is self-consistent: every executable the
    /// engine can name exists, every weight arg resolves into the
    /// weight table, and the schedule covers the paper's budgets.
    #[test]
    fn synthetic_manifest_is_self_consistent() {
        let spec = SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        assert_eq!(m.model.d_head * m.model.n_heads, m.model.d_model);
        assert!(m.weights.contains_key("embed"));
        assert!(m.weights.contains_key("layers.0.wq"));
        let block = m.model.block;
        for name in [
            format!("embed_t{block}"),
            "embed_t1".to_string(),
            format!("lm_head_t{block}"),
            format!("layer_dense_t{block}_s{}", m.model.buckets[0]),
            format!("layer_dense_t1_s{}", m.model.buckets[0]),
            format!(
                "layer_sparse_k{}_t{block}_s{}",
                m.k_grid[0], m.model.buckets[0]
            ),
            format!(
                "layer_sparse_nc_k{}_t{block}_s{}",
                m.k_grid[0], m.model.buckets[0]
            ),
            format!("layer_sparse_nc_k{}_t1_s{}",
                    m.k_grid[0], m.model.buckets[0]),
            format!("layer_attn_t{block}_s{}", m.model.buckets[0]),
            // attention-sparse variants: every grid level, full
            // blocks only (T=1 steps stay dense-attention)
            format!("layer_dense_a0_t{block}_s{}", m.model.buckets[0]),
            format!("layer_dense_a50_t{block}_s{}", m.model.buckets[0]),
            format!("layer_dense_a100_t{block}_s{}", m.model.buckets[0]),
            format!(
                "layer_sparse_a50_k{}_t{block}_s{}",
                m.k_grid[0], m.model.buckets[0]
            ),
            format!(
                "layer_sparse_nc_a50_k{}_t{block}_s{}",
                m.k_grid[0], m.model.buckets[0]
            ),
            format!("predictor_t{block}"),
            format!("ffn_acts_t{block}"),
            format!("ffn_dense_t{block}"),
            format!("ffn_sparse_ext_k{}_t{block}", m.k_grid[0]),
            format!("ffn_sparse_nc_k{}_t{block}", m.k_grid[0]),
        ] {
            assert!(m.executables.contains_key(&name), "{name} missing");
        }
        // every weight argument of every executable resolves to a
        // weight-table entry of the same shape, for every layer
        for e in m.executables.values() {
            for a in &e.args {
                if matches!(a.kind, ArgKind::Input(_)) {
                    continue;
                }
                for l in 0..m.model.n_layers {
                    let wname =
                        m.resolve_weight_name(&a.kind, l).unwrap();
                    let w = m
                        .weights
                        .get(&wname)
                        .unwrap_or_else(|| panic!("{wname} missing"));
                    assert_eq!(w.shape, a.shape, "{}: {wname}", e.name);
                }
            }
        }
        // weight offsets are disjoint and 4-byte aligned
        let mut spans: Vec<(usize, usize)> = m
            .weights
            .values()
            .map(|w| (w.offset, w.offset + w.numel() * 4))
            .collect();
        spans.sort_unstable();
        for pair in spans.windows(2) {
            assert!(pair[0].1 <= pair[1].0, "overlapping weights");
        }
        // no attention-sparse executable exists at T=1 (tail + decode
        // steps are always dense-attention), and the attn grid spans
        // full coverage (a0) through sink+local-only (a100)
        assert!(!m
            .executables
            .keys()
            .any(|k| k.contains("_a") && k.contains("_t1_")));
        assert_eq!(m.attn_grid, vec![0, 25, 50, 75, 100]);
        assert_eq!(m.model.block % m.model.attn_block, 0);
        // the K grid is tiled and the schedule covers the paper budgets
        assert!(m.k_grid.iter().all(|k| k % m.model.ftile == 0));
        assert!(m.k_grid.contains(&m.model.d_ffn));
        for sp in [0.3, 0.4, 0.5] {
            let b = m.budget(sp).unwrap();
            assert_eq!(b.layer_k.len(), m.model.n_layers);
            assert!(b.layer_k.iter().all(|&k| m.k_grid.contains(&k)));
        }
        // synthetic bucket selection behaves like the real one
        assert_eq!(m.bucket_for(1).unwrap(), m.model.buckets[0]);
        assert!(m.bucket_for(m.model.max_ctx).is_ok());
        assert!(m.bucket_for(m.model.max_ctx + 1).is_err());
    }

    #[test]
    fn fingerprint_tracks_model_identity() {
        let spec = SyntheticSpec::default();
        let a = Manifest::synthetic(&spec);
        let b = Manifest::synthetic(&spec);
        assert_eq!(a.fingerprint(), b.fingerprint());
        let other = SyntheticSpec {
            d_ffn: 512,
            ..SyntheticSpec::default()
        };
        assert_ne!(
            a.fingerprint(),
            Manifest::synthetic(&other).fingerprint()
        );
    }

    #[test]
    fn bucket_selection() {
        let dir = crate::test_artifacts_dir();
        let Some(dir) = dir else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), m.model.buckets[0]);
        assert_eq!(
            m.bucket_for(m.model.buckets[0] + 1).unwrap(),
            m.model.buckets[1]
        );
        assert!(m.bucket_for(m.model.max_ctx * 2).is_err());
    }
}
