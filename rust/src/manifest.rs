//! Artifact manifest: the ABI contract between python/compile/aot.py and
//! the Rust runtime. Parses manifest.json + schedule.json and exposes the
//! model config, weight table, executable argument specs and sparsity
//! schedules.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// Model hyperparameters (mirrors python ModelConfig).
#[derive(Debug, Clone)]
pub struct ModelCfg {
    /// Model name, e.g. "ff-mini-128".
    pub name: String,
    /// LM-head vocabulary size (byte tokenizer padded for tidy shapes).
    pub vocab: usize,
    /// Residual stream width.
    pub d_model: usize,
    /// Transformer layer count.
    pub n_layers: usize,
    /// Attention query heads.
    pub n_heads: usize,
    /// KV heads (GQA).
    pub n_kv_heads: usize,
    /// Per-head dimension.
    pub d_head: usize,
    /// FFN hidden width (the dimension sparsity selects over).
    pub d_ffn: usize,
    /// Prefill block size in tokens (paper §3.1: 128).
    pub block: usize,
    /// FFN kernel tile: every compiled K is a multiple of this.
    pub ftile: usize,
    /// Maximum context length any request may use.
    pub max_ctx: usize,
    /// Compiled KV-bucket sizes, ascending.
    pub buckets: Vec<usize>,
}

/// One weight's location in weights.bin.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    /// Byte offset into weights.bin (f32-aligned).
    pub offset: usize,
    /// Tensor shape.
    pub shape: Vec<usize>,
}

impl WeightEntry {
    /// Number of f32 elements (min 1 for scalars).
    pub fn numel(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// Kinds of executable arguments (the dispatch ABI).
#[derive(Debug, Clone, PartialEq)]
pub enum ArgKind {
    /// Global weight, e.g. "embed".
    Weight(String),
    /// Per-layer transformer weight role, e.g. "wq".
    LayerWeight(String),
    /// Per-layer expert-predictor weight role.
    PredWeight(String),
    /// Per-layer compensator weight role.
    CompWeight(String),
    /// Runtime input (x, k_cache, pos, idx, ...).
    Input(String),
}

/// One argument slot of an executable's ABI.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    /// How the slot is filled at dispatch time.
    pub kind: ArgKind,
    /// Expected tensor shape.
    pub shape: Vec<usize>,
    /// Whether the slot carries i32 data (f32 otherwise).
    pub is_i32: bool,
}

/// One AOT-lowered executable in the artifact bundle.
#[derive(Debug, Clone)]
pub struct ExecutableSpec {
    /// Manifest name, e.g. "layer_dense_t128_s512".
    pub name: String,
    /// HLO-text file relative to the artifact dir.
    pub file: String,
    /// Argument slots in positional order.
    pub args: Vec<ArgSpec>,
}

/// Per-sparsity-budget schedule (paper Algorithm 1 output).
#[derive(Debug, Clone)]
pub struct BudgetSchedule {
    /// Target sparsity level (e.g. 0.5).
    pub sparsity: f64,
    /// Per-layer density budgets b_i from Algorithm 1.
    pub layer_densities: Vec<f64>,
    /// Per-layer K (quantized to the compiled grid).
    pub layer_k: Vec<usize>,
    /// Uniform-allocation comparison K per layer (Table 4 ablation).
    pub uniform_k: Vec<usize>,
}

/// Calibration outputs shipped with the artifacts.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Per-layer attention mass (the Algorithm 1 importance signal).
    pub attention_masses: Vec<f64>,
    /// Schedules keyed by sparsity ("0.30", "0.40", "0.50").
    pub budgets: BTreeMap<String, BudgetSchedule>,
}

/// The parsed artifact manifest: the ABI contract between
/// python/compile/aot.py and the Rust runtime.
#[derive(Debug)]
pub struct Manifest {
    /// Artifact directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Model hyperparameters.
    pub model: ModelCfg,
    /// Absolute path to weights.bin.
    pub weights_file: PathBuf,
    /// Weight table keyed by name.
    pub weights: BTreeMap<String, WeightEntry>,
    /// Executable specs keyed by name.
    pub executables: BTreeMap<String, ExecutableSpec>,
    /// Compiled sparse-K grid for prefill blocks.
    pub k_grid: Vec<usize>,
    /// Compiled sparse-K grid for T=1 decode steps.
    pub decode_k: Vec<usize>,
    /// Calibrated sparsity schedules.
    pub schedule: Schedule,
}

impl Manifest {
    /// Parse manifest.json + schedule.json from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .with_context(|| format!("reading {mpath:?} (run `make artifacts`)"))?;
        let j = json::parse(&text).context("parsing manifest.json")?;

        let m = j.req("model")?;
        let model = ModelCfg {
            name: m.req("name")?.as_str().unwrap_or("?").to_string(),
            vocab: req_usize(m, "vocab")?,
            d_model: req_usize(m, "d_model")?,
            n_layers: req_usize(m, "n_layers")?,
            n_heads: req_usize(m, "n_heads")?,
            n_kv_heads: req_usize(m, "n_kv_heads")?,
            d_head: req_usize(m, "d_head")?,
            d_ffn: req_usize(m, "d_ffn")?,
            block: req_usize(m, "block")?,
            ftile: req_usize(m, "ftile")?,
            max_ctx: req_usize(m, "max_ctx")?,
            buckets: m.req("buckets")?.usize_vec()?,
        };

        let mut weights = BTreeMap::new();
        for (name, w) in j
            .req("weights")?
            .as_obj()
            .ok_or_else(|| anyhow!("weights not an object"))?
        {
            weights.insert(
                name.clone(),
                WeightEntry {
                    offset: req_usize(w, "offset")?,
                    shape: w.req("shape")?.usize_vec()?,
                },
            );
        }

        let mut executables = BTreeMap::new();
        for e in j
            .req("executables")?
            .as_arr()
            .ok_or_else(|| anyhow!("executables not an array"))?
        {
            let name = e.req("name")?.as_str().unwrap().to_string();
            let file = e.req("file")?.as_str().unwrap().to_string();
            let mut args = Vec::new();
            for a in e.req("args")?.as_arr().unwrap() {
                let kind = match a.req("kind")?.as_str().unwrap() {
                    "weight" => {
                        ArgKind::Weight(a.req("name")?.as_str().unwrap().into())
                    }
                    "layer_weight" => ArgKind::LayerWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "pred_weight" => ArgKind::PredWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "comp_weight" => ArgKind::CompWeight(
                        a.req("role")?.as_str().unwrap().into(),
                    ),
                    "input" => {
                        ArgKind::Input(a.req("name")?.as_str().unwrap().into())
                    }
                    other => anyhow::bail!("unknown arg kind {other}"),
                };
                args.push(ArgSpec {
                    kind,
                    shape: a.req("shape")?.usize_vec()?,
                    is_i32: a.req("dtype")?.as_str() == Some("i32"),
                });
            }
            executables.insert(
                name.clone(),
                ExecutableSpec { name, file, args },
            );
        }

        let schedule = load_schedule(&dir.join("schedule.json"))?;

        Ok(Manifest {
            dir: dir.to_path_buf(),
            weights_file: dir.join(
                j.req("weights_file")?.as_str().unwrap_or("weights.bin"),
            ),
            model,
            weights,
            executables,
            k_grid: j.req("k_grid")?.usize_vec()?,
            decode_k: j.req("decode_k")?.usize_vec()?,
            schedule,
        })
    }

    /// Resolve a weight-arg to a concrete weight name for `layer`.
    pub fn resolve_weight_name(&self, kind: &ArgKind, layer: usize) -> Option<String> {
        match kind {
            ArgKind::Weight(name) => Some(name.clone()),
            ArgKind::LayerWeight(role) => Some(format!("layers.{layer}.{role}")),
            ArgKind::PredWeight(role) => Some(format!("pred.{layer}.{role}")),
            ArgKind::CompWeight(role) => Some(format!("comp.{layer}.{role}")),
            ArgKind::Input(_) => None,
        }
    }

    /// Smallest KV bucket that can hold `len` positions.
    pub fn bucket_for(&self, len: usize) -> Result<usize> {
        self.model
            .buckets
            .iter()
            .copied()
            .find(|&b| b >= len)
            .ok_or_else(|| {
                anyhow!(
                    "context {len} exceeds max bucket {:?}",
                    self.model.buckets.last()
                )
            })
    }

    /// The schedule entry for a sparsity level (key like "0.50").
    pub fn budget(&self, sparsity: f64) -> Result<&BudgetSchedule> {
        let key = format!("{sparsity:.2}");
        self.schedule
            .budgets
            .get(&key)
            .ok_or_else(|| anyhow!("no schedule for sparsity {key}"))
    }
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.req(key)?
        .as_usize()
        .ok_or_else(|| anyhow!("{key} not a usize"))
}

fn load_schedule(path: &Path) -> Result<Schedule> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path:?}"))?;
    let j = json::parse(&text)?;
    let mut budgets = BTreeMap::new();
    for (key, s) in j.req("schedules")?.as_obj().unwrap() {
        budgets.insert(
            key.clone(),
            BudgetSchedule {
                sparsity: s.req("sparsity")?.as_f64().unwrap(),
                layer_densities: s.req("layer_densities")?.f64_vec()?,
                layer_k: s.req("layer_k")?.usize_vec()?,
                uniform_k: s.req("uniform_k")?.usize_vec()?,
            },
        );
    }
    Ok(Schedule {
        attention_masses: j.req("attention_masses")?.f64_vec()?,
        budgets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Manifest loading against real artifacts (skips if absent).
    #[test]
    fn loads_real_manifest() {
        let dir = crate::test_artifacts_dir();
        let Some(dir) = dir else { return };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.model.d_model >= 64);
        assert_eq!(m.model.d_head * m.model.n_heads, m.model.d_model);
        assert!(m.weights.contains_key("embed"));
        assert!(m.weights.contains_key("layers.0.wq"));
        assert!(m
            .executables
            .keys()
            .any(|k| k.starts_with("layer_dense_t128")));
        // every executable's file exists
        for e in m.executables.values() {
            assert!(m.dir.join(&e.file).exists(), "{} missing", e.file);
        }
        // schedules cover the paper's sparsity levels
        for sp in [0.3, 0.4, 0.5] {
            let b = m.budget(sp).unwrap();
            assert_eq!(b.layer_k.len(), m.model.n_layers);
            assert!(b.layer_k.iter().all(|&k| k <= m.model.d_ffn));
        }
    }

    #[test]
    fn bucket_selection() {
        let dir = crate::test_artifacts_dir();
        let Some(dir) = dir else { return };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.bucket_for(1).unwrap(), m.model.buckets[0]);
        assert_eq!(
            m.bucket_for(m.model.buckets[0] + 1).unwrap(),
            m.model.buckets[1]
        );
        assert!(m.bucket_for(m.model.max_ctx * 2).is_err());
    }
}
