//! Weight store: loads weights.bin (flat little-endian f32, offsets from
//! the manifest) and serves per-tensor slices to the runtime dispatcher.
//! [`WeightStore::seeded`] instead *generates* deterministic synthetic
//! weights from a manifest's table — the artifact-free substrate the
//! pure-Rust [`crate::runtime::CpuBackend`] runs the always-on numeric
//! test tier against.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{Manifest, WeightEntry};
use crate::util::hash;
use crate::util::rng::Rng;

/// Env var naming the synthetic weight storage precision
/// (`f32` | `bf16`); the `--weight-precision` CLI flag forwards
/// through it so every engine construction site resolves the same
/// mode.
pub const PRECISION_ENV: &str = "FF_WEIGHT_PREC";

/// Storage precision of the seeded synthetic weights.
///
/// `Bf16` is a *storage* mode: every generated value is rounded to
/// bfloat16 (round-to-nearest-even) and all arithmetic still
/// accumulates in f32 — the load-compressed/compute-dense pattern.
/// The f32 view served by [`WeightStore::get`] holds the widened
/// rounded values, so the scalar and SIMD f32 kernels compute over
/// exactly the numbers the bf16-streaming kernel widens on the fly.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightPrecision {
    /// Full f32 storage (the default).
    #[default]
    F32,
    /// bfloat16 storage, f32 accumulation.
    Bf16,
}

impl WeightPrecision {
    /// Parse a CLI/env spelling (`f32` | `bf16`).
    pub fn parse(s: &str) -> Option<WeightPrecision> {
        match s {
            "f32" => Some(WeightPrecision::F32),
            "bf16" => Some(WeightPrecision::Bf16),
            _ => None,
        }
    }

    /// Resolve from [`PRECISION_ENV`]; unset or unparsable means
    /// [`WeightPrecision::F32`].
    pub fn from_env() -> WeightPrecision {
        std::env::var(PRECISION_ENV)
            .ok()
            .and_then(|s| Self::parse(&s))
            .unwrap_or_default()
    }

    /// Stable display label (the CLI/env spelling).
    pub fn label(self) -> &'static str {
        match self {
            WeightPrecision::F32 => "f32",
            WeightPrecision::Bf16 => "bf16",
        }
    }
}

/// Round an f32 to bfloat16 (round-to-nearest-even on the dropped 16
/// mantissa bits). NaN payloads are quieted so the result is never an
/// accidental infinity.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// Widen a bfloat16 bit pattern back to f32 (exact).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// All model weights resident as one flat host f32 buffer plus the
/// name → (offset, shape) table from the manifest.
///
/// Plain immutable data, hence `Send + Sync`: the executor pool loads
/// or seeds **one** store and shares it across every replica thread
/// through an `Arc` (see
/// [`crate::pool::ExecutorPool::shared_backend_factory`]) — replicas
/// must never re-seed their own copy, which is asserted by the
/// fingerprint regression in `tests/backend_conformance.rs`.
#[derive(Debug)]
pub struct WeightStore {
    data: Vec<f32>,
    /// Raw bf16 mirror of `data` (same offset/4 layout), present only
    /// for [`WeightPrecision::Bf16`] stores: the SIMD matmul streams
    /// these half-width words and widens in registers, halving the
    /// weight-read bytes. `data` always holds the widened values, so
    /// every f32 consumer sees identical numbers.
    bf16: Option<Vec<u16>>,
    precision: WeightPrecision,
    table: BTreeMap<String, WeightEntry>,
}

impl WeightStore {
    /// Load the blob named by a manifest.
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        Self::load_from(&manifest.weights_file, manifest.weights.clone())
    }

    /// Load a blob with an explicit weight table (validated on load).
    pub fn load_from(
        path: &Path,
        table: BTreeMap<String, WeightEntry>,
    ) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "weights.bin length {} not a multiple of 4",
            bytes.len()
        );
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        // Validate the table against the blob before serving anything.
        for (name, e) in &table {
            let end = e.offset / 4 + e.numel();
            anyhow::ensure!(
                e.offset % 4 == 0 && end <= data.len(),
                "weight {name} out of bounds (offset {} numel {})",
                e.offset,
                e.numel()
            );
        }
        Ok(WeightStore {
            data,
            bf16: None,
            precision: WeightPrecision::F32,
            table,
        })
    }

    /// Build a store from an in-memory buffer + table (bounds-validated
    /// like [`WeightStore::load_from`]).
    pub fn from_data(
        data: Vec<f32>,
        table: BTreeMap<String, WeightEntry>,
    ) -> Result<WeightStore> {
        for (name, e) in &table {
            let end = e.offset / 4 + e.numel();
            anyhow::ensure!(
                e.offset % 4 == 0 && end <= data.len(),
                "weight {name} out of bounds (offset {} numel {})",
                e.offset,
                e.numel()
            );
        }
        Ok(WeightStore {
            data,
            bf16: None,
            precision: WeightPrecision::F32,
            table,
        })
    }

    /// Generate deterministic synthetic weights for every entry in the
    /// manifest's table. Each tensor draws from its own RNG stream
    /// (seeded by `seed` and the tensor *name*, so table iteration
    /// order is irrelevant): every run, on every machine, produces
    /// bit-identical weights — the foundation of the reproducible
    /// CPU-backend test tier.
    ///
    /// Initialization policy (shapes from [`Manifest::synthetic`]):
    /// * RMSNorm gains (`rms1`/`rms2`/`final_rms`) — near 1.
    /// * Compensator gates (`comp.*.alpha`) — one constant per layer,
    ///   strictly inside (0, 1): the reference compensator then
    ///   *provably* shrinks the sparse-FFN error (see
    ///   `runtime::cpu`).
    /// * Matrices — normal, scaled by `1/sqrt(fan_in)` (first dim).
    pub fn seeded(manifest: &Manifest, seed: u64) -> WeightStore {
        Self::seeded_with(manifest, seed, WeightPrecision::F32)
    }

    /// [`WeightStore::seeded`] with an explicit storage precision. For
    /// [`WeightPrecision::Bf16`] every generated value is rounded to
    /// bfloat16; the f32 buffer holds the widened rounded values and a
    /// parallel raw-u16 mirror feeds the bf16-streaming SIMD matmul.
    /// The value [`WeightStore::fingerprint`] therefore differs from
    /// the f32 store's, so prefix-cache KV never crosses precisions.
    pub fn seeded_with(
        manifest: &Manifest,
        seed: u64,
        precision: WeightPrecision,
    ) -> WeightStore {
        let mut store = Self::seeded_f32(manifest, seed);
        if precision == WeightPrecision::Bf16 {
            let raw: Vec<u16> =
                store.data.iter().map(|&v| f32_to_bf16(v)).collect();
            for (v, &b) in store.data.iter_mut().zip(raw.iter()) {
                *v = bf16_to_f32(b);
            }
            store.bf16 = Some(raw);
            store.precision = WeightPrecision::Bf16;
        }
        store
    }

    fn seeded_f32(manifest: &Manifest, seed: u64) -> WeightStore {
        let total = manifest
            .weights
            .values()
            .map(|e| e.offset / 4 + e.numel())
            .max()
            .unwrap_or(0);
        let mut data = vec![0f32; total];
        for (name, e) in &manifest.weights {
            let mut rng = Rng::new(seed ^ hash::fnv1a(name.as_bytes()));
            let start = e.offset / 4;
            let out = &mut data[start..start + e.numel()];
            if name.ends_with("rms1")
                || name.ends_with("rms2")
                || name == "final_rms"
            {
                for v in out.iter_mut() {
                    *v = 1.0 + 0.05 * rng.normal() as f32;
                }
            } else if name.ends_with(".alpha") {
                let gate = (0.4 + 0.2 * rng.f64()) as f32;
                for v in out.iter_mut() {
                    *v = gate;
                }
            } else {
                let fan_in = e.shape.first().copied().unwrap_or(1).max(1);
                let scale = 1.0 / (fan_in as f64).sqrt();
                for v in out.iter_mut() {
                    *v = (rng.normal() * scale) as f32;
                }
            }
        }
        Self::from_data(data, manifest.weights.clone())
            .expect("seeded data is sized to the manifest table")
    }

    /// Stable 64-bit fingerprint of the *weight values* (table layout +
    /// every f32 bit pattern). Computed once at runtime construction
    /// and mixed into [`crate::runtime::Runtime::numeric_fingerprint`]:
    /// two stores with the same shapes but different values (a
    /// different seed, retrained artifacts) must never share
    /// prefix-cache KV.
    pub fn fingerprint(&self) -> u64 {
        let mut h = hash::BASIS;
        for (name, e) in &self.table {
            h = hash::mix(h, hash::fnv1a(name.as_bytes()));
            h = hash::mix(h, e.offset as u64);
            let start = e.offset / 4;
            for &v in &self.data[start..start + e.numel()] {
                h = hash::mix(h, v.to_bits() as u64);
            }
        }
        h
    }

    /// Borrow one tensor's data by name.
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        let e = self
            .table
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))?;
        let start = e.offset / 4;
        Ok(&self.data[start..start + e.numel()])
    }

    /// Borrow one tensor's raw bf16 words, or `None` on an f32 store.
    /// Widening each word reproduces [`WeightStore::get`] exactly.
    pub fn get_bf16(&self, name: &str) -> Option<&[u16]> {
        let raw = self.bf16.as_ref()?;
        let e = self.table.get(name)?;
        let start = e.offset / 4;
        Some(&raw[start..start + e.numel()])
    }

    /// Storage precision of this store.
    pub fn precision(&self) -> WeightPrecision {
        self.precision
    }

    /// One tensor's shape by name.
    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self
            .table
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))?
            .shape)
    }

    /// Iterate all weight names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.table.keys()
    }

    /// Total parameter count across the table.
    pub fn total_params(&self) -> usize {
        self.table.values().map(|e| e.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn loads_and_validates_real_weights() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        let embed = w.get("embed").unwrap();
        assert_eq!(embed.len(), m.model.vocab * m.model.d_model);
        // trained weights should not be all-zero or NaN
        assert!(embed.iter().any(|&x| x != 0.0));
        assert!(embed.iter().all(|x| x.is_finite()));
        // rms gains near 1 (trained from init 1.0)
        let rms = w.get("layers.0.rms1").unwrap();
        let mean: f32 = rms.iter().sum::<f32>() / rms.len() as f32;
        assert!((0.2..5.0).contains(&mean), "rms1 mean {mean}");
    }

    #[test]
    fn seeded_weights_are_deterministic_and_sane() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let a = WeightStore::seeded(&m, spec.seed);
        let b = WeightStore::seeded(&m, spec.seed);
        for name in a.names() {
            let (wa, wb) = (a.get(name).unwrap(), b.get(name).unwrap());
            assert_eq!(wa.len(), wb.len());
            assert!(
                wa.iter()
                    .zip(wb.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{name}: seeded weights must be bit-identical"
            );
            assert!(wa.iter().all(|x| x.is_finite()), "{name} non-finite");
        }
        // a different seed changes the weights
        let c = WeightStore::seeded(&m, spec.seed ^ 1);
        assert!(a
            .get("embed")
            .unwrap()
            .iter()
            .zip(c.get("embed").unwrap())
            .any(|(x, y)| x != y));
        // policy spot checks
        let rms = a.get("layers.0.rms1").unwrap();
        let mean: f32 = rms.iter().sum::<f32>() / rms.len() as f32;
        assert!((0.5..1.5).contains(&mean), "rms gain mean {mean}");
        let alpha = a.get("comp.0.alpha").unwrap();
        assert!(alpha.iter().all(|&x| x > 0.0 && x < 1.0));
        assert!(
            alpha.windows(2).all(|w| w[0] == w[1]),
            "alpha is one gate per layer"
        );
        assert!(
            alpha[0] != a.get("comp.1.alpha").unwrap()[0],
            "distinct gates across layers"
        );
        assert_eq!(a.total_params(), b.total_params());
    }

    /// The synthetic table carries the low-rank expert predictor
    /// (`pred.{l}.wd` / `pred.{l}.wu`) with consistent shapes — the
    /// CPU backend derives the rank from these at dispatch time.
    #[test]
    fn seeded_low_rank_predictor_shapes_are_consistent() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let w = WeightStore::seeded(&m, spec.seed);
        for l in 0..m.model.n_layers {
            let wd = w.get(&format!("pred.{l}.wd")).unwrap();
            let wu = w.get(&format!("pred.{l}.wu")).unwrap();
            assert_eq!(wd.len(), m.model.d_model * spec.pred_rank);
            assert_eq!(wu.len(), spec.pred_rank * m.model.d_ffn);
            assert_eq!(
                w.shape(&format!("pred.{l}.wd")).unwrap(),
                &[m.model.d_model, spec.pred_rank]
            );
            assert!(wd.iter().chain(wu.iter()).all(|x| x.is_finite()));
            assert!(wd.iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn bf16_round_trip_and_rounding_mode() {
        // Exactly representable values survive the round trip.
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, f32::INFINITY] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)).to_bits(), v.to_bits());
        }
        // Round-to-nearest-even on the dropped mantissa half: 1.0 plus
        // exactly half a bf16 ulp rounds to the even neighbour (1.0).
        let half_ulp = f32::from_bits(1.0f32.to_bits() + 0x8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(half_ulp)), 1.0);
        // ...and anything past the halfway point rounds up.
        let past = f32::from_bits(1.0f32.to_bits() + 0x8001);
        assert!(bf16_to_f32(f32_to_bf16(past)) > 1.0);
        // NaN stays NaN (never collapses to an infinity).
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // Relative error of rounding is within 2^-8 for normal values.
        let v = 0.123456789f32;
        let r = bf16_to_f32(f32_to_bf16(v));
        assert!(((r - v) / v).abs() <= 1.0 / 256.0);
    }

    #[test]
    fn seeded_bf16_store_mirrors_widened_values() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let f = WeightStore::seeded(&m, spec.seed);
        let b = WeightStore::seeded_with(
            &m,
            spec.seed,
            WeightPrecision::Bf16,
        );
        assert_eq!(f.precision(), WeightPrecision::F32);
        assert_eq!(b.precision(), WeightPrecision::Bf16);
        assert!(f.get_bf16("embed").is_none());
        let mut any_rounded = false;
        for name in b.names() {
            let raw = b.get_bf16(name).expect("bf16 mirror present");
            let wide = b.get(name).unwrap();
            let full = f.get(name).unwrap();
            assert_eq!(raw.len(), wide.len());
            for i in 0..raw.len() {
                // the f32 view is exactly the widened raw word…
                assert_eq!(
                    wide[i].to_bits(),
                    bf16_to_f32(raw[i]).to_bits(),
                    "{name}[{i}]"
                );
                // …which is the rounded full-precision value
                assert_eq!(raw[i], f32_to_bf16(full[i]), "{name}[{i}]");
                any_rounded |= wide[i].to_bits() != full[i].to_bits();
            }
        }
        assert!(any_rounded, "rounding must actually change values");
        assert_ne!(
            f.fingerprint(),
            b.fingerprint(),
            "precisions must never share prefix-cache KV"
        );
    }

    #[test]
    fn weight_precision_parses_and_labels() {
        assert_eq!(WeightPrecision::parse("f32"), Some(WeightPrecision::F32));
        assert_eq!(
            WeightPrecision::parse("bf16"),
            Some(WeightPrecision::Bf16)
        );
        assert_eq!(WeightPrecision::parse("fp8"), None);
        assert_eq!(WeightPrecision::F32.label(), "f32");
        assert_eq!(WeightPrecision::Bf16.label(), "bf16");
    }

    #[test]
    fn from_data_validates_bounds() {
        let mut table = BTreeMap::new();
        table.insert(
            "w".to_string(),
            WeightEntry { offset: 0, shape: vec![4] },
        );
        assert!(WeightStore::from_data(vec![0.0; 4], table.clone()).is_ok());
        assert!(WeightStore::from_data(vec![0.0; 3], table).is_err());
    }

    #[test]
    fn rejects_out_of_bounds_table() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut bad = m.weights.clone();
        bad.insert(
            "bogus".into(),
            crate::manifest::WeightEntry {
                offset: usize::MAX / 2,
                shape: vec![10],
            },
        );
        assert!(WeightStore::load_from(&m.weights_file, bad).is_err());
    }
}
