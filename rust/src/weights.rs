//! Weight store: loads weights.bin (flat little-endian f32, offsets from
//! the manifest) and serves per-tensor slices to the runtime dispatcher.
//! [`WeightStore::seeded`] instead *generates* deterministic synthetic
//! weights from a manifest's table — the artifact-free substrate the
//! pure-Rust [`crate::runtime::CpuBackend`] runs the always-on numeric
//! test tier against.
//!
//! **Single residency.** A store holds exactly one representation of
//! the weights: f32 XOR raw bf16 words XOR int8 panels + per-tile f32
//! scales. Reduced-precision stores do *not* keep a widened f32 mirror
//! (an earlier revision did, leaving bf16 mode resident at 1.5× the
//! f32 footprint); consumers either stream the native representation
//! ([`WeightStore::view`]) or materialize a transient f32 copy
//! ([`WeightStore::dequant`]). The per-tier resident footprint is
//! regression-tested via [`WeightStore::resident_bytes`].

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{Manifest, WeightEntry};
use crate::util::hash;
use crate::util::rng::Rng;

/// Env var naming the synthetic weight storage precision
/// (`f32` | `bf16` | `int8`); the `--weight-precision` CLI flag
/// forwards through it so every engine construction site resolves the
/// same mode.
pub const PRECISION_ENV: &str = "FF_WEIGHT_PREC";

/// Column-tile width of the int8 quantizer: one f32 scale per
/// `QUANT_TILE`-wide slice of each panel row (symmetric absmax). Must
/// equal the CPU kernels' column tile (`COL_TILE`) so a tiled matmul
/// touches exactly one scale per (row, column-tile) pair — asserted at
/// backend construction in `runtime/cpu.rs`.
pub const QUANT_TILE: usize = 128;

/// Storage precision of the seeded synthetic weights.
///
/// `Bf16` and `Int8` are *storage* modes: the generated f32 values are
/// rounded (bf16, round-to-nearest-even) or quantized (int8, symmetric
/// absmax per [`QUANT_TILE`]-wide panel slice) once at seed time, and
/// all arithmetic still accumulates in f32 — the
/// load-compressed/compute-dense pattern. The store keeps only the
/// reduced representation resident; kernels widen it in registers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightPrecision {
    /// Full f32 storage (the default).
    #[default]
    F32,
    /// bfloat16 storage, f32 accumulation.
    Bf16,
    /// int8 storage with per-column-tile f32 scales, f32 accumulation.
    Int8,
}

impl WeightPrecision {
    /// Parse a CLI/env spelling (`f32` | `bf16` | `int8`).
    pub fn parse(s: &str) -> Option<WeightPrecision> {
        match s {
            "f32" => Some(WeightPrecision::F32),
            "bf16" => Some(WeightPrecision::Bf16),
            "int8" => Some(WeightPrecision::Int8),
            _ => None,
        }
    }

    /// Resolve from [`PRECISION_ENV`]; unset or unparsable means
    /// [`WeightPrecision::F32`].
    pub fn from_env() -> WeightPrecision {
        std::env::var(PRECISION_ENV)
            .ok()
            .and_then(|s| Self::parse(&s))
            .unwrap_or_default()
    }

    /// Stable display label (the CLI/env spelling).
    pub fn label(self) -> &'static str {
        match self {
            WeightPrecision::F32 => "f32",
            WeightPrecision::Bf16 => "bf16",
            WeightPrecision::Int8 => "int8",
        }
    }
}

/// Round an f32 to bfloat16 (round-to-nearest-even on the dropped 16
/// mantissa bits). NaN payloads are quieted so the result is never an
/// accidental infinity.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// Widen a bfloat16 bit pattern back to f32 (exact).
pub fn bf16_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Symmetric absmax int8 quantization of one row-major `rows × cols`
/// panel: each row is cut into [`QUANT_TILE`]-wide slices, every slice
/// gets `scale = absmax / 127` (an all-zero slice keeps scale 0 and
/// all-zero codes — no division by zero), and each value becomes
/// `round(v / scale)` clamped to ±127. Dequantization is
/// `q as f32 * scale`, so the per-element round-trip error is bounded
/// by `scale / 2 = absmax / 254`.
///
/// Returns `(codes, scales)` with `codes.len() == rows * cols` and
/// `scales.len() == rows * cols.div_ceil(QUANT_TILE)`; the scale for
/// element `(r, c)` is `scales[r * n_tiles + c / QUANT_TILE]`.
pub fn quantize_int8(
    values: &[f32],
    rows: usize,
    cols: usize,
) -> (Vec<i8>, Vec<f32>) {
    assert_eq!(values.len(), rows * cols, "panel shape mismatch");
    let n_tiles = cols.div_ceil(QUANT_TILE);
    let mut q = vec![0i8; values.len()];
    let mut scales = vec![0f32; rows * n_tiles];
    for r in 0..rows {
        let row = &values[r * cols..(r + 1) * cols];
        for tile in 0..n_tiles {
            let c0 = tile * QUANT_TILE;
            let c1 = (c0 + QUANT_TILE).min(cols);
            let absmax =
                row[c0..c1].iter().fold(0f32, |m, &v| m.max(v.abs()));
            if absmax == 0.0 {
                continue;
            }
            let scale = absmax / 127.0;
            scales[r * n_tiles + tile] = scale;
            for c in c0..c1 {
                let code = (row[c] / scale).round();
                q[r * cols + c] = code.clamp(-127.0, 127.0) as i8;
            }
        }
    }
    (q, scales)
}

/// The single resident representation of the weight values. Exactly
/// one variant is held — no widened mirrors (see module docs).
#[derive(Debug)]
enum Storage {
    /// Flat f32 buffer, indexed by `offset / 4` from the table.
    F32(Vec<f32>),
    /// Raw bf16 words, same `offset / 4` element layout as f32.
    Bf16(Vec<u16>),
    /// int8 codes (same element layout) plus per-tensor scale vectors
    /// in [`quantize_int8`]'s `(row, column-tile)` layout.
    Int8 {
        q: Vec<i8>,
        scales: BTreeMap<String, Vec<f32>>,
    },
}

/// Borrowed native representation of one tensor, for kernels that
/// stream reduced-precision panels and widen in registers.
#[derive(Debug, Clone, Copy)]
pub enum WeightView<'a> {
    /// Full-precision panel.
    F32(&'a [f32]),
    /// Raw bf16 words; widening each word is exact.
    Bf16(&'a [u16]),
    /// int8 codes + scales; element `(r, c)` of a `rows × cols` panel
    /// dequantizes as
    /// `q[r * cols + c] as f32 * scales[r * n_tiles + c / QUANT_TILE]`
    /// with `n_tiles = cols.div_ceil(QUANT_TILE)`.
    Int8 {
        /// Quantized codes, row-major.
        q: &'a [i8],
        /// Per-(row, column-tile) scales.
        scales: &'a [f32],
        /// Row length of the panel (scale indexing needs it).
        cols: usize,
    },
}

/// All model weights resident in one representation (see [`Storage`])
/// plus the name → (offset, shape) table from the manifest.
///
/// Plain immutable data, hence `Send + Sync`: the executor pool loads
/// or seeds **one** store and shares it across every replica thread
/// through an `Arc` (see
/// [`crate::pool::ExecutorPool::shared_backend_factory`]) — replicas
/// must never re-seed their own copy, which is asserted by the
/// fingerprint regression in `tests/backend_conformance.rs`.
#[derive(Debug)]
pub struct WeightStore {
    storage: Storage,
    table: BTreeMap<String, WeightEntry>,
}

/// Quantization panel geometry of a table entry: matrices quantize per
/// (first-dim row, [`QUANT_TILE`]-wide slice of the remaining dims),
/// vectors as a single row.
fn panel_dims(e: &WeightEntry) -> (usize, usize) {
    let rows = if e.shape.len() >= 2 { e.shape[0].max(1) } else { 1 };
    (rows, e.numel() / rows)
}

impl WeightStore {
    /// Load the blob named by a manifest.
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        Self::load_from(&manifest.weights_file, manifest.weights.clone())
    }

    /// Load a blob with an explicit weight table (validated on load).
    pub fn load_from(
        path: &Path,
        table: BTreeMap<String, WeightEntry>,
    ) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "weights.bin length {} not a multiple of 4",
            bytes.len()
        );
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        Self::from_data(data, table)
    }

    /// Build a store from an in-memory f32 buffer + table
    /// (bounds-validated like [`WeightStore::load_from`]).
    pub fn from_data(
        data: Vec<f32>,
        table: BTreeMap<String, WeightEntry>,
    ) -> Result<WeightStore> {
        for (name, e) in &table {
            let end = e.offset / 4 + e.numel();
            anyhow::ensure!(
                e.offset % 4 == 0 && end <= data.len(),
                "weight {name} out of bounds (offset {} numel {})",
                e.offset,
                e.numel()
            );
        }
        Ok(WeightStore { storage: Storage::F32(data), table })
    }

    /// Generate deterministic synthetic weights for every entry in the
    /// manifest's table. Each tensor draws from its own RNG stream
    /// (seeded by `seed` and the tensor *name*, so table iteration
    /// order is irrelevant): every run, on every machine, produces
    /// bit-identical weights — the foundation of the reproducible
    /// CPU-backend test tier.
    ///
    /// Initialization policy (shapes from [`Manifest::synthetic`]):
    /// * RMSNorm gains (`rms1`/`rms2`/`final_rms`) — near 1.
    /// * Compensator gates (`comp.*.alpha`) — one constant per layer,
    ///   strictly inside (0, 1): the reference compensator then
    ///   *provably* shrinks the sparse-FFN error (see
    ///   `runtime::cpu`).
    /// * Matrices — normal, scaled by `1/sqrt(fan_in)` (first dim).
    pub fn seeded(manifest: &Manifest, seed: u64) -> WeightStore {
        Self::seeded_with(manifest, seed, WeightPrecision::F32)
    }

    /// [`WeightStore::seeded`] with an explicit storage precision. The
    /// f32 values are generated first, then converted *in place of*
    /// the f32 buffer — only the reduced representation stays resident
    /// (bf16: RNE-rounded words; int8: [`quantize_int8`] codes +
    /// scales). The value [`WeightStore::fingerprint`] therefore
    /// differs from the f32 store's, so prefix-cache KV never crosses
    /// precisions.
    pub fn seeded_with(
        manifest: &Manifest,
        seed: u64,
        precision: WeightPrecision,
    ) -> WeightStore {
        let store = Self::seeded_f32(manifest, seed);
        let Storage::F32(data) = store.storage else {
            unreachable!("seeded_f32 builds an f32 store");
        };
        let table = store.table;
        let storage = match precision {
            WeightPrecision::F32 => Storage::F32(data),
            WeightPrecision::Bf16 => {
                Storage::Bf16(data.iter().map(|&v| f32_to_bf16(v)).collect())
            }
            WeightPrecision::Int8 => {
                let mut q = vec![0i8; data.len()];
                let mut scales = BTreeMap::new();
                for (name, e) in &table {
                    let (rows, cols) = panel_dims(e);
                    let start = e.offset / 4;
                    let (tq, ts) = quantize_int8(
                        &data[start..start + e.numel()],
                        rows,
                        cols,
                    );
                    q[start..start + e.numel()].copy_from_slice(&tq);
                    scales.insert(name.clone(), ts);
                }
                Storage::Int8 { q, scales }
            }
        };
        WeightStore { storage, table }
    }

    fn seeded_f32(manifest: &Manifest, seed: u64) -> WeightStore {
        let total = manifest
            .weights
            .values()
            .map(|e| e.offset / 4 + e.numel())
            .max()
            .unwrap_or(0);
        let mut data = vec![0f32; total];
        for (name, e) in &manifest.weights {
            let mut rng = Rng::new(seed ^ hash::fnv1a(name.as_bytes()));
            let start = e.offset / 4;
            let out = &mut data[start..start + e.numel()];
            if name.ends_with("rms1")
                || name.ends_with("rms2")
                || name == "final_rms"
            {
                for v in out.iter_mut() {
                    *v = 1.0 + 0.05 * rng.normal() as f32;
                }
            } else if name.ends_with(".alpha") {
                let gate = (0.4 + 0.2 * rng.f64()) as f32;
                for v in out.iter_mut() {
                    *v = gate;
                }
            } else {
                let fan_in = e.shape.first().copied().unwrap_or(1).max(1);
                let scale = 1.0 / (fan_in as f64).sqrt();
                for v in out.iter_mut() {
                    *v = (rng.normal() * scale) as f32;
                }
            }
        }
        Self::from_data(data, manifest.weights.clone())
            .expect("seeded data is sized to the manifest table")
    }

    /// Stable 64-bit fingerprint of the *stored weight values* (table
    /// layout + every raw bit pattern of the resident representation,
    /// plus the precision label for reduced tiers). Computed once at
    /// runtime construction and mixed into
    /// [`crate::runtime::Runtime::numeric_fingerprint`]: two stores
    /// with the same shapes but different values (a different seed,
    /// retrained artifacts) — or the same values at different storage
    /// precisions — must never share prefix-cache KV.
    pub fn fingerprint(&self) -> u64 {
        let mut h = hash::BASIS;
        for (name, e) in &self.table {
            h = hash::mix(h, hash::fnv1a(name.as_bytes()));
            h = hash::mix(h, e.offset as u64);
            let start = e.offset / 4;
            match &self.storage {
                Storage::F32(data) => {
                    for &v in &data[start..start + e.numel()] {
                        h = hash::mix(h, v.to_bits() as u64);
                    }
                }
                Storage::Bf16(raw) => {
                    for &b in &raw[start..start + e.numel()] {
                        h = hash::mix(h, b as u64);
                    }
                }
                Storage::Int8 { q, scales } => {
                    for &c in &q[start..start + e.numel()] {
                        h = hash::mix(h, c as u8 as u64);
                    }
                    for &s in scales.get(name).map_or(&[][..], |v| v) {
                        h = hash::mix(h, s.to_bits() as u64);
                    }
                }
            }
        }
        // The f32 hash stays byte-for-byte what it always was; reduced
        // tiers additionally mix their label so raw-word collisions
        // across representations can never alias fingerprints.
        match self.precision() {
            WeightPrecision::F32 => h,
            p => hash::mix(h, hash::fnv1a(p.label().as_bytes())),
        }
    }

    fn entry(&self, name: &str) -> Result<&WeightEntry> {
        self.table
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))
    }

    /// Borrow one tensor's f32 data by name. Only f32 stores serve
    /// this view — reduced-precision stores have no resident f32
    /// mirror (use [`WeightStore::view`] to stream the native panels
    /// or [`WeightStore::dequant`] for a transient widened copy).
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        let e = self.entry(name)?;
        let start = e.offset / 4;
        match &self.storage {
            Storage::F32(data) => Ok(&data[start..start + e.numel()]),
            _ => Err(anyhow!(
                "weight {name} is stored as {} (no resident f32 view); \
                 use view() or dequant()",
                self.precision().label()
            )),
        }
    }

    /// Borrow one tensor in its native stored representation.
    pub fn view(&self, name: &str) -> Result<WeightView<'_>> {
        let e = self.entry(name)?;
        let start = e.offset / 4;
        Ok(match &self.storage {
            Storage::F32(data) => {
                WeightView::F32(&data[start..start + e.numel()])
            }
            Storage::Bf16(raw) => {
                WeightView::Bf16(&raw[start..start + e.numel()])
            }
            Storage::Int8 { q, scales } => {
                let (_, cols) = panel_dims(e);
                WeightView::Int8 {
                    q: &q[start..start + e.numel()],
                    scales: scales
                        .get(name)
                        .map_or(&[][..], |v| v.as_slice()),
                    cols,
                }
            }
        })
    }

    /// Materialize one tensor as f32, whatever the stored
    /// representation (exact widening for bf16, `q * scale` for int8).
    /// A transient copy even on f32 stores — construction-time
    /// consumers only; hot paths stream [`WeightStore::view`].
    pub fn dequant(&self, name: &str) -> Result<Vec<f32>> {
        Ok(match self.view(name)? {
            WeightView::F32(w) => w.to_vec(),
            WeightView::Bf16(raw) => {
                raw.iter().map(|&b| bf16_to_f32(b)).collect()
            }
            WeightView::Int8 { q, scales, cols } => {
                let n_tiles = cols.div_ceil(QUANT_TILE);
                q.iter()
                    .enumerate()
                    .map(|(i, &c)| {
                        let (r, col) = (i / cols, i % cols);
                        c as f32 * scales[r * n_tiles + col / QUANT_TILE]
                    })
                    .collect()
            }
        })
    }

    /// Borrow one tensor's raw bf16 words, or `None` unless this is a
    /// bf16 store. Widening each word reproduces the seeded rounded
    /// values exactly.
    pub fn get_bf16(&self, name: &str) -> Option<&[u16]> {
        match self.view(name).ok()? {
            WeightView::Bf16(raw) => Some(raw),
            _ => None,
        }
    }

    /// Storage precision of this store.
    pub fn precision(&self) -> WeightPrecision {
        match &self.storage {
            Storage::F32(_) => WeightPrecision::F32,
            Storage::Bf16(_) => WeightPrecision::Bf16,
            Storage::Int8 { .. } => WeightPrecision::Int8,
        }
    }

    /// Bytes resident for the weight values themselves (codes +
    /// scales for int8). The single-residency regression test pins
    /// int8 < bf16 < f32 on the synthetic model.
    pub fn resident_bytes(&self) -> usize {
        match &self.storage {
            Storage::F32(data) => std::mem::size_of_val(data.as_slice()),
            Storage::Bf16(raw) => std::mem::size_of_val(raw.as_slice()),
            Storage::Int8 { q, scales } => {
                std::mem::size_of_val(q.as_slice())
                    + scales
                        .values()
                        .map(|s| std::mem::size_of_val(s.as_slice()))
                        .sum::<usize>()
            }
        }
    }

    /// One tensor's shape by name.
    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self.entry(name)?.shape)
    }

    /// Iterate all weight names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.table.keys()
    }

    /// Total parameter count across the table.
    pub fn total_params(&self) -> usize {
        self.table.values().map(|e| e.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn loads_and_validates_real_weights() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        let embed = w.get("embed").unwrap();
        assert_eq!(embed.len(), m.model.vocab * m.model.d_model);
        // trained weights should not be all-zero or NaN
        assert!(embed.iter().any(|&x| x != 0.0));
        assert!(embed.iter().all(|x| x.is_finite()));
        // rms gains near 1 (trained from init 1.0)
        let rms = w.get("layers.0.rms1").unwrap();
        let mean: f32 = rms.iter().sum::<f32>() / rms.len() as f32;
        assert!((0.2..5.0).contains(&mean), "rms1 mean {mean}");
    }

    #[test]
    fn seeded_weights_are_deterministic_and_sane() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let a = WeightStore::seeded(&m, spec.seed);
        let b = WeightStore::seeded(&m, spec.seed);
        for name in a.names() {
            let (wa, wb) = (a.get(name).unwrap(), b.get(name).unwrap());
            assert_eq!(wa.len(), wb.len());
            assert!(
                wa.iter()
                    .zip(wb.iter())
                    .all(|(x, y)| x.to_bits() == y.to_bits()),
                "{name}: seeded weights must be bit-identical"
            );
            assert!(wa.iter().all(|x| x.is_finite()), "{name} non-finite");
        }
        // a different seed changes the weights
        let c = WeightStore::seeded(&m, spec.seed ^ 1);
        assert!(a
            .get("embed")
            .unwrap()
            .iter()
            .zip(c.get("embed").unwrap())
            .any(|(x, y)| x != y));
        // policy spot checks
        let rms = a.get("layers.0.rms1").unwrap();
        let mean: f32 = rms.iter().sum::<f32>() / rms.len() as f32;
        assert!((0.5..1.5).contains(&mean), "rms gain mean {mean}");
        let alpha = a.get("comp.0.alpha").unwrap();
        assert!(alpha.iter().all(|&x| x > 0.0 && x < 1.0));
        assert!(
            alpha.windows(2).all(|w| w[0] == w[1]),
            "alpha is one gate per layer"
        );
        assert!(
            alpha[0] != a.get("comp.1.alpha").unwrap()[0],
            "distinct gates across layers"
        );
        assert_eq!(a.total_params(), b.total_params());
    }

    /// The synthetic table carries the low-rank expert predictor
    /// (`pred.{l}.wd` / `pred.{l}.wu`) with consistent shapes — the
    /// CPU backend derives the rank from these at dispatch time.
    #[test]
    fn seeded_low_rank_predictor_shapes_are_consistent() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let w = WeightStore::seeded(&m, spec.seed);
        for l in 0..m.model.n_layers {
            let wd = w.get(&format!("pred.{l}.wd")).unwrap();
            let wu = w.get(&format!("pred.{l}.wu")).unwrap();
            assert_eq!(wd.len(), m.model.d_model * spec.pred_rank);
            assert_eq!(wu.len(), spec.pred_rank * m.model.d_ffn);
            assert_eq!(
                w.shape(&format!("pred.{l}.wd")).unwrap(),
                &[m.model.d_model, spec.pred_rank]
            );
            assert!(wd.iter().chain(wu.iter()).all(|x| x.is_finite()));
            assert!(wd.iter().any(|&x| x != 0.0));
        }
    }

    #[test]
    fn bf16_round_trip_and_rounding_mode() {
        // Exactly representable values survive the round trip.
        for v in [0.0f32, -0.0, 1.0, -2.0, 0.5, f32::INFINITY] {
            assert_eq!(bf16_to_f32(f32_to_bf16(v)).to_bits(), v.to_bits());
        }
        // Round-to-nearest-even on the dropped mantissa half: 1.0 plus
        // exactly half a bf16 ulp rounds to the even neighbour (1.0).
        let half_ulp = f32::from_bits(1.0f32.to_bits() + 0x8000);
        assert_eq!(bf16_to_f32(f32_to_bf16(half_ulp)), 1.0);
        // ...and anything past the halfway point rounds up.
        let past = f32::from_bits(1.0f32.to_bits() + 0x8001);
        assert!(bf16_to_f32(f32_to_bf16(past)) > 1.0);
        // NaN stays NaN (never collapses to an infinity).
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
        // Relative error of rounding is within 2^-8 for normal values.
        let v = 0.123456789f32;
        let r = bf16_to_f32(f32_to_bf16(v));
        assert!(((r - v) / v).abs() <= 1.0 / 256.0);
    }

    /// Edge cases of the rounding path: NaN quieting, both infinities,
    /// and mantissa-rounding carries that overflow into the exponent
    /// (including the carry past `f32::MAX` into infinity — the case
    /// the `wrapping_add` must produce, not wrap into a small value).
    #[test]
    fn bf16_edge_cases_nan_inf_and_mantissa_carry() {
        // A NaN whose payload lives only in the dropped low 16 bits
        // would truncate to an infinity pattern; the quieting bit must
        // keep it NaN (and quiet: mantissa bit 6 set).
        let payload_nan = f32::from_bits(0x7F80_0001);
        assert!(payload_nan.is_nan());
        let q = f32_to_bf16(payload_nan);
        assert_eq!(q & 0x7F80, 0x7F80, "exponent stays all-ones");
        assert_ne!(q & 0x007F, 0, "mantissa must stay nonzero (NaN)");
        assert_eq!(q & 0x0040, 0x0040, "quiet bit set");
        assert!(bf16_to_f32(q).is_nan());
        // Sign survives quieting.
        let neg_nan = f32::from_bits(0xFF80_0001);
        assert_eq!(f32_to_bf16(neg_nan) & 0x8000, 0x8000);
        // Both infinities are exactly representable and exact.
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        assert_eq!(f32_to_bf16(f32::NEG_INFINITY), 0xFF80);
        // Mantissa carry into the exponent: just under 2.0 rounds up
        // across the binade boundary to exactly 2.0.
        let under_two = f32::from_bits(0x3FFF_FFFF);
        assert_eq!(bf16_to_f32(f32_to_bf16(under_two)), 2.0);
        // Carry past the largest finite bf16: f32::MAX (mantissa
        // all-ones) must round to +inf under RNE, and symmetrically
        // for -MAX — not wrap around.
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16(f32::MIN), 0xFF80);
        // The largest value that rounds *down* stays the top finite
        // bf16 (0x7F7F): bf16::MAX plus less than half an ulp.
        let max_bf16 = bf16_to_f32(0x7F7F);
        let below_half = f32::from_bits(max_bf16.to_bits() + 0x7FFF);
        assert_eq!(f32_to_bf16(below_half), 0x7F7F);
        // Exactly half an ulp above ties to even — and the even
        // neighbour here is the infinity pattern's predecessor's
        // upper neighbour 0x7F80 (odd mantissa 0x7F rounds away).
        let half_above = f32::from_bits(max_bf16.to_bits() + 0x8000);
        assert_eq!(f32_to_bf16(half_above), 0x7F80);
    }

    /// The bf16 store is single-residency: raw words only, no widened
    /// f32 mirror. `dequant` reproduces the RNE-rounded values of the
    /// f32 seed, rounding genuinely changes values, and the
    /// fingerprint diverges from the f32 store's.
    #[test]
    fn seeded_bf16_store_is_rounded_and_single_residency() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let f = WeightStore::seeded(&m, spec.seed);
        let b = WeightStore::seeded_with(
            &m,
            spec.seed,
            WeightPrecision::Bf16,
        );
        assert_eq!(f.precision(), WeightPrecision::F32);
        assert_eq!(b.precision(), WeightPrecision::Bf16);
        assert!(f.get_bf16("embed").is_none());
        // no resident f32 view on the reduced store
        let err = b.get("embed").unwrap_err().to_string();
        assert!(err.contains("bf16"), "{err}");
        let mut any_rounded = false;
        for name in b.names() {
            let raw = b.get_bf16(name).expect("bf16 words present");
            let wide = b.dequant(name).unwrap();
            let full = f.get(name).unwrap();
            assert_eq!(raw.len(), wide.len());
            for i in 0..raw.len() {
                // dequant is exactly the widened raw word…
                assert_eq!(
                    wide[i].to_bits(),
                    bf16_to_f32(raw[i]).to_bits(),
                    "{name}[{i}]"
                );
                // …which is the rounded full-precision value
                assert_eq!(raw[i], f32_to_bf16(full[i]), "{name}[{i}]");
                any_rounded |= wide[i].to_bits() != full[i].to_bits();
            }
        }
        assert!(any_rounded, "rounding must actually change values");
        assert_ne!(
            f.fingerprint(),
            b.fingerprint(),
            "precisions must never share prefix-cache KV"
        );
    }

    /// int8 quantizer properties: per-tile round-trip error bound
    /// (≤ absmax / 254), zero tiles quantize to zero scale + zero
    /// codes without dividing by zero, and the codes stay in ±127.
    #[test]
    fn int8_quantizer_round_trip_error_is_bounded() {
        let mut rng = Rng::new(0x1178_0001);
        let (rows, cols) = (7, 300); // ragged: 300 = 2*128 + 44
        let vals: Vec<f32> = (0..rows * cols)
            .map(|_| (rng.normal() * 0.3) as f32)
            .collect();
        let (q, scales) = quantize_int8(&vals, rows, cols);
        let n_tiles = cols.div_ceil(QUANT_TILE);
        assert_eq!(scales.len(), rows * n_tiles);
        for r in 0..rows {
            for tile in 0..n_tiles {
                let c0 = tile * QUANT_TILE;
                let c1 = (c0 + QUANT_TILE).min(cols);
                let absmax = vals[r * cols + c0..r * cols + c1]
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()));
                let s = scales[r * n_tiles + tile];
                assert!((s - absmax / 127.0).abs() <= f32::EPSILON * absmax);
                for c in c0..c1 {
                    let v = vals[r * cols + c];
                    let dq = q[r * cols + c] as f32 * s;
                    assert!(
                        (v - dq).abs() <= absmax / 254.0 + 1e-9,
                        "({r},{c}): |{v} - {dq}| > absmax/254"
                    );
                }
            }
        }
        assert!(q.iter().all(|&c| (-127..=127).contains(&(c as i32))));
    }

    #[test]
    fn int8_quantizer_zero_panel_and_determinism() {
        // an all-zero tile inside an otherwise nonzero panel
        let cols = 2 * QUANT_TILE;
        let mut vals = vec![0f32; cols];
        for (i, v) in vals[QUANT_TILE..].iter_mut().enumerate() {
            *v = (i as f32 - 60.0) * 0.01;
        }
        let (q, scales) = quantize_int8(&vals, 1, cols);
        assert_eq!(scales[0], 0.0, "zero tile keeps zero scale");
        assert!(q[..QUANT_TILE].iter().all(|&c| c == 0));
        assert!(scales[1] > 0.0);
        assert!(q[QUANT_TILE..].iter().any(|&c| c != 0));
        // extreme values land exactly on ±127
        let (q2, s2) = quantize_int8(&[-1.0, 1.0, 0.5], 1, 3);
        assert_eq!(s2[0], 1.0 / 127.0);
        assert_eq!((q2[0], q2[1]), (-127, 127));
        // deterministic: same input, same codes + scales
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let a = WeightStore::seeded_with(&m, spec.seed,
                                         WeightPrecision::Int8);
        let b = WeightStore::seeded_with(&m, spec.seed,
                                         WeightPrecision::Int8);
        assert_eq!(a.fingerprint(), b.fingerprint());
    }

    /// The seeded int8 store dequantizes within the per-tile bound of
    /// the f32 seed on every tensor, and its views carry consistent
    /// scale geometry.
    #[test]
    fn seeded_int8_store_dequantizes_within_bound() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let f = WeightStore::seeded(&m, spec.seed);
        let i8s = WeightStore::seeded_with(
            &m,
            spec.seed,
            WeightPrecision::Int8,
        );
        assert_eq!(i8s.precision(), WeightPrecision::Int8);
        assert!(i8s.get_bf16("embed").is_none());
        assert!(i8s.get("embed").is_err());
        for name in i8s.names() {
            let full = f.get(name).unwrap();
            let dq = i8s.dequant(name).unwrap();
            let WeightView::Int8 { q, scales, cols } =
                i8s.view(name).unwrap()
            else {
                panic!("{name}: int8 view expected");
            };
            assert_eq!(q.len(), full.len());
            let n_tiles = cols.div_ceil(QUANT_TILE);
            assert_eq!(scales.len(), (full.len() / cols) * n_tiles);
            for (i, (&v, &d)) in full.iter().zip(dq.iter()).enumerate() {
                let (r, c) = (i / cols, i % cols);
                let c0 = (c / QUANT_TILE) * QUANT_TILE;
                let c1 = (c0 + QUANT_TILE).min(cols);
                let absmax = full[r * cols + c0..r * cols + c1]
                    .iter()
                    .fold(0f32, |m, &v| m.max(v.abs()));
                assert!(
                    (v - d).abs() <= absmax / 254.0 + 1e-9,
                    "{name}[{i}]: |{v} - {d}| > absmax/254"
                );
            }
        }
        assert_ne!(f.fingerprint(), i8s.fingerprint());
    }

    /// The single-residency contract, measured: per-tier resident
    /// weight bytes strictly order int8 < bf16 < f32 (bf16 no longer
    /// keeps a widened mirror; int8 is codes + per-tile scales).
    #[test]
    fn resident_bytes_order_int8_lt_bf16_lt_f32() {
        let spec = crate::manifest::SyntheticSpec::default();
        let m = Manifest::synthetic(&spec);
        let f = WeightStore::seeded(&m, spec.seed);
        let b = WeightStore::seeded_with(&m, spec.seed,
                                         WeightPrecision::Bf16);
        let q = WeightStore::seeded_with(&m, spec.seed,
                                         WeightPrecision::Int8);
        let (bf, bb, bq) = (
            f.resident_bytes(),
            b.resident_bytes(),
            q.resident_bytes(),
        );
        assert_eq!(bb * 2, bf, "bf16 must be exactly half of f32");
        assert!(
            bq < bb && bb < bf,
            "resident bytes must order int8 ({bq}) < bf16 ({bb}) < \
             f32 ({bf})"
        );
        // int8 = 1 byte/param + scales; scales add < 4% on QUANT_TILE
        // panels of this model, so it stays well under 3/4 of bf16.
        assert!(bq * 4 < bf * 2, "int8 must stay under half of bf16×2");
    }

    #[test]
    fn weight_precision_parses_and_labels() {
        assert_eq!(WeightPrecision::parse("f32"), Some(WeightPrecision::F32));
        assert_eq!(
            WeightPrecision::parse("bf16"),
            Some(WeightPrecision::Bf16)
        );
        assert_eq!(
            WeightPrecision::parse("int8"),
            Some(WeightPrecision::Int8)
        );
        assert_eq!(WeightPrecision::parse("fp8"), None);
        assert_eq!(WeightPrecision::F32.label(), "f32");
        assert_eq!(WeightPrecision::Bf16.label(), "bf16");
        assert_eq!(WeightPrecision::Int8.label(), "int8");
    }

    #[test]
    fn from_data_validates_bounds() {
        let mut table = BTreeMap::new();
        table.insert(
            "w".to_string(),
            WeightEntry { offset: 0, shape: vec![4] },
        );
        assert!(WeightStore::from_data(vec![0.0; 4], table.clone()).is_ok());
        assert!(WeightStore::from_data(vec![0.0; 3], table).is_err());
    }

    #[test]
    fn rejects_out_of_bounds_table() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut bad = m.weights.clone();
        bad.insert(
            "bogus".into(),
            crate::manifest::WeightEntry {
                offset: usize::MAX / 2,
                shape: vec![10],
            },
        );
        assert!(WeightStore::load_from(&m.weights_file, bad).is_err());
    }
}
