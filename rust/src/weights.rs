//! Weight store: loads weights.bin (flat little-endian f32, offsets from
//! the manifest) and serves per-tensor slices to the runtime dispatcher.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::manifest::{Manifest, WeightEntry};

/// All model weights resident as one flat host f32 buffer plus the
/// name → (offset, shape) table from the manifest.
#[derive(Debug)]
pub struct WeightStore {
    data: Vec<f32>,
    table: BTreeMap<String, WeightEntry>,
}

impl WeightStore {
    /// Load the blob named by a manifest.
    pub fn load(manifest: &Manifest) -> Result<WeightStore> {
        Self::load_from(&manifest.weights_file, manifest.weights.clone())
    }

    /// Load a blob with an explicit weight table (validated on load).
    pub fn load_from(
        path: &Path,
        table: BTreeMap<String, WeightEntry>,
    ) -> Result<WeightStore> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading {path:?}"))?;
        anyhow::ensure!(
            bytes.len() % 4 == 0,
            "weights.bin length {} not a multiple of 4",
            bytes.len()
        );
        let mut data = vec![0f32; bytes.len() / 4];
        for (i, chunk) in bytes.chunks_exact(4).enumerate() {
            data[i] = f32::from_le_bytes(chunk.try_into().unwrap());
        }
        // Validate the table against the blob before serving anything.
        for (name, e) in &table {
            let end = e.offset / 4 + e.numel();
            anyhow::ensure!(
                e.offset % 4 == 0 && end <= data.len(),
                "weight {name} out of bounds (offset {} numel {})",
                e.offset,
                e.numel()
            );
        }
        Ok(WeightStore { data, table })
    }

    /// Borrow one tensor's data by name.
    pub fn get(&self, name: &str) -> Result<&[f32]> {
        let e = self
            .table
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))?;
        let start = e.offset / 4;
        Ok(&self.data[start..start + e.numel()])
    }

    /// One tensor's shape by name.
    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        Ok(&self
            .table
            .get(name)
            .ok_or_else(|| anyhow!("unknown weight {name}"))?
            .shape)
    }

    /// Iterate all weight names (sorted).
    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.table.keys()
    }

    /// Total parameter count across the table.
    pub fn total_params(&self) -> usize {
        self.table.values().map(|e| e.numel()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;

    #[test]
    fn loads_and_validates_real_weights() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let w = WeightStore::load(&m).unwrap();
        let embed = w.get("embed").unwrap();
        assert_eq!(embed.len(), m.model.vocab * m.model.d_model);
        // trained weights should not be all-zero or NaN
        assert!(embed.iter().any(|&x| x != 0.0));
        assert!(embed.iter().all(|x| x.is_finite()));
        // rms gains near 1 (trained from init 1.0)
        let rms = w.get("layers.0.rms1").unwrap();
        let mean: f32 = rms.iter().sum::<f32>() / rms.len() as f32;
        assert!((0.2..5.0).contains(&mean), "rms1 mean {mean}");
    }

    #[test]
    fn rejects_out_of_bounds_table() {
        let Some(dir) = crate::test_artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        let mut bad = m.weights.clone();
        bad.insert(
            "bogus".into(),
            crate::manifest::WeightEntry {
                offset: usize::MAX / 2,
                shape: vec![10],
            },
        );
        assert!(WeightStore::load_from(&m.weights_file, bad).is_err());
    }
}
