//! Inert stand-in for the vendored `xla` crate (PJRT bindings).
//!
//! The build environment for CI and pure host-side development does not
//! always ship the XLA extension. When the `pjrt` cargo feature is off,
//! [`crate::runtime`] compiles against this module instead of the real
//! bindings: every type checks, but constructing a client fails with a
//! clear error, so anything that actually needs to execute artifacts
//! (engine tests, benches) skips — the same behavior those tests already
//! have when artifacts are absent. All pure host-side logic (router,
//! executor pool, prefix cache, cost model, schedule, eval plumbing)
//! remains fully buildable and testable.
//!
//! The surface mirrors exactly the subset of the `xla` crate the runtime
//! dispatcher uses; see `runtime/mod.rs` for the call sites.

use std::path::Path;

/// Error returned by every stub entry point.
#[derive(Debug)]
pub struct Error;

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "fastforward was compiled without the `pjrt` feature; \
             rebuild with `--features pjrt` to execute artifacts"
        )
    }
}

impl std::error::Error for Error {}

/// Stub result alias matching the real crate's fallible API.
pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module (stub: never constructed).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text artifact. Always fails in the stub.
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<Self> {
        Err(Error)
    }
}

/// An XLA computation wrapping a parsed module.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a proto (stub: trivially constructible, but unreachable in
    /// practice because [`HloModuleProto::from_text_file`] always fails).
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Device-resident buffer handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Download the buffer to a host literal. Unreachable in the stub.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error)
    }
}

/// Host-side literal (stub: never constructed).
#[derive(Debug)]
pub struct Literal;

impl Literal {
    /// Split a tuple literal into its elements. Unreachable in the stub.
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(Error)
    }

    /// Copy out as a typed host vector. Unreachable in the stub.
    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        Err(Error)
    }
}

/// Compiled executable handle (stub: never constructed).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with device buffers. Unreachable in the stub.
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error)
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the stub's single failure
/// point: it returns [`Error`], so no other stub method ever runs.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// Create the CPU client. Always fails in the stub.
    pub fn cpu() -> Result<Self> {
        Err(Error)
    }

    /// Compile a computation. Unreachable in the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error)
    }

    /// Upload a host buffer to the device. Unreachable in the stub.
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error)
    }
}
