// Dev tool: compile an AOT HLO artifact on the PJRT CPU client and run it
// with fill-valued inputs of the given shapes, printing output shapes.
// Usage: hlo_smoke <file.hlo.txt> <specs: f128x128, i0 (scalar), i64 ...>
use anyhow::Result;

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let path = args.next().expect("hlo path");
    let client = xla::PjRtClient::cpu()?;
    let proto = xla::HloModuleProto::from_text_file(&path)?;
    let comp = xla::XlaComputation::from_proto(&proto);
    let t0 = std::time::Instant::now();
    let exe = client.compile(&comp)?;
    println!("compiled in {:?}", t0.elapsed());

    let mut lits = Vec::new();
    for spec in args {
        let (ty, dims) = spec.split_at(1);
        let dims: Vec<i64> = if dims.is_empty() || dims == "0" {
            vec![]
        } else {
            dims.split('x').map(|d| d.parse().unwrap()).collect()
        };
        let n: usize = dims.iter().product::<i64>().max(1) as usize;
        let lit = match (ty, dims.is_empty()) {
            ("f", true) => xla::Literal::from(0.1f32),
            ("f", false) => xla::Literal::vec1(&vec![0.1f32; n]).reshape(&dims)?,
            ("i", true) => xla::Literal::from(0i32),
            ("i", false) => xla::Literal::vec1(&vec![0i32; n]).reshape(&dims)?,
            _ => panic!("bad spec {spec}"),
        };
        lits.push(lit);
    }
    let t0 = std::time::Instant::now();
    let mut res = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
    println!("executed in {:?}", t0.elapsed());
    let parts = res.decompose_tuple()?;
    for (i, p) in parts.iter().enumerate() {
        println!("out[{i}]: {:?}", p.shape()?);
    }
    Ok(())
}
