//! Engine construction shared by the integration tests, benches and
//! examples — the switchboard of the two test tiers (docs/TESTING.md):
//!
//! * **Always-on tier** — [`test_engine`] returns a working engine on
//!   every machine: the real PJRT artifact engine when `make artifacts`
//!   output is present *and* the `pjrt` feature is compiled in,
//!   otherwise the deterministic pure-Rust CPU engine over a synthetic
//!   manifest + seeded weights. Weight-agnostic invariants (stepping ==
//!   one-shot, prefix adoption bit-identity, streamed == one-shot,
//!   determinism, schedule budgets) run against whichever engine comes
//!   back.
//! * **Artifact tier** — [`artifact_engine`] returns `Some` only with
//!   real trained artifacts; assertions about *trained-weight quality*
//!   (cos-sim fidelity bounds, python parity fixtures, ablation
//!   orderings) live behind it and skip cleanly elsewhere.

use std::sync::Arc;

use crate::batcher::BatcherConfig;
use crate::engine::{argmax, DecodeBatch, Engine, PrefillResult,
                    SparsityConfig};
use crate::manifest::SyntheticSpec;
use crate::pool::ExecutorPool;
use crate::router::Router;
use crate::runtime::BackendKind;

/// The deterministic CPU engine over the default synthetic model
/// (fast tiled/parallel backend; threads from `FF_CPU_THREADS`).
/// Infallible by construction (panics only on an internal bug).
pub fn cpu_engine() -> Engine {
    Engine::synthetic_cpu(&SyntheticSpec::default())
        .expect("synthetic CPU engine")
}

/// [`cpu_engine`] pinned to an explicit worker-lane count — the
/// conformance suite sweeps `threads ∈ {1, 4}` with it.
pub fn cpu_engine_threads(threads: usize) -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        crate::runtime::CpuOptions { threads, reference: false },
    )
    .expect("synthetic CPU engine")
}

/// The sequential scalar CPU *reference* engine — the oracle the fast
/// backend is conformance-tested against (bit-identical by contract).
pub fn cpu_engine_reference() -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        crate::runtime::CpuOptions { threads: 1, reference: true },
    )
    .expect("synthetic CPU reference engine")
}

/// The PJRT engine over real artifacts, or `None` when artifacts are
/// absent or the `pjrt` feature is off (caller skips trained-weight
/// assertions).
pub fn artifact_engine() -> Option<Engine> {
    let dir = crate::test_artifacts_dir()?;
    let manifest = Arc::new(
        crate::manifest::Manifest::load(&dir).expect("artifact manifest"),
    );
    let weights = Arc::new(
        crate::weights::WeightStore::load(&manifest)
            .expect("artifact weights"),
    );
    let rt = Arc::new(
        crate::runtime::Runtime::new(manifest, weights)
            .expect("pjrt runtime"),
    );
    Some(Engine::new(rt))
}

/// An engine on *this* machine, whatever it has: artifacts + PJRT when
/// available, the deterministic CPU reference otherwise. Never skips.
pub fn test_engine() -> Engine {
    artifact_engine().unwrap_or_else(cpu_engine)
}

/// Spawn an executor pool matching [`test_engine`]'s choice: artifact
/// replicas when artifacts + `pjrt` are available, synthetic CPU
/// replicas otherwise.
pub fn spawn_test_pool(router: Arc<Router>, cfg: BatcherConfig)
                       -> ExecutorPool {
    match crate::test_artifacts_dir() {
        Some(dir) => ExecutorPool::spawn_from_artifacts(router, cfg, dir),
        None => ExecutorPool::spawn_backend(
            router,
            cfg,
            BackendKind::Cpu,
            None,
        ),
    }
}

// ---------------------------------------------------------------------------
// Shared decode-bench harness (tier-1 perf gate + fig10 bench)
// ---------------------------------------------------------------------------

/// FFN-heavy decode-bench model shared by the tier-1 batched-decode
/// perf gate (`tests/perf_smoke.rs`) and the fig10 bench: ~12 MiB of
/// FFN weights per token pass (2 layers × 3 panels × 64×8192 f32), so
/// a T=1 pass streams them from beyond L2 and sequential decode is
/// weight-read bound — the regime where one shared pass for B rows
/// pays off. One definition, so the gate and the bench always measure
/// the same model.
pub fn decode_bench_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-decode".to_string(),
        n_layers: 2,
        d_ffn: 8192,
        max_ctx: 512,
        buckets: vec![256, 512],
        ..SyntheticSpec::default()
    }
}

/// Prefill `b` distinct short prompts on `engine` (dense config),
/// returning each prompt's length and prefill result — the fixed
/// starting state both decode drivers below consume.
pub fn decode_bench_seqs(engine: &Engine, b: usize)
                         -> Vec<(usize, PrefillResult)> {
    let cfg = SparsityConfig::dense();
    (0..b)
        .map(|i| {
            let toks: Vec<i32> = (0..8)
                .map(|j| ((i * 37 + j * 11) % 250 + 1) as i32)
                .collect();
            let pre = engine.prefill(&toks, &cfg).unwrap();
            (toks.len(), pre)
        })
        .collect()
}

/// Greedy-decode every sequence one at a time (`Engine::decode_step`)
/// for `steps` tokens each — the pre-batching execution profile. Each
/// run clones the prefilled caches, so it is repeatable for timing.
pub fn decode_bench_sequential(engine: &Engine,
                               seqs: &[(usize, PrefillResult)],
                               steps: usize) {
    let cfg = SparsityConfig::dense();
    for (len, pre) in seqs {
        let mut cache = pre.cache.clone();
        let mut logits = pre.last_logits.clone();
        let mut pos = *len;
        for _ in 0..steps {
            let tok = argmax(&logits) as i32;
            logits = engine
                .decode_step(tok, pos, &mut cache, &cfg)
                .unwrap();
            pos += 1;
        }
    }
}

/// Greedy-decode all sequences in lockstep through a [`DecodeBatch`]
/// (`steps` rounds, passes of at most `max_batch` rows) — the batched
/// execution profile. Clones the prefilled caches like the sequential
/// driver, so the two are directly comparable.
pub fn decode_bench_batched(engine: &Engine,
                            seqs: &[(usize, PrefillResult)],
                            steps: usize, max_batch: usize) {
    let cfg = SparsityConfig::dense();
    let mut db = DecodeBatch::new(engine.clone());
    let ids: Vec<usize> = seqs
        .iter()
        .map(|(len, pre)| {
            db.join(
                pre.cache.clone(),
                *len,
                pre.last_logits.clone(),
                cfg.clone(),
            )
        })
        .collect();
    for _ in 0..steps {
        for &id in &ids {
            let tok = argmax(db.logits(id)) as i32;
            db.feed(id, tok);
        }
        let stats = db.step(None, max_batch);
        assert!(stats.failures.is_empty(), "{:?}", stats.failures);
    }
}

// ---------------------------------------------------------------------------
// Shared attention-bench harness (tier-1 attn perf gate + fig11 bench)
// ---------------------------------------------------------------------------

/// Attention-heavy prefill-bench model shared by the tier-1 sparse-
/// attention perf gate (`tests/perf_smoke.rs`) and the fig11 bench:
/// long context with a deliberately small FFN (`d_ffn` 128), so at
/// T = 2048 the O(T²) score/softmax/weighted-V loop dominates the
/// prefill wall-clock — the regime where dropping key blocks pays off.
/// One definition, so the gate and the bench always measure the same
/// model.
pub fn attn_bench_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-attn".to_string(),
        n_layers: 2,
        d_ffn: 128,
        max_ctx: 2048,
        buckets: vec![512, 1024, 2048],
        ..SyntheticSpec::default()
    }
}

/// Dense-FFN config with block-sparse attention at `drop` (`None` =
/// fully dense attention) — the two ends the attention gate and the
/// fig11 sweep compare.
pub fn attn_bench_cfg(drop: Option<f64>) -> SparsityConfig {
    let mut cfg = SparsityConfig::dense();
    cfg.attn_sparsity = drop;
    cfg
}

/// One timed prefill of a `len`-token prompt under `cfg` (result
/// dropped; deterministic prompt so every run does identical work).
pub fn attn_bench_prefill(engine: &Engine, len: usize,
                          cfg: &SparsityConfig) {
    let toks: Vec<i32> = (0..len).map(|i| (i % 250) as i32 + 1).collect();
    engine.prefill(&toks, cfg).expect("attn bench prefill");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_engine_always_available() {
        let e = test_engine();
        assert!(e.block() > 0);
        assert!(e.manifest().model.n_layers > 0);
    }
}
