//! Engine construction shared by the integration tests, benches and
//! examples — the switchboard of the two test tiers (docs/TESTING.md):
//!
//! * **Always-on tier** — [`test_engine`] returns a working engine on
//!   every machine: the real PJRT artifact engine when `make artifacts`
//!   output is present *and* the `pjrt` feature is compiled in,
//!   otherwise the deterministic pure-Rust CPU engine over a synthetic
//!   manifest + seeded weights. Weight-agnostic invariants (stepping ==
//!   one-shot, prefix adoption bit-identity, streamed == one-shot,
//!   determinism, schedule budgets) run against whichever engine comes
//!   back.
//! * **Artifact tier** — [`artifact_engine`] returns `Some` only with
//!   real trained artifacts; assertions about *trained-weight quality*
//!   (cos-sim fidelity bounds, python parity fixtures, ablation
//!   orderings) live behind it and skip cleanly elsewhere.
//!
//! It also hosts the **conformance-tier machinery** (docs/TESTING.md
//! "Conformance tiers"): a [`Tolerance`] spec per backend/kernel mode
//! (bitwise | ULP budget | abs/rel epsilon), the [`compare_tensors`]
//! engine that reports the worst-case ULP distance with the offending
//! tensor/index on failure, and the statistical guards
//! ([`argmax_agrees`], [`rel_l2`]) that keep relaxed tiers honest. The
//! per-tier budgets live in [`bitwise_spec`] / [`simd_spec`] /
//! [`bf16_spec`] / [`int8_spec`].

use std::sync::Arc;

use crate::batcher::BatcherConfig;
use crate::engine::{argmax, DecodeBatch, Engine, PrefillResult,
                    SparsityConfig};
use crate::manifest::SyntheticSpec;
use crate::pool::ExecutorPool;
use crate::router::Router;
use crate::runtime::{BackendKind, CpuKernel, CpuOptions};
use crate::weights::WeightPrecision;

// ---------------------------------------------------------------------------
// Deterministic fuzz-seed replay (FF_TEST_SEED)
// ---------------------------------------------------------------------------

/// Env var overriding the RNG seed of every seeded fuzz/property suite
/// (`tests/attn_sparse.rs`, the kernel property tests, the proptest
/// harness). Accepts decimal or `0x`-hex, `_` separators allowed —
/// exactly the spelling failure messages print.
pub const TEST_SEED_ENV: &str = "FF_TEST_SEED";

/// The seed [`TEST_SEED_ENV`] requests, if any. Panics on an
/// unparseable value — a typo'd replay must not silently fuzz afresh.
pub fn seed_override() -> Option<u64> {
    let v = std::env::var(TEST_SEED_ENV).ok()?;
    let s = v.trim().replace('_', "");
    let parsed = match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    };
    Some(parsed.unwrap_or_else(|| {
        panic!("{TEST_SEED_ENV}={v}: expected a u64 (decimal or 0x-hex)")
    }))
}

/// The RNG seed a fuzz suite should run with: [`TEST_SEED_ENV`] when
/// set (deterministic replay of a reported failure), else `default`.
pub fn fuzz_seed(default: u64) -> u64 {
    seed_override().unwrap_or(default)
}

// ---------------------------------------------------------------------------
// Conformance tiers: tolerance specs, ULP comparison, statistical guards
// ---------------------------------------------------------------------------

/// Per-tensor numeric equivalence contract between a backend/kernel
/// mode and the scalar reference oracle (docs/TESTING.md, "Conformance
/// tiers").
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Tolerance {
    /// Byte-identical f32s. The scalar fast path's contract: tiling,
    /// threading and batching must not change a single output bit.
    Bitwise,
    /// Within `max_ulp` [`ulp_distance`] units, or within `abs_floor`
    /// absolutely (the floor absorbs cancellation near zero, where ULP
    /// distance explodes while the absolute error stays tiny). The
    /// kernel-level contract for re-associated accumulation.
    Ulp { max_ulp: u64, abs_floor: f32 },
    /// `|got - want| ≤ abs + rel·|want|` — the end-to-end contract for
    /// whole-model outputs, where per-layer rounding compounds and a
    /// fixed ULP budget would be shape-dependent.
    AbsRel { abs: f32, rel: f32 },
}

/// Distance between two f32s in units in the last place, over the
/// ordered-integer key (negative floats map below positives, so the
/// metric is monotone across the sign boundary and `-0.0 == +0.0`).
/// Both-NaN → 0; NaN vs non-NaN → `u64::MAX`. Infinities sit one step
/// past the largest finite value.
pub fn ulp_distance(a: f32, b: f32) -> u64 {
    if a.is_nan() || b.is_nan() {
        return if a.is_nan() && b.is_nan() { 0 } else { u64::MAX };
    }
    fn key(x: f32) -> i64 {
        let bits = x.to_bits() as i64;
        if bits & (1 << 31) != 0 {
            -(bits & 0x7FFF_FFFF)
        } else {
            bits
        }
    }
    (key(a) - key(b)).unsigned_abs()
}

/// Compare `got` against the oracle's `want` under `tol`, element by
/// element. On failure the message carries everything a debug session
/// needs: the tensor name, how many elements broke the budget, the
/// first offender (index, both values, ULP distance) and the
/// worst-case ULP distance with *its* index — whether or not that
/// element itself failed (under [`Tolerance::AbsRel`] the worst ULP
/// offender is usually a near-zero cancellation that passed).
pub fn compare_tensors(what: &str, want: &[f32], got: &[f32],
                       tol: Tolerance) -> Result<(), String> {
    if want.len() != got.len() {
        return Err(format!(
            "{what}: length mismatch — oracle {} vs {}",
            want.len(),
            got.len()
        ));
    }
    let ok = |a: f32, b: f32, d: u64| -> bool {
        match tol {
            Tolerance::Bitwise => a.to_bits() == b.to_bits(),
            Tolerance::Ulp { max_ulp, abs_floor } => {
                d <= max_ulp || (a - b).abs() <= abs_floor
            }
            Tolerance::AbsRel { abs, rel } => {
                (a - b).abs() <= abs + rel * a.abs()
            }
        }
    };
    let (mut worst_ulp, mut worst_idx) = (0u64, 0usize);
    let mut first_fail: Option<usize> = None;
    let mut failures = 0usize;
    for i in 0..want.len() {
        let d = ulp_distance(want[i], got[i]);
        if d > worst_ulp {
            (worst_ulp, worst_idx) = (d, i);
        }
        if !ok(want[i], got[i], d) {
            failures += 1;
            first_fail.get_or_insert(i);
        }
    }
    let Some(i) = first_fail else { return Ok(()) };
    Err(format!(
        "{what}: {failures}/{} elements out of {tol:?}; first at \
         [{i}]: want {} got {} ({} ulp); worst-case {worst_ulp} ulp at \
         [{worst_idx}]: want {} got {}",
        want.len(),
        want[i],
        got[i],
        ulp_distance(want[i], got[i]),
        want[worst_idx],
        got[worst_idx],
    ))
}

/// Statistical guard for relaxed tiers: the tier under test must pick
/// the oracle's argmax token, or a token whose *oracle* logit is
/// within `margin` of the oracle's max (a genuine near-tie the
/// rounding tier is allowed to flip). Catches the real bugs a loose
/// epsilon would wave through — a wrong-but-close logit surface still
/// has to rank tokens like the oracle does.
pub fn argmax_agrees(want: &[f32], got: &[f32], margin: f32)
                     -> Result<(), String> {
    if want.is_empty() || want.len() != got.len() {
        return Err(format!(
            "argmax: length mismatch — oracle {} vs {}",
            want.len(),
            got.len()
        ));
    }
    let wi = argmax(want);
    let gi = argmax(got);
    if wi == gi || want[gi] >= want[wi] - margin {
        return Ok(());
    }
    Err(format!(
        "argmax disagrees: oracle picks {wi} ({}), tier picks {gi} \
         (oracle logit {}, margin {margin})",
        want[wi], want[gi]
    ))
}

/// Relative L2 drift `‖got − want‖₂ / ‖want‖₂` — the KV-cache norm
/// guard of the relaxed tiers (a per-element epsilon can hide a
/// systematic bias; a norm bound cannot).
pub fn rel_l2(want: &[f32], got: &[f32]) -> f32 {
    assert_eq!(want.len(), got.len(), "rel_l2: length mismatch");
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (w, g) in want.iter().zip(got.iter()) {
        num += ((g - w) as f64).powi(2);
        den += (*w as f64).powi(2);
    }
    (num.sqrt() / den.sqrt().max(1e-30)) as f32
}

/// The full conformance contract of one backend/kernel mode against
/// the scalar reference oracle: per-tensor tolerances plus the
/// statistical guards.
#[derive(Debug, Clone, Copy)]
pub struct ConformanceSpec {
    /// Human tag for failure messages ("scalar", "simd", "bf16").
    pub tier: &'static str,
    /// Logits tolerance vs the oracle.
    pub logits: Tolerance,
    /// KV-cache tolerance vs the oracle.
    pub kv: Tolerance,
    /// [`argmax_agrees`] margin on logits.
    pub argmax_margin: f32,
    /// [`rel_l2`] bound on KV caches.
    pub kv_rel_l2: f32,
}

impl ConformanceSpec {
    /// Assert logits within this spec (tolerance + argmax guard).
    pub fn check_logits(&self, what: &str, want: &[f32], got: &[f32]) {
        compare_tensors(what, want, got, self.logits)
            .and_then(|()| argmax_agrees(want, got, self.argmax_margin))
            .unwrap_or_else(|e| panic!("[{}] {e}", self.tier));
    }

    /// Assert a KV tensor within this spec (tolerance + norm guard).
    pub fn check_kv(&self, what: &str, want: &[f32], got: &[f32]) {
        compare_tensors(what, want, got, self.kv)
            .unwrap_or_else(|e| panic!("[{}] {e}", self.tier));
        let drift = rel_l2(want, got);
        assert!(
            drift <= self.kv_rel_l2,
            "[{}] {what}: KV rel-L2 drift {drift} exceeds {}",
            self.tier,
            self.kv_rel_l2
        );
    }
}

/// The scalar fast path's contract: bit-identity with the oracle, at
/// any thread count, for every config (the pre-existing tier).
pub fn bitwise_spec() -> ConformanceSpec {
    ConformanceSpec {
        tier: "scalar",
        logits: Tolerance::Bitwise,
        kv: Tolerance::Bitwise,
        argmax_margin: 0.0,
        kv_rel_l2: 0.0,
    }
}

/// The SIMD kernel tier's budget. Re-association perturbs each
/// reduction by O(ulp) and the perturbation compounds across layers,
/// so the end-to-end bound is abs/rel rather than a per-op ULP count;
/// the statistical guards pin ranking and norm behaviour to the
/// oracle's.
pub fn simd_spec() -> ConformanceSpec {
    ConformanceSpec {
        tier: "simd",
        logits: Tolerance::AbsRel { abs: 1e-4, rel: 1e-3 },
        kv: Tolerance::AbsRel { abs: 1e-4, rel: 1e-3 },
        argmax_margin: 0.05,
        kv_rel_l2: 1e-4,
    }
}

/// The bf16-storage tier's budget vs the **f32-weight** oracle: the
/// dominant term is the one-time weight rounding (relative error up to
/// 2⁻⁸ per weight), not the kernels — so the budget is set by storage
/// precision, and the argmax margin is correspondingly wider.
pub fn bf16_spec() -> ConformanceSpec {
    ConformanceSpec {
        tier: "bf16",
        logits: Tolerance::AbsRel { abs: 5e-2, rel: 5e-2 },
        kv: Tolerance::AbsRel { abs: 2e-2, rel: 2e-2 },
        argmax_margin: 0.5,
        kv_rel_l2: 0.05,
    }
}

/// The int8-storage tier's budget vs the **f32-weight** oracle. Like
/// [`bf16_spec`], the dominant term is the one-time weight
/// quantization, not the kernels: symmetric absmax over each
/// [`crate::weights::QUANT_TILE`]-wide panel slice bounds each
/// weight's error by `absmax/254` of its slice — tiny relative to the
/// largest weight in a slice, but potentially large for small weights
/// sharing a slice with a big one — so the budget
/// sits a bit above bf16's and leans on the statistical guards
/// (ranking + KV norm) rather than per-element tightness. Within the
/// tier, outputs remain bitwise thread/batch-invariant (the
/// dequantize-in-register fold order is fixed; see `runtime/cpu.rs`).
pub fn int8_spec() -> ConformanceSpec {
    ConformanceSpec {
        tier: "int8",
        logits: Tolerance::AbsRel { abs: 8e-2, rel: 8e-2 },
        kv: Tolerance::AbsRel { abs: 4e-2, rel: 4e-2 },
        argmax_margin: 0.8,
        kv_rel_l2: 0.08,
    }
}

/// The deterministic CPU engine over the default synthetic model
/// (fast tiled/parallel backend; threads from `FF_CPU_THREADS`).
/// Infallible by construction (panics only on an internal bug).
pub fn cpu_engine() -> Engine {
    Engine::synthetic_cpu(&SyntheticSpec::default())
        .expect("synthetic CPU engine")
}

/// [`cpu_engine`] pinned to an explicit worker-lane count *and*
/// scalar kernels — the bitwise conformance matrix sweeps
/// `threads ∈ {1, 4}` with it, so it must not drift onto the SIMD
/// tier when `FF_CPU_KERNEL=simd` is exported for the whole test run.
pub fn cpu_engine_threads(threads: usize) -> Engine {
    cpu_engine_with(threads, CpuKernel::Scalar)
}

/// Default synthetic engine pinned to an explicit thread count and
/// kernel tier — the conformance matrix axis constructor.
pub fn cpu_engine_with(threads: usize, kernel: CpuKernel) -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        CpuOptions { threads, reference: false, kernel: Some(kernel) },
    )
    .expect("synthetic CPU engine")
}

/// [`cpu_engine_with`] on the SIMD kernel tier (f32 weights) — gated
/// by [`simd_spec`], never bitwise.
pub fn cpu_engine_simd(threads: usize) -> Engine {
    cpu_engine_with(threads, CpuKernel::Simd)
}

/// SIMD-tier engine over a **bf16** weight store (raw u16 panels as
/// the *only* resident copy, widened to f32 in-register;
/// `crate::weights::WeightStore::seeded_with`) — gated by
/// [`bf16_spec`] against the f32-weight reference oracle.
pub fn cpu_engine_bf16_simd(threads: usize) -> Engine {
    cpu_engine_precision_simd(threads, WeightPrecision::Bf16)
}

/// SIMD-tier engine over an **int8** weight store (int8 codes +
/// per-column-tile f32 scales as the only resident copy, dequantized
/// in-register inside the tile loop) — gated by [`int8_spec`] against
/// the f32-weight reference oracle.
pub fn cpu_engine_int8_simd(threads: usize) -> Engine {
    cpu_engine_precision_simd(threads, WeightPrecision::Int8)
}

/// Default synthetic engine on the SIMD kernel tier with an explicit
/// weight-storage precision — the reduced-precision conformance axis.
pub fn cpu_engine_precision_simd(threads: usize,
                                 precision: WeightPrecision) -> Engine {
    let spec = SyntheticSpec {
        weight_precision: precision,
        ..SyntheticSpec::default()
    };
    Engine::synthetic_cpu_with(
        &spec,
        CpuOptions {
            threads,
            reference: false,
            kernel: Some(CpuKernel::Simd),
        },
    )
    .expect("synthetic reduced-precision CPU engine")
}

/// The sequential scalar CPU *reference* engine — the oracle the fast
/// backend is conformance-tested against (bit-identical by contract
/// for the scalar tier; within [`simd_spec`] / [`bf16_spec`] for the
/// relaxed tiers).
pub fn cpu_engine_reference() -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        CpuOptions { threads: 1, reference: true, kernel: None },
    )
    .expect("synthetic CPU reference engine")
}

/// The PJRT engine over real artifacts, or `None` when artifacts are
/// absent or the `pjrt` feature is off (caller skips trained-weight
/// assertions).
pub fn artifact_engine() -> Option<Engine> {
    let dir = crate::test_artifacts_dir()?;
    let manifest = Arc::new(
        crate::manifest::Manifest::load(&dir).expect("artifact manifest"),
    );
    let weights = Arc::new(
        crate::weights::WeightStore::load(&manifest)
            .expect("artifact weights"),
    );
    let rt = Arc::new(
        crate::runtime::Runtime::new(manifest, weights)
            .expect("pjrt runtime"),
    );
    Some(Engine::new(rt))
}

/// An engine on *this* machine, whatever it has: artifacts + PJRT when
/// available, the deterministic CPU reference otherwise. Never skips.
pub fn test_engine() -> Engine {
    artifact_engine().unwrap_or_else(cpu_engine)
}

/// Spawn an executor pool matching [`test_engine`]'s choice: artifact
/// replicas when artifacts + `pjrt` are available, synthetic CPU
/// replicas otherwise.
pub fn spawn_test_pool(router: Arc<Router>, cfg: BatcherConfig)
                       -> ExecutorPool {
    match crate::test_artifacts_dir() {
        Some(dir) => ExecutorPool::spawn_from_artifacts(router, cfg, dir),
        None => ExecutorPool::spawn_backend(
            router,
            cfg,
            BackendKind::Cpu,
            None,
        ),
    }
}

// ---------------------------------------------------------------------------
// Shared decode-bench harness (tier-1 perf gate + fig10 bench)
// ---------------------------------------------------------------------------

/// FFN-heavy decode-bench model shared by the tier-1 batched-decode
/// perf gate (`tests/perf_smoke.rs`) and the fig10 bench: ~12 MiB of
/// FFN weights per token pass (2 layers × 3 panels × 64×8192 f32), so
/// a T=1 pass streams them from beyond L2 and sequential decode is
/// weight-read bound — the regime where one shared pass for B rows
/// pays off. One definition, so the gate and the bench always measure
/// the same model.
pub fn decode_bench_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-decode".to_string(),
        n_layers: 2,
        d_ffn: 8192,
        max_ctx: 512,
        buckets: vec![256, 512],
        ..SyntheticSpec::default()
    }
}

/// Prefill `b` distinct short prompts on `engine` (dense config),
/// returning each prompt's length and prefill result — the fixed
/// starting state both decode drivers below consume.
pub fn decode_bench_seqs(engine: &Engine, b: usize)
                         -> Vec<(usize, PrefillResult)> {
    let cfg = SparsityConfig::dense();
    (0..b)
        .map(|i| {
            let toks: Vec<i32> = (0..8)
                .map(|j| ((i * 37 + j * 11) % 250 + 1) as i32)
                .collect();
            let pre = engine.prefill(&toks, &cfg).unwrap();
            (toks.len(), pre)
        })
        .collect()
}

/// Greedy-decode every sequence one at a time (`Engine::decode_step`)
/// for `steps` tokens each — the pre-batching execution profile. Each
/// run clones the prefilled caches, so it is repeatable for timing.
pub fn decode_bench_sequential(engine: &Engine,
                               seqs: &[(usize, PrefillResult)],
                               steps: usize) {
    let cfg = SparsityConfig::dense();
    for (len, pre) in seqs {
        let mut cache = pre.cache.clone();
        let mut logits = pre.last_logits.clone();
        let mut pos = *len;
        for _ in 0..steps {
            let tok = argmax(&logits) as i32;
            logits = engine
                .decode_step(tok, pos, &mut cache, &cfg)
                .unwrap();
            pos += 1;
        }
    }
}

/// Greedy-decode all sequences in lockstep through a [`DecodeBatch`]
/// (`steps` rounds, passes of at most `max_batch` rows) — the batched
/// execution profile. Clones the prefilled caches like the sequential
/// driver, so the two are directly comparable.
pub fn decode_bench_batched(engine: &Engine,
                            seqs: &[(usize, PrefillResult)],
                            steps: usize, max_batch: usize) {
    let cfg = SparsityConfig::dense();
    let mut db = DecodeBatch::new(engine.clone());
    let ids: Vec<usize> = seqs
        .iter()
        .map(|(len, pre)| {
            db.join(
                pre.cache.clone(),
                *len,
                pre.last_logits.clone(),
                cfg.clone(),
            )
        })
        .collect();
    for _ in 0..steps {
        for &id in &ids {
            let tok = argmax(db.logits(id)) as i32;
            db.feed(id, tok);
        }
        let stats = db.step(None, max_batch);
        assert!(stats.failures.is_empty(), "{:?}", stats.failures);
    }
}

// ---------------------------------------------------------------------------
// Shared attention-bench harness (tier-1 attn perf gate + fig11 bench)
// ---------------------------------------------------------------------------

/// Attention-heavy prefill-bench model shared by the tier-1 sparse-
/// attention perf gate (`tests/perf_smoke.rs`) and the fig11 bench:
/// long context with a deliberately small FFN (`d_ffn` 128), so at
/// T = 2048 the O(T²) score/softmax/weighted-V loop dominates the
/// prefill wall-clock — the regime where dropping key blocks pays off.
/// One definition, so the gate and the bench always measure the same
/// model.
pub fn attn_bench_spec() -> SyntheticSpec {
    SyntheticSpec {
        name: "ff-perf-attn".to_string(),
        n_layers: 2,
        d_ffn: 128,
        max_ctx: 2048,
        buckets: vec![512, 1024, 2048],
        ..SyntheticSpec::default()
    }
}

/// Dense-FFN config with block-sparse attention at `drop` (`None` =
/// fully dense attention) — the two ends the attention gate and the
/// fig11 sweep compare.
pub fn attn_bench_cfg(drop: Option<f64>) -> SparsityConfig {
    let mut cfg = SparsityConfig::dense();
    cfg.attn_sparsity = drop;
    cfg
}

/// One timed prefill of a `len`-token prompt under `cfg` (result
/// dropped; deterministic prompt so every run does identical work).
pub fn attn_bench_prefill(engine: &Engine, len: usize,
                          cfg: &SparsityConfig) {
    let toks: Vec<i32> = (0..len).map(|i| (i % 250) as i32 + 1).collect();
    engine.prefill(&toks, cfg).expect("attn bench prefill");
}

// ---------------------------------------------------------------------------
// Cluster worker-process harness (tests/cluster.rs + fig15 + perf gate)
// ---------------------------------------------------------------------------

/// One real `fastforward serve` worker process on a loopback ephemeral
/// port, killed on drop — the substrate of the multi-process cluster
/// suites (`tests/cluster.rs`, the fig15 bench, the affinity perf
/// gate).
///
/// The binary path comes from the caller (`env!("CARGO_BIN_EXE_\
/// fastforward")` in integration tests and benches — that env var only
/// exists when cargo compiles test/bench targets, so the library cannot
/// bake it in).
pub struct WorkerProc {
    child: std::process::Child,
    addr: String,
}

/// Reserve a loopback `host:port` by binding port 0 and dropping the
/// listener. The reserve-release race is the test suite's established
/// pattern (the spawned process re-binds milliseconds later).
pub fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0")
        .expect("bind loopback");
    l.local_addr().expect("local addr").to_string()
}

impl WorkerProc {
    /// Spawn `bin serve --backend cpu --addr <ephemeral> <extra_args>`
    /// and wait (≤ 60 s) until its `/readyz` answers 200.
    pub fn spawn(bin: &str, extra_args: &[&str]) -> WorkerProc {
        let addr = free_addr();
        let mut cmd = std::process::Command::new(bin);
        cmd.arg("serve")
            .arg("--backend")
            .arg("cpu")
            .arg("--addr")
            .arg(&addr)
            .args(extra_args)
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null());
        let child = cmd.spawn().expect("spawn serve worker");
        let w = WorkerProc { child, addr };
        crate::cluster::wait_ready(
            &w.addr,
            std::time::Duration::from_secs(60),
        )
        .expect("worker became ready");
        w
    }

    /// The worker's `host:port`.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Kill the worker process immediately (chaos cases; idempotent —
    /// drop will find it already dead).
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        self.kill();
    }
}

/// Printable ASCII text of exactly `bytes` bytes — and, because the
/// byte-level tokenizer emits one id per byte, exactly `bytes` tokens.
/// Quote/backslash-free so it embeds in JSON prompts verbatim.
pub fn ascii_doc_text(seed: u64, bytes: usize) -> String {
    let mut rng = crate::util::rng::Rng::new(seed);
    let bank = crate::trace::WordBank::new(&mut rng, 128);
    let mut s: String = bank
        .filler(&mut rng, bytes * 2)
        .chars()
        .filter(|c| c.is_ascii() && *c != '"' && *c != '\\')
        .take(bytes)
        .collect();
    while s.len() < bytes {
        s.push('x');
    }
    s
}

/// `n_docs` shared-document texts of `doc_bytes` bytes each whose
/// routing keys split *evenly* across an `n_workers`-way hash ring
/// under `cfg` (same key walk + ring the front uses), so a cluster
/// bench's per-worker cache-sizing argument is deterministic instead of
/// hostage to a lucky ring split. Requires `n_docs % n_workers == 0`.
pub fn balanced_cluster_docs(cfg: &crate::cluster::ClusterConfig,
                             n_workers: usize, n_docs: usize,
                             doc_bytes: usize) -> Vec<String> {
    assert_eq!(n_docs % n_workers, 0, "docs must divide evenly");
    let tok = crate::tokenizer::Tokenizer::new(cfg.vocab);
    let ring = crate::cluster::policy::HashRing::new(n_workers,
                                                     cfg.vnodes);
    let mut per_worker = vec![0usize; n_workers];
    let mut docs = Vec::with_capacity(n_docs);
    let mut seed = 1000u64;
    while docs.len() < n_docs {
        let text = ascii_doc_text(seed, doc_bytes);
        seed += 1;
        let key = crate::kvcache::routing_key(cfg.routing_seed,
                                              &tok.encode(&text),
                                              cfg.block, cfg.key_blocks);
        let w = ring.assign(key, |_| true).expect("ring covers workers");
        if per_worker[w] < n_docs / n_workers {
            per_worker[w] += 1;
            docs.push(text);
        }
    }
    docs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_engine_always_available() {
        let e = test_engine();
        assert!(e.block() > 0);
        assert!(e.manifest().model.n_layers > 0);
    }

    // -- ULP-math unit suite (the comparison engine itself) ----------

    #[test]
    fn ulp_distance_identities_and_adjacency() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(f32::NEG_INFINITY, f32::NEG_INFINITY), 0);
        // adjacent representable values are exactly 1 apart
        let next = f32::from_bits(1.0f32.to_bits() + 1);
        assert_eq!(ulp_distance(1.0, next), 1);
        assert_eq!(ulp_distance(next, 1.0), 1);
        // symmetric for negatives
        let nprev = f32::from_bits((-1.0f32).to_bits() + 1);
        assert_eq!(ulp_distance(-1.0, nprev), 1);
    }

    #[test]
    fn ulp_distance_subnormals_and_sign_boundary() {
        let tiny = f32::from_bits(1); // smallest positive subnormal
        // one step off +0, two steps from its own negation (the metric
        // is monotone across the signed-zero boundary)
        assert_eq!(ulp_distance(0.0, tiny), 1);
        assert_eq!(ulp_distance(-tiny, tiny), 2);
        assert_eq!(ulp_distance(-0.0, tiny), 1);
        // adjacent subnormals
        let tiny2 = f32::from_bits(2);
        assert_eq!(ulp_distance(tiny, tiny2), 1);
        // a same-magnitude sign flip on a normal value is enormous
        assert!(ulp_distance(1.0, -1.0) > u32::MAX as u64 / 4);
    }

    #[test]
    fn ulp_distance_infinities_and_nan() {
        assert_eq!(ulp_distance(f32::MAX, f32::INFINITY), 1);
        assert_eq!(ulp_distance(-f32::MAX, f32::NEG_INFINITY), 1);
        assert!(ulp_distance(f32::INFINITY, f32::NEG_INFINITY)
                > u32::MAX as u64);
        assert_eq!(ulp_distance(f32::NAN, f32::NAN), 0);
        assert_eq!(ulp_distance(f32::NAN, 1.0), u64::MAX);
        assert_eq!(ulp_distance(1.0, f32::NAN), u64::MAX);
    }

    /// Regression: a single flipped mantissa bit in a 4096-element
    /// tensor must fail the ULP tier *and* be located by index in the
    /// report.
    #[test]
    fn flipped_mantissa_bit_is_caught_and_located() {
        let want: Vec<f32> =
            (0..4096).map(|i| 1.0 + i as f32 * 1e-3).collect();
        let mut got = want.clone();
        let idx = 2477;
        got[idx] = f32::from_bits(got[idx].to_bits() ^ (1 << 12));
        let err = compare_tensors(
            "logits", &want, &got,
            Tolerance::Ulp { max_ulp: 512, abs_floor: 0.0 },
        )
        .expect_err("flipped bit must fail the ULP tier");
        assert!(err.contains("[2477]"), "report must locate it: {err}");
        assert!(err.contains("1/4096"), "exactly one offender: {err}");
        // bitwise rejects it too; a loose abs/rel tier would not
        compare_tensors("logits", &want, &got, Tolerance::Bitwise)
            .expect_err("bitwise must fail");
        compare_tensors(
            "logits", &want, &got,
            Tolerance::AbsRel { abs: 1e-2, rel: 1e-2 },
        )
        .expect("a 2^12-mantissa flip is ~5e-4 relative — under 1e-2");
    }

    #[test]
    fn compare_tensors_reports_worst_case_ulp() {
        let want = vec![1.0f32, 2.0, 3.0, 4.0];
        let mut got = want.clone();
        got[1] = f32::from_bits(got[1].to_bits() + 3); // 3 ulp
        got[3] = f32::from_bits(got[3].to_bits() + 9); // 9 ulp (worst)
        let err = compare_tensors(
            "kv", &want, &got,
            Tolerance::Ulp { max_ulp: 2, abs_floor: 0.0 },
        )
        .expect_err("both exceed 2 ulp");
        assert!(err.contains("first at [1]"), "{err}");
        assert!(err.contains("worst-case 9 ulp at [3]"), "{err}");
        // with budget 16 both pass
        compare_tensors(
            "kv", &want, &got,
            Tolerance::Ulp { max_ulp: 16, abs_floor: 0.0 },
        )
        .unwrap();
        // abs floor rescues a near-zero cancellation (huge ULP count)
        compare_tensors(
            "z", &[1e-9], &[-1e-9],
            Tolerance::Ulp { max_ulp: 1, abs_floor: 1e-6 },
        )
        .unwrap();
    }

    #[test]
    fn statistical_guards_catch_rank_and_norm_bugs() {
        // argmax: exact agreement passes
        argmax_agrees(&[0.1, 0.9, 0.3], &[0.1, 0.8, 0.3], 0.0).unwrap();
        // near-tie flip within margin passes
        argmax_agrees(&[0.5, 0.49, 0.0], &[0.48, 0.5, 0.0], 0.05)
            .unwrap();
        // a genuine rank change beyond margin fails
        argmax_agrees(&[1.0, 0.2, 0.0], &[0.1, 0.9, 0.0], 0.05)
            .expect_err("rank flip must fail");
        // rel_l2: zero for identical tensors, scales with the bias
        assert_eq!(rel_l2(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let drift = rel_l2(&[3.0, 4.0], &[3.3, 4.4]); // 10% systematic
        assert!((drift - 0.1).abs() < 1e-6, "drift {drift}");
    }

    #[test]
    fn fuzz_seed_parses_decimal_and_hex() {
        // no env override in the normal test run → default comes back
        if std::env::var(TEST_SEED_ENV).is_err() {
            assert_eq!(fuzz_seed(0xA77_F022), 0xA77_F022);
        }
    }
}
