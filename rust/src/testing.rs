//! Engine construction shared by the integration tests, benches and
//! examples — the switchboard of the two test tiers (docs/TESTING.md):
//!
//! * **Always-on tier** — [`test_engine`] returns a working engine on
//!   every machine: the real PJRT artifact engine when `make artifacts`
//!   output is present *and* the `pjrt` feature is compiled in,
//!   otherwise the deterministic pure-Rust CPU engine over a synthetic
//!   manifest + seeded weights. Weight-agnostic invariants (stepping ==
//!   one-shot, prefix adoption bit-identity, streamed == one-shot,
//!   determinism, schedule budgets) run against whichever engine comes
//!   back.
//! * **Artifact tier** — [`artifact_engine`] returns `Some` only with
//!   real trained artifacts; assertions about *trained-weight quality*
//!   (cos-sim fidelity bounds, python parity fixtures, ablation
//!   orderings) live behind it and skip cleanly elsewhere.

use std::sync::Arc;

use crate::batcher::BatcherConfig;
use crate::engine::Engine;
use crate::manifest::SyntheticSpec;
use crate::pool::ExecutorPool;
use crate::router::Router;
use crate::runtime::BackendKind;

/// The deterministic CPU engine over the default synthetic model
/// (fast tiled/parallel backend; threads from `FF_CPU_THREADS`).
/// Infallible by construction (panics only on an internal bug).
pub fn cpu_engine() -> Engine {
    Engine::synthetic_cpu(&SyntheticSpec::default())
        .expect("synthetic CPU engine")
}

/// [`cpu_engine`] pinned to an explicit worker-lane count — the
/// conformance suite sweeps `threads ∈ {1, 4}` with it.
pub fn cpu_engine_threads(threads: usize) -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        crate::runtime::CpuOptions { threads, reference: false },
    )
    .expect("synthetic CPU engine")
}

/// The sequential scalar CPU *reference* engine — the oracle the fast
/// backend is conformance-tested against (bit-identical by contract).
pub fn cpu_engine_reference() -> Engine {
    Engine::synthetic_cpu_with(
        &SyntheticSpec::default(),
        crate::runtime::CpuOptions { threads: 1, reference: true },
    )
    .expect("synthetic CPU reference engine")
}

/// The PJRT engine over real artifacts, or `None` when artifacts are
/// absent or the `pjrt` feature is off (caller skips trained-weight
/// assertions).
pub fn artifact_engine() -> Option<Engine> {
    let dir = crate::test_artifacts_dir()?;
    use std::rc::Rc;
    let manifest = Arc::new(
        crate::manifest::Manifest::load(&dir).expect("artifact manifest"),
    );
    let weights = Arc::new(
        crate::weights::WeightStore::load(&manifest)
            .expect("artifact weights"),
    );
    let rt = Rc::new(
        crate::runtime::Runtime::new(manifest, weights)
            .expect("pjrt runtime"),
    );
    Some(Engine::new(rt))
}

/// An engine on *this* machine, whatever it has: artifacts + PJRT when
/// available, the deterministic CPU reference otherwise. Never skips.
pub fn test_engine() -> Engine {
    artifact_engine().unwrap_or_else(cpu_engine)
}

/// Spawn an executor pool matching [`test_engine`]'s choice: artifact
/// replicas when artifacts + `pjrt` are available, synthetic CPU
/// replicas otherwise.
pub fn spawn_test_pool(router: Arc<Router>, cfg: BatcherConfig)
                       -> ExecutorPool {
    match crate::test_artifacts_dir() {
        Some(dir) => ExecutorPool::spawn_from_artifacts(router, cfg, dir),
        None => ExecutorPool::spawn_backend(
            router,
            cfg,
            BackendKind::Cpu,
            None,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_engine_always_available() {
        let e = test_engine();
        assert!(e.block() > 0);
        assert!(e.manifest().model.n_layers > 0);
    }
}
