//! Dynamic batcher: the per-replica executor loop — continuous batching
//! with chunked prefill, prefix-aware KV reuse, token streaming and
//! SLO-aware preemptive scheduling.
//!
//! One executor thread owns one (non-Sync) engine and iterates:
//!
//! 1. admit new requests from its replica queue (interactive class
//!    first, up to `max_active`), adopting already-computed KV pages
//!    for the longest cached prefix,
//! 2. sweep cancellations (client disconnects release their KV pages
//!    here, mid-prefill or mid-decode),
//! 3. plan the iteration (`plan_schedule`): pick the prefill block
//!    budget and decide whether batch-class prefills are preempted,
//! 4. stage one decode token per decoding request (sampled from the
//!    logits the previous tick produced; EOS / budget-hit requests
//!    finish here), streaming each token as it is staged,
//! 5. run the **mixed step**: every staged decode row plus at most
//!    one preemptible prefill chunk (interactive prefills first) are
//!    folded into shared forward passes of at most `max_batch` rows
//!    each ([`crate::engine::DecodeBatch::step`] →
//!    [`crate::engine::Engine::step_batch`]) — B decode tokens cost
//!    one pass over the layer weights instead of B; any remaining
//!    prefill budget is then spent on standalone chunked-prefill
//!    steps, interactive first (Sarathi-style — long prompts still
//!    don't monopolize the engine),
//! 6. retire finished requests, releasing their KV pages and reporting
//!    their cost back to the replica's load accounting.
//!
//! **Streaming:** the executor emits [`TokenEvent`]s as they happen —
//! `First` at prefill completion (TTFT, the paper's definition), one
//! `Token` per decoded token (with incremental UTF-8 text from
//! [`StreamDecoder`]), and a terminal `Done` carrying the full
//! [`Response`]. Inter-token latency is recorded per SLO class.
//!
//! **Preemption:** while an interactive prefill is pending — or an
//! interactive completion deadline is projected to miss, per the
//! [`UnitClock`] wall-clock estimate over remaining scheduler steps —
//! batch-class prefills are paused in place. Pausing costs nothing:
//! [`PrefillSession`] is a block cursor, so a paused session simply
//! receives no budget and resumes where it stopped. Under KV pressure
//! a paused prefill can be *ejected* entirely: its computed blocks are
//! salvaged into the shared [`crate::kvcache::PrefixCache`], its pages
//! released, and the request requeued — on re-admission it adopts the
//! salvaged blocks and resumes from its cursor instead of restarting.
//!
//! When a prefill completes, its leading full blocks are offered to the
//! shared [`crate::kvcache::PrefixCache`], so a later request with the
//! same prompt prefix — on *any* replica — prefills only the uncached
//! suffix.
//!
//! [`crate::pool::ExecutorPool`] spawns one `Batcher` per replica; the
//! single-threaded stack (`Batcher::new`) remains for tests and
//! examples. See docs/SCHEDULING.md for the scheduling rules and
//! tuning guidance.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cost::UnitClock;
use crate::engine::{argmax, DecodeBatch, Engine, PrefillSession};
use crate::kvcache::{PageId, SeqKvCache};
use crate::metrics::Metrics;
use crate::router::{Replica, Request, Response, Router, SloClass,
                    TokenEvent};
use crate::tokenizer::{StreamDecoder, Tokenizer, EOS};
use crate::util::sync::lock_recover;

/// Executor tuning knobs (see docs/SCHEDULING.md for guidance).
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max concurrently active (admitted) requests per replica.
    pub max_active: usize,
    /// Prefill blocks processed per scheduler iteration.
    pub prefill_block_budget: usize,
    /// Prefill block budget while interactive requests are decoding
    /// and none are prefilling (decode-first mode): batch prefill
    /// trickles at this rate so streaming inter-token latency stays
    /// flat. Clamped to `prefill_block_budget`.
    pub decode_first_budget: usize,
    /// Maximum sequence rows per batched forward pass (decode rows
    /// plus the prefill chunk that rides along). More staged rows than
    /// this split into several passes within the same tick; `1`
    /// degenerates to sequential per-sequence execution. Served as
    /// `--max-batch`.
    pub max_batch: usize,
    /// Master switch for SLO-aware scheduling (priority prefill order,
    /// decode-first budget capping, batch-prefill preemption). With it
    /// off every request is scheduled round-robin as one class.
    pub slo: bool,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_active: 8,
            prefill_block_budget: 4,
            decode_first_budget: 1,
            max_batch: 8,
            slo: true,
        }
    }
}

/// Why an admission attempt failed.
enum AdmitError {
    /// Transient KV-page shortage: the request stays queued and is
    /// retried once retires (or prefix-cache reclaim) free pages.
    KvPressure,
    /// Permanent failure for this request: answer it with an error.
    Fatal(anyhow::Error),
}

enum Phase {
    Prefill(PrefillSession),
    Decode {
        /// Member id in the replica's shared [`DecodeBatch`] (the
        /// batch owns the sequence's KV cache and logits while it
        /// decodes).
        seq: usize,
        generated: Vec<i32>,
    },
    Finished,
}

struct Active {
    req: Request,
    phase: Phase,
    pages: Vec<PageId>,
    admitted: Instant,
    ttft_ms: Option<f64>,
    decode_ms_total: f64,
    reused_blocks: usize,
    ok: bool,
    /// Batch-class prefill paused by the scheduler (receives no
    /// prefill budget until interactive pressure clears).
    preempted: bool,
    /// Incremental UTF-8 assembly for streamed token text.
    decoder: StreamDecoder,
    /// When the last stream event was emitted (ITL measurement).
    last_emit: Option<Instant>,
}

/// One active request as the scheduler sees it (inputs to
/// `plan_schedule`).
#[derive(Debug, Clone, Copy)]
struct SchedReq {
    class: SloClass,
    /// Still in the prefill phase (false = decoding).
    prefilling: bool,
    /// Interactive request whose completion deadline is projected to
    /// miss.
    deadline_at_risk: bool,
}

/// One scheduler iteration's decisions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct SchedPlan {
    /// Prefill blocks to spend this iteration.
    prefill_budget: usize,
    /// Whether batch-class prefills are paused this iteration.
    preempt_batch: bool,
}

/// Pure scheduling decision for one iteration — kept free of engine
/// state so the preemption rules are unit-testable on the host.
///
/// Rules (with `cfg.slo`):
/// * an interactive prefill pending → full budget (spent on
///   interactive prefills first) and batch prefills paused;
/// * otherwise interactive decodes pending → budget capped to
///   `decode_first_budget` so batch prefill can't stretch the decode
///   round (inter-token latency protection);
/// * an interactive completion deadline projected to miss → batch
///   prefills paused regardless — for an at-risk *decode* this stops
///   even the decode-first trickle;
/// * no interactive work → full budget, nothing paused.
fn plan_schedule(cfg: &BatcherConfig, reqs: &[SchedReq]) -> SchedPlan {
    if !cfg.slo {
        return SchedPlan {
            prefill_budget: cfg.prefill_block_budget,
            preempt_batch: false,
        };
    }
    let interactive_prefill = reqs
        .iter()
        .any(|r| r.class.is_interactive() && r.prefilling);
    let interactive_decode = reqs
        .iter()
        .any(|r| r.class.is_interactive() && !r.prefilling);
    let at_risk = reqs.iter().any(|r| r.deadline_at_risk);
    let prefill_budget = if !interactive_prefill && interactive_decode {
        cfg.decode_first_budget.min(cfg.prefill_block_budget)
    } else {
        cfg.prefill_block_budget
    };
    SchedPlan {
        prefill_budget,
        preempt_batch: interactive_prefill || at_risk,
    }
}

/// Runs one replica's scheduling loop until the router closes.
pub struct Batcher {
    engine: Engine,
    router: Arc<Router>,
    replica: Arc<Replica>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
    tokenizer: Tokenizer,
    /// The replica's lockstep decode batch: requests join it as their
    /// prefill finishes and leave as they complete, and every tick
    /// advances all members through shared forward passes.
    decode: DecodeBatch,
    /// Measured wall-clock per scheduler step (EWMA), for deadline
    /// projection.
    clock: UnitClock,
}

impl Batcher {
    /// Executor for replica 0 — the single-replica stack used by tests,
    /// examples and `Batcher`-level embedding.
    pub fn new(engine: Engine, router: Arc<Router>,
               cfg: BatcherConfig) -> Self {
        Self::for_replica(engine, router, cfg, 0)
    }

    /// Executor bound to replica `replica_id` of the router's pool.
    pub fn for_replica(engine: Engine, router: Arc<Router>,
                       cfg: BatcherConfig, replica_id: usize) -> Self {
        let vocab = engine.manifest().model.vocab;
        Batcher {
            replica: router.replica(replica_id),
            metrics: router.metrics.clone(),
            decode: DecodeBatch::new(engine.clone()),
            engine,
            router,
            cfg,
            tokenizer: Tokenizer::new(vocab),
            clock: UnitClock::new(0.2),
        }
    }

    /// Main loop. Returns when the router is closed and all work drained.
    pub fn run(mut self) -> Result<()> {
        let mut active: Vec<Active> = Vec::new();
        loop {
            // 1. admit (replica pop order is interactive-first)
            let slots = self.cfg.max_active.saturating_sub(active.len());
            if slots > 0 {
                let mut popped = self.replica.pop_up_to(slots);
                'admit: while !popped.is_empty() {
                    let mut req = popped.remove(0);
                    if req.cancel.is_cancelled() {
                        self.drop_cancelled(req);
                        continue;
                    }
                    let mut ejected_once = false;
                    loop {
                        match self.admit(req) {
                            Ok(a) => {
                                active.push(a);
                                break;
                            }
                            Err((r, AdmitError::KvPressure)) => {
                                // Interactive work outranks a paused
                                // batch prefill's residency: eject one
                                // (salvaging its computed blocks into
                                // the prefix cache) and retry once.
                                if !ejected_once
                                    && r.class.is_interactive()
                                    && self.eject_preempted(&mut active)
                                {
                                    ejected_once = true;
                                    req = r;
                                    continue;
                                }
                                // transient: retires will free pages.
                                // Put back EVERYTHING we popped —
                                // front-first so FIFO order is
                                // preserved — and stop admitting this
                                // round.
                                for p in popped.drain(..).rev() {
                                    self.replica.requeue(p);
                                }
                                self.replica.requeue(r);
                                break 'admit;
                            }
                            Err((r, AdmitError::Fatal(e))) => {
                                self.reject_failed(r, e);
                                break;
                            }
                        }
                    }
                }
            }
            if active.is_empty() {
                // park on the replica queue until work (or shutdown)
                match self.replica.pop_blocking() {
                    Some(req) if req.cancel.is_cancelled() => {
                        self.drop_cancelled(req)
                    }
                    Some(req) => match self.admit(req) {
                        Ok(a) => active.push(a),
                        Err((req, AdmitError::KvPressure)) => {
                            // nothing of ours will retire; wait briefly
                            // for other replicas / the prefix cache to
                            // release pages, then retry
                            self.replica.requeue(req);
                            std::thread::sleep(
                                std::time::Duration::from_millis(2),
                            );
                        }
                        Err((req, AdmitError::Fatal(e))) => {
                            self.reject_failed(req, e)
                        }
                    },
                    None => return Ok(()), // closed + drained
                }
            }

            // 2. cancellation sweep (client disconnects)
            for a in active.iter_mut() {
                if !matches!(a.phase, Phase::Finished)
                    && a.req.cancel.is_cancelled()
                {
                    self.cancel_active(a);
                }
            }

            // 3. plan the iteration and apply preemption transitions
            let plan = {
                let reqs: Vec<SchedReq> = active
                    .iter()
                    .filter(|a| !matches!(a.phase, Phase::Finished))
                    .map(|a| SchedReq {
                        class: a.req.class,
                        prefilling: matches!(a.phase, Phase::Prefill(_)),
                        deadline_at_risk: self.deadline_at_risk(a),
                    })
                    .collect();
                plan_schedule(&self.cfg, &reqs)
            };
            for a in active.iter_mut() {
                let batch_prefilling = !a.req.class.is_interactive()
                    && matches!(a.phase, Phase::Prefill(_));
                if batch_prefilling && plan.preempt_batch {
                    if !a.preempted {
                        a.preempted = true;
                        self.metrics.record_preemption();
                    }
                } else {
                    a.preempted = false;
                }
            }

            // 4. stage decode tokens: sample each member's next token
            //    from its resident logits (finishing EOS / budget-hit
            //    requests), stream it, and stage it for the batched
            //    step — no engine work yet
            for a in active.iter_mut() {
                self.stage_decode(a);
            }

            // 5. the mixed step: every staged decode row plus at most
            //    one preemptible prefill chunk (interactive prefills
            //    first) share batched forward passes of at most
            //    `max_batch` rows
            let mut budget = plan.prefill_budget;
            let chunk_idx = if budget > 0 {
                Self::pick_chunk(&active)
            } else {
                None
            };
            if self.decode.staged() > 0 || chunk_idx.is_some() {
                if chunk_idx.is_some() {
                    budget -= 1;
                }
                self.run_mixed_step(&mut active, chunk_idx);
            }

            // 5b. spillover chunked prefill round-robin (standalone
            //     steps): interactive pass first, then un-preempted
            //     batch
            'prefill: loop {
                let mut progressed = false;
                for interactive_pass in [true, false] {
                    for a in active.iter_mut() {
                        if a.req.class.is_interactive() != interactive_pass
                        {
                            continue;
                        }
                        if !interactive_pass && a.preempted {
                            continue;
                        }
                        if budget == 0 {
                            break 'prefill;
                        }
                        if let Err(e) = self.step_prefill(
                            a,
                            &mut budget,
                            &mut progressed,
                        ) {
                            self.fail(a, e);
                        }
                    }
                }
                if !progressed {
                    break;
                }
            }

            // 6. retire
            for a in active.iter_mut() {
                if matches!(a.phase, Phase::Finished) {
                    self.retire(a);
                }
            }
            active.retain(|a| !matches!(a.phase, Phase::Finished));
        }
    }

    /// Whether `a` is an interactive request whose completion deadline
    /// is projected to miss: elapsed time plus the [`UnitClock`]
    /// projection over its remaining scheduler steps (prefill steps
    /// left plus the decode budget, or just the decode steps left once
    /// decoding) exceeds the deadline. The decode-phase case is what
    /// the projection buys over plain priority: an at-risk decode
    /// pauses even the batch-prefill trickle, which interactive
    /// priority alone never does. Requests without a deadline are
    /// never at risk, and neither is anything before the clock's first
    /// measurement.
    fn deadline_at_risk(&self, a: &Active) -> bool {
        if !a.req.class.is_interactive() {
            return false;
        }
        let Some(deadline_ms) = a.req.deadline_ms else {
            return false;
        };
        let remaining_units = match &a.phase {
            Phase::Prefill(session) => {
                session.remaining_steps() + a.req.max_tokens
            }
            Phase::Decode { generated, .. } => {
                a.req.max_tokens.saturating_sub(generated.len())
            }
            Phase::Finished => return false,
        };
        let Some(projected) =
            self.clock.project_ms(remaining_units as f64)
        else {
            return false;
        };
        let elapsed_ms = a.req.submitted.elapsed().as_secs_f64() * 1e3;
        elapsed_ms + projected > deadline_ms
    }

    /// A request cancelled while still queued: settle accounting and
    /// answer the (likely gone) client without running anything.
    fn drop_cancelled(&mut self, req: Request) {
        self.metrics.record_cancelled();
        self.replica.complete(req.prompt.len(), req.max_tokens);
        self.metrics.record_replica_done(self.replica.id(), false);
        let mut resp = Response::failed(req.id, "cancelled".to_string());
        resp.e2e_ms = req.submitted.elapsed().as_secs_f64() * 1e3;
        let _ = req.events.send(TokenEvent::Done(resp));
    }

    /// An active request whose cancel token flipped: stop it where it
    /// stands. Pages are released by the retire step; executed-block
    /// counters stay truthful for the part that ran, and a decoding
    /// request leaves the decode batch so the next tick's passes no
    /// longer carry it.
    fn cancel_active(&mut self, a: &mut Active) {
        match std::mem::replace(&mut a.phase, Phase::Finished) {
            Phase::Prefill(session) => {
                self.metrics.record_prefill_timing(session.timing());
            }
            Phase::Decode { seq, .. } => {
                let _ = self.decode.leave(seq);
            }
            Phase::Finished => {}
        }
        self.metrics.record_cancelled();
        let mut resp = Response::failed(a.req.id, "cancelled".to_string());
        resp.e2e_ms = a.admitted.elapsed().as_secs_f64() * 1e3;
        resp.reused_blocks = a.reused_blocks;
        let _ = a.req.events.send(TokenEvent::Done(resp));
        a.ok = false;
    }

    /// Eject one batch-class prefill (a paused one if any, else any —
    /// the arriving interactive request that triggered this may be the
    /// only reason no session is flagged yet) to free its KV pages for
    /// interactive admission. Whole computed blocks are salvaged into
    /// the shared prefix cache first, so the re-admitted session
    /// adopts them and resumes from its block cursor instead of
    /// re-executing the prefix. A session whose work *cannot* be
    /// salvaged (prefix cache disabled, or a non-prefix-cacheable
    /// configuration) is only ejectable while it has computed nothing
    /// — ejecting it later would discard real work and invite
    /// restart-starvation under sustained interactive load. Returns
    /// whether anything was ejected.
    fn eject_preempted(&mut self, active: &mut Vec<Active>) -> bool {
        let cache_enabled =
            lock_recover(&self.router.prefix_cache).enabled();
        let ejectable = |a: &Active| -> bool {
            let Phase::Prefill(session) = &a.phase else {
                return false;
            };
            if a.req.class.is_interactive() {
                return false;
            }
            session.resident_blocks() == 0
                || (cache_enabled && a.req.cfg.prefix_cacheable())
        };
        let Some(i) = active
            .iter()
            .position(|a| a.preempted && ejectable(a))
            .or_else(|| active.iter().position(&ejectable))
        else {
            return false;
        };
        let mut a = active.swap_remove(i);
        let Phase::Prefill(session) =
            std::mem::replace(&mut a.phase, Phase::Finished)
        else {
            unreachable!()
        };
        // counters first (blocks that ran, ran), then salvage. The
        // salvaged blocks are keyed on the *effective* (possibly
        // token-pruned) prompt, matching what re-admission will look up.
        self.metrics.record_prefill_timing(session.timing());
        self.offer_blocks(&a.req, session.effective_tokens(),
                          session.keep_map(), &session.cache,
                          session.resident_blocks());
        {
            let mut pool = lock_recover(&self.router.kv_pool);
            if let Err(e) = pool.release_all(&a.pages) {
                eprintln!(
                    "[batcher:{}] page release: {e}",
                    self.replica.id()
                );
            }
        }
        a.pages.clear();
        self.metrics.record_preemption_ejection();
        self.replica.requeue(a.req);
        true
    }

    /// A request that failed before becoming active: answer it and
    /// settle its load accounting immediately.
    fn reject_failed(&mut self, req: Request, err: anyhow::Error) {
        eprintln!("[batcher:{}] admit failed: {err}", self.replica.id());
        self.replica.complete(req.prompt.len(), req.max_tokens);
        self.metrics.record_replica_done(self.replica.id(), false);
        let _ = req.events.send(TokenEvent::Done(Response::failed(
            req.id,
            err.to_string(),
        )));
    }

    fn admit(&mut self, mut req: Request)
             -> std::result::Result<Active, (Request, AdmitError)> {
        match self.try_admit(&req) {
            Ok((session, pages, reused_blocks)) => {
                // sample queue delay once per request: an ejected and
                // re-admitted prefill keeps its first-admission sample
                if !req.delay_sampled {
                    req.delay_sampled = true;
                    self.metrics.record_queue_delay(
                        req.class,
                        req.submitted.elapsed().as_secs_f64() * 1e3,
                    );
                }
                Ok(Active {
                    req,
                    phase: Phase::Prefill(session),
                    pages,
                    admitted: Instant::now(),
                    ttft_ms: None,
                    decode_ms_total: 0.0,
                    reused_blocks,
                    ok: true,
                    preempted: false,
                    decoder: StreamDecoder::new(),
                    last_emit: None,
                })
            }
            Err(e) => Err((req, e)),
        }
    }

    /// Build the prefill session, allocate pages for its *effective*
    /// prompt and adopt the longest cached prefix (if any). Returns
    /// (session, pages, reused_blocks).
    ///
    /// The session is built **before** pages are allocated: under
    /// speculative token pruning the session's scoring pass decides how
    /// many tokens actually prefill, and the page reservation covers
    /// only the surviving tokens (plus the decode budget) — a keep=0.5
    /// request reserves roughly half the KV a dense one would. A
    /// KV-pressure retry rebuilds the session, re-running the cheap
    /// scoring pass; selection is deterministic, so it reproduces the
    /// same keep-set.
    fn try_admit(&mut self, req: &Request)
                 -> std::result::Result<
                     (PrefillSession, Vec<PageId>, usize),
                     AdmitError,
                 > {
        let mut session = match PrefillSession::new(
            self.engine.clone(),
            req.prompt.clone(),
            req.cfg.clone(),
        ) {
            Ok(s) => s,
            Err(e) => return Err(AdmitError::Fatal(e)),
        };
        let total = session.effective_tokens().len() + req.max_tokens;
        let pages = {
            let mut pool = lock_recover(&self.router.kv_pool);
            let n = pool.pages_for(total);
            match pool.allocate(n) {
                Ok(p) => p,
                Err(_) => {
                    // live work outranks cached residency: reclaim
                    // unpinned prefix entries and retry (lock order:
                    // prefix_cache before kv_pool, as everywhere).
                    // Still short = transient pressure, not a failure:
                    // the router admitted this request, so pages will
                    // appear as other work retires.
                    drop(pool);
                    let mut pc = lock_recover(&self.router.prefix_cache);
                    let mut pool = lock_recover(&self.router.kv_pool);
                    pc.evict_for(n, &mut pool);
                    pool.allocate(n).map_err(|_| AdmitError::KvPressure)?
                }
            }
        };
        let release_on_err = |pages: &[PageId], router: &Router| {
            let mut pool = lock_recover(&router.kv_pool);
            let _ = pool.release_all(pages);
        };

        // Prefix adoption: pin the longest cached prefix under the lock,
        // then copy lock-free from the hit's Arc-shared rows — a long
        // memcpy never serializes the other replicas' admissions. The
        // refcount pin keeps the entries (and their page accounting)
        // resident until released. Lookup keys on the *effective*
        // tokens: pruned KV only ever matches pruned KV (the config
        // fingerprint in the seed already separates keep ratios).
        let mut reused_blocks = 0;
        if req.cfg.prefix_cacheable() {
            // config ⊕ model ⊕ backend: KV is only shared when all match
            let seed = self.engine.prefix_seed(&req.cfg);
            let hit = {
                let mut pc = lock_recover(&self.router.prefix_cache);
                if !pc.enabled() {
                    None
                } else {
                    let hit = pc.acquire(seed, session.effective_tokens());
                    if hit.is_none() {
                        // miss already counted by acquire
                        self.metrics.set_prefix_state(
                            pc.stats(),
                            pc.used_bytes(),
                            pc.entry_count(),
                        );
                    }
                    hit
                }
            };
            if let Some(hit) = hit {
                let adopt = session
                    .adopt_prefix(hit.tokens, |cache| hit.copy_into(cache));
                {
                    let mut pc = lock_recover(&self.router.prefix_cache);
                    pc.release(&hit);
                    self.metrics.set_prefix_state(
                        pc.stats(),
                        pc.used_bytes(),
                        pc.entry_count(),
                    );
                }
                match adopt {
                    Ok(()) => {
                        reused_blocks = hit.tokens / self.engine.block();
                    }
                    Err(e) => {
                        release_on_err(&pages, &self.router);
                        return Err(AdmitError::Fatal(e));
                    }
                }
            }
        }
        Ok((session, pages, reused_blocks))
    }

    fn step_prefill(&mut self, a: &mut Active, budget: &mut usize,
                    progressed: &mut bool) -> Result<()> {
        let Phase::Prefill(session) = &mut a.phase else {
            return Ok(());
        };
        if *budget == 0 {
            return Ok(());
        }
        let t0 = Instant::now();
        session.step()?;
        self.clock.observe(1.0, t0.elapsed().as_secs_f64() * 1e3);
        *budget -= 1;
        *progressed = true;
        self.finish_prefill_if_done(a)
    }

    /// If `a`'s prefill session consumed its whole prompt, finish it:
    /// record timing, emit `First` (TTFT), offer the prefix blocks to
    /// the shared cache, and join the replica's decode batch.
    fn finish_prefill_if_done(&mut self, a: &mut Active) -> Result<()> {
        let done = match &a.phase {
            Phase::Prefill(session) => session.done(),
            _ => false,
        };
        if !done {
            return Ok(());
        }
        let Phase::Prefill(session) =
            std::mem::replace(&mut a.phase, Phase::Finished)
        else {
            unreachable!()
        };
        // accurate executed-block accounting (adopted blocks and
        // tail tokens never count as executed blocks) — recorded
        // before finish() so a finish-time error can't lose the
        // blocks that genuinely ran
        self.metrics.record_prefill_timing(session.timing());
        // the effective (possibly token-pruned) prompt keys the prefix
        // offer, and its length — the cache fill — is where decode
        // positions continue from
        let effective = session.effective_tokens().to_vec();
        let pre = session.finish()?;
        let ttft = a.admitted.elapsed().as_secs_f64() * 1e3;
        a.ttft_ms = Some(ttft);
        self.metrics.record_ttft(ttft);
        let _ = a.req.events.send(TokenEvent::First {
            ttft_ms: ttft,
            reused_blocks: a.reused_blocks,
        });
        a.last_emit = Some(Instant::now());
        self.offer_prefix(&a.req, &effective, pre.keep_map.as_deref(),
                          &pre.cache);
        let next_pos = pre.cache.len;
        let seq = self.decode.join(
            pre.cache,
            next_pos,
            pre.last_logits,
            a.req.cfg.clone(),
        );
        a.phase = Phase::Decode {
            seq,
            generated: Vec::new(),
        };
        Ok(())
    }

    /// Offer a finished prefill's leading full blocks to the shared
    /// prefix cache, keyed on the *effective* (possibly token-pruned)
    /// prompt. A `dense_last` final block is excluded: its KV is
    /// position-special and would be wrong for a longer prompt sharing
    /// the prefix. Never fails the request — caching is best-effort.
    fn offer_prefix(&self, req: &Request, tokens: &[i32],
                    keep_map: Option<&[u32]>, cache: &SeqKvCache) {
        let block = self.engine.block();
        let full_blocks = tokens.len() / block;
        let prompt_is_block_aligned = tokens.len() % block == 0;
        let dense_last_applies = !req.cfg.is_dense()
            && req.cfg.dense_last
            && prompt_is_block_aligned;
        let max_blocks = if dense_last_applies {
            full_blocks.saturating_sub(1)
        } else {
            full_blocks
        };
        self.offer_blocks(req, tokens, keep_map, cache, max_blocks);
    }

    /// Offer the leading `max_blocks` full blocks of `cache` to the
    /// shared prefix cache. `tokens` is the effective prompt the rows
    /// were computed from (pruned when `keep_map` is present; each
    /// compressed page then records its rows' original positions as
    /// metadata). Also used by `eject_preempted` to salvage a
    /// partially-executed prefill (`cache.len` then covers only the
    /// prompt prefix computed so far; a mid-prompt block is never
    /// `dense_last`, so no exclusion applies).
    fn offer_blocks(&self, req: &Request, tokens: &[i32],
                    keep_map: Option<&[u32]>, cache: &SeqKvCache,
                    max_blocks: usize) {
        if !req.cfg.prefix_cacheable() || max_blocks == 0 {
            return;
        }
        let seed = self.engine.prefix_seed(&req.cfg);
        // cheap probe under the lock: which blocks are actually new
        let missing = {
            let pc = lock_recover(&self.router.prefix_cache);
            if !pc.enabled() {
                return;
            }
            pc.missing_blocks(seed, tokens, max_blocks, cache.len)
        };
        // the expensive memcpy runs with NO locks held, so offering a
        // long prefill never serializes the other replicas
        let block = self.engine.block();
        let prepared: Vec<crate::kvcache::PreparedBlock> = missing
            .into_iter()
            .map(|b| {
                let p = crate::kvcache::PreparedBlock::copy_from(
                    cache, block, b,
                );
                match keep_map {
                    Some(km) => p.with_keep(
                        km[b * block..(b + 1) * block].to_vec(),
                    ),
                    None => p,
                }
            })
            .collect();
        let mut pc = lock_recover(&self.router.prefix_cache);
        // lock order: prefix_cache before kv_pool (as at every nested
        // site); insert_prepared only hashes, evicts and moves Arcs
        let mut pool = lock_recover(&self.router.kv_pool);
        pc.insert_prepared(seed, tokens, max_blocks, prepared, &mut pool);
        drop(pool);
        self.metrics.set_prefix_state(
            pc.stats(),
            pc.used_bytes(),
            pc.entry_count(),
        );
    }

    /// Sample one token for an active decode member from its resident
    /// logits: finish the request (EOS / token budget), or stream the
    /// token and stage it for this tick's batched step. No engine work
    /// happens here — that is what lets every staged row share one
    /// forward pass.
    fn stage_decode(&mut self, a: &mut Active) {
        let Phase::Decode { seq, generated } = &mut a.phase else {
            return;
        };
        let seq = *seq;
        let tok = argmax(self.decode.logits(seq)) as i32;
        if tok == EOS || generated.len() >= a.req.max_tokens {
            self.finish_ok(a);
            return;
        }
        generated.push(tok);
        let hit_limit = generated.len() >= a.req.max_tokens;
        // stream the token before the next engine step: it is already
        // final (argmax of the previous logits)
        let text = a.decoder.push(tok);
        let now = Instant::now();
        if let Some(prev) = a.last_emit {
            self.metrics.record_itl(
                a.req.class,
                (now - prev).as_secs_f64() * 1e3,
            );
        }
        a.last_emit = Some(now);
        let _ = a.req.events.send(TokenEvent::Token { token: tok, text });
        if hit_limit {
            // the budget-hitting token needs no further logits: finish
            // without spending a batch row on it
            self.finish_ok(a);
        } else {
            self.decode.feed(seq, tok);
        }
    }

    /// The tick's prefill-chunk candidate: the first prefilling
    /// request in priority order (interactive first; preempted batch
    /// prefills excluded).
    fn pick_chunk(active: &[Active]) -> Option<usize> {
        for interactive_pass in [true, false] {
            for (i, a) in active.iter().enumerate() {
                if a.req.class.is_interactive() != interactive_pass {
                    continue;
                }
                if !interactive_pass && a.preempted {
                    continue;
                }
                if matches!(a.phase, Phase::Prefill(_)) {
                    return Some(i);
                }
            }
        }
        None
    }

    /// Run the tick's shared forward pass(es): every staged decode row
    /// plus — when `chunk_idx` names a prefilling request — one prefill
    /// chunk riding the first pass. A pass that errors fails exactly
    /// the requests whose rows it carried; the scheduling loop itself
    /// never dies.
    fn run_mixed_step(&mut self, active: &mut [Active],
                      chunk_idx: Option<usize>) {
        let stats = {
            let chunk = match chunk_idx {
                Some(i) => match &mut active[i].phase {
                    Phase::Prefill(session) => Some(session),
                    _ => unreachable!(
                        "chunk candidate must be prefilling"
                    ),
                },
                None => None,
            };
            self.decode.step(chunk, self.cfg.max_batch)
        };
        // occupancy metrics + the scheduler-unit clock, per pass (each
        // pass row — decode token or prefill chunk — is one unit)
        for p in &stats.passes {
            self.metrics.record_batch_step(p.rows);
            self.clock.observe(p.rows as f64, p.ms);
        }
        // Per-token decode latency from chunk-free passes only: a pass
        // carrying a (block-sized) prefill chunk says nothing about
        // the cost of one decode token. When every pass carried the
        // chunk, fall back to even amortization — the only estimate
        // available.
        let (pure_ms, pure_rows) = stats
            .passes
            .iter()
            .filter(|p| !p.chunk)
            .fold((0.0, 0usize), |(ms, rows), p| (ms + p.ms, rows + p.rows));
        let per_row = if pure_rows > 0 {
            pure_ms / pure_rows as f64
        } else {
            let (ms, rows) = stats
                .passes
                .iter()
                .fold((0.0, 0usize), |(ms, rows), p| {
                    (ms + p.ms, rows + p.rows)
                });
            if rows > 0 { ms / rows as f64 } else { 0.0 }
        };
        if per_row > 0.0 {
            for a in active.iter_mut() {
                if matches!(a.phase, Phase::Decode { .. }) {
                    a.decode_ms_total += per_row;
                    self.metrics.record_tpot(per_row);
                }
            }
        }
        // fail exactly the rows of failed passes
        for failure in &stats.failures {
            for (i, a) in active.iter_mut().enumerate() {
                let hit = match &a.phase {
                    Phase::Decode { seq, .. } => {
                        failure.members.contains(seq)
                    }
                    Phase::Prefill(_) => {
                        failure.chunk && chunk_idx == Some(i)
                    }
                    Phase::Finished => false,
                };
                if hit {
                    self.fail(a, anyhow::anyhow!("{}", failure.error));
                }
            }
        }
        if let Some(i) = chunk_idx {
            // no-op unless the chunk's session just consumed its
            // whole prompt (and it survived any pass failure)
            if let Err(e) = self.finish_prefill_if_done(&mut active[i]) {
                self.fail(&mut active[i], e);
            }
        }
    }

    fn finish_ok(&mut self, a: &mut Active) {
        let Phase::Decode { seq, generated } =
            std::mem::replace(&mut a.phase, Phase::Finished)
        else {
            return;
        };
        // the decode batch owns the cache while decoding; reclaim (and
        // drop) it now that the sequence is done
        let _cache = self.decode.leave(seq);
        let e2e = a.admitted.elapsed().as_secs_f64() * 1e3;
        let n = generated.len();
        self.metrics
            .record_request(a.req.prompt.len(), n, e2e);
        let _ = a.req.events.send(TokenEvent::Done(Response {
            id: a.req.id,
            text: self.tokenizer.decode(&generated),
            tokens: n,
            ttft_ms: a.ttft_ms.unwrap_or(e2e),
            tpot_ms: if n > 0 { a.decode_ms_total / n as f64 } else { 0.0 },
            e2e_ms: e2e,
            reused_blocks: a.reused_blocks,
            error: None,
        }));
    }

    fn fail(&mut self, a: &mut Active, err: anyhow::Error) {
        match std::mem::replace(&mut a.phase, Phase::Finished) {
            // a request failing mid-prefill still executed blocks:
            // keep the engine's block-execution counters truthful
            Phase::Prefill(session) => {
                self.metrics.record_prefill_timing(session.timing());
            }
            // a decoding request must leave the batch, or the next
            // tick would step a retired sequence
            Phase::Decode { seq, .. } => {
                let _ = self.decode.leave(seq);
            }
            Phase::Finished => {}
        }
        let mut resp = Response::failed(a.req.id, err.to_string());
        resp.e2e_ms = a.admitted.elapsed().as_secs_f64() * 1e3;
        resp.reused_blocks = a.reused_blocks;
        let _ = a.req.events.send(TokenEvent::Done(resp));
        a.ok = false;
    }

    fn retire(&mut self, a: &mut Active) {
        let mut pool = lock_recover(&self.router.kv_pool);
        if let Err(e) = pool.release_all(&a.pages) {
            eprintln!("[batcher:{}] page release: {e}", self.replica.id());
        }
        drop(pool);
        a.pages.clear();
        self.replica
            .complete(a.req.prompt.len(), a.req.max_tokens);
        self.metrics.record_replica_done(self.replica.id(), a.ok);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BatcherConfig {
        BatcherConfig {
            max_active: 8,
            prefill_block_budget: 4,
            decode_first_budget: 1,
            max_batch: 8,
            slo: true,
        }
    }

    fn req(class: SloClass, prefilling: bool, at_risk: bool) -> SchedReq {
        SchedReq {
            class,
            prefilling,
            deadline_at_risk: at_risk,
        }
    }

    #[test]
    fn batch_only_runs_unconstrained() {
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Batch, true, false),
            req(SloClass::Batch, false, false),
        ]);
        assert_eq!(p.prefill_budget, 4);
        assert!(!p.preempt_batch);
    }

    #[test]
    fn interactive_prefill_preempts_batch_at_full_budget() {
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Batch, true, false),
            req(SloClass::Interactive, true, false),
        ]);
        assert_eq!(p.prefill_budget, 4, "interactive prefill needs budget");
        assert!(p.preempt_batch, "batch prefill pauses meanwhile");
    }

    #[test]
    fn interactive_decode_caps_budget_without_preempting() {
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Batch, true, false),
            req(SloClass::Interactive, false, false),
        ]);
        assert_eq!(p.prefill_budget, 1, "decode-first trickle budget");
        assert!(!p.preempt_batch, "batch still trickles forward");
    }

    #[test]
    fn deadline_risk_preempts_batch() {
        // the non-vacuous case: an at-risk interactive *decode* pauses
        // the batch trickle, which interactive priority alone would
        // let run at decode_first_budget
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Batch, true, false),
            req(SloClass::Interactive, false, true),
        ]);
        assert!(p.preempt_batch, "at-risk decode stops the trickle");
        assert_eq!(p.prefill_budget, 1, "decode-first cap still applies");
        // without the risk flag, the same shape does NOT preempt
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Batch, true, false),
            req(SloClass::Interactive, false, false),
        ]);
        assert!(!p.preempt_batch);
    }

    #[test]
    fn slo_off_disables_everything() {
        let mut c = cfg();
        c.slo = false;
        let p = plan_schedule(&c, &[
            req(SloClass::Interactive, true, true),
            req(SloClass::Batch, true, false),
        ]);
        assert_eq!(p.prefill_budget, 4);
        assert!(!p.preempt_batch);
    }

    #[test]
    fn idle_interactive_only() {
        // interactive prefill alone: full budget, preempt flag set but
        // vacuous (no batch prefill to pause)
        let p = plan_schedule(&cfg(), &[
            req(SloClass::Interactive, true, false),
        ]);
        assert_eq!(p.prefill_budget, 4);
    }

    #[test]
    fn decode_first_budget_clamped() {
        let mut c = cfg();
        c.decode_first_budget = 9;
        let p = plan_schedule(&c, &[
            req(SloClass::Interactive, false, false),
        ]);
        assert_eq!(p.prefill_budget, 4, "cap never exceeds base budget");
    }
}
