//! Dynamic batcher / executor: continuous batching with chunked prefill.
//!
//! One executor thread owns the (non-Sync) engine and iterates:
//!
//! 1. admit new requests from the router (up to `max_active`),
//! 2. schedule up to `prefill_block_budget` prefill *blocks* across
//!    active requests (Sarathi-style chunked prefill — long prompts
//!    don't monopolize the engine),
//! 3. run one decode round for every request in the decode phase
//!    (continuous batching semantics; execution is serialized on the
//!    single PJRT CPU stream but scheduling interleaves fairly),
//! 4. retire finished requests, releasing their KV pages.
//!
//! TTFT is recorded when a request's first decode logits are produced —
//! matching the paper's definition.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{argmax, Engine, PrefillSession};
use crate::kvcache::{PageId, SeqKvCache};
use crate::metrics::Metrics;
use crate::router::{Request, Response, Router};
use crate::tokenizer::{Tokenizer, EOS};

/// Executor tuning knobs.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max concurrently active (admitted) requests.
    pub max_active: usize,
    /// Prefill blocks processed per scheduler iteration.
    pub prefill_block_budget: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_active: 8,
            prefill_block_budget: 4,
        }
    }
}

enum Phase {
    Prefill(PrefillSession),
    Decode {
        cache: SeqKvCache,
        logits: Vec<f32>,
        pos: usize,
        generated: Vec<i32>,
    },
    Finished,
}

struct Active {
    req: Request,
    phase: Phase,
    pages: Vec<PageId>,
    admitted: Instant,
    ttft_ms: Option<f64>,
    decode_ms_total: f64,
}

/// Runs the scheduling loop until the router closes.
pub struct Batcher {
    engine: Engine,
    router: Arc<Router>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
    tokenizer: Tokenizer,
}

impl Batcher {
    pub fn new(engine: Engine, router: Arc<Router>,
               cfg: BatcherConfig) -> Self {
        let vocab = engine.manifest().model.vocab;
        Batcher {
            metrics: router.metrics.clone(),
            engine,
            router,
            cfg,
            tokenizer: Tokenizer::new(vocab),
        }
    }

    /// Main loop. Returns when the router is closed and all work drained.
    pub fn run(mut self) -> Result<()> {
        let mut active: Vec<Active> = Vec::new();
        loop {
            // 1. admit
            let slots = self.cfg.max_active.saturating_sub(active.len());
            if slots > 0 {
                for req in self.router.pop_up_to(slots) {
                    match self.admit(req) {
                        Ok(a) => active.push(a),
                        Err(e) => eprintln!("[batcher] admit failed: {e}"),
                    }
                }
            }
            if active.is_empty() {
                // park on the router until work (or shutdown) arrives
                match self.router.pop_blocking() {
                    Some(req) => match self.admit(req) {
                        Ok(a) => active.push(a),
                        Err(e) => eprintln!("[batcher] admit failed: {e}"),
                    },
                    None => return Ok(()), // closed + drained
                }
            }

            // 2. chunked prefill round-robin
            let mut budget = self.cfg.prefill_block_budget;
            'outer: loop {
                let mut progressed = false;
                for a in active.iter_mut() {
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(e) = self.step_prefill(a, &mut budget,
                                                      &mut progressed) {
                        self.fail(a, e);
                    }
                }
                if !progressed {
                    break;
                }
            }

            // 3. one decode round each
            for a in active.iter_mut() {
                if let Err(e) = self.step_decode(a) {
                    self.fail(a, e);
                }
            }

            // 4. retire
            for a in active.iter_mut() {
                if matches!(a.phase, Phase::Finished) {
                    self.retire(a);
                }
            }
            active.retain(|a| !matches!(a.phase, Phase::Finished));
        }
    }

    fn admit(&mut self, req: Request) -> Result<Active> {
        let total = req.prompt.len() + req.max_tokens;
        let pages = {
            let mut pool = self.router.kv_pool.lock().unwrap();
            let n = pool.pages_for(total);
            pool.allocate(n)?
        };
        let session = PrefillSession::new(
            self.engine.clone(),
            req.prompt.clone(),
            req.cfg.clone(),
        )?;
        Ok(Active {
            req,
            phase: Phase::Prefill(session),
            pages,
            admitted: Instant::now(),
            ttft_ms: None,
            decode_ms_total: 0.0,
        })
    }

    fn step_prefill(&mut self, a: &mut Active, budget: &mut usize,
                    progressed: &mut bool) -> Result<()> {
        let Phase::Prefill(session) = &mut a.phase else {
            return Ok(());
        };
        if *budget == 0 {
            return Ok(());
        }
        let consumed = session.step()?;
        self.metrics.record_block(consumed == self.engine.block());
        *budget -= 1;
        *progressed = true;
        if session.done() {
            let Phase::Prefill(session) =
                std::mem::replace(&mut a.phase, Phase::Finished)
            else {
                unreachable!()
            };
            let pre = session.finish()?;
            let ttft = a.admitted.elapsed().as_secs_f64() * 1e3;
            a.ttft_ms = Some(ttft);
            self.metrics.record_ttft(ttft);
            a.phase = Phase::Decode {
                pos: a.req.prompt.len(),
                logits: pre.last_logits,
                cache: pre.cache,
                generated: Vec::new(),
            };
        }
        Ok(())
    }

    fn step_decode(&mut self, a: &mut Active) -> Result<()> {
        let Phase::Decode { cache, logits, pos, generated } = &mut a.phase
        else {
            return Ok(());
        };
        let tok = argmax(logits) as i32;
        if tok == EOS || generated.len() >= a.req.max_tokens {
            self.finish_ok(a);
            return Ok(());
        }
        generated.push(tok);
        let t0 = Instant::now();
        let new_logits =
            self.engine.decode_step(tok, *pos, cache, &a.req.cfg)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        a.decode_ms_total += ms;
        self.metrics.record_tpot(ms);
        *logits = new_logits;
        *pos += 1;
        let hit_limit = generated.len() >= a.req.max_tokens;
        if hit_limit {
            self.finish_ok(a);
        }
        Ok(())
    }

    fn finish_ok(&mut self, a: &mut Active) {
        let Phase::Decode { generated, .. } =
            std::mem::replace(&mut a.phase, Phase::Finished)
        else {
            return;
        };
        let e2e = a.admitted.elapsed().as_secs_f64() * 1e3;
        let n = generated.len();
        self.metrics
            .record_request(a.req.prompt.len(), n, e2e);
        let _ = a.req.respond.send(Response {
            id: a.req.id,
            text: self.tokenizer.decode(&generated),
            tokens: n,
            ttft_ms: a.ttft_ms.unwrap_or(e2e),
            tpot_ms: if n > 0 { a.decode_ms_total / n as f64 } else { 0.0 },
            e2e_ms: e2e,
            error: None,
        });
    }

    fn fail(&mut self, a: &mut Active, err: anyhow::Error) {
        let _ = a.req.respond.send(Response {
            id: a.req.id,
            text: String::new(),
            tokens: 0,
            ttft_ms: 0.0,
            tpot_ms: 0.0,
            e2e_ms: a.admitted.elapsed().as_secs_f64() * 1e3,
            error: Some(err.to_string()),
        });
        a.phase = Phase::Finished;
    }

    fn retire(&mut self, a: &mut Active) {
        let mut pool = self.router.kv_pool.lock().unwrap();
        if let Err(e) = pool.release_all(&a.pages) {
            eprintln!("[batcher] page release: {e}");
        }
        a.pages.clear();
    }
}
