//! Dynamic batcher: the per-replica executor loop — continuous batching
//! with chunked prefill and prefix-aware KV reuse.
//!
//! One executor thread owns one (non-Sync) engine and iterates:
//!
//! 1. admit new requests from its replica queue (up to `max_active`),
//!    adopting already-computed KV pages for the longest cached prefix,
//! 2. schedule up to `prefill_block_budget` prefill *blocks* across
//!    active requests (Sarathi-style chunked prefill — long prompts
//!    don't monopolize the engine),
//! 3. run one decode round for every request in the decode phase
//!    (continuous batching semantics; execution is serialized on the
//!    replica's PJRT stream but scheduling interleaves fairly),
//! 4. retire finished requests, releasing their KV pages and reporting
//!    their cost back to the replica's load accounting.
//!
//! When a prefill completes, its leading full blocks are offered to the
//! shared [`crate::kvcache::PrefixCache`], so a later request with the
//! same prompt prefix — on *any* replica — prefills only the uncached
//! suffix.
//!
//! TTFT is recorded when a request's first decode logits are produced —
//! matching the paper's definition.
//!
//! [`crate::pool::ExecutorPool`] spawns one `Batcher` per replica; the
//! single-threaded stack (`Batcher::new`) remains for tests and
//! examples.

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::{argmax, Engine, PrefillSession};
use crate::kvcache::{PageId, SeqKvCache};
use crate::metrics::Metrics;
use crate::router::{Replica, Request, Response, Router};
use crate::tokenizer::{Tokenizer, EOS};

/// Executor tuning knobs (see docs/OPERATIONS.md for guidance).
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Max concurrently active (admitted) requests per replica.
    pub max_active: usize,
    /// Prefill blocks processed per scheduler iteration.
    pub prefill_block_budget: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_active: 8,
            prefill_block_budget: 4,
        }
    }
}

/// Why an admission attempt failed.
enum AdmitError {
    /// Transient KV-page shortage: the request stays queued and is
    /// retried once retires (or prefix-cache reclaim) free pages.
    KvPressure,
    /// Permanent failure for this request: answer it with an error.
    Fatal(anyhow::Error),
}

enum Phase {
    Prefill(PrefillSession),
    Decode {
        cache: SeqKvCache,
        logits: Vec<f32>,
        pos: usize,
        generated: Vec<i32>,
    },
    Finished,
}

struct Active {
    req: Request,
    phase: Phase,
    pages: Vec<PageId>,
    admitted: Instant,
    ttft_ms: Option<f64>,
    decode_ms_total: f64,
    reused_blocks: usize,
    ok: bool,
}

/// Runs one replica's scheduling loop until the router closes.
pub struct Batcher {
    engine: Engine,
    router: Arc<Router>,
    replica: Arc<Replica>,
    metrics: Arc<Metrics>,
    cfg: BatcherConfig,
    tokenizer: Tokenizer,
}

impl Batcher {
    /// Executor for replica 0 — the single-replica stack used by tests,
    /// examples and `Batcher`-level embedding.
    pub fn new(engine: Engine, router: Arc<Router>,
               cfg: BatcherConfig) -> Self {
        Self::for_replica(engine, router, cfg, 0)
    }

    /// Executor bound to replica `replica_id` of the router's pool.
    pub fn for_replica(engine: Engine, router: Arc<Router>,
                       cfg: BatcherConfig, replica_id: usize) -> Self {
        let vocab = engine.manifest().model.vocab;
        Batcher {
            replica: router.replica(replica_id),
            metrics: router.metrics.clone(),
            engine,
            router,
            cfg,
            tokenizer: Tokenizer::new(vocab),
        }
    }

    /// Main loop. Returns when the router is closed and all work drained.
    pub fn run(mut self) -> Result<()> {
        let mut active: Vec<Active> = Vec::new();
        loop {
            // 1. admit
            let slots = self.cfg.max_active.saturating_sub(active.len());
            if slots > 0 {
                let mut popped = self.replica.pop_up_to(slots);
                while !popped.is_empty() {
                    let req = popped.remove(0);
                    match self.admit(req) {
                        Ok(a) => active.push(a),
                        Err((req, AdmitError::KvPressure)) => {
                            // transient: retires will free pages. Put
                            // back EVERYTHING we popped — front-first so
                            // FIFO order is preserved — and stop
                            // admitting this round.
                            for r in popped.drain(..).rev() {
                                self.replica.requeue(r);
                            }
                            self.replica.requeue(req);
                            break;
                        }
                        Err((req, AdmitError::Fatal(e))) => {
                            self.reject_failed(req, e)
                        }
                    }
                }
            }
            if active.is_empty() {
                // park on the replica queue until work (or shutdown)
                match self.replica.pop_blocking() {
                    Some(req) => match self.admit(req) {
                        Ok(a) => active.push(a),
                        Err((req, AdmitError::KvPressure)) => {
                            // nothing of ours will retire; wait briefly
                            // for other replicas / the prefix cache to
                            // release pages, then retry
                            self.replica.requeue(req);
                            std::thread::sleep(
                                std::time::Duration::from_millis(2),
                            );
                        }
                        Err((req, AdmitError::Fatal(e))) => {
                            self.reject_failed(req, e)
                        }
                    },
                    None => return Ok(()), // closed + drained
                }
            }

            // 2. chunked prefill round-robin
            let mut budget = self.cfg.prefill_block_budget;
            'outer: loop {
                let mut progressed = false;
                for a in active.iter_mut() {
                    if budget == 0 {
                        break 'outer;
                    }
                    if let Err(e) = self.step_prefill(a, &mut budget,
                                                      &mut progressed) {
                        self.fail(a, e);
                    }
                }
                if !progressed {
                    break;
                }
            }

            // 3. one decode round each
            for a in active.iter_mut() {
                if let Err(e) = self.step_decode(a) {
                    self.fail(a, e);
                }
            }

            // 4. retire
            for a in active.iter_mut() {
                if matches!(a.phase, Phase::Finished) {
                    self.retire(a);
                }
            }
            active.retain(|a| !matches!(a.phase, Phase::Finished));
        }
    }

    /// A request that failed before becoming active: answer it and
    /// settle its load accounting immediately.
    fn reject_failed(&mut self, req: Request, err: anyhow::Error) {
        eprintln!("[batcher:{}] admit failed: {err}", self.replica.id());
        self.replica.complete(req.prompt.len(), req.max_tokens);
        self.metrics.record_replica_done(self.replica.id(), false);
        let _ = req
            .respond
            .send(Response::failed(req.id, err.to_string()));
    }

    fn admit(&mut self, req: Request)
             -> std::result::Result<Active, (Request, AdmitError)> {
        match self.try_admit(&req) {
            Ok((session, pages, reused_blocks)) => Ok(Active {
                req,
                phase: Phase::Prefill(session),
                pages,
                admitted: Instant::now(),
                ttft_ms: None,
                decode_ms_total: 0.0,
                reused_blocks,
                ok: true,
            }),
            Err(e) => Err((req, e)),
        }
    }

    /// Allocate pages, build the prefill session and adopt the longest
    /// cached prefix (if any). Returns (session, pages, reused_blocks).
    fn try_admit(&mut self, req: &Request)
                 -> std::result::Result<
                     (PrefillSession, Vec<PageId>, usize),
                     AdmitError,
                 > {
        let total = req.prompt.len() + req.max_tokens;
        let pages = {
            let mut pool = self.router.kv_pool.lock().unwrap();
            let n = pool.pages_for(total);
            match pool.allocate(n) {
                Ok(p) => p,
                Err(_) => {
                    // live work outranks cached residency: reclaim
                    // unpinned prefix entries and retry (lock order:
                    // prefix_cache before kv_pool, as everywhere).
                    // Still short = transient pressure, not a failure:
                    // the router admitted this request, so pages will
                    // appear as other work retires.
                    drop(pool);
                    let mut pc = self.router.prefix_cache.lock().unwrap();
                    let mut pool = self.router.kv_pool.lock().unwrap();
                    pc.evict_for(n, &mut pool);
                    pool.allocate(n).map_err(|_| AdmitError::KvPressure)?
                }
            }
        };
        let release_on_err = |pages: &[PageId], router: &Router| {
            let mut pool = router.kv_pool.lock().unwrap();
            let _ = pool.release_all(pages);
        };
        let mut session = match PrefillSession::new(
            self.engine.clone(),
            req.prompt.clone(),
            req.cfg.clone(),
        ) {
            Ok(s) => s,
            Err(e) => {
                release_on_err(&pages, &self.router);
                return Err(AdmitError::Fatal(e));
            }
        };

        // Prefix adoption: pin the longest cached prefix under the lock,
        // then copy lock-free from the hit's Arc-shared rows — a long
        // memcpy never serializes the other replicas' admissions. The
        // refcount pin keeps the entries (and their page accounting)
        // resident until released.
        let mut reused_blocks = 0;
        if req.cfg.prefix_cacheable() {
            let seed = req.cfg.prefill_fingerprint();
            let hit = {
                let mut pc = self.router.prefix_cache.lock().unwrap();
                if !pc.enabled() {
                    None
                } else {
                    let hit = pc.acquire(seed, &req.prompt);
                    if hit.is_none() {
                        // miss already counted by acquire
                        self.metrics.set_prefix_state(
                            pc.stats(),
                            pc.used_bytes(),
                            pc.entry_count(),
                        );
                    }
                    hit
                }
            };
            if let Some(hit) = hit {
                let adopt = session
                    .adopt_prefix(hit.tokens, |cache| hit.copy_into(cache));
                {
                    let mut pc = self.router.prefix_cache.lock().unwrap();
                    pc.release(&hit);
                    self.metrics.set_prefix_state(
                        pc.stats(),
                        pc.used_bytes(),
                        pc.entry_count(),
                    );
                }
                match adopt {
                    Ok(()) => {
                        reused_blocks = hit.tokens / self.engine.block();
                    }
                    Err(e) => {
                        release_on_err(&pages, &self.router);
                        return Err(AdmitError::Fatal(e));
                    }
                }
            }
        }
        Ok((session, pages, reused_blocks))
    }

    fn step_prefill(&mut self, a: &mut Active, budget: &mut usize,
                    progressed: &mut bool) -> Result<()> {
        let Phase::Prefill(session) = &mut a.phase else {
            return Ok(());
        };
        if *budget == 0 {
            return Ok(());
        }
        session.step()?;
        *budget -= 1;
        *progressed = true;
        if session.done() {
            let Phase::Prefill(session) =
                std::mem::replace(&mut a.phase, Phase::Finished)
            else {
                unreachable!()
            };
            // accurate executed-block accounting (adopted blocks and
            // tail tokens never count as executed blocks) — recorded
            // before finish() so a finish-time error can't lose the
            // blocks that genuinely ran
            self.metrics.record_prefill_timing(session.timing());
            let pre = session.finish()?;
            let ttft = a.admitted.elapsed().as_secs_f64() * 1e3;
            a.ttft_ms = Some(ttft);
            self.metrics.record_ttft(ttft);
            self.offer_prefix(&a.req, &pre.cache);
            a.phase = Phase::Decode {
                pos: a.req.prompt.len(),
                logits: pre.last_logits,
                cache: pre.cache,
                generated: Vec::new(),
            };
        }
        Ok(())
    }

    /// Offer a finished prefill's leading full blocks to the shared
    /// prefix cache. A `dense_last` final block is excluded: its KV is
    /// position-special and would be wrong for a longer prompt sharing
    /// the prefix. Never fails the request — caching is best-effort.
    fn offer_prefix(&self, req: &Request, cache: &SeqKvCache) {
        if !req.cfg.prefix_cacheable() {
            return;
        }
        let block = self.engine.block();
        let full_blocks = req.prompt.len() / block;
        let prompt_is_block_aligned = req.prompt.len() % block == 0;
        let dense_last_applies =
            !req.cfg.is_dense() && req.cfg.dense_last && prompt_is_block_aligned;
        let max_blocks = if dense_last_applies {
            full_blocks.saturating_sub(1)
        } else {
            full_blocks
        };
        if max_blocks == 0 {
            return;
        }
        let seed = req.cfg.prefill_fingerprint();
        // cheap probe under the lock: which blocks are actually new
        let missing = {
            let pc = self.router.prefix_cache.lock().unwrap();
            if !pc.enabled() {
                return;
            }
            pc.missing_blocks(seed, &req.prompt, max_blocks, cache.len)
        };
        // the expensive memcpy runs with NO locks held, so offering a
        // long prefill never serializes the other replicas
        let prepared: Vec<crate::kvcache::PreparedBlock> = missing
            .into_iter()
            .map(|b| crate::kvcache::PreparedBlock::copy_from(
                cache,
                self.engine.block(),
                b,
            ))
            .collect();
        let mut pc = self.router.prefix_cache.lock().unwrap();
        // lock order: prefix_cache before kv_pool (as at every nested
        // site); insert_prepared only hashes, evicts and moves Arcs
        let mut pool = self.router.kv_pool.lock().unwrap();
        pc.insert_prepared(seed, &req.prompt, max_blocks, prepared,
                           &mut pool);
        drop(pool);
        self.metrics.set_prefix_state(
            pc.stats(),
            pc.used_bytes(),
            pc.entry_count(),
        );
    }

    fn step_decode(&mut self, a: &mut Active) -> Result<()> {
        let Phase::Decode { cache, logits, pos, generated } = &mut a.phase
        else {
            return Ok(());
        };
        let tok = argmax(logits) as i32;
        if tok == EOS || generated.len() >= a.req.max_tokens {
            self.finish_ok(a);
            return Ok(());
        }
        generated.push(tok);
        let t0 = Instant::now();
        let new_logits =
            self.engine.decode_step(tok, *pos, cache, &a.req.cfg)?;
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        a.decode_ms_total += ms;
        self.metrics.record_tpot(ms);
        *logits = new_logits;
        *pos += 1;
        let hit_limit = generated.len() >= a.req.max_tokens;
        if hit_limit {
            self.finish_ok(a);
        }
        Ok(())
    }

    fn finish_ok(&mut self, a: &mut Active) {
        let Phase::Decode { generated, .. } =
            std::mem::replace(&mut a.phase, Phase::Finished)
        else {
            return;
        };
        let e2e = a.admitted.elapsed().as_secs_f64() * 1e3;
        let n = generated.len();
        self.metrics
            .record_request(a.req.prompt.len(), n, e2e);
        let _ = a.req.respond.send(Response {
            id: a.req.id,
            text: self.tokenizer.decode(&generated),
            tokens: n,
            ttft_ms: a.ttft_ms.unwrap_or(e2e),
            tpot_ms: if n > 0 { a.decode_ms_total / n as f64 } else { 0.0 },
            e2e_ms: e2e,
            reused_blocks: a.reused_blocks,
            error: None,
        });
    }

    fn fail(&mut self, a: &mut Active, err: anyhow::Error) {
        // a request failing mid-prefill still executed blocks: keep the
        // engine's block-execution counters truthful
        if let Phase::Prefill(session) = &a.phase {
            self.metrics.record_prefill_timing(session.timing());
        }
        let mut resp = Response::failed(a.req.id, err.to_string());
        resp.e2e_ms = a.admitted.elapsed().as_secs_f64() * 1e3;
        resp.reused_blocks = a.reused_blocks;
        let _ = a.req.respond.send(resp);
        a.ok = false;
        a.phase = Phase::Finished;
    }

    fn retire(&mut self, a: &mut Active) {
        let mut pool = self.router.kv_pool.lock().unwrap();
        if let Err(e) = pool.release_all(&a.pages) {
            eprintln!("[batcher:{}] page release: {e}", self.replica.id());
        }
        drop(pool);
        a.pages.clear();
        self.replica
            .complete(a.req.prompt.len(), a.req.max_tokens);
        self.metrics.record_replica_done(self.replica.id(), a.ok);
    }
}
