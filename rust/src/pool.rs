//! Replica-sharded executor pool.
//!
//! The engine is deliberately `!Send` (its runtime's backend keeps
//! per-replica mutable caches), so the pool cannot hand one engine to
//! N threads. Instead
//! each worker thread *constructs its own* engine from the same
//! artifacts via a caller-supplied factory, then runs a [`Batcher`]
//! loop against its [`crate::router::Replica`] queue. The router
//! performs least-loaded dispatch across the replicas, and the paged KV
//! pool and prefix cache are shared, so a prefix prefilled on one
//! replica is adoptable by all of them.
//!
//! ```text
//!                      ┌────────────── ExecutorPool ───────────────┐
//! HTTP ─▶ Router ──┬──▶ replica 0 queue ─▶ Batcher ─▶ Engine ─▶ PJRT
//!  (admission,     ├──▶ replica 1 queue ─▶ Batcher ─▶ Engine ─▶ PJRT
//!   least-loaded   └──▶ replica N-1  …
//!   dispatch)        shared: PagedAllocator · PrefixCache · Metrics
//! ```
//!
//! A worker whose factory fails marks its replica dead: the router
//! routes around it and its queued requests receive error responses
//! instead of hanging.

use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::batcher::{Batcher, BatcherConfig};
use crate::engine::Engine;
use crate::router::Router;

/// Handle to the pool's worker threads.
pub struct ExecutorPool {
    workers: Vec<JoinHandle<Result<()>>>,
}

/// A per-replica engine factory over shared model state, as built by
/// [`ExecutorPool::shared_backend_factory`]: each call constructs one
/// replica's engine from the same `Arc<Manifest>` / `Arc<WeightStore>`.
pub type BackendFactory =
    Box<dyn Fn() -> Result<Engine> + Send + Sync + 'static>;

/// Drop guard that marks a replica dead when its executor thread
/// terminates for *any* reason — normal drain, error return, or panic
/// (unwinding runs destructors). Without it, a panicking executor
/// would leave its queue live in the router: clients already queued
/// would hang forever and new traffic would keep being dispatched into
/// the void. Queued requests *fail over* to the surviving replicas
/// ([`Router::fail_over`]); only requests no alive replica can absorb
/// are errored back to their clients.
struct DeadOnExit {
    router: Arc<Router>,
    id: usize,
}

impl Drop for DeadOnExit {
    fn drop(&mut self) {
        self.router
            .fail_over(self.id, "executor thread terminated");
    }
}

impl ExecutorPool {
    /// Spawn one executor thread per router replica.
    ///
    /// `factory` runs once on each worker thread to build that
    /// replica's engine (loading artifacts, compiling nothing yet —
    /// executables compile lazily on first dispatch). A factory error
    /// kills only that replica; the rest of the pool keeps serving.
    pub fn spawn<F>(router: Arc<Router>, cfg: BatcherConfig,
                    factory: F) -> ExecutorPool
    where
        F: Fn() -> Result<Engine> + Send + Sync + 'static,
    {
        let factory = Arc::new(factory);
        let workers = (0..router.replica_count())
            .map(|id| {
                let router = router.clone();
                let factory = factory.clone();
                let cfg = cfg.clone();
                std::thread::Builder::new()
                    .name(format!("ff-executor-{id}"))
                    .spawn(move || -> Result<()> {
                        let engine = match (factory.as_ref())() {
                            Ok(e) => e,
                            Err(e) => {
                                let msg = format!(
                                    "replica {id} failed to start: {e}"
                                );
                                eprintln!("[pool] {msg}");
                                router.replica(id).mark_dead(&msg);
                                return Err(e);
                            }
                        };
                        let _guard = DeadOnExit {
                            router: router.clone(),
                            id,
                        };
                        Batcher::for_replica(engine, router, cfg, id).run()
                    })
                    .expect("spawn executor thread")
            })
            .collect();
        ExecutorPool { workers }
    }

    /// Spawn a pool whose workers each load the artifact bundle at
    /// `dir` — the standard production factory (PJRT backend).
    pub fn spawn_from_artifacts(router: Arc<Router>, cfg: BatcherConfig,
                                dir: std::path::PathBuf) -> ExecutorPool {
        Self::spawn_backend(router, cfg, crate::runtime::BackendKind::Pjrt,
                            Some(dir))
    }

    /// Spawn a pool on an explicit execution backend.
    ///
    /// * `Pjrt` + `Some(dir)` — compile the AOT bundle at `dir` (the
    ///   production path; requires the `pjrt` cargo feature).
    /// * `Cpu` + `None` — fully self-contained: the deterministic
    ///   pure-Rust interpreter over the synthetic reference model
    ///   ([`crate::manifest::SyntheticSpec::default`]).
    /// * `Cpu` + `Some(dir)` / `Pjrt` + `None` — every replica fails
    ///   fast with a clear error instead of hanging: the CPU backend
    ///   cannot execute artifact bundles (their fused low-rank
    ///   predictor/compensator networks are PJRT-only), and PJRT needs
    ///   artifacts.
    ///
    /// The manifest and weights are loaded (or seeded) **once**, on the
    /// caller's thread, and shared across every replica through `Arc`s
    /// — replicas must never re-seed or re-load their own copy, or a
    /// torn deployment could serve different weights per replica (see
    /// [`ExecutorPool::shared_backend_factory`] and the fingerprint
    /// regression test in `tests/backend_conformance.rs`). A load
    /// failure degrades to an error factory, so queued requests are
    /// answered with the error instead of hanging.
    pub fn spawn_backend(router: Arc<Router>, cfg: BatcherConfig,
                         kind: crate::runtime::BackendKind,
                         dir: Option<std::path::PathBuf>) -> ExecutorPool {
        match Self::shared_backend_factory(kind, dir) {
            Ok(factory) => Self::spawn(router, cfg, factory),
            Err(e) => {
                let msg = e.to_string();
                Self::spawn(router, cfg, move || Err(anyhow!("{msg}")))
            }
        }
    }

    /// Build the per-replica engine factory for
    /// [`ExecutorPool::spawn_backend`]: resolves the backend/artifact
    /// combination, loads (PJRT) or seeds (CPU) the manifest + weight
    /// store exactly once, and returns a `Send + Sync` closure every
    /// replica thread calls to construct its own engine over the
    /// *shared* `Arc`s. Exposed so tests can assert the sharing
    /// invariant (same allocation, equal numeric fingerprints across
    /// replicas).
    pub fn shared_backend_factory(
        kind: crate::runtime::BackendKind,
        dir: Option<std::path::PathBuf>,
    ) -> Result<BackendFactory> {
        use crate::runtime::BackendKind;
        let (manifest, weights) = match (kind, dir) {
            (BackendKind::Pjrt, Some(d)) => {
                let manifest =
                    Arc::new(crate::manifest::Manifest::load(&d)?);
                let weights = Arc::new(
                    crate::weights::WeightStore::load(&manifest)?,
                );
                (manifest, weights)
            }
            (BackendKind::Cpu, None) => {
                // Serving honors the process-wide storage choice
                // (`--weight-precision` forwards through FF_WEIGHT_PREC)
                // so every replica shares one store of the right mode.
                let mut spec = crate::manifest::SyntheticSpec::default();
                spec.weight_precision =
                    crate::weights::WeightPrecision::from_env();
                let manifest =
                    Arc::new(crate::manifest::Manifest::synthetic(&spec));
                let weights = Arc::new(
                    crate::weights::WeightStore::seeded_with(
                        &manifest, spec.seed, spec.weight_precision,
                    ),
                );
                (manifest, weights)
            }
            (BackendKind::Cpu, Some(d)) => {
                return Err(anyhow!(
                    "the cpu backend serves the synthetic reference \
                     model and cannot execute the artifact bundle at \
                     {d:?} (its fused low-rank predictor/compensator \
                     networks are PJRT-only); use the pjrt backend"
                ))
            }
            (BackendKind::Pjrt, None) => {
                return Err(anyhow!(
                    "the pjrt backend requires an artifact directory \
                     (run `make artifacts` or pass --artifacts DIR)"
                ))
            }
        };
        Ok(Box::new(move || -> Result<Engine> {
            let rt = Arc::new(crate::runtime::Runtime::with_backend(
                kind,
                manifest.clone(),
                weights.clone(),
            )?);
            Ok(Engine::new(rt))
        }))
    }

    /// Number of worker threads (== router replicas at spawn time).
    pub fn replicas(&self) -> usize {
        self.workers.len()
    }

    /// Wait for every worker to drain and exit (call after
    /// [`Router::close`]). Returns the first worker error, if any.
    pub fn join(self) -> Result<()> {
        let mut first_err = None;
        for (i, w) in self.workers.into_iter().enumerate() {
            match w.join() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                Err(_) => {
                    first_err
                        .get_or_insert(anyhow!("executor {i} panicked"));
                }
            }
        }
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::SparsityConfig;
    use crate::metrics::Metrics;
    use crate::router::{LoadEstimator, Response};
    use std::sync::mpsc::channel;

    /// The artifact-free pool path: CPU backend + synthetic manifest
    /// serves a real generation end to end.
    #[test]
    fn cpu_pool_serves_requests_without_artifacts() {
        let router = Arc::new(Router::new_pooled(
            8,
            2048,
            256,
            128,
            Arc::new(Metrics::new()),
            1,
            LoadEstimator::new(128),
            0,
        ));
        let (tx, rx) = channel();
        router
            .submit(vec![b'a' as i32; 40], 4, SparsityConfig::dense(), tx)
            .unwrap();
        let pool = ExecutorPool::spawn_backend(
            router.clone(),
            BatcherConfig::default(),
            crate::runtime::BackendKind::Cpu,
            None,
        );
        let resp = Response::collect_timeout(
            &rx,
            std::time::Duration::from_secs(120),
        )
        .expect("cpu pool answers");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        router.close();
        pool.join().unwrap();
        assert_eq!(router.kv_pool.lock().unwrap().used_pages(), 0);
    }

    #[test]
    fn failed_factory_fails_requests_instead_of_hanging() {
        let router = Arc::new(Router::new_pooled(
            8,
            4096,
            64,
            128,
            Arc::new(Metrics::new()),
            1,
            LoadEstimator::new(128),
            0,
        ));
        let (tx, rx) = channel();
        router
            .submit(vec![1; 64], 4, SparsityConfig::dense(), tx)
            .unwrap();
        let pool = ExecutorPool::spawn(
            router.clone(),
            BatcherConfig::default(),
            || Err(anyhow!("no artifacts in unit tests")),
        );
        let resp = Response::collect_timeout(
            &rx,
            std::time::Duration::from_secs(10),
        )
        .expect("queued request must be answered");
        assert!(resp.error.unwrap().contains("failed to start"));
        router.close();
        assert!(pool.join().is_err(), "factory error surfaces on join");
        // and the router now rejects instead of queueing into the void
        let (tx, _rx) = channel();
        assert_eq!(
            router
                .submit(vec![1; 64], 4, SparsityConfig::dense(), tx)
                .unwrap_err(),
            crate::router::Reject::Unavailable
        );
    }
}
