//! The execution-backend abstraction behind [`crate::runtime::Runtime`].
//!
//! A [`Backend`] turns one manifest executable plus resolved inputs into
//! host f32 outputs. Two implementations exist:
//!
//! * [`crate::runtime::PjrtBackend`] — compiles the AOT HLO-text
//!   artifacts on the PJRT CPU client and dispatches device buffers
//!   (the production path; inert without the `pjrt` cargo feature).
//! * [`crate::runtime::CpuBackend`] — a dependency-free pure-Rust
//!   interpreter for the small op set the artifact ABI names (embed,
//!   rmsnorm + attention, gather-indexed sparse FFN, dense FFN,
//!   lm_head). Two flavours sharing one bit-exact numeric contract:
//!   the fast tiled/parallel default (worker pool sized by
//!   `--cpu-threads` / `FF_CPU_THREADS`) and the sequential scalar
//!   [`crate::runtime::CpuBackend::reference`] oracle it is
//!   conformance-tested against. Deterministic on any machine and at
//!   any thread count, which is what un-gates the end-to-end numeric
//!   test suites in CI.
//!
//! The [`crate::runtime::Runtime`] wrapper owns the manifest, performs
//! ABI-level input validation common to every backend (missing inputs,
//! shape mismatches), and delegates execution here.

use anyhow::{anyhow, Result};

use crate::manifest::{ArgKind, ExecutableSpec};

use super::{DispatchStats, Input, Output};

/// One sequence's slice of a mixed prefill-chunk/decode step batch at
/// one transformer layer — the batched (`decode_batch`) extension of
/// the executable ABI.
///
/// Each row names its *own* per-row layer executable (already resolved
/// and shape-validated by [`crate::runtime::Runtime::run_layer_batch`])
/// plus that sequence's activations, KV views and absolute position. A
/// backend receives every row of the step at once, so it can fold the
/// rows into shared weight passes (one read of the layer weights for B
/// decode rows plus a prefill chunk) while keeping each row's
/// arithmetic — and therefore each row's output bits — exactly what a
/// per-row [`Backend::execute`] dispatch would produce.
pub struct BatchRow<'a> {
    /// The row's layer executable (e.g. `layer_dense_t1_s256`).
    pub spec: &'a ExecutableSpec,
    /// Input activations, `[t, d_model]` row-major.
    pub x: &'a [f32],
    /// Token rows in this slice (1 for a decode row, the prefill block
    /// size for a chunk row).
    pub t: usize,
    /// This sequence's KV bucket capacity (the `s` in the exe name).
    pub s: usize,
    /// Absolute position of the slice's first token in its sequence.
    pub pos: usize,
    /// This sequence's key cache, `[s, n_kv, d_head]`.
    pub k_cache: &'a [f32],
    /// This sequence's value cache, same layout as `k_cache`.
    pub v_cache: &'a [f32],
}

impl BatchRow<'_> {
    /// The declared ABI shape of runtime input `name` on this row's
    /// executable (empty when the spec does not declare it).
    fn input_shape(&self, name: &str) -> Vec<usize> {
        self.spec
            .args
            .iter()
            .find_map(|a| match &a.kind {
                ArgKind::Input(n) if n == name => Some(a.shape.clone()),
                _ => None,
            })
            .unwrap_or_default()
    }
}

/// One row's outputs from a batched layer step: the post-layer
/// activations plus the fresh KV rows to scatter into that sequence's
/// own cache.
pub struct BatchRowOut {
    /// Post-layer activations, `[t, d_model]`.
    pub y: Vec<f32>,
    /// Fresh key rows, `[t, n_kv, d_head]`.
    pub k_new: Vec<f32>,
    /// Fresh value rows, `[t, n_kv, d_head]`.
    pub v_new: Vec<f32>,
}

/// Run every row of a batched layer step through the ordinary per-row
/// [`Backend::execute`] entry, in row order — the sequential semantics
/// of the batched ABI. This is the default [`Backend::execute_batch`]
/// body, the PJRT path (one device dispatch per row), and the CPU
/// reference oracle's path; the fast CPU backend must match its output
/// bits exactly (`tests/backend_conformance.rs`).
pub fn sequential_batch<B: Backend + ?Sized>(
    backend: &B, layer: usize, rows: &[BatchRow<'_>],
) -> Result<Vec<BatchRowOut>> {
    let mut out = Vec::with_capacity(rows.len());
    for row in rows {
        let pos_i = [row.pos as i32];
        let inputs = [
            ("x", Input::F32(row.x, row.input_shape("x"))),
            ("k_cache", Input::F32(row.k_cache, row.input_shape("k_cache"))),
            ("v_cache", Input::F32(row.v_cache, row.input_shape("v_cache"))),
            ("pos", Input::I32(&pos_i, vec![])),
        ];
        let outs = backend.execute(row.spec, layer, &inputs)?;
        let mut it = outs.into_iter();
        let (Some(y), Some(k_new), Some(v_new)) =
            (it.next(), it.next(), it.next())
        else {
            return Err(anyhow!(
                "{}: layer executable returned fewer than 3 outputs",
                row.spec.name
            ));
        };
        out.push(BatchRowOut {
            y: y.data,
            k_new: k_new.data,
            v_new: v_new.data,
        });
    }
    Ok(out)
}

/// One execution backend: prepares executables and runs dispatches.
///
/// Implementations are `!Send` by design (like the engine that drives
/// them): every executor-pool replica constructs its own backend on its
/// own thread — over *shared* `Arc<Manifest>` / `Arc<WeightStore>`
/// state, so N replicas share one weight store (per-backend derived
/// state — PJRT device buffers, the CPU fast path's transposed gate/up
/// panels — stays per replica).
pub trait Backend {
    /// Stable backend label ("cpu" / "pjrt"); feeds the runtime's
    /// numeric fingerprint so KV computed by one backend is never
    /// adopted by another.
    fn name(&self) -> &'static str;

    /// Prepare an executable for dispatch (compile it, or validate that
    /// the interpreter understands it). Idempotent and cached.
    fn prepare(&self, spec: &ExecutableSpec) -> Result<()>;

    /// Number of distinct executables prepared so far.
    fn prepared_count(&self) -> usize;

    /// Execute `spec` for transformer layer `layer` over ABI-validated
    /// inputs, returning the decomposed output tuple as host f32
    /// tensors.
    fn execute(&self, spec: &ExecutableSpec, layer: usize,
               inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>>;

    /// Execute one transformer layer for *every* row of a mixed
    /// prefill-chunk/decode step batch — the batched ABI entry behind
    /// continuous batching. Rows are independent sequences (disjoint
    /// KV caches); outputs are returned in row order.
    ///
    /// The default body is [`sequential_batch`]: one per-row
    /// [`Backend::execute`] dispatch each, which is what the PJRT
    /// backend and the CPU reference oracle run. The fast CPU backend
    /// overrides it to fold all rows into shared weight passes;
    /// whatever the implementation, the output bits per row must equal
    /// the sequential semantics exactly.
    fn execute_batch(&self, layer: usize, rows: &[BatchRow<'_>])
                     -> Result<Vec<BatchRowOut>> {
        sequential_batch(self, layer, rows)
    }

    /// Snapshot of cumulative dispatch statistics.
    fn stats(&self) -> DispatchStats;
}

/// Which [`Backend`] implementation a [`crate::runtime::Runtime`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust deterministic interpreter over the synthetic
    /// reference model (synthetic manifest + seeded weights; artifact
    /// bundles are PJRT-only).
    Cpu,
    /// PJRT over AOT HLO artifacts (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI string ("cpu" / "pjrt").
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cpu" => Some(BackendKind::Cpu),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// Stable label, the inverse of [`BackendKind::parse`].
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// The default backend for this build: `pjrt` when the feature is
    /// compiled in, `cpu` otherwise.
    pub fn default_for_build() -> BackendKind {
        if cfg!(feature = "pjrt") {
            BackendKind::Pjrt
        } else {
            BackendKind::Cpu
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Cpu, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.label()), Some(k));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
    }
}
