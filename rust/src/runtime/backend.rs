//! The execution-backend abstraction behind [`crate::runtime::Runtime`].
//!
//! A [`Backend`] turns one manifest executable plus resolved inputs into
//! host f32 outputs. Two implementations exist:
//!
//! * [`crate::runtime::PjrtBackend`] — compiles the AOT HLO-text
//!   artifacts on the PJRT CPU client and dispatches device buffers
//!   (the production path; inert without the `pjrt` cargo feature).
//! * [`crate::runtime::CpuBackend`] — a dependency-free pure-Rust
//!   interpreter for the small op set the artifact ABI names (embed,
//!   rmsnorm + attention, gather-indexed sparse FFN, dense FFN,
//!   lm_head). Two flavours sharing one bit-exact numeric contract:
//!   the fast tiled/parallel default (worker pool sized by
//!   `--cpu-threads` / `FF_CPU_THREADS`) and the sequential scalar
//!   [`crate::runtime::CpuBackend::reference`] oracle it is
//!   conformance-tested against. Deterministic on any machine and at
//!   any thread count, which is what un-gates the end-to-end numeric
//!   test suites in CI.
//!
//! The [`crate::runtime::Runtime`] wrapper owns the manifest, performs
//! ABI-level input validation common to every backend (missing inputs,
//! shape mismatches), and delegates execution here.

use anyhow::Result;

use crate::manifest::ExecutableSpec;

use super::{DispatchStats, Input, Output};

/// One execution backend: prepares executables and runs dispatches.
///
/// Implementations are `!Send` by design (like the engine that drives
/// them): every executor-pool replica constructs its own backend on its
/// own thread — over *shared* `Arc<Manifest>` / `Arc<WeightStore>`
/// state, so N replicas share one weight store (per-backend derived
/// state — PJRT device buffers, the CPU fast path's transposed gate/up
/// panels — stays per replica).
pub trait Backend {
    /// Stable backend label ("cpu" / "pjrt"); feeds the runtime's
    /// numeric fingerprint so KV computed by one backend is never
    /// adopted by another.
    fn name(&self) -> &'static str;

    /// Prepare an executable for dispatch (compile it, or validate that
    /// the interpreter understands it). Idempotent and cached.
    fn prepare(&self, spec: &ExecutableSpec) -> Result<()>;

    /// Number of distinct executables prepared so far.
    fn prepared_count(&self) -> usize;

    /// Execute `spec` for transformer layer `layer` over ABI-validated
    /// inputs, returning the decomposed output tuple as host f32
    /// tensors.
    fn execute(&self, spec: &ExecutableSpec, layer: usize,
               inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>>;

    /// Snapshot of cumulative dispatch statistics.
    fn stats(&self) -> DispatchStats;
}

/// Which [`Backend`] implementation a [`crate::runtime::Runtime`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust deterministic interpreter over the synthetic
    /// reference model (synthetic manifest + seeded weights; artifact
    /// bundles are PJRT-only).
    Cpu,
    /// PJRT over AOT HLO artifacts (requires the `pjrt` cargo feature).
    Pjrt,
}

impl BackendKind {
    /// Parse a CLI string ("cpu" / "pjrt").
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "cpu" => Some(BackendKind::Cpu),
            "pjrt" => Some(BackendKind::Pjrt),
            _ => None,
        }
    }

    /// Stable label, the inverse of [`BackendKind::parse`].
    pub fn label(self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::Pjrt => "pjrt",
        }
    }

    /// The default backend for this build: `pjrt` when the feature is
    /// compiled in, `cpu` otherwise.
    pub fn default_for_build() -> BackendKind {
        if cfg!(feature = "pjrt") {
            BackendKind::Pjrt
        } else {
            BackendKind::Cpu
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in [BackendKind::Cpu, BackendKind::Pjrt] {
            assert_eq!(BackendKind::parse(k.label()), Some(k));
        }
        assert_eq!(BackendKind::parse("tpu"), None);
    }
}
