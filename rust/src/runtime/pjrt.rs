//! PJRT execution backend: loads AOT HLO-text artifacts, compiles them
//! on the CPU PJRT client (lazily, cached), keeps every model weight
//! resident as a device buffer, and dispatches executions with
//! manifest-driven argument resolution (the per-layer weight
//! substitution of the artifact ABI).
//!
//! Interchange gotcha (see /opt/xla-example/README.md): artifacts are HLO
//! *text*; `HloModuleProto::from_text_file` reassigns instruction ids,
//! which is what makes jax≥0.5 output loadable on xla_extension 0.5.1.
//!
//! Without the `pjrt` cargo feature the real XLA bindings are replaced
//! by an inert, API-identical stub (see [`crate::xla_stub`]): the whole
//! crate still typechecks and constructing this backend fails with a
//! clear error — use [`crate::runtime::CpuBackend`] instead.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

#[cfg(not(feature = "pjrt"))]
use crate::xla_stub as xla;

use crate::manifest::{ArgKind, ExecutableSpec, Manifest};
use crate::weights::WeightStore;

use super::backend::Backend;
use super::{DispatchStats, Input, Output};

/// Pre-resolved argument slot for one (executable, layer) pair: weight
/// slots hold the device buffer directly; input slots remember which
/// ABI arg they validate against.
enum PlanArg {
    Weight(Rc<xla::PjRtBuffer>),
    Input { name: String },
}

/// The PJRT dispatcher: compiled-executable cache, device-resident
/// weights, per-(executable, layer) dispatch plans and timing stats.
/// `!Send` by design — each executor replica owns one.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    weights: Arc<WeightStore>,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    wbufs: RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
    plans: RefCell<HashMap<(String, usize), Rc<Vec<PlanArg>>>>,
    stats: RefCell<DispatchStats>,
}

impl PjrtBackend {
    /// Create a CPU PJRT client over loaded artifacts (shared `Arc`s:
    /// replicas reuse one loaded manifest + weight blob). Fails when
    /// built without the `pjrt` feature (see [`crate::xla_stub`]).
    pub fn new(manifest: Arc<Manifest>, weights: Arc<WeightStore>)
               -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(PjrtBackend {
            client,
            manifest,
            weights,
            exes: RefCell::new(HashMap::new()),
            wbufs: RefCell::new(HashMap::new()),
            plans: RefCell::new(HashMap::new()),
            stats: RefCell::new(DispatchStats::default()),
        })
    }

    /// Compile (or fetch cached) an executable.
    fn executable(&self, spec: &ExecutableSpec)
                  -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(&spec.name) {
            return Ok(e.clone());
        }
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", spec.name))?;
        self.stats.borrow_mut().compile_time += t0.elapsed();
        let exe = Rc::new(exe);
        self.exes
            .borrow_mut()
            .insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Device-resident weight buffer (uploaded once, cached).
    fn weight_buffer(&self, name: &str) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.wbufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let data = self.weights.get(name)?;
        let dims = self.weights.shape(name)?.to_vec();
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(data, &dims, None)
            .map_err(|e| anyhow!("uploading weight {name}: {e}"))?;
        let buf = Rc::new(buf);
        self.wbufs
            .borrow_mut()
            .insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    /// Build (or fetch) the cached dispatch plan for (exe, layer).
    fn plan(&self, spec: &ExecutableSpec, layer: usize)
            -> Result<Rc<Vec<PlanArg>>> {
        let key = (spec.name.clone(), layer);
        if let Some(p) = self.plans.borrow().get(&key) {
            return Ok(p.clone());
        }
        let mut plan = Vec::with_capacity(spec.args.len());
        for arg in spec.args.iter() {
            match &arg.kind {
                ArgKind::Input(name) => plan.push(PlanArg::Input {
                    name: name.clone(),
                }),
                kind => {
                    let wname = self
                        .manifest
                        .resolve_weight_name(kind, layer)
                        .unwrap();
                    plan.push(PlanArg::Weight(self.weight_buffer(&wname)?));
                }
            }
        }
        let plan = Rc::new(plan);
        self.plans.borrow_mut().insert(key, plan.clone());
        Ok(plan)
    }

    fn upload(&self, input: &Input) -> Result<xla::PjRtBuffer> {
        let r = match input {
            Input::F32(data, dims) => {
                self.client.buffer_from_host_buffer::<f32>(data, dims, None)
            }
            Input::I32(data, dims) => {
                self.client.buffer_from_host_buffer::<i32>(data, dims, None)
            }
        };
        r.map_err(|e| anyhow!("uploading input: {e}"))
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(&self, spec: &ExecutableSpec) -> Result<()> {
        self.executable(spec).map(|_| ())
    }

    fn prepared_count(&self) -> usize {
        self.exes.borrow().len()
    }

    fn execute(&self, spec: &ExecutableSpec, layer: usize,
               inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>> {
        // Perf (EXPERIMENTS.md §Perf, L3 iters 1+2): the per-(executable,
        // layer) dispatch plan — weight-name resolution, weight-buffer
        // lookup — is computed once and cached; steady-state dispatch
        // only uploads the true inputs.
        let plan = self.plan(spec, layer)?;
        let exe = self.executable(spec)?;

        let t0 = Instant::now();
        let mut owned: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (slot, pa) in plan.iter().enumerate() {
            if let PlanArg::Input { name } = pa {
                let (_, input) = inputs
                    .iter()
                    .find(|(n, _)| n == name)
                    .ok_or_else(|| {
                        anyhow!("{}: missing input '{name}'", spec.name)
                    })?;
                owned.push((slot, self.upload(input)?));
            }
        }
        let mut owned_it = owned.iter().peekable();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(plan.len());
        for (slot, pa) in plan.iter().enumerate() {
            match pa {
                PlanArg::Weight(b) => args.push(b.as_ref()),
                PlanArg::Input { .. } => {
                    let (s, b) = owned_it.next().unwrap();
                    debug_assert_eq!(*s, slot);
                    args.push(b);
                }
            }
        }
        let upload_t = t0.elapsed();

        let t1 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {}: {e}", spec.name))?;
        let execute_t = t1.elapsed();

        let t2 = Instant::now();
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading {} output: {e}", spec.name))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("untupling {}: {e}", spec.name))?;
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(Output {
                data: p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e}"))?,
            });
        }
        let download_t = t2.elapsed();

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.upload_time += upload_t;
        s.execute_time += execute_t;
        s.download_time += download_t;
        Ok(outputs)
    }

    fn stats(&self) -> DispatchStats {
        self.stats.borrow().clone()
    }
}
