//! Runtime dispatch: executable lookup, ABI input validation, and
//! execution through a pluggable [`Backend`].
//!
//! The [`Runtime`] owns the manifest (the ABI contract) and delegates
//! actual execution to one of two backends:
//!
//! * [`PjrtBackend`] — compiles the AOT HLO-text artifacts on the PJRT
//!   CPU client (the production path; an inert stub without the `pjrt`
//!   cargo feature, see [`crate::xla_stub`]).
//! * [`CpuBackend`] — a pure-Rust deterministic interpreter over the
//!   [`crate::weights::WeightStore`], which needs no artifacts at all
//!   when paired with [`crate::manifest::Manifest::synthetic`] — this
//!   is what makes the end-to-end numeric test tier run everywhere
//!   (docs/TESTING.md).
//!
//! Every dispatch validates inputs against the manifest's argument
//! specs (missing inputs, shape mismatches) *before* reaching the
//! backend, so both backends fail identically on ABI misuse.

mod backend;
mod cpu;
mod pjrt;

pub use backend::{Backend, BackendKind, BatchRow, BatchRowOut};
pub use cpu::{CpuBackend, CpuKernel, CpuOptions, KERNEL_ENV};
pub use pjrt::PjrtBackend;

use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::manifest::Manifest;
use crate::weights::WeightStore;

/// A runtime input value (host-side view, uploaded per call).
pub enum Input<'a> {
    /// f32 tensor data with its shape.
    F32(&'a [f32], Vec<usize>),
    /// i32 tensor data with its shape.
    I32(&'a [i32], Vec<usize>),
}

impl<'a> Input<'a> {
    fn dims(&self) -> &[usize] {
        match self {
            Input::F32(_, d) | Input::I32(_, d) => d,
        }
    }
}

/// One decomposed output tensor.
#[derive(Debug, Clone)]
pub struct Output {
    /// Host f32 data in row-major layout.
    pub data: Vec<f32>,
}

/// One sequence's slot in a batched layer dispatch, as the engine
/// submits it to [`Runtime::run_layer_batch`]: the per-row executable
/// by ABI name plus this row's activations, KV views and absolute
/// position. The runtime resolves the name, validates shapes exactly
/// as [`Runtime::run`] would, and hands the resolved
/// [`BatchRow`] set to the backend in one call.
pub struct StepRow<'a> {
    /// Layer-executable ABI name (e.g. `layer_dense_t1_s256`).
    pub exe: &'a str,
    /// Input activations, `[t, d_model]` row-major.
    pub x: &'a [f32],
    /// Token rows in this slot (1 for decode, block size for a chunk).
    pub t: usize,
    /// Absolute position of the slot's first token in its sequence.
    pub pos: usize,
    /// This sequence's key cache, `[s, n_kv, d_head]`.
    pub k_cache: &'a [f32],
    /// This sequence's value cache, same layout.
    pub v_cache: &'a [f32],
    /// This sequence's KV bucket capacity.
    pub s: usize,
}

/// Cumulative dispatch statistics (perf accounting; EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct DispatchStats {
    /// Total executable invocations.
    pub executions: u64,
    /// Time spent compiling executables (first use only, cached after;
    /// zero for the interpreter backend).
    pub compile_time: Duration,
    /// Time uploading input buffers (zero for the interpreter backend).
    pub upload_time: Duration,
    /// Time inside executions.
    pub execute_time: Duration,
    /// Time downloading output tuples (zero for the interpreter).
    pub download_time: Duration,
}

/// Manifest-driven dispatcher bound to one [`Backend`]. `!Send` by
/// design — each executor replica owns one. The manifest and weight
/// store themselves are plain data behind `Arc`s, so replicas *share*
/// one loaded/seeded copy instead of cloning it per thread.
pub struct Runtime {
    /// The artifact manifest driving argument resolution.
    pub manifest: Arc<Manifest>,
    backend: Box<dyn Backend>,
    /// Combined numeric identity (manifest ⊕ weight values ⊕ backend),
    /// computed once at construction.
    numeric_fp: u64,
}

impl Runtime {
    /// PJRT runtime over loaded artifacts (the historical constructor).
    /// Fails when built without the `pjrt` feature.
    pub fn new(manifest: Arc<Manifest>, weights: Arc<WeightStore>)
               -> Result<Self> {
        Self::with_backend(BackendKind::Pjrt, manifest, weights)
    }

    /// Pure-Rust deterministic runtime (fast tiled/parallel kernels) —
    /// works in every build; pair it with
    /// [`crate::manifest::Manifest::synthetic`] +
    /// [`WeightStore::seeded`] (artifact bundles are PJRT-only).
    pub fn cpu(manifest: Arc<Manifest>, weights: Arc<WeightStore>)
               -> Result<Self> {
        Self::with_backend(BackendKind::Cpu, manifest, weights)
    }

    /// The sequential scalar CPU reference interpreter — the oracle of
    /// the backend-conformance suite. Bit-identical to [`Runtime::cpu`]
    /// by contract (`tests/backend_conformance.rs`), including its
    /// numeric fingerprint, just slow.
    pub fn cpu_reference(manifest: Arc<Manifest>,
                         weights: Arc<WeightStore>) -> Result<Self> {
        Self::cpu_with_options(
            manifest,
            weights,
            CpuOptions { threads: 1, reference: true, kernel: None },
        )
    }

    /// CPU runtime with explicit [`CpuOptions`] (thread count /
    /// reference mode / kernel tier). The kernel tier is resolved
    /// *here* — explicit option, else [`KERNEL_ENV`] — so it can fold
    /// into the numeric fingerprint before the backend is built.
    pub fn cpu_with_options(manifest: Arc<Manifest>,
                            weights: Arc<WeightStore>, opts: CpuOptions)
                            -> Result<Self> {
        let mut fp = Self::fingerprint_for(BackendKind::Cpu, &manifest,
                                           &weights);
        // The SIMD tier is deterministic but *not* bit-identical to
        // the scalar/reference tier (re-associated accumulation), so
        // its KV must never be adopted across tiers: mix the tier into
        // the fingerprint. Scalar keeps the historical fingerprint —
        // scalar, reference and pre-SIMD caches stay interchangeable.
        if opts.resolved_kernel() == CpuKernel::Simd {
            use crate::util::hash;
            fp = hash::mix(fp, hash::fnv1a(b"cpu-kernel:simd"));
        }
        let backend: Box<dyn Backend> = Box::new(
            CpuBackend::with_options(manifest.clone(), weights, opts)?,
        );
        Ok(Runtime {
            manifest,
            backend,
            numeric_fp: fp,
        })
    }

    /// Construct a runtime with an explicit backend choice.
    pub fn with_backend(kind: BackendKind, manifest: Arc<Manifest>,
                        weights: Arc<WeightStore>) -> Result<Self> {
        // CPU resolves its kernel tier from the environment inside
        // cpu_with_options so the tier also lands in the fingerprint.
        if matches!(kind, BackendKind::Cpu) {
            return Self::cpu_with_options(manifest, weights,
                                          CpuOptions::default());
        }
        let fp = Self::fingerprint_for(kind, &manifest, &weights);
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Cpu => unreachable!("handled above"),
            BackendKind::Pjrt => {
                Box::new(PjrtBackend::new(manifest.clone(), weights)?)
            }
        };
        Ok(Runtime {
            manifest,
            backend,
            numeric_fp: fp,
        })
    }

    /// The combined numeric identity of (backend kind, model, weight
    /// values). Deliberately *not* a function of thread count or
    /// fast-vs-reference mode: those are bit-identical by the
    /// determinism contract, so their KV is interchangeable. The CPU
    /// *kernel tier* is the exception — it changes accumulation order,
    /// so [`Runtime::cpu_with_options`] mixes the resolved tier on top
    /// of this base (reduced-precision weight stores — bf16, int8 —
    /// differ automatically through [`WeightStore::fingerprint`] over
    /// the stored representation plus a precision label).
    fn fingerprint_for(kind: BackendKind, manifest: &Manifest,
                       weights: &WeightStore) -> u64 {
        use crate::util::hash;
        hash::mix(
            hash::mix(manifest.fingerprint(), weights.fingerprint()),
            hash::fnv1a(kind.label().as_bytes()),
        )
    }

    /// The active backend's stable label ("cpu" / "pjrt").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// 64-bit fingerprint of everything that determines this runtime's
    /// numerics besides the sparsity configuration: the manifest's
    /// model identity ([`Manifest::fingerprint`]), the actual weight
    /// values ([`WeightStore::fingerprint`] — different seeds or
    /// retrained artifacts never collide), and the backend. Mixed into
    /// the prefix cache's hash-chain seed (see
    /// [`crate::engine::Engine::prefix_seed`]) so KV computed by one
    /// backend, model, or weight set is never adopted by another.
    pub fn numeric_fingerprint(&self) -> u64 {
        self.numeric_fp
    }

    /// Snapshot of the cumulative dispatch statistics.
    pub fn stats(&self) -> DispatchStats {
        self.backend.stats()
    }

    /// Pre-prepare a set of executables (startup warmup: compilation on
    /// PJRT, name validation on the interpreter).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            let spec = self
                .manifest
                .executables
                .get(*n)
                .ok_or_else(|| anyhow!("unknown executable {n}"))?;
            self.backend.prepare(spec)?;
        }
        Ok(())
    }

    /// Number of distinct executables prepared/compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.backend.prepared_count()
    }

    /// Execute `exe_name` for transformer layer `layer` (ignored by
    /// layer-independent entry points). `inputs` are matched by ABI name
    /// and shape-checked against the manifest spec; weight arguments
    /// resolve through the manifest + weight store inside the backend.
    /// Returns the decomposed output tuple as host f32 tensors.
    ///
    /// Outputs are screened for non-finite values: a NaN/inf activation
    /// (corrupt weights, numeric overflow) comes back as a request
    /// `Err` naming the executable and offending element — never as a
    /// poisoned tensor that would later panic a score ordering or a
    /// sampler deep inside the engine.
    pub fn run(&self, exe_name: &str, layer: usize,
               inputs: &[(&str, Input)]) -> Result<Vec<Output>> {
        let manifest = self.manifest.clone();
        let spec = manifest
            .executables
            .get(exe_name)
            .ok_or_else(|| anyhow!("unknown executable {exe_name}"))?;
        Self::validate_inputs(spec, inputs)?;
        let outputs = self.backend.execute(spec, layer, inputs)?;
        for (i, out) in outputs.iter().enumerate() {
            Self::ensure_finite(exe_name, &format!("output {i}"),
                                &out.data)?;
        }
        Ok(outputs)
    }

    /// Reject non-finite backend outputs as a request error. A NaN or
    /// inf that slipped through here would surface much later as a
    /// nonsense sample or a panicking comparison; failing the dispatch
    /// keeps the blast radius to the one request that produced it.
    fn ensure_finite(exe: &str, what: &str, data: &[f32]) -> Result<()> {
        if let Some((i, v)) =
            data.iter().enumerate().find(|(_, v)| !v.is_finite())
        {
            return Err(anyhow!(
                "{exe}: non-finite activation in {what} at element {i} \
                 ({v}) — rejecting the request instead of propagating it"
            ));
        }
        Ok(())
    }

    /// ABI validation common to every backend: each declared input
    /// must be present with the declared shape and dtype.
    fn validate_inputs(spec: &crate::manifest::ExecutableSpec,
                       inputs: &[(&str, Input)]) -> Result<()> {
        let exe_name = &spec.name;
        for arg in &spec.args {
            if let crate::manifest::ArgKind::Input(name) = &arg.kind {
                let (_, input) = inputs
                    .iter()
                    .find(|(n, _)| n == name)
                    .ok_or_else(|| {
                        anyhow!("{exe_name}: missing input '{name}'")
                    })?;
                anyhow::ensure!(
                    input.dims() == arg.shape.as_slice(),
                    "{exe_name}: input '{name}' shape {:?} != ABI {:?}",
                    input.dims(),
                    arg.shape
                );
                let got_i32 = matches!(input, Input::I32(..));
                anyhow::ensure!(
                    got_i32 == arg.is_i32,
                    "{exe_name}: input '{name}' dtype {} != ABI {}",
                    if got_i32 { "i32" } else { "f32" },
                    if arg.is_i32 { "i32" } else { "f32" }
                );
            }
        }
        Ok(())
    }

    /// Execute one transformer layer for every row of a mixed
    /// prefill-chunk/decode step batch — the batched (`decode_batch`)
    /// ABI entry behind continuous batching.
    ///
    /// Every row is validated exactly as [`Runtime::run`] validates a
    /// single dispatch (unknown executable, missing input, shape or
    /// dtype mismatch — both backends fail identically on ABI misuse),
    /// then the whole row set is handed to the backend in **one**
    /// [`Backend::execute_batch`] call so it can fold the rows into
    /// shared weight passes. Outputs come back in row order and are
    /// bit-identical to dispatching each row through [`Runtime::run`]
    /// one at a time. Like [`Runtime::run`], non-finite activations in
    /// any row's outputs fail the dispatch with a request error naming
    /// the row's executable.
    pub fn run_layer_batch(&self, layer: usize, rows: &[StepRow])
                           -> Result<Vec<BatchRowOut>> {
        let m = &self.manifest.model;
        let mut resolved: Vec<BatchRow> = Vec::with_capacity(rows.len());
        let pos_scratch: Vec<[i32; 1]> =
            rows.iter().map(|r| [r.pos as i32]).collect();
        for (row, pos_i) in rows.iter().zip(&pos_scratch) {
            let spec = self
                .manifest
                .executables
                .get(row.exe)
                .ok_or_else(|| anyhow!("unknown executable {}", row.exe))?;
            let inputs = [
                ("x", Input::F32(row.x, vec![row.t, m.d_model])),
                (
                    "k_cache",
                    Input::F32(row.k_cache,
                               vec![row.s, m.n_kv_heads, m.d_head]),
                ),
                (
                    "v_cache",
                    Input::F32(row.v_cache,
                               vec![row.s, m.n_kv_heads, m.d_head]),
                ),
                ("pos", Input::I32(pos_i, vec![])),
            ];
            Self::validate_inputs(spec, &inputs)?;
            resolved.push(BatchRow {
                spec,
                x: row.x,
                t: row.t,
                s: row.s,
                pos: row.pos,
                k_cache: row.k_cache,
                v_cache: row.v_cache,
            });
        }
        let outs = self.backend.execute_batch(layer, &resolved)?;
        for (row, out) in rows.iter().zip(&outs) {
            Self::ensure_finite(row.exe, "y", &out.y)?;
            Self::ensure_finite(row.exe, "k_new", &out.k_new)?;
            Self::ensure_finite(row.exe, "v_new", &out.v_new)?;
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::{Manifest, SyntheticSpec};
    use crate::weights::WeightStore;

    /// Always-available runtime: the deterministic CPU backend over a
    /// synthetic manifest + seeded weights.
    fn cpu_runtime() -> Runtime {
        let spec = SyntheticSpec::default();
        let m = Arc::new(Manifest::synthetic(&spec));
        let w = Arc::new(WeightStore::seeded(&m, spec.seed));
        Runtime::cpu(m, w).unwrap()
    }

    /// PJRT runtime over real artifacts (None → caller skips).
    fn pjrt_runtime() -> Option<Runtime> {
        let dir = crate::test_artifacts_dir()?;
        let m = Arc::new(Manifest::load(&dir).unwrap());
        let w = Arc::new(WeightStore::load(&m).unwrap());
        Some(Runtime::new(m, w).unwrap())
    }

    fn embed_roundtrip(rt: &Runtime) {
        let block = rt.manifest.model.block;
        let d = rt.manifest.model.d_model;
        let tokens: Vec<i32> = (0..block as i32).map(|i| i % 250).collect();
        let out = rt
            .run(
                &format!("embed_t{block}"),
                0,
                &[("tokens", Input::I32(&tokens, vec![block]))],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data.len(), block * d);
        assert!(out[0].data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn embed_executes_cpu() {
        embed_roundtrip(&cpu_runtime());
    }

    #[test]
    fn embed_executes_pjrt() {
        let Some(rt) = pjrt_runtime() else { return };
        embed_roundtrip(&rt);
    }

    #[test]
    fn layer_dense_roundtrip_shapes() {
        let rt = cpu_runtime();
        let m = &rt.manifest.model;
        let s = m.buckets[0];
        let (block, d, nkv, dh) =
            (m.block, m.d_model, m.n_kv_heads, m.d_head);
        let x = vec![0.05f32; block * d];
        let kc = vec![0f32; s * nkv * dh];
        let pos = [0i32];
        let out = rt
            .run(
                &format!("layer_dense_t{block}_s{s}"),
                0,
                &[
                    ("x", Input::F32(&x, vec![block, d])),
                    ("k_cache", Input::F32(&kc, vec![s, nkv, dh])),
                    ("v_cache", Input::F32(&kc, vec![s, nkv, dh])),
                    ("pos", Input::I32(&pos, vec![])),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].data.len(), block * d);
        assert_eq!(out[1].data.len(), block * nkv * dh);
        assert_eq!(out[2].data.len(), block * nkv * dh);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn missing_input_is_reported() {
        let rt = cpu_runtime();
        let block = rt.manifest.model.block;
        let err = rt
            .run(&format!("embed_t{block}"), 0, &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "{err}");
    }

    #[test]
    fn shape_mismatch_is_reported() {
        let rt = cpu_runtime();
        let block = rt.manifest.model.block;
        let tokens = vec![0i32; 3];
        let err = rt
            .run(
                &format!("embed_t{block}"),
                0,
                &[("tokens", Input::I32(&tokens, vec![3]))],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn executables_are_cached() {
        let rt = cpu_runtime();
        let block = rt.manifest.model.block;
        let name = format!("embed_t{block}");
        rt.warm(&[&name]).unwrap();
        let n = rt.compiled_count();
        rt.warm(&[&name]).unwrap();
        assert_eq!(rt.compiled_count(), n);
        assert!(rt.warm(&["no_such_exe_t1"]).is_err());
    }

    /// CPU runtime pinned to an explicit kernel tier (env-independent,
    /// so the fingerprint assertions below hold under any
    /// `FF_CPU_KERNEL`).
    fn cpu_runtime_kernel(kernel: CpuKernel) -> Runtime {
        let spec = SyntheticSpec::default();
        let m = Arc::new(Manifest::synthetic(&spec));
        let w = Arc::new(WeightStore::seeded(&m, spec.seed));
        Runtime::cpu_with_options(
            m,
            w,
            CpuOptions { threads: 0, reference: false,
                         kernel: Some(kernel) },
        )
        .unwrap()
    }

    #[test]
    fn backend_fingerprints_differ_per_backend_and_model() {
        let a = cpu_runtime_kernel(CpuKernel::Scalar);
        assert_eq!(a.backend_name(), "cpu");
        let b = cpu_runtime_kernel(CpuKernel::Scalar);
        assert_eq!(
            a.numeric_fingerprint(),
            b.numeric_fingerprint(),
            "same model + backend → same fingerprint"
        );
        let spec = SyntheticSpec {
            name: "ff-other".to_string(),
            ..SyntheticSpec::default()
        };
        let m = Arc::new(Manifest::synthetic(&spec));
        let w = Arc::new(WeightStore::seeded(&m, spec.seed));
        let c = Runtime::cpu(m, w).unwrap();
        assert_ne!(
            a.numeric_fingerprint(),
            c.numeric_fingerprint(),
            "different model → different fingerprint"
        );
        // same model, different weight *values*: must also differ, or
        // the prefix cache could adopt KV computed under other weights
        let spec = SyntheticSpec::default();
        let m = Arc::new(Manifest::synthetic(&spec));
        let w = Arc::new(WeightStore::seeded(&m, spec.seed ^ 0xDEAD));
        let d = Runtime::cpu(m, w).unwrap();
        assert_ne!(
            a.numeric_fingerprint(),
            d.numeric_fingerprint(),
            "different weights → different fingerprint"
        );
        // fast and reference CPU runtimes are numerically the same
        // runtime (bit-identical outputs) and must share a fingerprint
        let spec = SyntheticSpec::default();
        let m = Arc::new(Manifest::synthetic(&spec));
        let w = Arc::new(WeightStore::seeded(&m, spec.seed));
        let r = Runtime::cpu_reference(m, w).unwrap();
        assert_eq!(
            a.numeric_fingerprint(),
            r.numeric_fingerprint(),
            "reference oracle must share the fast backend's fingerprint"
        );
        // the SIMD kernel tier is NOT bit-identical to scalar, so its
        // KV must never be adopted across tiers: distinct fingerprint,
        // stable across constructions
        let s1 = cpu_runtime_kernel(CpuKernel::Simd);
        let s2 = cpu_runtime_kernel(CpuKernel::Simd);
        assert_ne!(
            a.numeric_fingerprint(),
            s1.numeric_fingerprint(),
            "simd tier must not share the scalar fingerprint"
        );
        assert_eq!(
            s1.numeric_fingerprint(),
            s2.numeric_fingerprint(),
            "simd fingerprint is deterministic"
        );
    }

    /// A NaN smuggled into the weight store must surface as a request
    /// error naming the executable — not poison downstream score
    /// orderings (where a NaN comparison used to panic the replica).
    #[test]
    fn non_finite_activations_are_a_request_error() {
        let spec = SyntheticSpec::default();
        let m = Arc::new(Manifest::synthetic(&spec));
        let seeded = WeightStore::seeded(&m, spec.seed);
        // Rebuild the seeded store's flat f32 buffer entry by entry,
        // then poison one embedding value and reload via `from_data`.
        let total = m
            .weights
            .values()
            .map(|e| e.offset / 4 + e.numel())
            .max()
            .unwrap();
        let mut data = vec![0f32; total];
        for (name, e) in &m.weights {
            let start = e.offset / 4;
            data[start..start + e.numel()]
                .copy_from_slice(&seeded.dequant(name).unwrap());
        }
        let embed = &m.weights["embed"];
        data[embed.offset / 4 + 1] = f32::NAN;
        let w = Arc::new(
            WeightStore::from_data(data, m.weights.clone()).unwrap(),
        );
        let rt = Runtime::cpu(m, w).unwrap();
        let block = rt.manifest.model.block;
        // Flat element 1 of `embed` ([vocab, d_model]) is token row 0,
        // column 1 — embedding token 0 streams the NaN straight out.
        let tokens = vec![0i32; block];
        let err = rt
            .run(
                &format!("embed_t{block}"),
                0,
                &[("tokens", Input::I32(&tokens, vec![block]))],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("non-finite"), "{err}");
    }

    #[test]
    fn stats_count_executions() {
        let rt = cpu_runtime();
        let block = rt.manifest.model.block;
        let tokens: Vec<i32> = vec![7; block];
        assert_eq!(rt.stats().executions, 0);
        rt.run(
            &format!("embed_t{block}"),
            0,
            &[("tokens", Input::I32(&tokens, vec![block]))],
        )
        .unwrap();
        assert_eq!(rt.stats().executions, 1);
    }
}
