//! PJRT runtime: loads AOT HLO-text artifacts, compiles them on the CPU
//! PJRT client (lazily, cached), keeps every model weight resident as a
//! device buffer, and dispatches executions with manifest-driven argument
//! resolution (the per-layer weight substitution of the artifact ABI).
//!
//! Interchange gotcha (see /opt/xla-example/README.md): artifacts are HLO
//! *text*; `HloModuleProto::from_text_file` reassigns instruction ids,
//! which is what makes jax≥0.5 output loadable on xla_extension 0.5.1.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

// Without the `pjrt` feature the real XLA bindings are replaced by an
// inert, API-identical stub (see `crate::xla_stub`): the whole crate
// still typechecks and pure host-side logic stays testable.
#[cfg(not(feature = "pjrt"))]
use crate::xla_stub as xla;

use crate::manifest::{ArgKind, Manifest};
use crate::weights::WeightStore;

/// A runtime input value (host-side view, uploaded per call).
pub enum Input<'a> {
    /// f32 tensor data with its shape.
    F32(&'a [f32], Vec<usize>),
    /// i32 tensor data with its shape.
    I32(&'a [i32], Vec<usize>),
}

impl<'a> Input<'a> {
    fn dims(&self) -> &[usize] {
        match self {
            Input::F32(_, d) | Input::I32(_, d) => d,
        }
    }
}

/// One decomposed output tensor.
#[derive(Debug, Clone)]
pub struct Output {
    /// Host f32 data in row-major layout.
    pub data: Vec<f32>,
}

/// Cumulative dispatch statistics (perf accounting; EXPERIMENTS.md §Perf).
#[derive(Debug, Default, Clone)]
pub struct DispatchStats {
    /// Total executable invocations.
    pub executions: u64,
    /// Time spent compiling executables (first use only, cached after).
    pub compile_time: Duration,
    /// Time uploading input buffers.
    pub upload_time: Duration,
    /// Time inside executions.
    pub execute_time: Duration,
    /// Time downloading output tuples.
    pub download_time: Duration,
}

/// Pre-resolved argument slot for one (executable, layer) pair: weight
/// slots hold the device buffer directly; input slots remember which
/// ABI arg they validate against.
enum PlanArg {
    Weight(Rc<xla::PjRtBuffer>),
    Input { name: String, arg_idx: usize },
}

/// The PJRT dispatcher: compiled-executable cache, device-resident
/// weights, per-(executable, layer) dispatch plans and timing stats.
/// `!Send` by design — each executor replica owns one.
pub struct Runtime {
    client: xla::PjRtClient,
    /// The artifact manifest driving argument resolution.
    pub manifest: Rc<Manifest>,
    weights: Rc<WeightStore>,
    exes: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    wbufs: RefCell<HashMap<String, Rc<xla::PjRtBuffer>>>,
    plans: RefCell<HashMap<(String, usize), Rc<Vec<PlanArg>>>>,
    stats: RefCell<DispatchStats>,
}

impl Runtime {
    /// Create a CPU PJRT client over loaded artifacts. Fails when built
    /// without the `pjrt` feature (see [`crate::xla_stub`]).
    pub fn new(manifest: Rc<Manifest>, weights: Rc<WeightStore>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e}"))?;
        Ok(Runtime {
            client,
            manifest,
            weights,
            exes: RefCell::new(HashMap::new()),
            wbufs: RefCell::new(HashMap::new()),
            plans: RefCell::new(HashMap::new()),
            stats: RefCell::new(DispatchStats::default()),
        })
    }

    /// Snapshot of the cumulative dispatch statistics.
    pub fn stats(&self) -> DispatchStats {
        self.stats.borrow().clone()
    }

    /// Compile (or fetch cached) an executable by manifest name.
    pub fn executable(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("unknown executable {name}"))?;
        let path = self.manifest.dir.join(&spec.file);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.stats.borrow_mut().compile_time += t0.elapsed();
        let exe = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Pre-compile a set of executables (startup warmup).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.exes.borrow().len()
    }

    /// Device-resident weight buffer (uploaded once, cached).
    fn weight_buffer(&self, name: &str) -> Result<Rc<xla::PjRtBuffer>> {
        if let Some(b) = self.wbufs.borrow().get(name) {
            return Ok(b.clone());
        }
        let data = self.weights.get(name)?;
        let dims = self.weights.shape(name)?.to_vec();
        let buf = self
            .client
            .buffer_from_host_buffer::<f32>(data, &dims, None)
            .map_err(|e| anyhow!("uploading weight {name}: {e}"))?;
        let buf = Rc::new(buf);
        self.wbufs
            .borrow_mut()
            .insert(name.to_string(), buf.clone());
        Ok(buf)
    }

    /// Build (or fetch) the cached dispatch plan for (exe, layer).
    fn plan(&self, exe_name: &str, layer: usize)
            -> Result<Rc<Vec<PlanArg>>> {
        let key = (exe_name.to_string(), layer);
        if let Some(p) = self.plans.borrow().get(&key) {
            return Ok(p.clone());
        }
        let spec = self
            .manifest
            .executables
            .get(exe_name)
            .ok_or_else(|| anyhow!("unknown executable {exe_name}"))?;
        let mut plan = Vec::with_capacity(spec.args.len());
        for (arg_idx, arg) in spec.args.iter().enumerate() {
            match &arg.kind {
                ArgKind::Input(name) => plan.push(PlanArg::Input {
                    name: name.clone(),
                    arg_idx,
                }),
                kind => {
                    let wname = self
                        .manifest
                        .resolve_weight_name(kind, layer)
                        .unwrap();
                    plan.push(PlanArg::Weight(self.weight_buffer(&wname)?));
                }
            }
        }
        let plan = Rc::new(plan);
        self.plans.borrow_mut().insert(key, plan.clone());
        Ok(plan)
    }

    fn upload(&self, input: &Input) -> Result<xla::PjRtBuffer> {
        let r = match input {
            Input::F32(data, dims) => {
                self.client.buffer_from_host_buffer::<f32>(data, dims, None)
            }
            Input::I32(data, dims) => {
                self.client.buffer_from_host_buffer::<i32>(data, dims, None)
            }
        };
        r.map_err(|e| anyhow!("uploading input: {e}"))
    }

    /// Execute `exe_name` for transformer layer `layer` (ignored by
    /// layer-independent entry points). `inputs` are matched by ABI name;
    /// weight arguments resolve through the manifest + weight store.
    /// Returns the decomposed output tuple as host f32 tensors.
    pub fn run(&self, exe_name: &str, layer: usize,
               inputs: &[(&str, Input)]) -> Result<Vec<Output>> {
        // Perf (EXPERIMENTS.md §Perf, L3 iters 1+2): the per-(executable,
        // layer) dispatch plan — weight-name resolution, weight-buffer
        // lookup, spec clone — is computed once and cached; steady-state
        // dispatch only uploads the true inputs.
        let manifest = self.manifest.clone();
        let plan = self.plan(exe_name, layer)?;
        let spec = manifest
            .executables
            .get(exe_name)
            .ok_or_else(|| anyhow!("unknown executable {exe_name}"))?;
        let exe = self.executable(exe_name)?;

        let t0 = Instant::now();
        let mut owned: Vec<(usize, xla::PjRtBuffer)> = Vec::new();
        for (slot, pa) in plan.iter().enumerate() {
            if let PlanArg::Input { name, arg_idx } = pa {
                let (_, input) = inputs
                    .iter()
                    .find(|(n, _)| n == name)
                    .ok_or_else(|| {
                        anyhow!("{exe_name}: missing input '{name}'")
                    })?;
                let arg = &spec.args[*arg_idx];
                anyhow::ensure!(
                    input.dims() == arg.shape.as_slice(),
                    "{exe_name}: input '{name}' shape {:?} != ABI {:?}",
                    input.dims(),
                    arg.shape
                );
                owned.push((slot, self.upload(input)?));
            }
        }
        let mut owned_it = owned.iter().peekable();
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(plan.len());
        for (slot, pa) in plan.iter().enumerate() {
            match pa {
                PlanArg::Weight(b) => args.push(b.as_ref()),
                PlanArg::Input { .. } => {
                    let (s, b) = owned_it.next().unwrap();
                    debug_assert_eq!(*s, slot);
                    args.push(b);
                }
            }
        }
        let upload_t = t0.elapsed();

        let t1 = Instant::now();
        let result = exe
            .execute_b(&args)
            .map_err(|e| anyhow!("executing {exe_name}: {e}"))?;
        let execute_t = t1.elapsed();

        let t2 = Instant::now();
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("downloading {exe_name} output: {e}"))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("untupling {exe_name}: {e}"))?;
        let mut outputs = Vec::with_capacity(parts.len());
        for p in parts {
            outputs.push(Output {
                data: p
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("output to_vec: {e}"))?,
            });
        }
        let download_t = t2.elapsed();

        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.upload_time += upload_t;
        s.execute_time += execute_t;
        s.download_time += download_t;
        Ok(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::manifest::Manifest;
    use crate::weights::WeightStore;

    fn runtime() -> Option<Runtime> {
        let dir = crate::test_artifacts_dir()?;
        let m = Rc::new(Manifest::load(&dir).unwrap());
        let w = Rc::new(WeightStore::load(&m).unwrap());
        Some(Runtime::new(m, w).unwrap())
    }

    #[test]
    fn embed_executes() {
        let Some(rt) = runtime() else { return };
        let block = rt.manifest.model.block;
        let d = rt.manifest.model.d_model;
        let tokens: Vec<i32> = (0..block as i32).map(|i| i % 250).collect();
        let out = rt
            .run(
                &format!("embed_t{block}"),
                0,
                &[("tokens", Input::I32(&tokens, vec![block]))],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].data.len(), block * d);
        assert!(out[0].data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn layer_dense_roundtrip_shapes() {
        let Some(rt) = runtime() else { return };
        let m = &rt.manifest.model;
        let s = m.buckets[0];
        let (block, d, nkv, dh) = (m.block, m.d_model, m.n_kv_heads, m.d_head);
        let x = vec![0.05f32; block * d];
        let kc = vec![0f32; s * nkv * dh];
        let pos = [0i32];
        let out = rt
            .run(
                &format!("layer_dense_t{block}_s{s}"),
                0,
                &[
                    ("x", Input::F32(&x, vec![block, d])),
                    ("k_cache", Input::F32(&kc, vec![s, nkv, dh])),
                    ("v_cache", Input::F32(&kc, vec![s, nkv, dh])),
                    ("pos", Input::I32(&pos, vec![])),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0].data.len(), block * d);
        assert_eq!(out[1].data.len(), block * nkv * dh);
        assert_eq!(out[2].data.len(), block * nkv * dh);
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn missing_input_is_reported() {
        let Some(rt) = runtime() else { return };
        let block = rt.manifest.model.block;
        let err = rt
            .run(&format!("embed_t{block}"), 0, &[])
            .unwrap_err()
            .to_string();
        assert!(err.contains("missing input"), "{err}");
    }

    #[test]
    fn shape_mismatch_is_reported() {
        let Some(rt) = runtime() else { return };
        let block = rt.manifest.model.block;
        let tokens = vec![0i32; 3];
        let err = rt
            .run(
                &format!("embed_t{block}"),
                0,
                &[("tokens", Input::I32(&tokens, vec![3]))],
            )
            .unwrap_err()
            .to_string();
        assert!(err.contains("shape"), "{err}");
    }

    #[test]
    fn executables_are_cached() {
        let Some(rt) = runtime() else { return };
        let block = rt.manifest.model.block;
        let name = format!("embed_t{block}");
        rt.executable(&name).unwrap();
        let n = rt.compiled_count();
        rt.executable(&name).unwrap();
        assert_eq!(rt.compiled_count(), n);
    }
}
