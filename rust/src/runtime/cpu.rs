//! Pure-Rust CPU backend: a dependency-free, deterministic interpreter
//! for the small op set the artifact ABI names — now in two flavours
//! sharing one numeric contract:
//!
//! * **Fast** ([`CpuBackend::new`] / [`CpuBackend::with_options`]) —
//!   cache-blocked/tiled matmuls, a gathered per-row sparse FFN path
//!   over pre-transposed gate/up weights, and a worker-thread pool
//!   ([`crate::util::threadpool::ThreadPool`], sized by
//!   `--cpu-threads` / `FF_CPU_THREADS`) that parallelizes work across
//!   token rows and neuron/output tiles.
//! * **Reference** ([`CpuBackend::reference`]) — the original
//!   sequential scalar interpreter, kept verbatim as the oracle the
//!   fast path is tested against (`tests/backend_conformance.rs`).
//!
//! **Determinism across tiles and threads.** Every fast kernel
//! partitions *output elements* across tasks and accumulates each
//! element's reduction in exactly the order the naive loops use
//! (ascending reduction index). Parallelism and tiling only change
//! *which lane* computes an element, never the sequence of f32
//! additions behind it — so the fast backend is **bit-identical** to
//! the sequential reference for every op, at every thread count. Two
//! runs of the same trace produce byte-identical logits, which is the
//! foundation of the always-on numeric test tier (docs/TESTING.md).
//!
//! **Kernel tiers.** The fast path has two inner-kernel tiers,
//! selected by `--cpu-kernel scalar|simd` / [`KERNEL_ENV`]:
//!
//! * [`CpuKernel::Scalar`] (the default) keeps the sequential
//!   per-element accumulation order above — bit-identical to the
//!   reference oracle, gated by the **bitwise** conformance tier.
//! * [`CpuKernel::Simd`] reduces dot products in fixed-width lane
//!   chunks ([`kernels::lane_dot`]: 8 independent partial sums, folded
//!   in lane order) so the compiler can keep the accumulators in
//!   vector registers. The lane split is a pure function of the
//!   operand length — never of thread count, tiling, or batch shape —
//!   so SIMD output is still deterministic and thread-invariant, but
//!   it is *re-associated* relative to the scalar order and therefore
//!   gated by the **tolerance** conformance tier
//!   (`crate::testing::simd_spec`), not bitwise identity. On a bf16
//!   weight store ([`crate::weights::WeightPrecision::Bf16`]) the SIMD
//!   matmul additionally streams the raw half-width weight words and
//!   widens them in registers (f32 accumulation throughout). On an
//!   int8 store ([`crate::weights::WeightPrecision::Int8`]) it streams
//!   quarter-width codes plus one f32 scale per
//!   [`crate::weights::QUANT_TILE`]-wide row slice
//!   ([`kernels::matmul_tiled_int8`]), dequantizing `q as f32 * scale`
//!   in-register with the same fixed fold order — so the int8 tier is
//!   deterministic, thread-invariant and batch-invariant exactly like
//!   scalar/simd/bf16, and is gated by the wider
//!   `crate::testing::int8_spec` tolerance tier. Under the scalar
//!   kernel or the reference oracle a reduced-precision store is
//!   dequantized once to an f32 shadow at construction, so those
//!   paths keep their sequential-order numerics unchanged.
//!
//! Every executable the engine can dispatch —
//!
//! * `embed_t{T}` / `lm_head_t{T}` — token embedding and LM head,
//! * `layer_dense_t{T}_s{S}` — RMSNorm → GQA causal attention (RoPE) →
//!   RMSNorm → dense SwiGLU FFN, with residual adds,
//! * `layer_sparse_k{K}_t{T}_s{S}` — the fused sparse layer: predictor
//!   scores → host top-K → gather-indexed sparse FFN → compensator,
//! * `layer_sparse_nc_k{K}_t{T}_s{S}` — the fused sparse layer without
//!   the compensator: the only variant whose compute is genuinely
//!   *sub-dense* (only selected neurons are ever touched; see below),
//! * `layer_dense_a{A}_t{T}_s{S}` / `layer_sparse[_nc]_a{A}_k{K}_…` —
//!   the same fused layers with *block-sparse attention*: keys pooled
//!   into `attn_block`-sized blocks, a pooled-QK estimate ranks the
//!   causal key blocks per query block per head, and each query row
//!   visits only the selected blocks (always including a mandatory
//!   sink + local band — [`crate::sparsity::attn`]). `A` is the percent
//!   of optional blocks dropped; `a0` covers every causal block and is
//!   bit-identical to the dense attention path by the shared
//!   accumulation-order contract,
//! * `layer_attn_t{T}_s{S}` / `predictor_t{T}` / `ffn_acts_t{T}` /
//!   `ffn_dense_t{T}` / `ffn_sparse_ext_k{K}_t{T}` /
//!   `ffn_sparse_nc_k{K}_t{T}` — the split ablation pipeline
//!
//! — is interpreted directly over the [`WeightStore`], with no PJRT and
//! no artifacts on disk.
//!
//! Reference-semantics notes:
//!
//! * The sparse FFN iterates its (ascending) expert indices with the
//!   same accumulation order as the dense FFN, so `K == d_ffn` sparse
//!   output is *bit-identical* to dense output — the strongest form of
//!   the paper's "sparsity is exact at full K" sanity invariant.
//! * The compensator is modeled as a per-layer learned gate `alpha`
//!   applied to the *dropped* neurons' true contributions: zero when
//!   nothing is dropped, and (with seeded `alpha` strictly inside
//!   (0, 1)) it strictly shrinks the sparse FFN error — both properties
//!   hold by construction and are asserted by the test suite. The AOT
//!   compensator is a trained low-rank net; the reference keeps its
//!   *contract* in an exactly-testable form. The price of exactness is
//!   that compensated ops must compute every dropped neuron's true
//!   activation — dense cost — which is why the wall-clock speedup
//!   claims (fig6/fig7 `--backend cpu`, `tests/perf_smoke.rs`) are
//!   measured on the `*_nc` variants, whose cost scales with `K`.
//! * The expert predictor is low-rank (`pred.{l}.wd [d, r]` →
//!   `pred.{l}.wu [r, f]`, r ≪ f), matching the paper's small
//!   predictor networks: its overhead is a fraction of one FFN matmul
//!   instead of a full one.

#![allow(clippy::needless_range_loop)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::manifest::{ExecutableSpec, Manifest};
use crate::sparsity::masks::top_k_indices;
use crate::util::threadpool::{self, ThreadPool};
use crate::weights::{WeightPrecision, WeightStore, WeightView};

use super::backend::{sequential_batch, Backend, BatchRow, BatchRowOut};
use super::{DispatchStats, Input, Output};

/// RMSNorm epsilon (matches python/compile's model).
const RMS_EPS: f32 = 1e-5;
/// RoPE base frequency.
const ROPE_THETA: f64 = 10000.0;

/// One parsed executable name. `a` on the fused layer ops is the
/// block-sparse attention drop level in percent (`None` = the original
/// dense attention path, `Some(0)` = the sparse machinery at full
/// coverage — bit-identical to dense by the accumulation-order
/// contract, `Some(100)` = sink + local band only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Embed { t: usize },
    LmHead { t: usize },
    LayerDense { t: usize, s: usize, a: Option<usize> },
    LayerSparse { k: usize, t: usize, s: usize, a: Option<usize> },
    LayerSparseNc { k: usize, t: usize, s: usize, a: Option<usize> },
    LayerAttn { t: usize, s: usize },
    Predictor { t: usize },
    FfnActs { t: usize },
    FfnDense { t: usize },
    FfnSparseExt { k: usize, t: usize },
    FfnSparseNc { k: usize, t: usize },
}

/// Split `name` into its base and its `t`/`s`/`k`/`a` parameters
/// (`layer_sparse_a50_k64_t128_s512` → ("layer_sparse", k=64, t=128,
/// s=512, a=50)). Segments whose tail is not all digits (`attn`,
/// `acts`, `sparse`, …) join the base, so the pre-existing names parse
/// unchanged.
fn parse_name(name: &str) -> Option<(String, [Option<usize>; 4])> {
    let mut base: Vec<&str> = Vec::new();
    let mut tska: [Option<usize>; 4] = [None, None, None, None];
    for seg in name.split('_') {
        let mut chars = seg.chars();
        let head = chars.next()?;
        let rest: &str = &seg[head.len_utf8()..];
        let slot = match head {
            't' => 0,
            's' => 1,
            'k' => 2,
            'a' => 3,
            _ => 4,
        };
        if slot < 4
            && !rest.is_empty()
            && rest.bytes().all(|b| b.is_ascii_digit())
        {
            tska[slot] = rest.parse().ok();
        } else {
            base.push(seg);
        }
    }
    Some((base.join("_"), tska))
}

fn parse_op(name: &str) -> Result<Op> {
    let (base, [t, s, k, a]) =
        parse_name(name).ok_or_else(|| anyhow!("bad exe name {name}"))?;
    let need = |v: Option<usize>, what: &str| {
        v.ok_or_else(|| anyhow!("{name}: missing {what} parameter"))
    };
    Ok(match base.as_str() {
        "embed" => Op::Embed { t: need(t, "t")? },
        "lm_head" => Op::LmHead { t: need(t, "t")? },
        "layer_dense" => Op::LayerDense {
            t: need(t, "t")?,
            s: need(s, "s")?,
            a,
        },
        "layer_sparse" => Op::LayerSparse {
            k: need(k, "k")?,
            t: need(t, "t")?,
            s: need(s, "s")?,
            a,
        },
        "layer_sparse_nc" => Op::LayerSparseNc {
            k: need(k, "k")?,
            t: need(t, "t")?,
            s: need(s, "s")?,
            a,
        },
        "layer_attn" => Op::LayerAttn {
            t: need(t, "t")?,
            s: need(s, "s")?,
        },
        "predictor" => Op::Predictor { t: need(t, "t")? },
        "ffn_acts" => Op::FfnActs { t: need(t, "t")? },
        "ffn_dense" => Op::FfnDense { t: need(t, "t")? },
        "ffn_sparse_ext" => Op::FfnSparseExt {
            k: need(k, "k")?,
            t: need(t, "t")?,
        },
        "ffn_sparse_nc" => Op::FfnSparseNc {
            k: need(k, "k")?,
            t: need(t, "t")?,
        },
        other => {
            return Err(anyhow!("cpu backend: unknown executable {other}"))
        }
    })
}

fn f32_input<'a>(inputs: &[(&str, Input<'a>)], exe: &str, name: &str)
                 -> Result<&'a [f32]> {
    for (n, v) in inputs {
        if *n == name {
            if let Input::F32(d, _) = v {
                return Ok(*d);
            }
            return Err(anyhow!("{exe}: input '{name}' must be f32"));
        }
    }
    Err(anyhow!("{exe}: missing input '{name}'"))
}

fn i32_input<'a>(inputs: &[(&str, Input<'a>)], exe: &str, name: &str)
                 -> Result<&'a [i32]> {
    for (n, v) in inputs {
        if *n == name {
            if let Input::I32(d, _) = v {
                return Ok(*d);
            }
            return Err(anyhow!("{exe}: input '{name}' must be i32"));
        }
    }
    Err(anyhow!("{exe}: missing input '{name}'"))
}

/// Row-wise RMSNorm: `y[r,c] = x[r,c] * inv_rms(row r) * gain[c]`.
fn rmsnorm_rows(x: &[f32], gain: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &x[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for c in 0..d {
            out[r * d + c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// [`rmsnorm_rows`] with the square-sum reduced by lane-chunked
/// accumulation ([`kernels::lane_dot`] of the row with itself). Same
/// normalization math; the re-associated mean-square is what puts the
/// SIMD tier on the tolerance (not bitwise) conformance contract.
fn rmsnorm_rows_simd(x: &[f32], gain: &[f32], t: usize, d: usize)
                     -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &x[r * d..(r + 1) * d];
        let ms = kernels::lane_dot(row, row) / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for c in 0..d {
            out[r * d + c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// The attention score dot under the active kernel tier: lane-chunked
/// in SIMD mode ([`kernels::lane_dot`]), sequential otherwise. Shared
/// by the dense and block-sparse query-row kernels so the two stay on
/// the same accumulation order within a tier (the full-coverage ≡
/// dense identity holds per tier, including SIMD).
#[inline]
fn attn_dot(simd: bool, a: &[f32], b: &[f32]) -> f32 {
    if simd {
        kernels::lane_dot(a, b)
    } else {
        a.iter().zip(b.iter()).map(|(x, y)| x * y).sum()
    }
}

/// `x [t, m] @ w [m, n] -> [t, n]`, plain sequential accumulation (the
/// naive reference kernel; [`kernels::matmul_tiled`] must match it
/// bit-for-bit — see the kernel property suite below).
fn matmul(x: &[f32], w: &[f32], t: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), t * m);
    debug_assert_eq!(w.len(), m * n);
    let mut out = vec![0.0f32; t * n];
    for r in 0..t {
        let xr = &x[r * m..(r + 1) * m];
        let or = &mut out[r * n..(r + 1) * n];
        for (i, &xv) in xr.iter().enumerate() {
            let wr = &w[i * n..(i + 1) * n];
            for (o, &wv) in or.iter_mut().zip(wr.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// `w [rows, cols]` → `[cols, rows]` (row-major both ways).
fn transpose(w: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), rows * cols);
    let mut out = vec![0.0f32; w.len()];
    for r in 0..rows {
        for c in 0..cols {
            out[c * rows + r] = w[r * cols + c];
        }
    }
    out
}

/// Element-wise `a + b`.
fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Rotary position embedding applied in place to one `[heads * dh]` row
/// at absolute position `p`.
fn rope_row(row: &mut [f32], heads: usize, dh: usize, p: usize) {
    for h in 0..heads {
        let base = h * dh;
        for i in 0..dh / 2 {
            let freq =
                1.0 / ROPE_THETA.powf(2.0 * i as f64 / dh as f64);
            let angle = p as f64 * freq;
            let (sin, cos) = angle.sin_cos();
            let a = row[base + 2 * i] as f64;
            let b = row[base + 2 * i + 1] as f64;
            row[base + 2 * i] = (a * cos - b * sin) as f32;
            row[base + 2 * i + 1] = (a * sin + b * cos) as f32;
        }
    }
}

/// One query row of causal GQA attention over one sequence's KV view:
/// cached rows `[0, pos)` plus that sequence's fresh (already-roped)
/// rows `[pos, pos + t)`. `lr` is the query's local row index within
/// the fresh rows (absolute position `pos + lr`), `q_row` its
/// `[nh * dh]` roped query, `out_row` its `[nh * dh]` output slot.
/// Identical code runs for every query row whether executed inline
/// (reference / one thread), on a pool lane, or as one row of a fused
/// batched step — which is what keeps attention bit-identical across
/// all three paths.
#[allow(clippy::too_many_arguments)]
fn attn_query_row(simd: bool, q_row: &[f32], k_cache: &[f32],
                  v_cache: &[f32], k_new: &[f32], v_new: &[f32],
                  pos: usize, lr: usize, nh: usize, nkv: usize,
                  dh: usize, scale: f32, out_row: &mut [f32],
                  scores: &mut Vec<f32>) {
    let group = nh / nkv;
    let p = pos + lr; // absolute position of this query
    for h in 0..nh {
        let g = h / group; // the KV head this query head reads
        let qv = &q_row[h * dh..(h + 1) * dh];
        scores.clear();
        let mut max = f32::NEG_INFINITY;
        for j in 0..=p {
            let kv = if j < pos {
                &k_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
            } else {
                let jr = j - pos;
                &k_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
            };
            let dot = attn_dot(simd, qv, kv);
            let sc = dot * scale;
            max = max.max(sc);
            scores.push(sc);
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - max).exp();
            denom += *sc;
        }
        let out = &mut out_row[h * dh..(h + 1) * dh];
        for (j, &wgt) in scores.iter().enumerate() {
            let vv = if j < pos {
                &v_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
            } else {
                let jr = j - pos;
                &v_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
            };
            let wn = wgt / denom;
            for (o, &v) in out.iter_mut().zip(vv.iter()) {
                *o += wn * v;
            }
        }
    }
}

/// One query row of *block-sparse* causal GQA attention: identical to
/// [`attn_query_row`] except that each head only visits the key
/// positions inside its selected key blocks (`blocks_by_head[h]`,
/// ascending — see [`crate::sparsity::attn`]), clamped per row to the
/// causal frontier `j ≤ p`. The three passes (score/max, exp/denom,
/// weighted V) run over that position subset in ascending order with
/// the dense kernel's exact per-element accumulation order — so when
/// the selection covers every causal block the f32 op sequence is
/// *the same* as the dense kernel's and the output is bit-identical.
#[allow(clippy::too_many_arguments)]
fn attn_query_row_sparse(simd: bool, q_row: &[f32], k_cache: &[f32],
                         v_cache: &[f32], k_new: &[f32], v_new: &[f32],
                         pos: usize, lr: usize, nh: usize, nkv: usize,
                         dh: usize, scale: f32, out_row: &mut [f32],
                         scores: &mut Vec<f32>,
                         blocks_by_head: &[Vec<u32>], ab: usize) {
    let group = nh / nkv;
    let p = pos + lr; // absolute position of this query
    for h in 0..nh {
        let g = h / group; // the KV head this query head reads
        let qv = &q_row[h * dh..(h + 1) * dh];
        let blocks = &blocks_by_head[h];
        scores.clear();
        let mut max = f32::NEG_INFINITY;
        for &b in blocks {
            let lo = b as usize * ab;
            let hi = (lo + ab).min(p + 1);
            for j in lo..hi {
                let kv = if j < pos {
                    &k_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
                } else {
                    let jr = j - pos;
                    &k_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
                };
                let dot = attn_dot(simd, qv, kv);
                let sc = dot * scale;
                max = max.max(sc);
                scores.push(sc);
            }
        }
        let mut denom = 0.0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - max).exp();
            denom += *sc;
        }
        let out = &mut out_row[h * dh..(h + 1) * dh];
        // re-walk the same blocks with a running score cursor — no
        // position buffer, same per-element order as the dense pass
        let mut cursor = 0usize;
        for &b in blocks {
            let lo = b as usize * ab;
            let hi = (lo + ab).min(p + 1);
            for j in lo..hi {
                let vv = if j < pos {
                    &v_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
                } else {
                    let jr = j - pos;
                    &v_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
                };
                let wn = scores[cursor] / denom;
                cursor += 1;
                for (o, &v) in out.iter_mut().zip(vv.iter()) {
                    *o += wn * v;
                }
            }
        }
    }
}

/// Expert indices *not* selected, ascending (the compensator's domain).
fn complement(idx: &[i32], f: usize) -> Vec<i32> {
    let mut present = vec![false; f];
    for &ji in idx {
        if ji >= 0 && (ji as usize) < f {
            present[ji as usize] = true;
        }
    }
    (0..f as i32)
        .filter(|&j| !present[j as usize])
        .collect()
}

/// Cache-blocked kernels behind the fast path. Shared invariant: every
/// kernel writes each output element from exactly one task, and the
/// reduction order behind each element is a pure function of the
/// operands and the kernel tier — never of threads or tiling. Scalar
/// kernels ascend the reduction index (the naive reference order, so
/// tiling and threading never change a single output bit); the SIMD
/// variants re-associate through [`lane_dot`]'s fixed lane split and
/// are gated by the tolerance tier instead.
mod kernels {
    use crate::util::threadpool::ThreadPool;

    /// Rows (tokens) per parallel task.
    pub(super) const ROW_CHUNK: usize = 16;
    /// Output-column tile width per task: 128 f32 = 512 B of
    /// accumulator slab, small enough to stay in L1 while a weight
    /// panel streams through. Must equal
    /// [`crate::weights::QUANT_TILE`] so the int8 store's
    /// per-row-slice scales line up one-to-one with the kernels'
    /// column tiles (asserted below).
    pub(super) const COL_TILE: usize = 128;
    const _: () = assert!(
        COL_TILE == crate::weights::QUANT_TILE,
        "int8 scale tiling must match the kernel column tile"
    );

    /// A weight panel in whichever representation the store keeps
    /// resident. Kernels widen reduced panels to f32 in-register —
    /// bf16 exactly, int8 as `q as f32 * scale` with one scale per
    /// [`COL_TILE`]-wide row slice — in the same fixed fold order as
    /// the f32 SIMD path, preserving the module-level determinism
    /// contract (reduction order is a pure function of operands and
    /// kernel tier, never of threads, tiling, or batch shape).
    #[derive(Clone, Copy)]
    pub(super) enum Panel<'a> {
        /// Full-precision panel.
        F32(&'a [f32]),
        /// Raw bf16 words of the logical `[m, n]` panel.
        Bf16(&'a [u16]),
        /// int8 codes plus per-`(row, COL_TILE slice)` f32 scales for
        /// a panel whose rows are `cols` elements wide (`cols` must
        /// equal the matmul `n`; debug-asserted at every row access).
        I8 { q: &'a [i8], scales: &'a [f32], cols: usize },
    }

    impl<'a> Panel<'a> {
        /// Element count of the backing buffer (codes for int8).
        pub(super) fn elems(&self) -> usize {
            match self {
                Panel::F32(w) => w.len(),
                Panel::Bf16(w) => w.len(),
                Panel::I8 { q, .. } => q.len(),
            }
        }

        /// Row `j` columns `[c0, c1)` as f32, widening reduced panels
        /// into `buf`. `n` is the row stride; the caller's task grid
        /// guarantees `c0` is COL_TILE-aligned and
        /// `c1 - c0 <= COL_TILE`, so an int8 slice spans exactly one
        /// scale tile.
        #[inline]
        fn row<'b>(&self, j: usize, n: usize, c0: usize, c1: usize,
                   buf: &'b mut [f32; COL_TILE]) -> &'b [f32]
        where
            'a: 'b,
        {
            let width = c1 - c0;
            match *self {
                Panel::F32(w) => &w[j * n + c0..j * n + c1],
                Panel::Bf16(raw) => {
                    for (wc, &b) in buf[..width]
                        .iter_mut()
                        .zip(raw[j * n + c0..j * n + c1].iter())
                    {
                        *wc = crate::weights::bf16_to_f32(b);
                    }
                    &buf[..width]
                }
                Panel::I8 { q, scales, cols } => {
                    debug_assert_eq!(cols, n);
                    let s =
                        scales[j * n.div_ceil(COL_TILE) + c0 / COL_TILE];
                    for (wc, &cq) in buf[..width]
                        .iter_mut()
                        .zip(q[j * n + c0..j * n + c1].iter())
                    {
                        *wc = cq as f32 * s;
                    }
                    &buf[..width]
                }
            }
        }
    }
    /// Register-blocked row micro-tile: each loaded weight panel row is
    /// reused across this many token rows.
    const ROW_BLOCK: usize = 4;
    /// Accumulator lanes for the SIMD kernel tier (chosen to fill one
    /// AVX2 register / two NEON registers of f32).
    pub(super) const LANES: usize = 8;

    /// Lane-chunked dot product — the SIMD tier's reduction primitive.
    ///
    /// The aligned body accumulates into [`LANES`] *independent*
    /// partial sums (stride-`LANES` interleave), which are folded in
    /// fixed lane order, followed by a sequential scalar tail. The
    /// independent local accumulators are what lets the compiler keep
    /// the reduction in vector registers; the price is that the f32
    /// additions are *re-associated* relative to the sequential dot,
    /// so results differ from the scalar kernel by rounding (ULP
    /// tier), not bitwise. The split depends only on `a.len()` — never
    /// on threads, tiling, or batch shape — so `lane_dot` is a pure
    /// function of its operands: deterministic and thread-invariant.
    pub(super) fn lane_dot(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let body = n - n % LANES;
        let mut acc = [0.0f32; LANES];
        let mut i = 0;
        while i < body {
            for l in 0..LANES {
                acc[l] += a[i + l] * b[i + l];
            }
            i += LANES;
        }
        let mut sum = 0.0f32;
        for l in 0..LANES {
            sum += acc[l];
        }
        for j in body..n {
            sum += a[j] * b[j];
        }
        sum
    }

    /// Raw output pointer shareable across pool lanes.
    ///
    /// SAFETY: every call site partitions the output into disjoint
    /// (row-range × column-range) regions, one task each, and the pool
    /// joins all tasks before the owning `Vec` is touched again.
    #[derive(Clone, Copy)]
    struct OutPtr(*mut f32);
    unsafe impl Send for OutPtr {}
    unsafe impl Sync for OutPtr {}

    /// The (row, column) block grid for a `[t, n]` output.
    fn grid(t: usize, n: usize) -> (usize, usize) {
        (t.div_ceil(ROW_CHUNK).max(1), n.div_ceil(COL_TILE).max(1))
    }

    /// Tiled `x [t, m] @ w [m, n] -> [t, n]`, bit-identical to the
    /// naive `matmul` (per output element the `m` reduction ascends).
    pub(super) fn matmul_tiled(x: &[f32], w: &[f32], t: usize, m: usize,
                               n: usize, pool: &ThreadPool) -> Vec<f32> {
        debug_assert_eq!(x.len(), t * m);
        debug_assert_eq!(w.len(), m * n);
        let mut out = vec![0.0f32; t * n];
        let (rows, cols) = grid(t, n);
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(rows * cols, |task| {
            let (ri, ci) = (task / cols, task % cols);
            let (r0, r1) = (ri * ROW_CHUNK, (ri * ROW_CHUNK + ROW_CHUNK).min(t));
            let (c0, c1) = (ci * COL_TILE, (ci * COL_TILE + COL_TILE).min(n));
            let p = optr;
            // SAFETY: tasks cover disjoint [r0,r1) × [c0,c1) regions.
            unsafe { matmul_block(x, w, m, n, r0, r1, c0, c1, p.0) };
        });
        out
    }

    /// Accumulate `out[r, c] += Σ_i x[r, i] · w[i, c]` over one block.
    ///
    /// SAFETY: caller guarantees `out` points at a `[t, n]` buffer and
    /// no other thread touches rows `[r0, r1)` columns `[c0, c1)`.
    #[allow(clippy::too_many_arguments)]
    unsafe fn matmul_block(x: &[f32], w: &[f32], m: usize, n: usize,
                           r0: usize, r1: usize, c0: usize, c1: usize,
                           out: *mut f32) {
        let width = c1 - c0;
        let mut rb = r0;
        while rb < r1 {
            let rend = (rb + ROW_BLOCK).min(r1);
            for i in 0..m {
                let wrow = &w[i * n + c0..i * n + c1];
                for r in rb..rend {
                    let xv = x[r * m + i];
                    let orow = out.add(r * n + c0);
                    for c in 0..width {
                        *orow.add(c) += xv * wrow[c];
                    }
                }
            }
            rb = rend;
        }
    }

    /// Register-tiled `x [t, m] @ w [m, n] -> [t, n]` for the SIMD
    /// kernel tier: same task grid as [`matmul_tiled`], but each
    /// `ROW_BLOCK × COL_TILE` output tile accumulates in a stack-local
    /// array written back once per tile, instead of read-modify-writing
    /// the shared output buffer on every reduction step. The local
    /// accumulators carry no aliasing with the streamed weight panel,
    /// which is what lets the compiler vectorize the column loop and
    /// keep the tile in registers — the scalar kernel's raw-pointer
    /// writes defeat both. Per output element the `m` reduction still
    /// ascends, so this kernel's *values* match the scalar tiling; the
    /// SIMD tier's re-association enters through [`lane_dot`]
    /// (attention dots, gathered activations, RMSNorm square sums).
    pub(super) fn matmul_tiled_simd(x: &[f32], w: &[f32], t: usize,
                                    m: usize, n: usize,
                                    pool: &ThreadPool) -> Vec<f32> {
        matmul_tiled_wide(x, Panel::F32(w), t, m, n, pool)
    }

    /// [`matmul_tiled_simd`] streaming a raw bf16 weight buffer
    /// (`w16`, one `u16` per element of the logical `[m, n]` panel):
    /// each panel row slice is widened to f32 in a stack buffer once
    /// per reduction step, then accumulated exactly as the f32 SIMD
    /// kernel does. Widening bf16→f32 is exact, so over a bf16 weight
    /// store this is bit-identical to [`matmul_tiled_simd`] on the
    /// widened f32 panel — it just moves half the weight bytes.
    pub(super) fn matmul_tiled_bf16(x: &[f32], w16: &[u16], t: usize,
                                    m: usize, n: usize,
                                    pool: &ThreadPool) -> Vec<f32> {
        matmul_tiled_wide(x, Panel::Bf16(w16), t, m, n, pool)
    }

    /// [`matmul_tiled_simd`] streaming int8 codes (`q`, one per
    /// element of the logical `[m, n]` panel) plus per-`(row,
    /// COL_TILE slice)` f32 `scales`: each panel row slice is
    /// dequantized `q as f32 * scale` into a stack buffer once per
    /// reduction step, then accumulated exactly as the f32 SIMD
    /// kernel does. The dequantized values are identical for every
    /// task/thread split (one scale covers the whole slice), so over
    /// the *same* codes this is bit-identical to
    /// [`matmul_tiled_simd`] on the dequantized panel — it just moves
    /// a quarter of the weight bytes. Accuracy vs the original f32
    /// weights is bounded by the quantizer (absmax/254 per element)
    /// and gated by `crate::testing::int8_spec`.
    pub(super) fn matmul_tiled_int8(x: &[f32], q: &[i8], scales: &[f32],
                                    t: usize, m: usize, n: usize,
                                    pool: &ThreadPool) -> Vec<f32> {
        debug_assert_eq!(scales.len(), m * n.div_ceil(COL_TILE));
        matmul_tiled_wide(x, Panel::I8 { q, scales, cols: n }, t, m, n,
                          pool)
    }

    /// Shared grid driver for the SIMD-tier matmuls over any panel
    /// representation.
    fn matmul_tiled_wide(x: &[f32], w: Panel<'_>, t: usize, m: usize,
                         n: usize, pool: &ThreadPool) -> Vec<f32> {
        debug_assert_eq!(x.len(), t * m);
        debug_assert_eq!(w.elems(), m * n);
        let mut out = vec![0.0f32; t * n];
        let (rows, cols) = grid(t, n);
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(rows * cols, |task| {
            let (ri, ci) = (task / cols, task % cols);
            let (r0, r1) = (ri * ROW_CHUNK, (ri * ROW_CHUNK + ROW_CHUNK).min(t));
            let (c0, c1) = (ci * COL_TILE, (ci * COL_TILE + COL_TILE).min(n));
            let p = optr;
            // SAFETY: tasks cover disjoint [r0,r1) × [c0,c1) regions.
            unsafe { matmul_block_simd(x, w, m, n, r0, r1, c0, c1, p.0) };
        });
        out
    }

    /// One register-tiled block for the SIMD tier. Reads the weight
    /// panel through [`Panel::row`], widening reduced representations
    /// into a stack row buffer.
    ///
    /// SAFETY: caller guarantees `out` points at a `[t, n]` buffer and
    /// no other thread touches rows `[r0, r1)` columns `[c0, c1)`.
    #[allow(clippy::too_many_arguments)]
    unsafe fn matmul_block_simd(x: &[f32], w: Panel<'_>,
                                m: usize, n: usize, r0: usize, r1: usize,
                                c0: usize, c1: usize, out: *mut f32) {
        let width = c1 - c0;
        let mut wide = [0.0f32; COL_TILE];
        let mut rb = r0;
        while rb < r1 {
            let rend = (rb + ROW_BLOCK).min(r1);
            let mut acc = [[0.0f32; COL_TILE]; ROW_BLOCK];
            for i in 0..m {
                let wrow = w.row(i, n, c0, c1, &mut wide);
                for r in rb..rend {
                    let xv = x[r * m + i];
                    let arow = &mut acc[r - rb];
                    for c in 0..width {
                        arow[c] += xv * wrow[c];
                    }
                }
            }
            for r in rb..rend {
                let orow = out.add(r * n + c0);
                let arow = &acc[r - rb];
                for c in 0..width {
                    *orow.add(c) = arow[c];
                }
            }
            rb = rend;
        }
    }

    /// Full-row dot `x · panel[j, :]` (`x.len() == d`). An f32 panel
    /// reduces in one pass — lane-chunked when `simd`, else the
    /// sequential bitwise order. A reduced panel widens one
    /// COL_TILE-wide slice at a time into a stack buffer and folds the
    /// per-slice partial sums in ascending slice order — a pure
    /// function of the operands and representation, so the reduced
    /// gather path keeps the determinism contract (tolerance tier).
    fn panel_row_dot(x: &[f32], p: Panel<'_>, j: usize, d: usize,
                     simd: bool) -> f32 {
        if let Panel::F32(w) = p {
            let row = &w[j * d..(j + 1) * d];
            return if simd {
                lane_dot(x, row)
            } else {
                x.iter().zip(row.iter()).map(|(a, b)| a * b).sum()
            };
        }
        let mut buf = [0.0f32; COL_TILE];
        let mut sum = 0.0f32;
        let mut c0 = 0;
        while c0 < d {
            let c1 = (c0 + COL_TILE).min(d);
            let row = p.row(j, d, c0, c1, &mut buf);
            let xa = &x[c0..c1];
            sum += if simd {
                lane_dot(xa, row)
            } else {
                xa.iter().zip(row.iter()).map(|(a, b)| a * b).sum::<f32>()
            };
            c0 = c1;
        }
        sum
    }

    /// Gathered SwiGLU activations restricted to `idx`, compact layout:
    /// `out[r, j'] = silu(h2[r]·gate_t[idx[j']]) * (h2[r]·up_t[idx[j']])`
    /// over pre-transposed `[f, d]` gate/up panels, so each selected
    /// neuron is one pair of contiguous row dots ([`panel_row_dot`]).
    /// With `simd` unset (f32 panels only) the dots ascend the `d`
    /// axis — bit-identical to the corresponding columns of the dense
    /// `h2 @ w_gate` / `h2 @ w_up` matmuls; with `simd` set they run
    /// through [`lane_dot`] (tolerance tier), dequantizing int8 panels
    /// slice-by-slice inside the loop. Cost scales with `idx.len()`
    /// instead of `d_ffn`: this is the sub-dense sparse hot path.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn gather_acts(h2: &[f32], gate_t: Panel<'_>,
                              up_t: Panel<'_>, t: usize, d: usize,
                              idx: &[i32], simd: bool,
                              pool: &ThreadPool) -> Vec<f32> {
        let k = idx.len();
        debug_assert_eq!(h2.len(), t * d);
        let mut out = vec![0.0f32; t * k];
        let (rows, cols) = grid(t, k);
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(rows * cols, |task| {
            let (ri, ci) = (task / cols, task % cols);
            let (r0, r1) = (ri * ROW_CHUNK, (ri * ROW_CHUNK + ROW_CHUNK).min(t));
            let (c0, c1) = (ci * COL_TILE, (ci * COL_TILE + COL_TILE).min(k));
            let p = optr;
            for r in r0..r1 {
                let hr = &h2[r * d..(r + 1) * d];
                for jj in c0..c1 {
                    let j = idx[jj] as usize;
                    let g = panel_row_dot(hr, gate_t, j, d, simd);
                    let u = panel_row_dot(hr, up_t, j, d, simd);
                    // SAFETY: element (r, jj) belongs to this task only.
                    unsafe {
                        *p.0.add(r * k + jj) = super::silu(g) * u;
                    }
                }
            }
        });
        out
    }

    /// Tiled down-projection over full-width activations `[t, f]`:
    /// `out[r, c] += Σ_{j ∈ idx} alpha?[j] · acts[r, j] · w_down[j, c]`,
    /// `j` in `idx` order per element — over an f32 panel this is
    /// bit-identical to the reference `down_proj` loop; reduced panels
    /// widen each `[j, c0..c1)` slice on the stack first (bf16
    /// exactly; int8 with its one scale per slice) and keep the same
    /// accumulation order.
    #[allow(clippy::too_many_arguments)]
    pub(super) fn down_proj_tiled(acts: &[f32], w_down: Panel<'_>,
                                  alpha: Option<&[f32]>, t: usize,
                                  f: usize, d: usize, idx: &[i32],
                                  pool: &ThreadPool) -> Vec<f32> {
        debug_assert_eq!(acts.len(), t * f);
        debug_assert_eq!(w_down.elems(), f * d);
        let mut out = vec![0.0f32; t * d];
        let (rows, cols) = grid(t, d);
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(rows * cols, |task| {
            let (ri, ci) = (task / cols, task % cols);
            let (r0, r1) = (ri * ROW_CHUNK, (ri * ROW_CHUNK + ROW_CHUNK).min(t));
            let (c0, c1) = (ci * COL_TILE, (ci * COL_TILE + COL_TILE).min(d));
            let width = c1 - c0;
            let p = optr;
            let mut wide = [0.0f32; COL_TILE];
            for r in r0..r1 {
                // SAFETY: rows/cols of this region belong to this task.
                let orow = unsafe { p.0.add(r * d + c0) };
                for &ji in idx {
                    let j = ji as usize;
                    let a = acts[r * f + j]
                        * alpha.map_or(1.0, |al| al[j]);
                    let wrow = w_down.row(j, d, c0, c1, &mut wide);
                    for c in 0..width {
                        unsafe { *orow.add(c) += a * wrow[c] };
                    }
                }
            }
        });
        out
    }

    /// Tiled down-projection over *compact* activations `[t, K]`
    /// (column `j'` holds neuron `idx[j']`):
    /// `out[r, c] += Σ_{j'} acts[r, j'] · w_down[idx[j'], c]`.
    /// Same per-element accumulation order as `down_proj_tiled` /
    /// the reference loop over the same `idx`; reduced panels widen
    /// each row slice on the stack exactly as `down_proj_tiled` does.
    pub(super) fn down_proj_compact(acts: &[f32], w_down: Panel<'_>,
                                    t: usize, d: usize, idx: &[i32],
                                    pool: &ThreadPool) -> Vec<f32> {
        let k = idx.len();
        debug_assert_eq!(acts.len(), t * k);
        let mut out = vec![0.0f32; t * d];
        let (rows, cols) = grid(t, d);
        let optr = OutPtr(out.as_mut_ptr());
        pool.run(rows * cols, |task| {
            let (ri, ci) = (task / cols, task % cols);
            let (r0, r1) = (ri * ROW_CHUNK, (ri * ROW_CHUNK + ROW_CHUNK).min(t));
            let (c0, c1) = (ci * COL_TILE, (ci * COL_TILE + COL_TILE).min(d));
            let width = c1 - c0;
            let p = optr;
            let mut wide = [0.0f32; COL_TILE];
            for r in r0..r1 {
                // SAFETY: rows/cols of this region belong to this task.
                let orow = unsafe { p.0.add(r * d + c0) };
                for (jj, &ji) in idx.iter().enumerate() {
                    let j = ji as usize;
                    let a = acts[r * k + jj];
                    let wrow = w_down.row(j, d, c0, c1, &mut wide);
                    for c in 0..width {
                        unsafe { *orow.add(c) += a * wrow[c] };
                    }
                }
            }
        });
        out
    }
}

/// Env var naming the CPU kernel tier (`scalar` | `simd`); the
/// `--cpu-kernel` CLI flag forwards through it so engine construction
/// anywhere in the process (including pool replicas) sees the choice.
/// Unset or unrecognized → scalar.
pub const KERNEL_ENV: &str = "FF_CPU_KERNEL";

/// Inner-kernel tier of the fast CPU path (module docs, "Kernel
/// tiers"). Orthogonal to reference mode: the reference oracle is
/// always scalar.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CpuKernel {
    /// Sequential per-element accumulation — bit-identical to the
    /// reference oracle at any thread count (bitwise conformance
    /// tier). The default.
    #[default]
    Scalar,
    /// Lane-chunked accumulation ([`kernels::LANES`]-wide partial
    /// sums) — deterministic and thread-invariant but re-associated;
    /// gated by the tolerance tier (`crate::testing::simd_spec`).
    Simd,
}

impl CpuKernel {
    /// Parse a CLI/env spelling (`scalar` | `simd`, case-insensitive).
    pub fn parse(s: &str) -> Option<CpuKernel> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(CpuKernel::Scalar),
            "simd" => Some(CpuKernel::Simd),
            _ => None,
        }
    }

    /// The tier [`KERNEL_ENV`] selects (scalar when unset or
    /// unrecognized — an opt-in knob must fail closed).
    pub fn from_env() -> CpuKernel {
        std::env::var(KERNEL_ENV)
            .ok()
            .and_then(|v| Self::parse(&v))
            .unwrap_or_default()
    }

    /// Stable lowercase label (bench/log spelling, `parse`-able).
    pub fn label(self) -> &'static str {
        match self {
            CpuKernel::Scalar => "scalar",
            CpuKernel::Simd => "simd",
        }
    }
}

/// Construction options for [`CpuBackend::with_options`].
#[derive(Debug, Clone, Default)]
pub struct CpuOptions {
    /// Worker lanes (caller included). `0` resolves via
    /// [`crate::util::threadpool::resolve_threads`]: `FF_CPU_THREADS`,
    /// else available parallelism (capped).
    pub threads: usize,
    /// Force the sequential scalar reference interpreter (implies one
    /// thread, naive kernels). This is the conformance oracle.
    pub reference: bool,
    /// Kernel tier for the fast path; `None` resolves via
    /// [`CpuKernel::from_env`]. Ignored (forced scalar) in reference
    /// mode.
    pub kernel: Option<CpuKernel>,
}

impl CpuOptions {
    /// The kernel tier this option set builds: explicit choice, else
    /// [`KERNEL_ENV`], with reference mode pinned to scalar. Exposed
    /// so fingerprinting can resolve the tier *before* constructing
    /// the backend ([`crate::runtime::Runtime::cpu_with_options`]).
    pub fn resolved_kernel(&self) -> CpuKernel {
        if self.reference {
            CpuKernel::Scalar
        } else {
            self.kernel.unwrap_or_else(CpuKernel::from_env)
        }
    }
}

/// The pure-Rust deterministic backend. See the module docs for the
/// op-set, the fast/reference split and the determinism contract.
pub struct CpuBackend {
    manifest: Arc<Manifest>,
    weights: Arc<WeightStore>,
    /// Parsed-op cache (name → [`Op`]): names parse once, and the map
    /// doubles as the "prepared executables" set.
    ops: RefCell<HashMap<String, Op>>,
    stats: RefCell<DispatchStats>,
    /// Sequential scalar oracle mode (naive kernels, no pool).
    reference: bool,
    /// Inner-kernel tier of the fast path (always scalar in reference
    /// mode).
    kernel: CpuKernel,
    /// Worker pool for the fast kernels (1 lane → inline execution).
    pool: ThreadPool,
    /// Fast path only: per-layer transposed `w_gate` (`[f, d]`) for the
    /// gathered sparse activation kernel. Empty in reference mode.
    /// Materialized per backend (so per pool replica) — the shared
    /// `Arc<WeightStore>` stays untransposed; sharing these panels
    /// through the pool factory is a known follow-up
    /// (docs/ARCHITECTURE.md §2.4).
    gate_t: Vec<Vec<f32>>,
    /// Fast path only: per-layer transposed `w_up` (`[f, d]`).
    up_t: Vec<Vec<f32>>,
    /// Int8 + SIMD only: per-layer transposed gate panels re-quantized
    /// along the `d` axis (`[f, d]` codes + per-`(neuron, QUANT_TILE
    /// slice)` scales), so the gathered sparse path streams
    /// quarter-width rows like the dense path does. Empty on every
    /// other tier (the f32 `gate_t`/`up_t` panels are used instead).
    gate_t_q: Vec<(Vec<i8>, Vec<f32>)>,
    /// Int8 + SIMD only: per-layer transposed up panels (see
    /// `gate_t_q`).
    up_t_q: Vec<(Vec<i8>, Vec<f32>)>,
    /// Dequantized f32 copies served by [`Self::w`] when the store
    /// keeps a reduced representation. Under the reference oracle or
    /// the scalar kernel tier this holds *every* tensor (those paths
    /// keep their sequential-order f32 numerics, at f32 residency);
    /// under SIMD it holds only the tensors the kernels consume as
    /// f32 — the 1-D gains/alphas and the `embed` table (row copies,
    /// never a matmul operand) — so reduced residency is preserved on
    /// the tier that exists to exploit it.
    shadow: HashMap<String, Vec<f32>>,
}

impl CpuBackend {
    /// The fast tiled/parallel interpreter with default options
    /// (thread count from `FF_CPU_THREADS`, else available
    /// parallelism). Validates that the weight table follows the
    /// reference naming convention the interpreter dispatches against
    /// (AOT artifact bundles do *not*: their fused low-rank
    /// predictor/compensator networks are PJRT-only, and construction
    /// fails fast here with a clear error).
    pub fn new(manifest: Arc<Manifest>, weights: Arc<WeightStore>)
               -> Result<Self> {
        Self::with_options(manifest, weights, CpuOptions::default())
    }

    /// The sequential scalar reference interpreter — the oracle the
    /// fast path is conformance-tested against. Numerically
    /// bit-identical to [`CpuBackend::new`] (that is the tested
    /// contract), just slow.
    pub fn reference(manifest: Arc<Manifest>, weights: Arc<WeightStore>)
                     -> Result<Self> {
        Self::with_options(
            manifest,
            weights,
            CpuOptions { threads: 1, reference: true, kernel: None },
        )
    }

    /// Build the interpreter over a manifest + weight store — in
    /// practice [`Manifest::synthetic`] + [`WeightStore::seeded`] —
    /// with explicit [`CpuOptions`].
    pub fn with_options(manifest: Arc<Manifest>,
                        weights: Arc<WeightStore>, opts: CpuOptions)
                        -> Result<Self> {
        for name in ["embed", "final_rms", "lm_head", "layers.0.wq",
                     "layers.0.rms1", "pred.0.wd", "comp.0.alpha"] {
            weights.shape(name).map_err(|_| {
                anyhow!(
                    "cpu backend: weight table missing '{name}' — the \
                     interpreter requires the ff weight naming convention"
                )
            })?;
        }
        let threads = if opts.reference {
            1
        } else {
            threadpool::resolve_threads(
                (opts.threads > 0).then_some(opts.threads),
            )
        };
        let kernel = opts.resolved_kernel();
        let precision = weights.precision();

        // Dequantized f32 shadow (struct-field docs): everything for
        // reference/scalar over a reduced store, just the non-matmul
        // tensors (1-D gains/alphas + embed row table) under SIMD.
        let mut shadow = HashMap::new();
        if precision != WeightPrecision::F32 {
            let full = opts.reference || kernel == CpuKernel::Scalar;
            for name in weights.names() {
                let small =
                    name == "embed" || weights.shape(name)?.len() < 2;
                if full || small {
                    shadow.insert(name.clone(), weights.dequant(name)?);
                }
            }
        }

        let (mut gate_t, mut up_t) = (Vec::new(), Vec::new());
        let (mut gate_t_q, mut up_t_q) = (Vec::new(), Vec::new());
        if !opts.reference {
            let (d, f) = (manifest.model.d_model, manifest.model.d_ffn);
            let quantized_gather = precision == WeightPrecision::Int8
                && kernel == CpuKernel::Simd;
            for l in 0..manifest.model.n_layers {
                let g = weights.dequant(&format!("layers.{l}.w_gate"))?;
                let u = weights.dequant(&format!("layers.{l}.w_up"))?;
                anyhow::ensure!(
                    g.len() == d * f && u.len() == d * f,
                    "layer {l}: gate/up shape mismatch"
                );
                let (gt, ut) = (transpose(&g, d, f), transpose(&u, d, f));
                if quantized_gather {
                    gate_t_q.push(crate::weights::quantize_int8(&gt, f, d));
                    up_t_q.push(crate::weights::quantize_int8(&ut, f, d));
                } else {
                    gate_t.push(gt);
                    up_t.push(ut);
                }
            }
        }
        Ok(CpuBackend {
            manifest,
            weights,
            ops: RefCell::new(HashMap::new()),
            stats: RefCell::new(DispatchStats::default()),
            reference: opts.reference,
            kernel,
            pool: ThreadPool::new(threads),
            gate_t,
            up_t,
            gate_t_q,
            up_t_q,
            shadow,
        })
    }

    /// Worker lanes in use (1 in reference mode).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Whether this is the sequential reference oracle.
    pub fn is_reference(&self) -> bool {
        self.reference
    }

    /// The inner-kernel tier this backend runs (scalar in reference
    /// mode).
    pub fn kernel(&self) -> CpuKernel {
        self.kernel
    }

    /// Whether the lane-chunked SIMD kernel tier is active (never in
    /// reference mode — resolution pins the oracle to scalar).
    fn simd(&self) -> bool {
        self.kernel == CpuKernel::Simd
    }

    /// RMSNorm through the active kernel tier.
    fn rms(&self, x: &[f32], gain: &[f32], t: usize, d: usize)
           -> Vec<f32> {
        if self.simd() {
            rmsnorm_rows_simd(x, gain, t, d)
        } else {
            rmsnorm_rows(x, gain, t, d)
        }
    }

    /// Parse (and cache) the op an executable name denotes. Steady-state
    /// dispatch is a single map lookup — no re-parse, no allocation.
    fn op_for(&self, name: &str) -> Result<Op> {
        if let Some(op) = self.ops.borrow().get(name) {
            return Ok(*op);
        }
        let op = parse_op(name)?;
        self.ops.borrow_mut().insert(name.to_string(), op);
        Ok(op)
    }

    /// Fetch a weight slice as f32, validating its element count.
    /// Serves the dequantized shadow when the store is reduced (the
    /// construction shadow policy guarantees the shadow covers every
    /// name this is called with on the active tier).
    fn w(&self, name: &str, expect: usize) -> Result<&[f32]> {
        let data = match self.shadow.get(name) {
            Some(s) => s.as_slice(),
            None => self.weights.get(name)?,
        };
        anyhow::ensure!(
            data.len() == expect,
            "weight {name}: {} elements, interpreter expects {expect}",
            data.len()
        );
        Ok(data)
    }

    fn lw(&self, l: usize, role: &str, expect: usize) -> Result<&[f32]> {
        self.w(&format!("layers.{l}.{role}"), expect)
    }

    /// Fetch a weight as a kernel [`kernels::Panel`] in the
    /// representation the active tier consumes: the f32 shadow when
    /// present (always, for reference/scalar over a reduced store),
    /// else the store's native panel (f32, raw bf16 words, or int8
    /// codes + scales). Validates the element count.
    fn wp(&self, name: &str, expect: usize)
          -> Result<kernels::Panel<'_>> {
        if let Some(s) = self.shadow.get(name) {
            anyhow::ensure!(
                s.len() == expect,
                "weight {name}: {} elements, interpreter expects {expect}",
                s.len()
            );
            return Ok(kernels::Panel::F32(s));
        }
        let p = match self.weights.view(name)? {
            WeightView::F32(w) => kernels::Panel::F32(w),
            WeightView::Bf16(raw) => kernels::Panel::Bf16(raw),
            WeightView::Int8 { q, scales, cols } => {
                kernels::Panel::I8 { q, scales, cols }
            }
        };
        anyhow::ensure!(
            p.elems() == expect,
            "weight {name}: {} elements, interpreter expects {expect}",
            p.elems()
        );
        Ok(p)
    }

    /// [`Self::wp`] for a per-layer weight role.
    fn lwp(&self, l: usize, role: &str, expect: usize)
           -> Result<kernels::Panel<'_>> {
        self.wp(&format!("layers.{l}.{role}"), expect)
    }

    /// Matmul through the active kernel tier (naive in reference mode,
    /// tiled + pooled otherwise; bit-identical to the reference in
    /// scalar tier, tolerance tier under SIMD). Reduced-precision
    /// panels only reach the SIMD kernels — bf16 streams half-width
    /// words, int8 streams quarter-width codes + per-tile scales —
    /// because reference/scalar modes shadow every tensor to f32 at
    /// construction.
    fn mm2(&self, x: &[f32], w: kernels::Panel<'_>, t: usize, m: usize,
           n: usize) -> Vec<f32> {
        if self.reference || self.kernel == CpuKernel::Scalar {
            let kernels::Panel::F32(w) = w else {
                unreachable!(
                    "reference/scalar tiers consume the f32 shadow"
                );
            };
            return if self.reference {
                matmul(x, w, t, m, n)
            } else {
                kernels::matmul_tiled(x, w, t, m, n, &self.pool)
            };
        }
        match w {
            kernels::Panel::F32(w) => {
                kernels::matmul_tiled_simd(x, w, t, m, n, &self.pool)
            }
            kernels::Panel::Bf16(raw) => {
                kernels::matmul_tiled_bf16(x, raw, t, m, n, &self.pool)
            }
            kernels::Panel::I8 { q, scales, cols } => {
                debug_assert_eq!(cols, n);
                kernels::matmul_tiled_int8(x, q, scales, t, m, n,
                                           &self.pool)
            }
        }
    }

    /// The gathered sparse-FFN gate/up panels for layer `l`, in the
    /// representation the active tier streams (int8 under SIMD on an
    /// int8 store, f32 otherwise). Errors in reference mode, which
    /// builds no panels.
    fn gather_panels(&self, l: usize)
                     -> Result<(kernels::Panel<'_>, kernels::Panel<'_>)> {
        if l < self.gate_t_q.len() {
            let (gq, gs) = &self.gate_t_q[l];
            let (uq, us) = &self.up_t_q[l];
            let d = self.manifest.model.d_model;
            return Ok((
                kernels::Panel::I8 { q: gq, scales: gs, cols: d },
                kernels::Panel::I8 { q: uq, scales: us, cols: d },
            ));
        }
        anyhow::ensure!(
            l < self.gate_t.len() && l < self.up_t.len(),
            "layer {l} out of range for transposed FFN weights"
        );
        Ok((
            kernels::Panel::F32(&self.gate_t[l]),
            kernels::Panel::F32(&self.up_t[l]),
        ))
    }

    /// Compute the block-sparse attention plan for a chunk when the
    /// dispatched executable carries an `a{pct}` drop level, or `None`
    /// for the original dense attention path. Runs sequentially on the
    /// dispatching thread (selection never depends on thread count);
    /// the per-row kernels consume it read-only.
    #[allow(clippy::too_many_arguments)]
    fn attn_plan(&self, a: Option<usize>, q: &[f32], k_cache: &[f32],
                 k_new: &[f32], pos: usize, t: usize)
                 -> Result<Option<Vec<Vec<Vec<u32>>>>> {
        let Some(pct) = a else { return Ok(None) };
        let m = &self.manifest.model;
        let ab = m.attn_block;
        anyhow::ensure!(pct <= 100, "attention drop {pct}% out of range");
        anyhow::ensure!(
            ab > 0 && pos % ab == 0 && t % ab == 0,
            "attention-sparse dispatch must be block-aligned \
             (pos {pos}, t {t}, attn_block {ab})"
        );
        Ok(Some(crate::sparsity::attn::plan(
            q,
            k_cache,
            k_new,
            pos,
            t,
            m.n_heads,
            m.n_kv_heads,
            m.d_head,
            ab,
            pct as f64 / 100.0,
        )))
    }

    /// RMSNorm(x, rms1) → QKV (+ RoPE) → causal GQA attention → output
    /// projection → residual. Returns `(h, k_new, v_new)` where `h` is
    /// the post-attention residual stream `x + attn_out @ wo`. The
    /// score/softmax/weighted-sum loop parallelizes across query rows
    /// (each row's computation is untouched, so thread count never
    /// changes a bit). `a` is the block-sparse attention drop level in
    /// percent (`None` = dense attention, the pre-existing path,
    /// untouched op for op).
    #[allow(clippy::too_many_arguments)]
    fn attention_block(&self, l: usize, x: &[f32], t: usize, s: usize,
                       pos: usize, k_cache: &[f32], v_cache: &[f32],
                       a: Option<usize>)
                       -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = &self.manifest.model;
        let (d, nh, nkv, dh) =
            (m.d_model, m.n_heads, m.n_kv_heads, m.d_head);
        let ab = m.attn_block;
        anyhow::ensure!(nh % nkv == 0, "n_heads must be divisible by n_kv");
        anyhow::ensure!(
            pos + t <= s,
            "attention: pos {pos} + t {t} exceeds bucket {s}"
        );

        let h1 = self.rms(x, self.lw(l, "rms1", d)?, t, d);
        let mut q = self.mm2(&h1, self.lwp(l, "wq", d * nh * dh)?, t, d,
                             nh * dh);
        let mut k_new = self.mm2(&h1, self.lwp(l, "wk", d * nkv * dh)?,
                                 t, d, nkv * dh);
        let v_new = self.mm2(&h1, self.lwp(l, "wv", d * nkv * dh)?, t,
                             d, nkv * dh);
        for r in 0..t {
            rope_row(&mut q[r * nh * dh..(r + 1) * nh * dh], nh, dh,
                     pos + r);
            rope_row(&mut k_new[r * nkv * dh..(r + 1) * nkv * dh], nkv, dh,
                     pos + r);
        }

        let plan = self.attn_plan(a, &q, k_cache, &k_new, pos, t)?;
        let scale = 1.0 / (dh as f32).sqrt();
        let mut attn = vec![0.0f32; t * nh * dh];
        // One query row of attention output — delegated to the shared
        // per-row helpers the fused batched step uses too. The sparse
        // variant reads the precomputed plan of the row's query block.
        let simd = self.simd();
        let attn_row = |r: usize, out_row: &mut [f32],
                        scores: &mut Vec<f32>| {
            match &plan {
                Some(p) => attn_query_row_sparse(
                    simd,
                    &q[r * nh * dh..(r + 1) * nh * dh],
                    k_cache,
                    v_cache,
                    &k_new,
                    &v_new,
                    pos,
                    r,
                    nh,
                    nkv,
                    dh,
                    scale,
                    out_row,
                    scores,
                    &p[r / ab],
                    ab,
                ),
                None => attn_query_row(
                    simd,
                    &q[r * nh * dh..(r + 1) * nh * dh],
                    k_cache,
                    v_cache,
                    &k_new,
                    &v_new,
                    pos,
                    r,
                    nh,
                    nkv,
                    dh,
                    scale,
                    out_row,
                    scores,
                ),
            }
        };
        if self.reference || t == 1 {
            let mut scores: Vec<f32> = Vec::new();
            for (r, out_row) in attn.chunks_mut(nh * dh).enumerate() {
                attn_row(r, out_row, &mut scores);
            }
        } else {
            struct RowPtr(*mut f32);
            unsafe impl Send for RowPtr {}
            unsafe impl Sync for RowPtr {}
            let aptr = RowPtr(attn.as_mut_ptr());
            let row_elems = nh * dh;
            self.pool.run(t, |r| {
                let p = &aptr;
                // SAFETY: each task owns exactly row `r` of `attn`,
                // and the pool joins before `attn` is read.
                let out_row = unsafe {
                    std::slice::from_raw_parts_mut(
                        p.0.add(r * row_elems),
                        row_elems,
                    )
                };
                let mut scores: Vec<f32> = Vec::new();
                attn_row(r, out_row, &mut scores);
            });
        }
        let proj = self.mm2(&attn, self.lwp(l, "wo", nh * dh * d)?, t,
                            nh * dh, d);
        Ok((add(x, &proj), k_new, v_new))
    }

    /// SwiGLU activations of the normalized post-attention state:
    /// `silu(h2 @ w_gate) * (h2 @ w_up)`, shape `[t, d_ffn]`.
    fn ffn_activations(&self, l: usize, h: &[f32], t: usize)
                       -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let h2 = self.rms(h, self.lw(l, "rms2", d)?, t, d);
        let gate =
            self.mm2(&h2, self.lwp(l, "w_gate", d * f)?, t, d, f);
        let up = self.mm2(&h2, self.lwp(l, "w_up", d * f)?, t, d, f);
        Ok(gate
            .iter()
            .zip(up.iter())
            .map(|(&g, &u)| silu(g) * u)
            .collect())
    }

    /// Down-projection restricted to the experts in `idx` (ascending),
    /// optionally gated per neuron by `alpha`:
    /// `y[r] = Σ_{j ∈ idx} alpha[j] * acts[r,j] * w_down[j]`.
    ///
    /// The dense FFN calls this with `idx == [0, d_ffn)` so the sparse
    /// and dense paths share one accumulation order — that is what makes
    /// `K == d_ffn` sparse output bit-identical to dense output.
    fn down_proj(&self, l: usize, acts: &[f32], t: usize, idx: &[i32],
                 alpha: Option<&[f32]>) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        for &ji in idx {
            anyhow::ensure!(
                ji >= 0 && (ji as usize) < f,
                "expert index {ji} out of range [0, {f})"
            );
        }
        if !self.reference {
            let w_down = self.lwp(l, "w_down", f * d)?;
            // The full-range ungated projection is exactly the matmul
            // `acts [t, f] @ w_down [f, d]` with the same per-element
            // accumulation order (ascending j), so route it through
            // the micro-tiled matmul kernel: unlike `down_proj_tiled`
            // (which streams `w_down` once per token row), it reuses
            // each weight panel row across `ROW_BLOCK` token rows —
            // the weight amortization that batched dense decode and
            // multi-row blocks are built on. Bit-identical by the
            // shared-order argument; the conformance suite pins it.
            let full = alpha.is_none()
                && idx.len() == f
                && idx.iter().enumerate().all(|(i, &j)| j as usize == i);
            if full {
                return Ok(self.mm2(acts, w_down, t, f, d));
            }
            return Ok(kernels::down_proj_tiled(
                acts, w_down, alpha, t, f, d, idx, &self.pool,
            ));
        }
        let w_down = self.lw(l, "w_down", f * d)?;
        let mut out = vec![0.0f32; t * d];
        for r in 0..t {
            for &ji in idx {
                let j = ji as usize;
                let a = acts[r * f + j]
                    * alpha.map_or(1.0, |al| al[j]);
                let wr = &w_down[j * d..(j + 1) * d];
                let or = &mut out[r * d..(r + 1) * d];
                for (o, &wv) in or.iter_mut().zip(wr.iter()) {
                    *o += a * wv;
                }
            }
        }
        Ok(out)
    }

    /// Sparse FFN restricted to `idx`, *no compensator* — the only FFN
    /// variant whose compute is sub-dense. The fast path gathers
    /// activations for selected neurons only (cost ∝ K); the reference
    /// path computes full activations and projects the same selection —
    /// identical values at dense cost.
    fn ffn_sparse_only(&self, l: usize, h: &[f32], t: usize, idx: &[i32])
                       -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        for &ji in idx {
            anyhow::ensure!(
                ji >= 0 && (ji as usize) < f,
                "expert index {ji} out of range [0, {f})"
            );
        }
        if self.reference {
            let acts = self.ffn_activations(l, h, t)?;
            return self.down_proj(l, &acts, t, idx, None);
        }
        let (gate_p, up_p) = self.gather_panels(l)?;
        let h2 = self.rms(h, self.lw(l, "rms2", d)?, t, d);
        let acts = kernels::gather_acts(
            &h2, gate_p, up_p, t, d, idx, self.simd(), &self.pool,
        );
        let w_down = self.lwp(l, "w_down", f * d)?;
        Ok(kernels::down_proj_compact(
            &acts, w_down, t, d, idx, &self.pool,
        ))
    }

    /// Block-aggregated predictor scores `[d_ffn]` from the low-rank
    /// expert predictor (`h2 @ wd @ wu`, then column-wise |·| sums —
    /// the trained predictor output the engine top-Ks on the host).
    fn predictor_scores(&self, l: usize, h: &[f32], t: usize)
                        -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let h2 = self.rms(h, self.lw(l, "rms2", d)?, t, d);
        let wd_numel: usize = self
            .weights
            .shape(&format!("pred.{l}.wd"))?
            .iter()
            .product();
        anyhow::ensure!(
            wd_numel > 0 && wd_numel % d == 0,
            "pred.{l}.wd: {wd_numel} elements not a multiple of \
             d_model {d}"
        );
        let rank = wd_numel / d;
        let wd = self.wp(&format!("pred.{l}.wd"), d * rank)?;
        let wu = self.wp(&format!("pred.{l}.wu"), rank * f)?;
        let z = self.mm2(&h2, wd, t, d, rank);
        let p = self.mm2(&z, wu, t, rank, f);
        let mut scores = vec![0.0f32; f];
        for r in 0..t {
            for j in 0..f {
                scores[j] += p[r * f + j].abs();
            }
        }
        Ok(scores)
    }

    /// Block-aggregated |activation| scores `[d_ffn]` (the GRIFFIN-style
    /// oracle statistic used by the ablation sources).
    fn activation_scores(&self, l: usize, h: &[f32], t: usize)
                         -> Result<Vec<f32>> {
        let f = self.manifest.model.d_ffn;
        let acts = self.ffn_activations(l, h, t)?;
        let mut scores = vec![0.0f32; f];
        for r in 0..t {
            for j in 0..f {
                scores[j] += acts[r * f + j].abs();
            }
        }
        Ok(scores)
    }

    fn alpha(&self, l: usize) -> Result<&[f32]> {
        self.w(&format!("comp.{l}.alpha"), self.manifest.model.d_ffn)
    }

    fn run_op(&self, op: Op, spec: &ExecutableSpec, layer: usize,
              inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>> {
        let m = &self.manifest.model;
        let (d, f, vocab) = (m.d_model, m.d_ffn, m.vocab);
        let exe = spec.name.as_str();
        match op {
            Op::Embed { t } => {
                let tokens = i32_input(inputs, exe, "tokens")?;
                anyhow::ensure!(tokens.len() == t, "{exe}: token count");
                let table = self.w("embed", vocab * d)?;
                let mut out = vec![0.0f32; t * d];
                for (r, &tok) in tokens.iter().enumerate() {
                    let id = (tok.max(0) as usize).min(vocab - 1);
                    out[r * d..(r + 1) * d]
                        .copy_from_slice(&table[id * d..(id + 1) * d]);
                }
                Ok(vec![Output { data: out }])
            }
            Op::LmHead { t } => {
                let x = f32_input(inputs, exe, "x")?;
                let xr = self.rms(x, self.w("final_rms", d)?, t, d);
                let logits = self.mm2(
                    &xr,
                    self.wp("lm_head", d * vocab)?,
                    t,
                    d,
                    vocab,
                );
                Ok(vec![Output { data: logits }])
            }
            Op::LayerDense { t, s, a } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc, a)?;
                let acts = self.ffn_activations(layer, &h, t)?;
                let all: Vec<i32> = (0..f as i32).collect();
                let y = self.down_proj(layer, &acts, t, &all, None)?;
                Ok(vec![
                    Output { data: add(&h, &y) },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::LayerSparse { k, t, s, a } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc, a)?;
                let scores = self.predictor_scores(layer, &h, t)?;
                let idx = top_k_indices(&scores, k.min(f));
                let acts = self.ffn_activations(layer, &h, t)?;
                let y = self.down_proj(layer, &acts, t, &idx, None)?;
                let comp = self.down_proj(
                    layer,
                    &acts,
                    t,
                    &complement(&idx, f),
                    Some(self.alpha(layer)?),
                )?;
                let mut out = add(&h, &y);
                add_assign(&mut out, &comp);
                Ok(vec![
                    Output { data: out },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::LayerSparseNc { k, t, s, a } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc, a)?;
                let scores = self.predictor_scores(layer, &h, t)?;
                let idx = top_k_indices(&scores, k.min(f));
                let y = self.ffn_sparse_only(layer, &h, t, &idx)?;
                Ok(vec![
                    Output { data: add(&h, &y) },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::LayerAttn { t, s } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                // the split ablation pipeline keeps dense attention
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc,
                                         None)?;
                Ok(vec![
                    Output { data: h },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::Predictor { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let scores = self.predictor_scores(layer, h, t)?;
                Ok(vec![Output { data: scores }])
            }
            Op::FfnActs { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let scores = self.activation_scores(layer, h, t)?;
                Ok(vec![Output { data: scores }])
            }
            Op::FfnDense { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let acts = self.ffn_activations(layer, h, t)?;
                let all: Vec<i32> = (0..f as i32).collect();
                let y = self.down_proj(layer, &acts, t, &all, None)?;
                Ok(vec![Output { data: add(h, &y) }])
            }
            Op::FfnSparseExt { k, t } => {
                let h = f32_input(inputs, exe, "h")?;
                let idx = i32_input(inputs, exe, "idx")?;
                anyhow::ensure!(
                    idx.len() == k,
                    "{exe}: idx has {} entries, compiled K is {k}",
                    idx.len()
                );
                let acts = self.ffn_activations(layer, h, t)?;
                let y = self.down_proj(layer, &acts, t, idx, None)?;
                let comp = self.down_proj(
                    layer,
                    &acts,
                    t,
                    &complement(idx, f),
                    Some(self.alpha(layer)?),
                )?;
                Ok(vec![Output { data: add(h, &y) }, Output { data: comp }])
            }
            Op::FfnSparseNc { k, t } => {
                let h = f32_input(inputs, exe, "h")?;
                let idx = i32_input(inputs, exe, "idx")?;
                anyhow::ensure!(
                    idx.len() == k,
                    "{exe}: idx has {} entries, compiled K is {k}",
                    idx.len()
                );
                let y = self.ffn_sparse_only(layer, h, t, idx)?;
                Ok(vec![Output { data: add(h, &y) }])
            }
        }
    }
}

impl CpuBackend {
    /// Whether every row of a batch is a fused transformer-layer op the
    /// batched kernel path understands (anything else — split-pipeline
    /// ops, embed/lm_head — falls back to sequential dispatch).
    fn batch_fusable(&self, rows: &[BatchRow<'_>]) -> bool {
        rows.iter().all(|r| {
            matches!(
                self.op_for(&r.spec.name),
                Ok(Op::LayerDense { .. }
                    | Op::LayerSparse { .. }
                    | Op::LayerSparseNc { .. })
            )
        })
    }

    /// The fused batched layer step behind continuous batching: the
    /// QKV/O projections and FFN weight passes run over the *stacked*
    /// row activations — one read of each weight panel for the whole
    /// batch — while attention, expert selection and sparse gathers
    /// stay strictly per row (each row reads only its own sequence's
    /// KV view and selects its own experts).
    ///
    /// Bit-identity with [`sequential_batch`] holds because every
    /// constituent kernel is row-independent with an unchanged
    /// per-element accumulation order: stacking rows into one matmul
    /// decides *which call* computes a row, never the sequence of f32
    /// additions behind any of its elements. The conformance suite
    /// (`tests/backend_conformance.rs`) pins this against the
    /// sequential reference oracle.
    fn run_batch_fused(&self, layer: usize, rows: &[BatchRow<'_>])
                       -> Result<Vec<BatchRowOut>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let (nh, nkv, dh) = (m.n_heads, m.n_kv_heads, m.d_head);
        anyhow::ensure!(nh % nkv == 0, "n_heads must be divisible by n_kv");
        let ops: Vec<Op> = rows
            .iter()
            .map(|r| self.op_for(&r.spec.name))
            .collect::<Result<_>>()?;
        for r in rows {
            anyhow::ensure!(
                r.pos + r.t <= r.s,
                "attention: pos {} + t {} exceeds bucket {}",
                r.pos,
                r.t,
                r.s
            );
        }

        // Row spans in the stacked [total, d] activation matrix.
        let total: usize = rows.iter().map(|r| r.t).sum();
        let mut offs = Vec::with_capacity(rows.len());
        {
            let mut o = 0usize;
            for r in rows {
                offs.push(o);
                o += r.t;
            }
        }

        // ---- shared attention projections over the stacked rows ----
        let mut x_all = vec![0.0f32; total * d];
        for (r, &o) in rows.iter().zip(&offs) {
            x_all[o * d..(o + r.t) * d].copy_from_slice(r.x);
        }
        let h1 = self.rms(&x_all, self.lw(layer, "rms1", d)?, total, d);
        let mut q = self.mm2(&h1, self.lwp(layer, "wq", d * nh * dh)?,
                             total, d, nh * dh);
        let mut k_new_all =
            self.mm2(&h1, self.lwp(layer, "wk", d * nkv * dh)?, total,
                     d, nkv * dh);
        let v_new_all =
            self.mm2(&h1, self.lwp(layer, "wv", d * nkv * dh)?, total,
                     d, nkv * dh);
        for (r, &o) in rows.iter().zip(&offs) {
            for lr in 0..r.t {
                let g = o + lr;
                rope_row(&mut q[g * nh * dh..(g + 1) * nh * dh], nh, dh,
                         r.pos + lr);
                rope_row(
                    &mut k_new_all[g * nkv * dh..(g + 1) * nkv * dh],
                    nkv,
                    dh,
                    r.pos + lr,
                );
            }
        }

        // ---- per-row attention over per-sequence KV views ----------
        // Block-sparse selection plans are computed sequentially here,
        // one per attention-sparse row, *before* the row-parallel loop
        // — so the selection (and hence every output bit) is invariant
        // under thread count, exactly as in the sequential dispatch.
        let ab = m.attn_block;
        let mut plans: Vec<Option<Vec<Vec<Vec<u32>>>>> =
            Vec::with_capacity(rows.len());
        for (i, (r, op)) in rows.iter().zip(&ops).enumerate() {
            let a = match op {
                Op::LayerDense { a, .. }
                | Op::LayerSparse { a, .. }
                | Op::LayerSparseNc { a, .. } => *a,
                _ => unreachable!("checked by batch_fusable"),
            };
            let span = offs[i];
            plans.push(self.attn_plan(
                a,
                &q[span * nh * dh..(span + r.t) * nh * dh],
                r.k_cache,
                &k_new_all[span * nkv * dh..(span + r.t) * nkv * dh],
                r.pos,
                r.t,
            )?);
        }
        let seq_of: Vec<usize> = rows
            .iter()
            .enumerate()
            .flat_map(|(i, r)| std::iter::repeat(i).take(r.t))
            .collect();
        let scale = 1.0 / (dh as f32).sqrt();
        let simd = self.simd();
        let mut attn = vec![0.0f32; total * nh * dh];
        {
            struct RowPtr(*mut f32);
            unsafe impl Send for RowPtr {}
            unsafe impl Sync for RowPtr {}
            let aptr = RowPtr(attn.as_mut_ptr());
            let row_elems = nh * dh;
            self.pool.run(total, |g| {
                let p = &aptr;
                // SAFETY: each task owns exactly row `g` of `attn`,
                // and the pool joins before `attn` is read.
                let out_row = unsafe {
                    std::slice::from_raw_parts_mut(
                        p.0.add(g * row_elems),
                        row_elems,
                    )
                };
                let i = seq_of[g];
                let r = &rows[i];
                let span = offs[i] * nkv * dh;
                let kn = &k_new_all[span..span + r.t * nkv * dh];
                let vn = &v_new_all[span..span + r.t * nkv * dh];
                let lr = g - offs[i];
                let mut scores: Vec<f32> = Vec::new();
                match &plans[i] {
                    Some(plan) => attn_query_row_sparse(
                        simd,
                        &q[g * nh * dh..(g + 1) * nh * dh],
                        r.k_cache,
                        r.v_cache,
                        kn,
                        vn,
                        r.pos,
                        lr,
                        nh,
                        nkv,
                        dh,
                        scale,
                        out_row,
                        &mut scores,
                        &plan[lr / ab],
                        ab,
                    ),
                    None => attn_query_row(
                        simd,
                        &q[g * nh * dh..(g + 1) * nh * dh],
                        r.k_cache,
                        r.v_cache,
                        kn,
                        vn,
                        r.pos,
                        lr,
                        nh,
                        nkv,
                        dh,
                        scale,
                        out_row,
                        &mut scores,
                    ),
                }
            });
        }
        let proj = self.mm2(&attn, self.lwp(layer, "wo", nh * dh * d)?,
                            total, nh * dh, d);
        let h = add(&x_all, &proj);

        // ---- FFN: stacked weight passes, per-row expert selection --
        let h2 = self.rms(&h, self.lw(layer, "rms2", d)?, total, d);

        let mut dense_rows = Vec::new();
        let mut comp_rows = Vec::new(); // fused sparse with compensator
        let mut nc_rows = Vec::new(); // fused sparse, sub-dense path
        for (i, op) in ops.iter().enumerate() {
            match op {
                Op::LayerDense { .. } => dense_rows.push(i),
                Op::LayerSparse { .. } => comp_rows.push(i),
                Op::LayerSparseNc { .. } => nc_rows.push(i),
                _ => unreachable!("checked by batch_fusable"),
            }
        }

        // Stack the h2 spans of a row group contiguously; returns the
        // stacked buffer, each row's offset within it, and its total
        // row count.
        let stack = |ids: &[usize]| -> (Vec<f32>, Vec<usize>, usize) {
            let mut tt = 0usize;
            let mut go = Vec::with_capacity(ids.len());
            for &i in ids {
                go.push(tt);
                tt += rows[i].t;
            }
            let mut buf = vec![0.0f32; tt * d];
            for (&i, &o) in ids.iter().zip(&go) {
                buf[o * d..(o + rows[i].t) * d].copy_from_slice(
                    &h2[offs[i] * d..(offs[i] + rows[i].t) * d],
                );
            }
            (buf, go, tt)
        };

        let mut y: Vec<Option<Vec<f32>>> = vec![None; rows.len()];
        let mut comp: Vec<Option<Vec<f32>>> = vec![None; rows.len()];

        // Dense rows: one shared gate/up/down pass. The down
        // projection over the full ascending index range routes
        // through the micro-tiled matmul (see `down_proj`), so all
        // three FFN weight panels are read once for the whole group.
        if !dense_rows.is_empty() {
            let (h2d, go, tt) = stack(&dense_rows);
            let gate = self.mm2(&h2d, self.lwp(layer, "w_gate", d * f)?,
                                tt, d, f);
            let up = self.mm2(&h2d, self.lwp(layer, "w_up", d * f)?, tt,
                              d, f);
            let acts: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            // the full-range ungated down projection IS the matmul
            // `acts @ w_down` (same ascending-j accumulation order —
            // see `down_proj`); dispatch the matmul directly instead
            // of materializing a 0..d_ffn index vector per pass
            let w_down = self.lwp(layer, "w_down", f * d)?;
            let yd = self.mm2(&acts, w_down, tt, f, d);
            for (&i, &o) in dense_rows.iter().zip(&go) {
                y[i] = Some(yd[o * d..(o + rows[i].t) * d].to_vec());
            }
        }

        // Predictor rows (both fused sparse flavours): one shared
        // low-rank predictor pass, then per-row span aggregation and
        // top-K — each row selects its own experts, exactly as its
        // sequential dispatch would.
        let pred_rows: Vec<usize> = ops
            .iter()
            .enumerate()
            .filter(|(_, op)| {
                matches!(op,
                         Op::LayerSparse { .. } | Op::LayerSparseNc { .. })
            })
            .map(|(i, _)| i)
            .collect();
        let mut idx_of: Vec<Option<Vec<i32>>> = vec![None; rows.len()];
        if !pred_rows.is_empty() {
            let (h2p, go, tt) = stack(&pred_rows);
            let wd_numel: usize = self
                .weights
                .shape(&format!("pred.{layer}.wd"))?
                .iter()
                .product();
            anyhow::ensure!(
                wd_numel > 0 && wd_numel % d == 0,
                "pred.{layer}.wd: {wd_numel} elements not a multiple \
                 of d_model {d}"
            );
            let rank = wd_numel / d;
            let wd = self.wp(&format!("pred.{layer}.wd"), d * rank)?;
            let wu = self.wp(&format!("pred.{layer}.wu"), rank * f)?;
            let z = self.mm2(&h2p, wd, tt, d, rank);
            let p = self.mm2(&z, wu, tt, rank, f);
            for (&i, &o) in pred_rows.iter().zip(&go) {
                let k = match ops[i] {
                    Op::LayerSparse { k, .. }
                    | Op::LayerSparseNc { k, .. } => k,
                    _ => unreachable!(),
                };
                let mut scores = vec![0.0f32; f];
                for lr in 0..rows[i].t {
                    for j in 0..f {
                        scores[j] += p[(o + lr) * f + j].abs();
                    }
                }
                idx_of[i] = Some(top_k_indices(&scores, k.min(f)));
            }
        }

        // Compensated sparse rows: full activations from one shared
        // gate/up pass, then per-row selected + complement-gated down
        // projections (dense cost by construction; conformance path).
        if !comp_rows.is_empty() {
            let (h2c, go, tt) = stack(&comp_rows);
            let gate = self.mm2(&h2c, self.lwp(layer, "w_gate", d * f)?,
                                tt, d, f);
            let up = self.mm2(&h2c, self.lwp(layer, "w_up", d * f)?, tt,
                              d, f);
            let acts: Vec<f32> = gate
                .iter()
                .zip(up.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            for (&i, &o) in comp_rows.iter().zip(&go) {
                let t = rows[i].t;
                let span = &acts[o * f..(o + t) * f];
                let idx = idx_of[i]
                    .as_ref()
                    .ok_or_else(|| anyhow!("row {i}: missing indices"))?;
                y[i] = Some(self.down_proj(layer, span, t, idx, None)?);
                comp[i] = Some(self.down_proj(
                    layer,
                    span,
                    t,
                    &complement(idx, f),
                    Some(self.alpha(layer)?),
                )?);
            }
        }

        // Sub-dense sparse rows: per-row gathers over the shared
        // transposed panels — cost scales with each row's K, and the
        // indices (hence the touched neurons) are per row.
        if !nc_rows.is_empty() {
            let (gate_p, up_p) = self.gather_panels(layer)?;
            let w_down = self.lwp(layer, "w_down", f * d)?;
            for &i in &nc_rows {
                let t = rows[i].t;
                let span = &h2[offs[i] * d..(offs[i] + t) * d];
                let idx = idx_of[i]
                    .as_ref()
                    .ok_or_else(|| anyhow!("row {i}: missing indices"))?;
                let acts = kernels::gather_acts(
                    span, gate_p, up_p, t, d, idx, simd, &self.pool,
                );
                y[i] = Some(kernels::down_proj_compact(
                    &acts, w_down, t, d, idx, &self.pool,
                ));
            }
        }

        // ---- per-row assembly: residual add (+ compensator) and the
        // fresh KV rows to scatter into each sequence's cache --------
        let mut out = Vec::with_capacity(rows.len());
        for (i, (r, &o)) in rows.iter().zip(&offs).enumerate() {
            let hs = &h[o * d..(o + r.t) * d];
            let yi = y[i]
                .take()
                .ok_or_else(|| anyhow!("row {i}: missing FFN output"))?;
            let mut yr = add(hs, &yi);
            if let Some(c) = comp[i].take() {
                add_assign(&mut yr, &c);
            }
            let span = o * nkv * dh;
            out.push(BatchRowOut {
                y: yr,
                k_new: k_new_all[span..span + r.t * nkv * dh].to_vec(),
                v_new: v_new_all[span..span + r.t * nkv * dh].to_vec(),
            });
        }
        Ok(out)
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn prepare(&self, spec: &ExecutableSpec) -> Result<()> {
        self.op_for(&spec.name).map(|_| ())
    }

    fn prepared_count(&self) -> usize {
        self.ops.borrow().len()
    }

    fn execute(&self, spec: &ExecutableSpec, layer: usize,
               inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>> {
        let op = self.op_for(&spec.name)?;
        let t0 = Instant::now();
        let out = self.run_op(op, spec, layer, inputs)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_time += t0.elapsed();
        Ok(out)
    }

    fn execute_batch(&self, layer: usize, rows: &[BatchRow<'_>])
                     -> Result<Vec<BatchRowOut>> {
        // The reference oracle keeps the sequential semantics verbatim
        // (per-row dispatch, per-row stats); so does any batch the
        // fused path does not understand.
        if self.reference || !self.batch_fusable(rows) {
            return sequential_batch(self, layer, rows);
        }
        let t0 = Instant::now();
        let out = self.run_batch_fused(layer, rows)?;
        let mut s = self.stats.borrow_mut();
        // one fused pass still executes one layer step per row
        s.executions += rows.len() as u64;
        s.execute_time += t0.elapsed();
        Ok(out)
    }

    fn stats(&self) -> DispatchStats {
        self.stats.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Rng;

    #[test]
    fn name_parsing() {
        assert_eq!(parse_op("embed_t128").unwrap(), Op::Embed { t: 128 });
        assert_eq!(parse_op("lm_head_t1").unwrap(), Op::LmHead { t: 1 });
        assert_eq!(
            parse_op("layer_dense_t128_s512").unwrap(),
            Op::LayerDense { t: 128, s: 512, a: None }
        );
        assert_eq!(
            parse_op("layer_sparse_k64_t1_s256").unwrap(),
            Op::LayerSparse { k: 64, t: 1, s: 256, a: None }
        );
        assert_eq!(
            parse_op("layer_sparse_nc_k64_t128_s256").unwrap(),
            Op::LayerSparseNc { k: 64, t: 128, s: 256, a: None }
        );
        assert_eq!(
            parse_op("ffn_sparse_ext_k96_t128").unwrap(),
            Op::FfnSparseExt { k: 96, t: 128 }
        );
        assert_eq!(
            parse_op("ffn_sparse_nc_k96_t128").unwrap(),
            Op::FfnSparseNc { k: 96, t: 128 }
        );
        assert_eq!(
            parse_op("ffn_acts_t128").unwrap(),
            Op::FfnActs { t: 128 }
        );
        assert!(parse_op("warp_drive_t4").is_err());
        assert!(parse_op("layer_dense_t128").is_err(), "missing s");
    }

    /// The `a{pct}` attention-sparsity segment parses on the fused
    /// layer ops — including `a0`, a *distinct* name from the base
    /// (sparse machinery at full coverage vs the untouched dense
    /// path) — and names with an `attn`/`acts` segment still route the
    /// non-numeric segment into the base, not the `a` slot.
    #[test]
    fn name_parsing_attn_sparsity() {
        assert_eq!(
            parse_op("layer_dense_a50_t128_s512").unwrap(),
            Op::LayerDense { t: 128, s: 512, a: Some(50) }
        );
        assert_eq!(
            parse_op("layer_dense_a0_t128_s512").unwrap(),
            Op::LayerDense { t: 128, s: 512, a: Some(0) }
        );
        assert_eq!(
            parse_op("layer_sparse_a25_k64_t128_s256").unwrap(),
            Op::LayerSparse { k: 64, t: 128, s: 256, a: Some(25) }
        );
        assert_eq!(
            parse_op("layer_sparse_nc_a100_k64_t128_s256").unwrap(),
            Op::LayerSparseNc { k: 64, t: 128, s: 256, a: Some(100) }
        );
        // `attn` / `acts` start with 'a' but are not digit tails —
        // they stay in the base name exactly as before
        assert_eq!(
            parse_op("layer_attn_t128_s512").unwrap(),
            Op::LayerAttn { t: 128, s: 512 }
        );
        assert_eq!(
            parse_op("ffn_acts_t128").unwrap(),
            Op::FfnActs { t: 128 }
        );
    }

    #[test]
    fn complement_partitions_the_expert_set() {
        let idx = vec![0, 3, 4];
        let rest = complement(&idx, 6);
        assert_eq!(rest, vec![1, 2, 5]);
        assert_eq!(complement(&[], 3), vec![0, 1, 2]);
        assert_eq!(complement(&[0, 1, 2], 3), Vec::<i32>::new());
    }

    #[test]
    fn matmul_matches_hand_computed() {
        // [2,3] @ [3,2]
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, &w, 2, 3, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn transpose_roundtrips() {
        let w: Vec<f32> = (0..6).map(|v| v as f32).collect(); // [2,3]
        let wt = transpose(&w, 2, 3); // [3,2]
        assert_eq!(wt, vec![0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        assert_eq!(transpose(&wt, 3, 2), w);
    }

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = [3.0f32, 4.0, 0.0, 0.0];
        let gain = [1.0f32; 4];
        let y = rmsnorm_rows(&x, &gain, 1, 4);
        let ms: f32 = y.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3, "normalized mean square: {ms}");
    }

    #[test]
    fn rope_preserves_norm() {
        let mut row = vec![1.0f32, 0.0, 0.5, -0.5];
        let before: f32 = row.iter().map(|v| v * v).sum();
        rope_row(&mut row, 1, 4, 37);
        let after: f32 = row.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-5);
        // position 0 is the identity rotation
        let mut row0 = vec![1.0f32, 2.0, 3.0, 4.0];
        rope_row(&mut row0, 1, 4, 0);
        assert_eq!(row0, vec![1.0, 2.0, 3.0, 4.0]);
    }

    // -----------------------------------------------------------------
    // kernel property suite: tiled/gathered kernels vs the naive loops,
    // asserted *bit-identical* (same per-element accumulation order)
    // -----------------------------------------------------------------

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 0.5) as f32).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str)
                      -> Result<(), String> {
        if a.len() != b.len() {
            return Err(format!("{what}: length {} vs {}", a.len(),
                               b.len()));
        }
        for i in 0..a.len() {
            if a[i].to_bits() != b[i].to_bits() {
                return Err(format!(
                    "{what}: element {i} differs ({} vs {})", a[i], b[i]
                ));
            }
        }
        Ok(())
    }

    /// Random distinct ascending indices from [0, f), length k.
    fn rand_idx(rng: &mut Rng, f: usize, k: usize) -> Vec<i32> {
        let mut idx: Vec<usize> = rng.choose_k(f, k);
        idx.sort_unstable();
        idx.into_iter().map(|j| j as i32).collect()
    }

    #[test]
    fn prop_tiled_matmul_is_bit_identical_to_naive() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            proptest::check("tiled-matmul", 40, |rng| {
                // shapes straddling tile boundaries, incl. T=1 and
                // ragged tails not divisible by ROW_CHUNK/COL_TILE
                let t = [1, 2, 7, 16, 17, 33][rng.range(0, 6)];
                let m = rng.range(1, 70);
                let n = [1, 3, 31, 64, 127, 128, 129, 200]
                    [rng.range(0, 8)];
                let x = rand_vec(rng, t * m);
                let w = rand_vec(rng, m * n);
                let naive = matmul(&x, &w, t, m, n);
                let tiled =
                    kernels::matmul_tiled(&x, &w, t, m, n, &pool);
                assert_bits_eq(&naive, &tiled,
                               &format!("t={t} m={m} n={n}"))
            });
        }
    }

    #[test]
    fn prop_gather_kernels_match_full_activation_path() {
        let pool = ThreadPool::new(3);
        proptest::check("gather-ffn", 30, |rng| {
            let t = [1, 2, 5, 17][rng.range(0, 4)];
            let d = rng.range(4, 24);
            let f = rng.range(8, 80);
            let k = match rng.range(0, 4) {
                0 => 0,           // K = 0 edge
                1 => f,           // K = d_ffn edge
                _ => rng.range(1, f + 1),
            };
            let h2 = rand_vec(rng, t * d);
            let gate = rand_vec(rng, d * f);
            let up = rand_vec(rng, d * f);
            let w_down = rand_vec(rng, f * d);
            let idx = rand_idx(rng, f, k);

            // naive path: full dense activations → naive down_proj
            let g_full = matmul(&h2, &gate, t, d, f);
            let u_full = matmul(&h2, &up, t, d, f);
            let acts_full: Vec<f32> = g_full
                .iter()
                .zip(u_full.iter())
                .map(|(&g, &u)| silu(g) * u)
                .collect();
            let mut naive = vec![0.0f32; t * d];
            for r in 0..t {
                for &ji in &idx {
                    let j = ji as usize;
                    let a = acts_full[r * f + j];
                    for c in 0..d {
                        naive[r * d + c] += a * w_down[j * d + c];
                    }
                }
            }

            // gathered path over transposed weights
            let gate_t = transpose(&gate, d, f);
            let up_t = transpose(&up, d, f);
            let acts = kernels::gather_acts(
                &h2,
                kernels::Panel::F32(&gate_t),
                kernels::Panel::F32(&up_t),
                t,
                d,
                &idx,
                false,
                &pool,
            );
            // gathered compact activations == the selected columns
            for r in 0..t {
                for (jj, &ji) in idx.iter().enumerate() {
                    let want = acts_full[r * f + ji as usize];
                    let got = acts[r * idx.len() + jj];
                    if want.to_bits() != got.to_bits() {
                        return Err(format!(
                            "acts[{r},{jj}] {got} != {want}"
                        ));
                    }
                }
            }
            let got = kernels::down_proj_compact(
                &acts,
                kernels::Panel::F32(&w_down),
                t,
                d,
                &idx,
                &pool,
            );
            assert_bits_eq(&naive, &got,
                           &format!("t={t} d={d} f={f} k={k}"))?;

            // the full-width tiled down_proj agrees too (with alpha)
            let alpha = rand_vec(rng, f);
            let mut naive_a = vec![0.0f32; t * d];
            for r in 0..t {
                for &ji in &idx {
                    let j = ji as usize;
                    let a = acts_full[r * f + j] * alpha[j];
                    for c in 0..d {
                        naive_a[r * d + c] += a * w_down[j * d + c];
                    }
                }
            }
            let got_a = kernels::down_proj_tiled(
                &acts_full,
                kernels::Panel::F32(&w_down),
                Some(&alpha),
                t,
                f,
                d,
                &idx,
                &pool,
            );
            assert_bits_eq(&naive_a, &got_a, "down_proj_tiled+alpha")
        });
    }

    // -----------------------------------------------------------------
    // SIMD kernel tier properties. The register-tiled matmul preserves
    // the per-element ascending-i order (bitwise vs naive; the tier's
    // re-association lives in lane_dot), lane_dot is a pure function
    // of its operands (bitwise thread/rerun-invariant) within a small
    // ULP envelope of the sequential dot, and the bf16 kernel is
    // bitwise the f32 SIMD kernel over widened weights.
    // -----------------------------------------------------------------

    /// Pass/fail for the kernel-level ULP envelope: within
    /// `max_ulp` ULPs or `abs` absolute difference.
    fn within_ulp(a: f32, b: f32, max_ulp: u64, abs: f32) -> bool {
        crate::testing::ulp_distance(a, b) <= max_ulp
            || (a - b).abs() <= abs
    }

    #[test]
    fn prop_simd_matmul_is_order_preserving_and_thread_invariant() {
        let pools: Vec<ThreadPool> =
            [1, 2, 4].iter().map(|&t| ThreadPool::new(t)).collect();
        proptest::check("simd-matmul", 40, |rng| {
            let t = [1, 2, 7, 16, 17, 33][rng.range(0, 6)];
            let m = rng.range(1, 70);
            let n = [1, 3, 31, 64, 127, 128, 129, 200][rng.range(0, 8)];
            let x = rand_vec(rng, t * m);
            let w = rand_vec(rng, m * n);
            let naive = matmul(&x, &w, t, m, n);
            let base =
                kernels::matmul_tiled_simd(&x, &w, t, m, n, &pools[0]);
            // per-element reduction order is unchanged → bitwise
            assert_bits_eq(&naive, &base,
                           &format!("simd vs naive t={t} m={m} n={n}"))?;
            for pool in &pools[1..] {
                let other =
                    kernels::matmul_tiled_simd(&x, &w, t, m, n, pool);
                assert_bits_eq(&base, &other, "simd thread-invariance")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_lane_dot_within_ulp_of_sequential_dot() {
        proptest::check("lane-dot", 60, |rng| {
            let n = [1, 7, 8, 9, 16, 23, 64, 100, 257][rng.range(0, 9)];
            let a = rand_vec(rng, n);
            let b = rand_vec(rng, n);
            let seq: f32 =
                a.iter().zip(b.iter()).map(|(x, y)| x * y).sum();
            let lane = kernels::lane_dot(&a, &b);
            // absolute floor scales with the mass of the summands so a
            // cancelling sum (seq ≈ 0, huge relative error) still passes
            let mass: f32 = a
                .iter()
                .zip(b.iter())
                .map(|(x, y)| (x * y).abs())
                .sum();
            let floor = 1e-5f32.max(1e-6 * mass);
            if !within_ulp(seq, lane, 512, floor) {
                return Err(format!(
                    "n={n}: lane {lane} vs seq {seq} ({} ulp)",
                    crate::testing::ulp_distance(seq, lane)
                ));
            }
            // pure function of the operands: rerun is bitwise
            if lane.to_bits() != kernels::lane_dot(&a, &b).to_bits() {
                return Err(format!("n={n}: lane_dot not deterministic"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_bf16_matmul_matches_simd_over_widened_weights() {
        use crate::weights::{bf16_to_f32, f32_to_bf16};
        let pool = ThreadPool::new(2);
        proptest::check("bf16-matmul", 30, |rng| {
            let t = [1, 3, 17][rng.range(0, 3)];
            let m = rng.range(1, 50);
            let n = [1, 31, 128, 130][rng.range(0, 4)];
            let x = rand_vec(rng, t * m);
            let raw: Vec<u16> = rand_vec(rng, m * n)
                .iter()
                .map(|&v| f32_to_bf16(v))
                .collect();
            let wide: Vec<f32> =
                raw.iter().map(|&bb| bf16_to_f32(bb)).collect();
            let a = kernels::matmul_tiled_simd(&x, &wide, t, m, n, &pool);
            let b = kernels::matmul_tiled_bf16(&x, &raw, t, m, n, &pool);
            // widening is exact → streaming raw bf16 changes nothing
            assert_bits_eq(&a, &b, &format!("t={t} m={m} n={n}"))
        });
    }

    #[test]
    fn prop_int8_matmul_matches_simd_over_dequantized_weights() {
        use crate::weights::quantize_int8;
        let pools: Vec<ThreadPool> =
            [1, 2, 4].iter().map(|&t| ThreadPool::new(t)).collect();
        proptest::check("int8-matmul", 30, |rng| {
            let t = [1, 3, 17][rng.range(0, 3)];
            let m = rng.range(1, 50);
            let n = [1, 31, 128, 130, 257][rng.range(0, 5)];
            let x = rand_vec(rng, t * m);
            let w = rand_vec(rng, m * n);
            let (q, scales) = quantize_int8(&w, m, n);
            // `q as f32 * scale` yields the same f32 for every
            // task/thread split, so the int8 kernel must be bitwise
            // the f32 SIMD kernel over the dequantized panel
            let wide: Vec<f32> = q
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    let (r, col) = (i / n, i % n);
                    c as f32
                        * scales[r * n.div_ceil(kernels::COL_TILE)
                            + col / kernels::COL_TILE]
                })
                .collect();
            let a = kernels::matmul_tiled_simd(&x, &wide, t, m, n,
                                               &pools[0]);
            let b = kernels::matmul_tiled_int8(&x, &q, &scales, t, m, n,
                                               &pools[0]);
            assert_bits_eq(&a, &b, &format!("t={t} m={m} n={n}"))?;
            // thread-invariant like every other kernel tier
            for pool in &pools[1..] {
                let c = kernels::matmul_tiled_int8(&x, &q, &scales, t,
                                                   m, n, pool);
                assert_bits_eq(&b, &c, "int8 thread-invariance")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_int8_gather_and_down_proj_are_deterministic_and_close() {
        use crate::weights::quantize_int8;
        let pools: Vec<ThreadPool> =
            [1, 2, 4].iter().map(|&t| ThreadPool::new(t)).collect();
        proptest::check("int8-gather", 20, |rng| {
            let t = rng.range(1, 5);
            let d = [8, 64, 130, 200][rng.range(0, 4)];
            let f = rng.range(4, 40);
            let k = rng.range(1, f + 1);
            let x = rand_vec(rng, t * d);
            let gate_t = rand_vec(rng, f * d);
            let up_t = rand_vec(rng, f * d);
            let idx = rand_idx(rng, f, k);
            let (gq, gs) = quantize_int8(&gate_t, f, d);
            let (uq, us) = quantize_int8(&up_t, f, d);
            let gp = kernels::Panel::I8 { q: &gq, scales: &gs, cols: d };
            let up = kernels::Panel::I8 { q: &uq, scales: &us, cols: d };
            let base = kernels::gather_acts(&x, gp, up, t, d, &idx,
                                            true, &pools[0]);
            // quantization error bounded → close to the f32 gather
            let f32acts = kernels::gather_acts(
                &x,
                kernels::Panel::F32(&gate_t),
                kernels::Panel::F32(&up_t),
                t,
                d,
                &idx,
                true,
                &pools[0],
            );
            for i in 0..base.len() {
                let (a, b) = (f32acts[i], base[i]);
                let tol = 0.05f32.max(0.05 * a.abs().max(b.abs()));
                if (a - b).abs() > tol {
                    return Err(format!(
                        "gather[{i}]: int8 {b} vs f32 {a}"
                    ));
                }
            }
            // deterministic + thread-invariant (bitwise within tier)
            for pool in &pools[1..] {
                let other = kernels::gather_acts(&x, gp, up, t, d, &idx,
                                                 true, pool);
                for i in 0..base.len() {
                    if base[i].to_bits() != other[i].to_bits() {
                        return Err(format!(
                            "gather[{i}] thread-variant"
                        ));
                    }
                }
            }
            // compact down-proj over an int8 panel: bitwise equal to
            // the same kernel over the dequantized panel (one scale
            // per COL_TILE slice → identical widened values), and
            // thread-invariant
            let w_down = rand_vec(rng, f * d);
            let (dq, ds) = quantize_int8(&w_down, f, d);
            let wide: Vec<f32> = dq
                .iter()
                .enumerate()
                .map(|(i, &c)| {
                    let (r, col) = (i / d, i % d);
                    c as f32
                        * ds[r * d.div_ceil(kernels::COL_TILE)
                            + col / kernels::COL_TILE]
                })
                .collect();
            let dp = kernels::Panel::I8 { q: &dq, scales: &ds, cols: d };
            let y8 = kernels::down_proj_compact(&base, dp, t, d, &idx,
                                                &pools[0]);
            let yw = kernels::down_proj_compact(
                &base,
                kernels::Panel::F32(&wide),
                t,
                d,
                &idx,
                &pools[0],
            );
            assert_bits_eq(&yw, &y8, "down_proj_compact int8 vs wide")?;
            for pool in &pools[1..] {
                let yo = kernels::down_proj_compact(&base, dp, t, d,
                                                    &idx, pool);
                assert_bits_eq(&y8, &yo, "down_proj thread-invariance")?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_simd_rmsnorm_and_gather_within_ulp_of_scalar() {
        let pool = ThreadPool::new(2);
        proptest::check("simd-rmsnorm-gather", 30, |rng| {
            let t = rng.range(1, 6);
            let d = [4, 8, 15, 64, 100][rng.range(0, 5)];
            let x = rand_vec(rng, t * d);
            let gain = rand_vec(rng, d);
            let a = rmsnorm_rows(&x, &gain, t, d);
            let b = rmsnorm_rows_simd(&x, &gain, t, d);
            for i in 0..a.len() {
                if !within_ulp(a[i], b[i], 512, 1e-5) {
                    return Err(format!(
                        "rmsnorm[{i}]: {} vs {} ({} ulp)", a[i], b[i],
                        crate::testing::ulp_distance(a[i], b[i])
                    ));
                }
            }
            let f = rng.range(4, 40);
            let k = rng.range(1, f + 1);
            let gate_t = rand_vec(rng, f * d);
            let up_t = rand_vec(rng, f * d);
            let idx = rand_idx(rng, f, k);
            let sc = kernels::gather_acts(
                &x,
                kernels::Panel::F32(&gate_t),
                kernels::Panel::F32(&up_t),
                t,
                d,
                &idx,
                false,
                &pool,
            );
            let sv = kernels::gather_acts(
                &x,
                kernels::Panel::F32(&gate_t),
                kernels::Panel::F32(&up_t),
                t,
                d,
                &idx,
                true,
                &pool,
            );
            for i in 0..sc.len() {
                if !within_ulp(sc[i], sv[i], 512, 1e-4) {
                    return Err(format!(
                        "gather[{i}]: {} vs {} ({} ulp)", sc[i], sv[i],
                        crate::testing::ulp_distance(sc[i], sv[i])
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cpu_kernel_parse_env_and_reference_pinning() {
        assert_eq!(CpuKernel::parse("simd"), Some(CpuKernel::Simd));
        assert_eq!(CpuKernel::parse("SIMD"), Some(CpuKernel::Simd));
        assert_eq!(CpuKernel::parse("scalar"), Some(CpuKernel::Scalar));
        assert_eq!(CpuKernel::parse("avx512"), None);
        for k in [CpuKernel::Scalar, CpuKernel::Simd] {
            assert_eq!(CpuKernel::parse(k.label()), Some(k));
        }
        // reference mode pins the oracle to scalar even when SIMD is
        // requested explicitly
        let opts = CpuOptions {
            threads: 1,
            reference: true,
            kernel: Some(CpuKernel::Simd),
        };
        assert_eq!(opts.resolved_kernel(), CpuKernel::Scalar);
        let opts = CpuOptions {
            threads: 0,
            reference: false,
            kernel: Some(CpuKernel::Simd),
        };
        assert_eq!(opts.resolved_kernel(), CpuKernel::Simd);
    }

    #[test]
    fn fast_and_reference_backends_agree_on_one_dispatch() {
        use crate::manifest::SyntheticSpec;
        let spec = SyntheticSpec::default();
        let manifest = Arc::new(Manifest::synthetic(&spec));
        let weights =
            Arc::new(WeightStore::seeded(&manifest, spec.seed));
        let fast = CpuBackend::with_options(
            manifest.clone(),
            weights.clone(),
            CpuOptions {
                threads: 4,
                reference: false,
                kernel: Some(CpuKernel::Scalar),
            },
        )
        .unwrap();
        let refr =
            CpuBackend::reference(manifest.clone(), weights).unwrap();
        assert!(refr.is_reference() && !fast.is_reference());
        assert_eq!(refr.threads(), 1);
        let block = manifest.model.block;
        let name = format!("embed_t{block}");
        let spec_e = manifest.executables.get(&name).unwrap();
        let tokens: Vec<i32> = (0..block as i32).collect();
        let inputs = [("tokens", Input::I32(&tokens, vec![block]))];
        let a = fast.execute(spec_e, 0, &inputs).unwrap();
        let b = refr.execute(spec_e, 0, &inputs).unwrap();
        assert_eq!(a[0].data, b[0].data);
    }
}
