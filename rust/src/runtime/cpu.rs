//! Pure-Rust reference backend: a dependency-free, deterministic
//! interpreter for the small op set the artifact ABI names.
//!
//! Every executable the engine can dispatch —
//!
//! * `embed_t{T}` / `lm_head_t{T}` — token embedding and LM head,
//! * `layer_dense_t{T}_s{S}` — RMSNorm → GQA causal attention (RoPE) →
//!   RMSNorm → dense SwiGLU FFN, with residual adds,
//! * `layer_sparse_k{K}_t{T}_s{S}` — the fused sparse layer: predictor
//!   scores → host top-K → gather-indexed sparse FFN → compensator,
//! * `layer_attn_t{T}_s{S}` / `predictor_t{T}` / `ffn_acts_t{T}` /
//!   `ffn_dense_t{T}` / `ffn_sparse_ext_k{K}_t{T}` — the split ablation
//!   pipeline
//!
//! — is interpreted directly over the [`WeightStore`], with no PJRT, no
//! artifacts on disk, and no floating-point reordering: plain sequential
//! f32 accumulation, so two runs of the same trace produce **byte-
//! identical** logits. That determinism is the foundation of the
//! always-on numeric test tier (see docs/TESTING.md).
//!
//! Reference-semantics notes:
//!
//! * The sparse FFN iterates its (ascending) expert indices with the
//!   same accumulation loop as the dense FFN, so `K == d_ffn` sparse
//!   output is *bit-identical* to dense output — the strongest form of
//!   the paper's "sparsity is exact at full K" sanity invariant.
//! * The compensator is modeled as a per-layer learned gate `alpha`
//!   applied to the *dropped* neurons' true contributions: zero when
//!   nothing is dropped, and (with seeded `alpha` strictly inside
//!   (0, 1)) it strictly shrinks the sparse FFN error — both properties
//!   hold by construction and are asserted by the test suite. The AOT
//!   compensator is a trained low-rank net; the reference keeps its
//!   *contract* in an exactly-testable form.

#![allow(clippy::needless_range_loop)]

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::manifest::{ExecutableSpec, Manifest};
use crate::sparsity::masks::top_k_indices;
use crate::weights::WeightStore;

use super::backend::Backend;
use super::{DispatchStats, Input, Output};

/// RMSNorm epsilon (matches python/compile's model).
const RMS_EPS: f32 = 1e-5;
/// RoPE base frequency.
const ROPE_THETA: f64 = 10000.0;

/// One parsed executable name.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Embed { t: usize },
    LmHead { t: usize },
    LayerDense { t: usize, s: usize },
    LayerSparse { k: usize, t: usize, s: usize },
    LayerAttn { t: usize, s: usize },
    Predictor { t: usize },
    FfnActs { t: usize },
    FfnDense { t: usize },
    FfnSparseExt { k: usize, t: usize },
}

/// Split `name` into its base and its `t`/`s`/`k` parameters
/// (`layer_sparse_k64_t128_s512` → ("layer_sparse", k=64, t=128, s=512)).
fn parse_name(name: &str) -> Option<(String, [Option<usize>; 3])> {
    let mut base: Vec<&str> = Vec::new();
    let mut tsk: [Option<usize>; 3] = [None, None, None];
    for seg in name.split('_') {
        let mut chars = seg.chars();
        let head = chars.next()?;
        let rest: &str = &seg[head.len_utf8()..];
        let slot = match head {
            't' => 0,
            's' => 1,
            'k' => 2,
            _ => 3,
        };
        if slot < 3
            && !rest.is_empty()
            && rest.bytes().all(|b| b.is_ascii_digit())
        {
            tsk[slot] = rest.parse().ok();
        } else {
            base.push(seg);
        }
    }
    Some((base.join("_"), tsk))
}

fn parse_op(name: &str) -> Result<Op> {
    let (base, [t, s, k]) =
        parse_name(name).ok_or_else(|| anyhow!("bad exe name {name}"))?;
    let need = |v: Option<usize>, what: &str| {
        v.ok_or_else(|| anyhow!("{name}: missing {what} parameter"))
    };
    Ok(match base.as_str() {
        "embed" => Op::Embed { t: need(t, "t")? },
        "lm_head" => Op::LmHead { t: need(t, "t")? },
        "layer_dense" => Op::LayerDense {
            t: need(t, "t")?,
            s: need(s, "s")?,
        },
        "layer_sparse" => Op::LayerSparse {
            k: need(k, "k")?,
            t: need(t, "t")?,
            s: need(s, "s")?,
        },
        "layer_attn" => Op::LayerAttn {
            t: need(t, "t")?,
            s: need(s, "s")?,
        },
        "predictor" => Op::Predictor { t: need(t, "t")? },
        "ffn_acts" => Op::FfnActs { t: need(t, "t")? },
        "ffn_dense" => Op::FfnDense { t: need(t, "t")? },
        "ffn_sparse_ext" => Op::FfnSparseExt {
            k: need(k, "k")?,
            t: need(t, "t")?,
        },
        other => {
            return Err(anyhow!("cpu backend: unknown executable {other}"))
        }
    })
}

fn f32_input<'a>(inputs: &[(&str, Input<'a>)], exe: &str, name: &str)
                 -> Result<&'a [f32]> {
    for (n, v) in inputs {
        if *n == name {
            if let Input::F32(d, _) = v {
                return Ok(*d);
            }
            return Err(anyhow!("{exe}: input '{name}' must be f32"));
        }
    }
    Err(anyhow!("{exe}: missing input '{name}'"))
}

fn i32_input<'a>(inputs: &[(&str, Input<'a>)], exe: &str, name: &str)
                 -> Result<&'a [i32]> {
    for (n, v) in inputs {
        if *n == name {
            if let Input::I32(d, _) = v {
                return Ok(*d);
            }
            return Err(anyhow!("{exe}: input '{name}' must be i32"));
        }
    }
    Err(anyhow!("{exe}: missing input '{name}'"))
}

/// Row-wise RMSNorm: `y[r,c] = x[r,c] * inv_rms(row r) * gain[c]`.
fn rmsnorm_rows(x: &[f32], gain: &[f32], t: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &x[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for c in 0..d {
            out[r * d + c] = row[c] * inv * gain[c];
        }
    }
    out
}

/// `x [t, m] @ w [m, n] -> [t, n]`, plain sequential accumulation.
fn matmul(x: &[f32], w: &[f32], t: usize, m: usize, n: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), t * m);
    debug_assert_eq!(w.len(), m * n);
    let mut out = vec![0.0f32; t * n];
    for r in 0..t {
        let xr = &x[r * m..(r + 1) * m];
        let or = &mut out[r * n..(r + 1) * n];
        for (i, &xv) in xr.iter().enumerate() {
            let wr = &w[i * n..(i + 1) * n];
            for (o, &wv) in or.iter_mut().zip(wr.iter()) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// Element-wise `a + b`.
fn add(a: &[f32], b: &[f32]) -> Vec<f32> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| x + y).collect()
}

fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += y;
    }
}

fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Rotary position embedding applied in place to one `[heads * dh]` row
/// at absolute position `p`.
fn rope_row(row: &mut [f32], heads: usize, dh: usize, p: usize) {
    for h in 0..heads {
        let base = h * dh;
        for i in 0..dh / 2 {
            let freq =
                1.0 / ROPE_THETA.powf(2.0 * i as f64 / dh as f64);
            let angle = p as f64 * freq;
            let (sin, cos) = angle.sin_cos();
            let a = row[base + 2 * i] as f64;
            let b = row[base + 2 * i + 1] as f64;
            row[base + 2 * i] = (a * cos - b * sin) as f32;
            row[base + 2 * i + 1] = (a * sin + b * cos) as f32;
        }
    }
}

/// Expert indices *not* selected, ascending (the compensator's domain).
fn complement(idx: &[i32], f: usize) -> Vec<i32> {
    let mut present = vec![false; f];
    for &ji in idx {
        if ji >= 0 && (ji as usize) < f {
            present[ji as usize] = true;
        }
    }
    (0..f as i32)
        .filter(|&j| !present[j as usize])
        .collect()
}

/// The pure-Rust deterministic backend. See the module docs for the
/// op-set and reference-semantics contract.
pub struct CpuBackend {
    manifest: Rc<Manifest>,
    weights: Rc<WeightStore>,
    /// Parsed-op cache (name → [`Op`]): names parse once, and the map
    /// doubles as the "prepared executables" set.
    ops: RefCell<HashMap<String, Op>>,
    stats: RefCell<DispatchStats>,
}

impl CpuBackend {
    /// Build the interpreter over a manifest + weight store — in
    /// practice [`Manifest::synthetic`] +
    /// [`WeightStore::seeded`]. Validates that the weight table
    /// follows the reference naming convention the interpreter
    /// dispatches against (AOT artifact bundles do *not*: their fused
    /// low-rank predictor/compensator networks are PJRT-only, and
    /// construction fails fast here with a clear error).
    pub fn new(manifest: Rc<Manifest>, weights: Rc<WeightStore>)
               -> Result<Self> {
        for name in ["embed", "final_rms", "lm_head", "layers.0.wq",
                     "layers.0.rms1"] {
            weights.get(name).map_err(|_| {
                anyhow!(
                    "cpu backend: weight table missing '{name}' — the \
                     interpreter requires the ff weight naming convention"
                )
            })?;
        }
        Ok(CpuBackend {
            manifest,
            weights,
            ops: RefCell::new(HashMap::new()),
            stats: RefCell::new(DispatchStats::default()),
        })
    }

    /// Parse (and cache) the op an executable name denotes. Steady-state
    /// dispatch is a single map lookup — no re-parse, no allocation.
    fn op_for(&self, name: &str) -> Result<Op> {
        if let Some(op) = self.ops.borrow().get(name) {
            return Ok(*op);
        }
        let op = parse_op(name)?;
        self.ops.borrow_mut().insert(name.to_string(), op);
        Ok(op)
    }

    /// Fetch a weight slice, validating its element count.
    fn w(&self, name: &str, expect: usize) -> Result<&[f32]> {
        let data = self.weights.get(name)?;
        anyhow::ensure!(
            data.len() == expect,
            "weight {name}: {} elements, interpreter expects {expect}",
            data.len()
        );
        Ok(data)
    }

    fn lw(&self, l: usize, role: &str, expect: usize) -> Result<&[f32]> {
        self.w(&format!("layers.{l}.{role}"), expect)
    }

    /// RMSNorm(x, rms1) → QKV (+ RoPE) → causal GQA attention → output
    /// projection → residual. Returns `(h, k_new, v_new)` where `h` is
    /// the post-attention residual stream `x + attn_out @ wo`.
    #[allow(clippy::too_many_arguments)]
    fn attention_block(&self, l: usize, x: &[f32], t: usize, s: usize,
                       pos: usize, k_cache: &[f32], v_cache: &[f32])
                       -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let m = &self.manifest.model;
        let (d, nh, nkv, dh) =
            (m.d_model, m.n_heads, m.n_kv_heads, m.d_head);
        anyhow::ensure!(nh % nkv == 0, "n_heads must be divisible by n_kv");
        anyhow::ensure!(
            pos + t <= s,
            "attention: pos {pos} + t {t} exceeds bucket {s}"
        );
        let group = nh / nkv;

        let h1 = rmsnorm_rows(x, self.lw(l, "rms1", d)?, t, d);
        let mut q = matmul(&h1, self.lw(l, "wq", d * nh * dh)?, t, d,
                           nh * dh);
        let mut k_new =
            matmul(&h1, self.lw(l, "wk", d * nkv * dh)?, t, d, nkv * dh);
        let v_new =
            matmul(&h1, self.lw(l, "wv", d * nkv * dh)?, t, d, nkv * dh);
        for r in 0..t {
            rope_row(&mut q[r * nh * dh..(r + 1) * nh * dh], nh, dh,
                     pos + r);
            rope_row(&mut k_new[r * nkv * dh..(r + 1) * nkv * dh], nkv, dh,
                     pos + r);
        }

        let scale = 1.0 / (dh as f32).sqrt();
        let mut attn = vec![0.0f32; t * nh * dh];
        let mut scores: Vec<f32> = Vec::new();
        for r in 0..t {
            let p = pos + r; // absolute position of this query
            for h in 0..nh {
                let g = h / group; // the KV head this query head reads
                let qv = &q[(r * nh + h) * dh..(r * nh + h + 1) * dh];
                scores.clear();
                let mut max = f32::NEG_INFINITY;
                for j in 0..=p {
                    let kv = if j < pos {
                        &k_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
                    } else {
                        let jr = j - pos;
                        &k_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
                    };
                    let dot: f32 =
                        qv.iter().zip(kv.iter()).map(|(a, b)| a * b).sum();
                    let sc = dot * scale;
                    max = max.max(sc);
                    scores.push(sc);
                }
                let mut denom = 0.0f32;
                for sc in scores.iter_mut() {
                    *sc = (*sc - max).exp();
                    denom += *sc;
                }
                let out =
                    &mut attn[(r * nh + h) * dh..(r * nh + h + 1) * dh];
                for (j, &wgt) in scores.iter().enumerate() {
                    let vv = if j < pos {
                        &v_cache[(j * nkv + g) * dh..(j * nkv + g + 1) * dh]
                    } else {
                        let jr = j - pos;
                        &v_new[(jr * nkv + g) * dh..(jr * nkv + g + 1) * dh]
                    };
                    let wn = wgt / denom;
                    for (o, &v) in out.iter_mut().zip(vv.iter()) {
                        *o += wn * v;
                    }
                }
            }
        }
        let proj = matmul(&attn, self.lw(l, "wo", nh * dh * d)?, t,
                          nh * dh, d);
        Ok((add(x, &proj), k_new, v_new))
    }

    /// SwiGLU activations of the normalized post-attention state:
    /// `silu(h2 @ w_gate) * (h2 @ w_up)`, shape `[t, d_ffn]`.
    fn ffn_activations(&self, l: usize, h: &[f32], t: usize)
                       -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let h2 = rmsnorm_rows(h, self.lw(l, "rms2", d)?, t, d);
        let gate = matmul(&h2, self.lw(l, "w_gate", d * f)?, t, d, f);
        let up = matmul(&h2, self.lw(l, "w_up", d * f)?, t, d, f);
        Ok(gate
            .iter()
            .zip(up.iter())
            .map(|(&g, &u)| silu(g) * u)
            .collect())
    }

    /// Down-projection restricted to the experts in `idx` (ascending),
    /// optionally gated per neuron by `alpha`:
    /// `y[r] = Σ_{j ∈ idx} alpha[j] * acts[r,j] * w_down[j]`.
    ///
    /// The dense FFN calls this with `idx == [0, d_ffn)` so the sparse
    /// and dense paths share one accumulation order — that is what makes
    /// `K == d_ffn` sparse output bit-identical to dense output.
    fn down_proj(&self, l: usize, acts: &[f32], t: usize, idx: &[i32],
                 alpha: Option<&[f32]>) -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let w_down = self.lw(l, "w_down", f * d)?;
        for &ji in idx {
            anyhow::ensure!(
                ji >= 0 && (ji as usize) < f,
                "expert index {ji} out of range [0, {f})"
            );
        }
        let mut out = vec![0.0f32; t * d];
        for r in 0..t {
            for &ji in idx {
                let j = ji as usize;
                let a = acts[r * f + j]
                    * alpha.map_or(1.0, |al| al[j]);
                let wr = &w_down[j * d..(j + 1) * d];
                let or = &mut out[r * d..(r + 1) * d];
                for (o, &wv) in or.iter_mut().zip(wr.iter()) {
                    *o += a * wv;
                }
            }
        }
        Ok(out)
    }

    /// Block-aggregated predictor scores `[d_ffn]` (the trained expert
    /// predictor's output the engine top-Ks on the host).
    fn predictor_scores(&self, l: usize, h: &[f32], t: usize)
                        -> Result<Vec<f32>> {
        let m = &self.manifest.model;
        let (d, f) = (m.d_model, m.d_ffn);
        let h2 = rmsnorm_rows(h, self.lw(l, "rms2", d)?, t, d);
        let p = matmul(&h2, self.w(&format!("pred.{l}.w"), d * f)?, t, d, f);
        let mut scores = vec![0.0f32; f];
        for r in 0..t {
            for j in 0..f {
                scores[j] += p[r * f + j].abs();
            }
        }
        Ok(scores)
    }

    /// Block-aggregated |activation| scores `[d_ffn]` (the GRIFFIN-style
    /// oracle statistic used by the ablation sources).
    fn activation_scores(&self, l: usize, h: &[f32], t: usize)
                         -> Result<Vec<f32>> {
        let f = self.manifest.model.d_ffn;
        let acts = self.ffn_activations(l, h, t)?;
        let mut scores = vec![0.0f32; f];
        for r in 0..t {
            for j in 0..f {
                scores[j] += acts[r * f + j].abs();
            }
        }
        Ok(scores)
    }

    fn alpha(&self, l: usize) -> Result<&[f32]> {
        self.w(&format!("comp.{l}.alpha"), self.manifest.model.d_ffn)
    }

    fn run_op(&self, op: Op, spec: &ExecutableSpec, layer: usize,
              inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>> {
        let m = &self.manifest.model;
        let (d, f, vocab) = (m.d_model, m.d_ffn, m.vocab);
        let exe = spec.name.as_str();
        match op {
            Op::Embed { t } => {
                let tokens = i32_input(inputs, exe, "tokens")?;
                anyhow::ensure!(tokens.len() == t, "{exe}: token count");
                let table = self.w("embed", vocab * d)?;
                let mut out = vec![0.0f32; t * d];
                for (r, &tok) in tokens.iter().enumerate() {
                    let id = (tok.max(0) as usize).min(vocab - 1);
                    out[r * d..(r + 1) * d]
                        .copy_from_slice(&table[id * d..(id + 1) * d]);
                }
                Ok(vec![Output { data: out }])
            }
            Op::LmHead { t } => {
                let x = f32_input(inputs, exe, "x")?;
                let xr = rmsnorm_rows(x, self.w("final_rms", d)?, t, d);
                let logits =
                    matmul(&xr, self.w("lm_head", d * vocab)?, t, d, vocab);
                Ok(vec![Output { data: logits }])
            }
            Op::LayerDense { t, s } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc)?;
                let acts = self.ffn_activations(layer, &h, t)?;
                let all: Vec<i32> = (0..f as i32).collect();
                let y = self.down_proj(layer, &acts, t, &all, None)?;
                Ok(vec![
                    Output { data: add(&h, &y) },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::LayerSparse { k, t, s } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc)?;
                let scores = self.predictor_scores(layer, &h, t)?;
                let idx = top_k_indices(&scores, k.min(f));
                let acts = self.ffn_activations(layer, &h, t)?;
                let y = self.down_proj(layer, &acts, t, &idx, None)?;
                let comp = self.down_proj(
                    layer,
                    &acts,
                    t,
                    &complement(&idx, f),
                    Some(self.alpha(layer)?),
                )?;
                let mut out = add(&h, &y);
                add_assign(&mut out, &comp);
                Ok(vec![
                    Output { data: out },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::LayerAttn { t, s } => {
                let x = f32_input(inputs, exe, "x")?;
                let kc = f32_input(inputs, exe, "k_cache")?;
                let vc = f32_input(inputs, exe, "v_cache")?;
                let pos = i32_input(inputs, exe, "pos")?[0] as usize;
                let (h, k_new, v_new) =
                    self.attention_block(layer, x, t, s, pos, kc, vc)?;
                Ok(vec![
                    Output { data: h },
                    Output { data: k_new },
                    Output { data: v_new },
                ])
            }
            Op::Predictor { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let scores = self.predictor_scores(layer, h, t)?;
                Ok(vec![Output { data: scores }])
            }
            Op::FfnActs { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let scores = self.activation_scores(layer, h, t)?;
                Ok(vec![Output { data: scores }])
            }
            Op::FfnDense { t } => {
                let h = f32_input(inputs, exe, "h")?;
                let acts = self.ffn_activations(layer, h, t)?;
                let all: Vec<i32> = (0..f as i32).collect();
                let y = self.down_proj(layer, &acts, t, &all, None)?;
                Ok(vec![Output { data: add(h, &y) }])
            }
            Op::FfnSparseExt { k, t } => {
                let h = f32_input(inputs, exe, "h")?;
                let idx = i32_input(inputs, exe, "idx")?;
                anyhow::ensure!(
                    idx.len() == k,
                    "{exe}: idx has {} entries, compiled K is {k}",
                    idx.len()
                );
                let acts = self.ffn_activations(layer, h, t)?;
                let y = self.down_proj(layer, &acts, t, idx, None)?;
                let comp = self.down_proj(
                    layer,
                    &acts,
                    t,
                    &complement(idx, f),
                    Some(self.alpha(layer)?),
                )?;
                Ok(vec![Output { data: add(h, &y) }, Output { data: comp }])
            }
        }
    }
}

impl Backend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn prepare(&self, spec: &ExecutableSpec) -> Result<()> {
        self.op_for(&spec.name).map(|_| ())
    }

    fn prepared_count(&self) -> usize {
        self.ops.borrow().len()
    }

    fn execute(&self, spec: &ExecutableSpec, layer: usize,
               inputs: &[(&str, Input<'_>)]) -> Result<Vec<Output>> {
        let op = self.op_for(&spec.name)?;
        let t0 = Instant::now();
        let out = self.run_op(op, spec, layer, inputs)?;
        let mut s = self.stats.borrow_mut();
        s.executions += 1;
        s.execute_time += t0.elapsed();
        Ok(out)
    }

    fn stats(&self) -> DispatchStats {
        self.stats.borrow().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_parsing() {
        assert_eq!(parse_op("embed_t128").unwrap(), Op::Embed { t: 128 });
        assert_eq!(parse_op("lm_head_t1").unwrap(), Op::LmHead { t: 1 });
        assert_eq!(
            parse_op("layer_dense_t128_s512").unwrap(),
            Op::LayerDense { t: 128, s: 512 }
        );
        assert_eq!(
            parse_op("layer_sparse_k64_t1_s256").unwrap(),
            Op::LayerSparse { k: 64, t: 1, s: 256 }
        );
        assert_eq!(
            parse_op("ffn_sparse_ext_k96_t128").unwrap(),
            Op::FfnSparseExt { k: 96, t: 128 }
        );
        assert_eq!(
            parse_op("ffn_acts_t128").unwrap(),
            Op::FfnActs { t: 128 }
        );
        assert!(parse_op("warp_drive_t4").is_err());
        assert!(parse_op("layer_dense_t128").is_err(), "missing s");
    }

    #[test]
    fn complement_partitions_the_expert_set() {
        let idx = vec![0, 3, 4];
        let rest = complement(&idx, 6);
        assert_eq!(rest, vec![1, 2, 5]);
        assert_eq!(complement(&[], 3), vec![0, 1, 2]);
        assert_eq!(complement(&[0, 1, 2], 3), Vec::<i32>::new());
    }

    #[test]
    fn matmul_matches_hand_computed() {
        // [2,3] @ [3,2]
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, &w, 2, 3, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = [3.0f32, 4.0, 0.0, 0.0];
        let gain = [1.0f32; 4];
        let y = rmsnorm_rows(&x, &gain, 1, 4);
        let ms: f32 = y.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!((ms - 1.0).abs() < 1e-3, "normalized mean square: {ms}");
    }

    #[test]
    fn rope_preserves_norm() {
        let mut row = vec![1.0f32, 0.0, 0.5, -0.5];
        let before: f32 = row.iter().map(|v| v * v).sum();
        rope_row(&mut row, 1, 4, 37);
        let after: f32 = row.iter().map(|v| v * v).sum();
        assert!((before - after).abs() < 1e-5);
        // position 0 is the identity rotation
        let mut row0 = vec![1.0f32, 2.0, 3.0, 4.0];
        rope_row(&mut row0, 1, 4, 0);
        assert_eq!(row0, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
