#![warn(missing_docs)]

//! # FastForward
//!
//! Full-stack reproduction of *"Fast Forward: Accelerating LLM Prefill
//! with Predictive FFN Sparsity"* (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas serving system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): gathered sparse
//!   SwiGLU FFN, expert predictor, error compensator, flash block
//!   attention. Build-time only.
//! * **L2** — JAX model (`python/compile/`): LLaMA-architecture
//!   transformer, trained + AOT-lowered once to HLO-text artifacts.
//! * **L3** — this crate: the serving coordinator. Block-wise prefill
//!   engine with predictive FFN sparsity, a replica-sharded executor
//!   pool with least-loaded dispatch, block-granular prefix-aware KV
//!   reuse, continuous batching (batched decode + mixed
//!   prefill-chunk/decode steps through one shared forward pass,
//!   bit-identical to sequential execution) with SLO-aware preemptive
//!   scheduling (interactive vs batch classes, deadline projection),
//!   SSE token streaming end to end, request routing, HTTP server,
//!   paged KV
//!   management, the paper's layerwise sparsity schedule (Algorithm 1),
//!   cost model, workload generators and the full evaluation/benchmark
//!   harness.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `fastforward` binary is self-contained.
//!
//! ```text
//!                          ┌───────────── ExecutorPool ─────────────┐
//! client ─▶ Router ────┬──▶ replica 0: Batcher ─▶ Engine ─▶ PJRT
//!   │  (admission,     ├──▶ replica 1: Batcher ─▶ Engine ─▶ PJRT
//!   │   least-loaded   └──▶ replica N-1  …
//!   │   dispatch)
//!   └─ shared: PagedAllocator · PrefixCache · Metrics
//!
//! engine, per prompt block ─┬─ cached prefix → adopt KV rows (no compute)
//!                           ├─ dense block   → layer_dense_*    (backend)
//!                           └─ sparse block  → layer_sparse_K_* (backend)
//! ```
//!
//! Execution is backend-pluggable (`--backend cpu|pjrt`): the PJRT
//! backend compiles the AOT HLO artifacts, while the pure-Rust
//! [`runtime::CpuBackend`] interprets the same ABI deterministically on
//! any machine over the synthetic reference model
//! ([`manifest::Manifest::synthetic`] +
//! [`weights::WeightStore::seeded`]) — no artifacts, no setup. That is
//! what un-gates the end-to-end numeric test tier (docs/TESTING.md).
//!
//! See `docs/ARCHITECTURE.md` for the end-to-end request-path
//! walkthrough, `docs/OPERATIONS.md` for endpoints (including the SSE
//! wire format), CLI flags, metrics and tuning, and
//! `docs/SCHEDULING.md` for the SLO scheduling rules.

pub mod batcher;
pub mod cluster;
pub mod cost;
pub mod engine;
pub mod eval;
pub mod kvcache;
pub mod manifest;
pub mod metrics;
pub mod pool;
pub mod router;
pub mod runtime;
pub mod server;
pub mod sparsity;
pub mod testing;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod weights;

#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

use std::path::PathBuf;

/// Locate the artifacts directory for tests/benches: `FF_ARTIFACTS` env
/// var, else `<crate>/artifacts` if it holds a manifest. Returns None
/// when artifacts have not been built, or when the crate was built
/// without the `pjrt` feature (artifacts cannot execute). Callers that
/// only need *an* engine should use [`testing::test_engine`], which
/// falls back to the deterministic CPU backend instead of skipping —
/// see docs/TESTING.md for the test-tier layout.
pub fn test_artifacts_dir() -> Option<PathBuf> {
    if cfg!(not(feature = "pjrt")) {
        eprintln!(
            "[skip] built without the `pjrt` feature — artifact-backed \
             tests and benches are disabled"
        );
        return None;
    }
    let cand = std::env::var("FF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if cand.join("manifest.json").exists() {
        Some(cand)
    } else {
        eprintln!(
            "[skip] artifacts not found at {cand:?} — run `make artifacts`"
        );
        None
    }
}
