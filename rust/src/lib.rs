//! # FastForward
//!
//! Full-stack reproduction of *"Fast Forward: Accelerating LLM Prefill
//! with Predictive FFN Sparsity"* (CS.LG 2026) as a three-layer
//! Rust + JAX + Pallas serving system:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): gathered sparse
//!   SwiGLU FFN, expert predictor, error compensator, flash block
//!   attention. Build-time only.
//! * **L2** — JAX model (`python/compile/`): LLaMA-architecture
//!   transformer, trained + AOT-lowered once to HLO-text artifacts.
//! * **L3** — this crate: the serving coordinator. Block-wise prefill
//!   engine with predictive FFN sparsity, dynamic batcher, request
//!   router, HTTP server, paged KV management, the paper's layerwise
//!   sparsity schedule (Algorithm 1), cost model, workload generators and
//!   the full evaluation/benchmark harness.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `fastforward` binary is self-contained.
//!
//! ```text
//! router → batcher → engine ─┬─ dense blocks  → layer_dense_*    (PJRT)
//!                            └─ sparse blocks → layer_sparse_K_* (PJRT)
//! ```

pub mod batcher;
pub mod cost;
pub mod engine;
pub mod eval;
pub mod kvcache;
pub mod manifest;
pub mod metrics;
pub mod router;
pub mod runtime;
pub mod server;
pub mod sparsity;
pub mod tokenizer;
pub mod trace;
pub mod util;
pub mod weights;

use std::path::PathBuf;

/// Locate the artifacts directory for tests/benches: `FF_ARTIFACTS` env
/// var, else `<crate>/artifacts` if it holds a manifest. Returns None
/// (tests skip) when artifacts have not been built.
pub fn test_artifacts_dir() -> Option<PathBuf> {
    let cand = std::env::var("FF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        });
    if cand.join("manifest.json").exists() {
        Some(cand)
    } else {
        eprintln!(
            "[skip] artifacts not found at {cand:?} — run `make artifacts`"
        );
        None
    }
}
