//! KV-cache management.
//!
//! Three cooperating pieces:
//!
//! * [`PagedAllocator`] — a vLLM-style page pool for admission control:
//!   pages of `page_size` positions, ref-counted for prefix sharing, with
//!   exact accounting so the router can bound resident memory.
//! * [`SeqKvCache`] — the per-sequence host-resident cache the engine
//!   feeds to the bucketed AOT executables: contiguous padded buffers per
//!   layer, grown bucket-by-bucket, appended after each block step.
//! * [`PrefixCache`] — a block-granular cache of already-computed KV
//!   rows, keyed by a chained hash of token blocks (and the sparsity
//!   configuration they were computed under). A new prefill session
//!   adopts the KV pages of its longest cached prefix and only runs
//!   prefill — dense or sparse — over the uncached suffix. Entries are
//!   ref-counted while a session copies from them (eviction never frees
//!   an in-use entry) and evicted LRU-first under memory pressure.
//!
//! The prefix-cache page lifecycle (see also docs/ARCHITECTURE.md):
//!
//! ```text
//! prefill finishes ── insert ──▶ entry (pages allocated, refs=0)
//!       new session ── acquire ─▶ refs+1 (pinned; eviction skips it)
//!                      copy_into ▶ rows memcpy'd into the session cache
//!                      release ──▶ refs-1
//! memory pressure ──── evict ───▶ LRU entry with refs==0 dropped,
//!                                 pages released to the allocator
//! ```

use std::collections::HashMap;

use anyhow::{anyhow, Result};

// ---------------------------------------------------------------------------
// Paged allocator
// ---------------------------------------------------------------------------

/// Identifier of one fixed-size page in the [`PagedAllocator`] pool.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageId(pub u32);

/// Ref-counted page pool bounding total resident KV memory.
///
/// Pure accounting: pages carry no storage themselves (the engine's
/// per-sequence buffers live in [`SeqKvCache`]); the allocator is what
/// lets the router reject work *before* memory is committed, and what
/// makes prefix-cache residency visible to admission control.
#[derive(Debug)]
pub struct PagedAllocator {
    page_size: usize,
    ref_counts: Vec<u32>,
    free: Vec<PageId>,
}

impl PagedAllocator {
    /// Create a pool of `total_pages` pages of `page_size` positions.
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        PagedAllocator {
            page_size,
            ref_counts: vec![0; total_pages],
            free: (0..total_pages as u32).rev().map(PageId).collect(),
        }
    }

    /// Positions covered by one page.
    pub fn page_size(&self) -> usize {
        self.page_size
    }

    /// Number of pages needed to hold `positions` positions.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    /// Pages currently free.
    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    /// Pages currently held by at least one owner.
    pub fn used_pages(&self) -> usize {
        self.ref_counts.len() - self.free.len()
    }

    /// Can `positions` more positions be allocated right now?
    pub fn can_allocate(&self, positions: usize) -> bool {
        self.pages_for(positions) <= self.free.len()
    }

    /// Take `n_pages` pages out of the free list (each with refcount 1).
    pub fn allocate(&mut self, n_pages: usize) -> Result<Vec<PageId>> {
        if n_pages > self.free.len() {
            return Err(anyhow!(
                "kv pool exhausted: want {n_pages}, free {}",
                self.free.len()
            ));
        }
        let mut out = Vec::with_capacity(n_pages);
        for _ in 0..n_pages {
            let p = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[p.0 as usize], 0);
            self.ref_counts[p.0 as usize] = 1;
            out.push(p);
        }
        Ok(out)
    }

    /// Share an existing page (prefix reuse): bump its refcount.
    pub fn retain(&mut self, page: PageId) -> Result<()> {
        let rc = self
            .ref_counts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow!("bad page {page:?}"))?;
        if *rc == 0 {
            return Err(anyhow!("retain of free page {page:?}"));
        }
        *rc += 1;
        Ok(())
    }

    /// Drop one reference; the page returns to the free list at zero.
    pub fn release(&mut self, page: PageId) -> Result<()> {
        let rc = self
            .ref_counts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow!("bad page {page:?}"))?;
        if *rc == 0 {
            return Err(anyhow!("double free of page {page:?}"));
        }
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
        Ok(())
    }

    /// [`Self::release`] over a whole page list.
    pub fn release_all(&mut self, pages: &[PageId]) -> Result<()> {
        for &p in pages {
            self.release(p)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Per-sequence host cache
// ---------------------------------------------------------------------------

/// Contiguous padded K/V buffers for one sequence, one pair per layer.
/// Layout per buffer: [bucket, n_kv_heads, d_head] row-major f32, matching
/// the AOT executable input shapes exactly.
#[derive(Debug, Clone)]
pub struct SeqKvCache {
    /// Number of transformer layers (outer dimension of `k`/`v`).
    pub n_layers: usize,
    /// KV heads per layer.
    pub n_kv: usize,
    /// Head dimension.
    pub d_head: usize,
    /// Current padded capacity in positions (an artifact bucket size).
    pub bucket: usize,
    /// Filled positions (`<= bucket`).
    pub len: usize,
    /// Per-layer key buffers, `bucket * n_kv * d_head` elements each.
    pub k: Vec<Vec<f32>>,
    /// Per-layer value buffers, same layout as `k`.
    pub v: Vec<Vec<f32>>,
}

impl SeqKvCache {
    /// Fresh empty cache at an initial `bucket` capacity.
    pub fn new(n_layers: usize, n_kv: usize, d_head: usize,
               bucket: usize) -> Self {
        let sz = bucket * n_kv * d_head;
        SeqKvCache {
            n_layers,
            n_kv,
            d_head,
            bucket,
            len: 0,
            k: vec![vec![0.0; sz]; n_layers],
            v: vec![vec![0.0; sz]; n_layers],
        }
    }

    /// Elements per cached position per layer (`n_kv * d_head`).
    pub fn row_elems(&self) -> usize {
        self.n_kv * self.d_head
    }

    /// Grow to a bigger bucket, preserving contents.
    pub fn grow(&mut self, new_bucket: usize) {
        assert!(new_bucket >= self.bucket);
        if new_bucket == self.bucket {
            return;
        }
        let row = self.row_elems();
        for l in 0..self.n_layers {
            self.k[l].resize(new_bucket * row, 0.0);
            self.v[l].resize(new_bucket * row, 0.0);
        }
        self.bucket = new_bucket;
    }

    /// Append `t` new rows for layer `l` (from the executable's k_new /
    /// v_new outputs, shape [t, n_kv, d_head]).
    pub fn append_layer(&mut self, l: usize, k_new: &[f32], v_new: &[f32],
                        t: usize) -> Result<()> {
        let row = self.row_elems();
        anyhow::ensure!(k_new.len() == t * row, "k_new wrong size");
        anyhow::ensure!(v_new.len() == t * row, "v_new wrong size");
        anyhow::ensure!(
            self.len + t <= self.bucket,
            "cache overflow: len {} + {t} > bucket {}",
            self.len,
            self.bucket
        );
        let dst = self.len * row;
        self.k[l][dst..dst + t * row].copy_from_slice(k_new);
        self.v[l][dst..dst + t * row].copy_from_slice(v_new);
        Ok(())
    }

    /// Advance the filled length after all layers appended a block.
    pub fn advance(&mut self, t: usize) {
        self.len += t;
        debug_assert!(self.len <= self.bucket);
    }
}

// ---------------------------------------------------------------------------
// Batched step view
// ---------------------------------------------------------------------------

/// Disjoint per-sequence KV views for one continuous-batching step.
///
/// A batched step runs several sequences through one shared forward
/// pass; each sequence's fresh KV rows must scatter into its *own*
/// cache — its own page set — at its own write cursor. `StepKv` wraps
/// the member caches behind a `(seq, layer, pos)`-addressable facade:
/// [`StepKv::layer`] yields the read view a layer dispatch feeds the
/// backend, [`StepKv::append`] scatters that sequence's fresh rows at
/// its current fill position, and [`StepKv::advance`] moves the write
/// cursor once every layer has appended. Holding `&mut SeqKvCache`
/// exclusively per member is what makes the scatter sets disjoint by
/// construction — no two rows of a batch can alias a page.
pub struct StepKv<'a> {
    caches: Vec<&'a mut SeqKvCache>,
}

impl<'a> StepKv<'a> {
    /// Wrap the member caches of one batched step, in row order.
    pub fn new(caches: Vec<&'a mut SeqKvCache>) -> Self {
        StepKv { caches }
    }

    /// Number of member sequences.
    pub fn len(&self) -> usize {
        self.caches.len()
    }

    /// Whether the view has no members.
    pub fn is_empty(&self) -> bool {
        self.caches.is_empty()
    }

    /// Sequence `seq`'s padded bucket capacity (the `s` of its
    /// executable shapes).
    pub fn bucket(&self, seq: usize) -> usize {
        self.caches[seq].bucket
    }

    /// Sequence `seq`'s current fill position — the absolute position
    /// its next appended row lands at.
    pub fn pos(&self, seq: usize) -> usize {
        self.caches[seq].len
    }

    /// The `(k, v)` buffers of sequence `seq` at `layer`, each
    /// `[bucket, n_kv, d_head]` — the read view a batched layer
    /// dispatch hands the backend.
    pub fn layer(&self, seq: usize, layer: usize) -> (&[f32], &[f32]) {
        let c = &self.caches[seq];
        (&c.k[layer], &c.v[layer])
    }

    /// Scatter `t` fresh rows for `(seq, layer)` at the sequence's
    /// write cursor.
    pub fn append(&mut self, seq: usize, layer: usize, k_new: &[f32],
                  v_new: &[f32], t: usize) -> Result<()> {
        self.caches[seq].append_layer(layer, k_new, v_new, t)
    }

    /// Advance sequence `seq`'s write cursor after all layers appended
    /// its `t` rows.
    pub fn advance(&mut self, seq: usize, t: usize) {
        self.caches[seq].advance(t);
    }
}

// ---------------------------------------------------------------------------
// Prefix cache
// ---------------------------------------------------------------------------

/// FNV-1a over the previous chain hash and one token block: the key of
/// block `b` commits to the *entire* token prefix `[0, (b+1)*block)` and
/// to the sparsity-configuration seed the KV was computed under.
fn chain_hash(prev: u64, tokens: &[i32]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325 ^ prev;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    // one extra round so a zero block still perturbs the chain
    h ^= prev.rotate_left(17);
    h.wrapping_mul(0x100000001b3)
}

/// Cluster routing key for a prompt: the chained block hash of up to
/// `max_blocks` leading **full** `block`-token blocks, seeded at `seed`
/// — exactly the walk [`PrefixCache::acquire`] performs, so two prompts
/// share a routing key iff they would adopt the same leading cache
/// entries. Prompts shorter than one block (which the prefix cache
/// never stores) hash their whole token slice instead, so short prompts
/// still spread deterministically across a hash ring.
///
/// Cheap by construction — O(`min(len, max_blocks·block)`) byte hashing,
/// no allocation, no cache lock — so a front tier can key *every*
/// incoming request on it before any session state exists.
pub fn routing_key(seed: u64, tokens: &[i32], block: usize,
                   max_blocks: usize) -> u64 {
    let mut h = seed;
    let full = (tokens.len() / block.max(1)).min(max_blocks);
    if full == 0 {
        return chain_hash(h, tokens);
    }
    for b in 0..full {
        h = chain_hash(h, &tokens[b * block..(b + 1) * block]);
    }
    h
}

/// One cached block's KV rows for all layers. `Arc`-shared between the
/// resident entry and in-flight adoptions, so copies proceed without
/// holding the cache lock.
#[derive(Debug)]
struct BlockKv {
    /// Per-layer key rows, `block * n_kv * d_head` elements each.
    k: Vec<Vec<f32>>,
    /// Per-layer value rows.
    v: Vec<Vec<f32>>,
}

/// One cached token block entry.
#[derive(Debug)]
struct PrefixBlock {
    /// The block's own tokens, re-verified on every lookup. Combined
    /// with the chain walk from block 0 this checks each adopted
    /// block's tokens exactly; the *ancestry* (earlier blocks) is
    /// committed only through the 64-bit chain hash, so a silent wrong
    /// adoption requires both a chain-hash collision *and* identical
    /// current-block tokens — random collisions are caught here.
    tokens: Vec<i32>,
    /// The KV rows (shared with adopters).
    data: std::sync::Arc<BlockKv>,
    /// Compressed-page metadata: the original prompt positions of this
    /// block's rows under speculative token pruning (`None` = dense
    /// identity block). Purely diagnostic — the KV rows are a function
    /// of the *effective* (pruned) token sequence alone, which the
    /// chain hash and the configuration seed already commit to, so
    /// adoption correctness never consults this map.
    keep: Option<std::sync::Arc<[u32]>>,
    /// Pages accounting for this entry's residency in the shared pool.
    pages: Vec<PageId>,
    /// Sessions currently adopting this entry; eviction skips entries
    /// with `refs > 0` so resident-page accounting stays honest while
    /// an adoption is in flight.
    refs: u32,
    /// Logical clock of the last lookup/insert touch (LRU order).
    last_used: u64,
}

/// A pinned run of cached blocks returned by [`PrefixCache::acquire`].
///
/// Holds `Arc` handles to the matched blocks' KV rows, so
/// [`PrefixHit::copy_into`] runs **without** the cache lock. Every key
/// in `keys` also has its entry's refcount bumped; the holder must call
/// [`PrefixCache::release`] exactly once — after the copy, or on any
/// error path — so the entries become evictable again.
#[derive(Debug, Clone)]
pub struct PrefixHit {
    /// Chain keys of the matched blocks, in block order from position 0.
    pub keys: Vec<u64>,
    /// Total prompt tokens covered (`keys.len() * block`).
    pub tokens: usize,
    block: usize,
    data: Vec<std::sync::Arc<BlockKv>>,
    keep: Vec<Option<std::sync::Arc<[u32]>>>,
}

/// One block's KV rows staged for insertion, copied from a finished
/// prefill's cache by [`PreparedBlock::copy_from`] — deliberately a
/// free-standing copy so the executor can run the memcpy *without*
/// holding the cache lock, then hand the result to
/// [`PrefixCache::insert_prepared`].
#[derive(Debug)]
pub struct PreparedBlock {
    index: usize,
    data: BlockKv,
    keep: Option<std::sync::Arc<[u32]>>,
}

impl PreparedBlock {
    /// Stage block `index` (0-based) of `src`'s rows. Pure memcpy; no
    /// cache involvement.
    pub fn copy_from(src: &SeqKvCache, block: usize, index: usize) -> Self {
        let row = src.row_elems();
        let lo = index * block * row;
        let hi = (index + 1) * block * row;
        PreparedBlock {
            index,
            data: BlockKv {
                k: (0..src.n_layers)
                    .map(|l| src.k[l][lo..hi].to_vec())
                    .collect(),
                v: (0..src.n_layers)
                    .map(|l| src.v[l][lo..hi].to_vec())
                    .collect(),
            },
            keep: None,
        }
    }

    /// Attach compressed-page metadata: `rows[i]` is the *original*
    /// prompt position of this block's row `i` (the keep-map slice a
    /// speculative prefill recorded for these tokens). Stored alongside
    /// the entry so cache observability can attribute compression; the
    /// KV itself is keyed purely on the effective token chain.
    pub fn with_keep(mut self, rows: Vec<u32>) -> Self {
        self.keep = Some(rows.into());
        self
    }
}

impl PrefixHit {
    /// Copy the pinned blocks into an empty session cache, advancing
    /// its filled length to `self.tokens`. The destination must already
    /// have `bucket >= self.tokens` (the session grows it first). Runs
    /// lock-free: the data is `Arc`-shared and the refcount pin keeps
    /// the entries resident meanwhile.
    pub fn copy_into(&self, dst: &mut SeqKvCache) -> Result<()> {
        anyhow::ensure!(dst.len == 0, "prefix adoption into non-empty cache");
        anyhow::ensure!(
            dst.bucket >= self.tokens,
            "destination bucket {} < adopted tokens {}",
            dst.bucket,
            self.tokens
        );
        for blk in &self.data {
            anyhow::ensure!(
                blk.k.len() == dst.n_layers
                    && blk.k[0].len() == self.block * dst.row_elems(),
                "prefix entry shape mismatch"
            );
            for l in 0..dst.n_layers {
                dst.append_layer(l, &blk.k[l], &blk.v[l], self.block)?;
            }
            dst.advance(self.block);
        }
        Ok(())
    }

    /// How many of the matched blocks hold token-pruned (compressed)
    /// KV — rows covering more original prompt positions than they
    /// occupy.
    pub fn compressed_blocks(&self) -> usize {
        self.keep.iter().filter(|k| k.is_some()).count()
    }

    /// The keep-map recorded for matched block `i`: the original prompt
    /// position of each of its rows, or `None` for a dense identity
    /// block.
    pub fn keep_map(&self, i: usize) -> Option<&[u32]> {
        self.keep.get(i).and_then(|k| k.as_deref())
    }
}

/// Lifetime counters for the prefix cache (exported via `/metrics`).
#[derive(Debug, Default, Clone, Copy)]
pub struct PrefixCacheStats {
    /// Lookups that adopted at least one block.
    pub hits: u64,
    /// Lookups that adopted nothing.
    pub misses: u64,
    /// Total blocks adopted across all hits (each skips one block of
    /// prefill compute).
    pub blocks_reused: u64,
    /// Block entries inserted.
    pub insertions: u64,
    /// Of the insertions, entries holding token-pruned (compressed) KV
    /// — each covers more prompt positions than the rows it pays for,
    /// so cached capacity effectively multiplies by `1 / keep_ratio`.
    pub compressed_insertions: u64,
    /// Block entries evicted under memory pressure.
    pub evictions: u64,
}

/// Block-granular cache of computed KV rows shared by all replicas.
///
/// Keys chain-hash the token prefix *and* a sparsity-configuration seed
/// ([`crate::engine::SparsityConfig::prefill_fingerprint`]): KV computed
/// under 50% sparsity is numerically different from dense KV and must
/// never be adopted across configurations. Entries hold pages from the
/// shared [`PagedAllocator`] so cached residency competes with live
/// sequences under the same admission bound.
///
/// The insert → acquire → copy → release cycle in miniature:
///
/// ```
/// use fastforward::kvcache::{PagedAllocator, PrefixCache, SeqKvCache};
///
/// let block = 4;
/// let mut alloc = PagedAllocator::new(16, block);
/// let mut cache = PrefixCache::new(block, 1 << 20);
/// // a finished prefill's KV for a 9-token prompt (2 layers, 1 KV
/// // head, head width 2)
/// let tokens: Vec<i32> = (0..9).collect();
/// let mut src = SeqKvCache::new(2, 1, 2, tokens.len());
/// let row = vec![0.0; src.row_elems()];
/// for _pos in 0..tokens.len() {
///     for l in 0..2 {
///         src.append_layer(l, &row, &row, 1).unwrap();
///     }
///     src.advance(1);
/// }
/// // cache the two leading full blocks under config seed 7
/// assert_eq!(cache.insert(7, &tokens, usize::MAX, &src, &mut alloc), 2);
/// // a later request with the same prefix adopts them (pinned while
/// // the copy runs, so eviction can't free them mid-adoption)
/// let hit = cache.acquire(7, &tokens).expect("prefix hit");
/// assert_eq!(hit.tokens, 2 * block);
/// let mut dst = SeqKvCache::new(2, 1, 2, tokens.len());
/// hit.copy_into(&mut dst).unwrap();
/// cache.release(&hit);
/// assert_eq!(dst.len, 2 * block, "8 of 9 tokens skip prefill");
/// // a different configuration seed never adopts this KV
/// assert!(cache.acquire(8, &tokens).is_none());
/// ```
#[derive(Debug)]
pub struct PrefixCache {
    block: usize,
    budget_bytes: usize,
    used_bytes: usize,
    clock: u64,
    entries: HashMap<u64, PrefixBlock>,
    stats: PrefixCacheStats,
}

impl PrefixCache {
    /// A cache holding at most `budget_bytes` of KV data, at `block`
    /// token granularity (must equal the engine's prefill block size).
    /// A zero budget disables the cache entirely.
    pub fn new(block: usize, budget_bytes: usize) -> Self {
        PrefixCache {
            block,
            budget_bytes,
            used_bytes: 0,
            clock: 0,
            entries: HashMap::new(),
            stats: PrefixCacheStats::default(),
        }
    }

    /// Whether the cache participates at all (a zero byte budget turns
    /// both insertion and adoption off).
    pub fn enabled(&self) -> bool {
        self.budget_bytes > 0
    }

    /// Token-block granularity (the engine's prefill block size).
    pub fn block(&self) -> usize {
        self.block
    }

    /// Bytes of KV data currently resident.
    pub fn used_bytes(&self) -> usize {
        self.used_bytes
    }

    /// Configured byte budget.
    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Number of resident block entries.
    pub fn entry_count(&self) -> usize {
        self.entries.len()
    }

    /// Snapshot of the lifetime counters.
    pub fn stats(&self) -> PrefixCacheStats {
        self.stats
    }

    /// Largest adoptable prefix for a prompt: whole blocks only, and
    /// always at least one token left to prefill so the session still
    /// produces last-position logits (and a `dense_last` final block is
    /// still computed, not adopted).
    fn max_adopt_tokens(&self, prompt_len: usize) -> usize {
        if prompt_len == 0 {
            return 0;
        }
        ((prompt_len - 1) / self.block) * self.block
    }

    /// Find and pin the longest cached prefix of `tokens` under the
    /// configuration `seed`. Returns `None` (and counts a miss) when no
    /// leading block is cached. On `Some(hit)`, every matched entry's
    /// refcount is bumped — the caller owns a [`PrefixCache::release`].
    pub fn acquire(&mut self, seed: u64, tokens: &[i32]) -> Option<PrefixHit> {
        if !self.enabled() {
            return None;
        }
        let max_tokens = self.max_adopt_tokens(tokens.len());
        let mut keys = Vec::new();
        let mut data = Vec::new();
        let mut keep = Vec::new();
        let mut h = seed;
        let mut covered = 0;
        while covered + self.block <= max_tokens {
            let blk = &tokens[covered..covered + self.block];
            h = chain_hash(h, blk);
            match self.entries.get_mut(&h) {
                Some(e) if e.tokens == blk => {
                    e.refs += 1;
                    self.clock += 1;
                    e.last_used = self.clock;
                    keys.push(h);
                    data.push(e.data.clone());
                    keep.push(e.keep.clone());
                    covered += self.block;
                }
                _ => break,
            }
        }
        if keys.is_empty() {
            self.stats.misses += 1;
            return None;
        }
        self.stats.hits += 1;
        self.stats.blocks_reused += keys.len() as u64;
        Some(PrefixHit {
            tokens: covered,
            keys,
            block: self.block,
            data,
            keep,
        })
    }

    /// Unpin the entries of a hit (the mirror of [`Self::acquire`]).
    pub fn release(&mut self, hit: &PrefixHit) {
        for key in &hit.keys {
            if let Some(e) = self.entries.get_mut(key) {
                debug_assert!(e.refs > 0, "release of unpinned prefix entry");
                e.refs = e.refs.saturating_sub(1);
            }
        }
    }

    /// Bytes one block entry occupies for a given cache shape.
    fn entry_bytes(&self, n_layers: usize, row: usize) -> usize {
        n_layers * 2 * self.block * row * std::mem::size_of::<f32>()
    }

    /// Evict the least-recently-used unpinned entry, returning its pages
    /// to `alloc`. Returns false when nothing is evictable (everything
    /// pinned, or cache empty).
    fn evict_one(&mut self, alloc: &mut PagedAllocator) -> bool {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| e.refs == 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k);
        let Some(key) = victim else { return false };
        let e = self.entries.remove(&key).unwrap();
        self.used_bytes = self.used_bytes.saturating_sub(self.entry_bytes(
            e.data.k.len(),
            e.data.k[0].len() / self.block,
        ));
        if let Err(err) = alloc.release_all(&e.pages) {
            eprintln!("[prefix-cache] page release on evict: {err}");
        }
        self.stats.evictions += 1;
        true
    }

    /// Which of the leading full blocks of `tokens` (up to `max_blocks`,
    /// and never past the `src_len` rows actually computed) are not yet
    /// cached. A cheap probe — hashing and map lookups only — so callers
    /// can stage the memcpy of just those blocks *outside* the cache
    /// lock and hand the result to [`PrefixCache::insert_prepared`].
    pub fn missing_blocks(&self, seed: u64, tokens: &[i32],
                          max_blocks: usize, src_len: usize) -> Vec<usize> {
        if !self.enabled() {
            return Vec::new();
        }
        let n_blocks = (tokens.len() / self.block)
            .min(max_blocks)
            .min(src_len / self.block);
        let mut out = Vec::new();
        let mut h = seed;
        for b in 0..n_blocks {
            let blk = &tokens[b * self.block..(b + 1) * self.block];
            h = chain_hash(h, blk);
            if !self.entries.contains_key(&h) {
                out.push(b);
            }
        }
        out
    }

    /// Cache the leading full blocks of a finished prefill.
    ///
    /// `src` must hold the prompt's KV rows (`src.len == tokens.len()`).
    /// At most `max_blocks` leading blocks are inserted (the caller
    /// excludes a `dense_last` final block, whose KV is not
    /// position-generic). Returns the number of *new* block entries
    /// stored. Convenience wrapper over [`PrefixCache::missing_blocks`]
    /// + [`PreparedBlock::copy_from`] + [`PrefixCache::insert_prepared`]
    /// — the executor uses those directly so the memcpy runs outside
    /// the cache lock.
    pub fn insert(&mut self, seed: u64, tokens: &[i32], max_blocks: usize,
                  src: &SeqKvCache, alloc: &mut PagedAllocator) -> usize {
        let prepared: Vec<PreparedBlock> = self
            .missing_blocks(seed, tokens, max_blocks, src.len)
            .into_iter()
            .map(|b| PreparedBlock::copy_from(src, self.block, b))
            .collect();
        self.insert_prepared(seed, tokens, max_blocks, prepared, alloc)
    }

    /// Insert pre-staged blocks ([`PreparedBlock::copy_from`]) and
    /// LRU-touch the already-cached ones. Cheap under the lock: the row
    /// data was copied by the caller beforehand; this only hashes,
    /// evicts under pressure, allocates pages and moves `Arc`s. Blocks
    /// another replica cached in the probe→insert window are skipped
    /// (their staged copy is dropped). Under byte-budget or page
    /// pressure, LRU entries are evicted first; if space still cannot
    /// be found the remaining blocks are simply not cached — insertion
    /// never fails a request.
    pub fn insert_prepared(&mut self, seed: u64, tokens: &[i32],
                           max_blocks: usize,
                           prepared: Vec<PreparedBlock>,
                           alloc: &mut PagedAllocator) -> usize {
        if !self.enabled() {
            return 0;
        }
        let n_blocks = (tokens.len() / self.block).min(max_blocks);
        let mut staged: HashMap<usize, PreparedBlock> = prepared
            .into_iter()
            .map(|p| (p.index, p))
            .collect();
        let pages_needed = alloc.pages_for(self.block);
        let mut inserted = 0;
        // Pin every block of the chain as we walk it, so make-room
        // eviction can never cannibalize the *earlier* blocks of the
        // chain being inserted (an evicted ancestor would strand the
        // later blocks unreachable — lookups walk from block 0).
        let mut pinned: Vec<u64> = Vec::new();
        let mut h = seed;
        'blocks: for b in 0..n_blocks {
            let blk = &tokens[b * self.block..(b + 1) * self.block];
            h = chain_hash(h, blk);
            if let Some(e) = self.entries.get_mut(&h) {
                // already cached (by us or another replica): LRU-touch
                self.clock += 1;
                e.last_used = self.clock;
                e.refs += 1;
                pinned.push(h);
                continue;
            }
            // Neither cached nor staged: an ancestor was evicted in the
            // probe→insert window. Later blocks of this chain would be
            // unreachable (lookups walk from block 0), so stop rather
            // than insert orphans that pin pages with zero hit value.
            let Some(p) = staged.remove(&b) else { break 'blocks };
            let (data, keep) = (p.data, p.keep);
            let bytes =
                self.entry_bytes(data.k.len(), data.k[0].len() / self.block);
            // make room: byte budget first, then page feasibility; if
            // only pinned entries remain, stop caching instead
            while self.used_bytes + bytes > self.budget_bytes {
                if !self.evict_one(alloc) {
                    break 'blocks;
                }
            }
            let pages = loop {
                match alloc.allocate(pages_needed) {
                    Ok(p) => break Some(p),
                    Err(_) => {
                        if !self.evict_one(alloc) {
                            break None;
                        }
                    }
                }
            };
            let Some(pages) = pages else { break 'blocks };
            self.clock += 1;
            if keep.is_some() {
                self.stats.compressed_insertions += 1;
            }
            self.entries.insert(
                h,
                PrefixBlock {
                    tokens: blk.to_vec(),
                    data: std::sync::Arc::new(data),
                    keep,
                    pages,
                    refs: 1,
                    last_used: self.clock,
                },
            );
            pinned.push(h);
            self.used_bytes += bytes;
            self.stats.insertions += 1;
            inserted += 1;
        }
        for key in pinned {
            if let Some(e) = self.entries.get_mut(&key) {
                e.refs = e.refs.saturating_sub(1);
            }
        }
        inserted
    }

    /// Evict unpinned entries (LRU-first) until `alloc` has at least
    /// `pages_needed` free pages. Returns whether it got there. This is
    /// how *live* requests reclaim cached residency: admission calls it
    /// before rejecting with KV-exhausted, so a full prefix cache can
    /// never permanently starve the pool.
    pub fn evict_for(&mut self, pages_needed: usize,
                     alloc: &mut PagedAllocator) -> bool {
        while alloc.free_pages() < pages_needed {
            if !self.evict_one(alloc) {
                return false;
            }
        }
        true
    }

    /// Drop every unpinned entry, returning all pages to `alloc`.
    pub fn clear(&mut self, alloc: &mut PagedAllocator) {
        while self.evict_one(alloc) {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn alloc_release_cycle() {
        let mut a = PagedAllocator::new(8, 128);
        assert!(a.can_allocate(1024));
        assert!(!a.can_allocate(1025));
        let pages = a.allocate(4).unwrap();
        assert_eq!(a.used_pages(), 4);
        a.release_all(&pages).unwrap();
        assert_eq!(a.used_pages(), 0);
        assert_eq!(a.free_pages(), 8);
    }

    #[test]
    fn routing_key_tracks_leading_blocks_only() {
        let block = 4;
        let a: Vec<i32> = (0..13).collect();
        // same leading blocks, different tail → same key (the tail is
        // beyond the keyed prefix, so affinity still lands together)
        let mut b = a.clone();
        b[12] = 999;
        assert_eq!(
            routing_key(7, &a, block, 2),
            routing_key(7, &b, block, 2)
        );
        // a flipped token inside the first block changes the key
        let mut c = a.clone();
        c[0] = 999;
        assert_ne!(
            routing_key(7, &a, block, 2),
            routing_key(7, &c, block, 2)
        );
        // key matches the acquire-walk chain for the same blocks
        assert_eq!(
            routing_key(7, &a, block, 1),
            chain_hash(7, &a[..block])
        );
        assert_eq!(
            routing_key(7, &a, block, 2),
            chain_hash(chain_hash(7, &a[..block]), &a[block..2 * block])
        );
        // max_blocks caps the walk even when more full blocks exist
        assert_eq!(
            routing_key(7, &a, block, 1),
            routing_key(7, &a[..block], block, 8)
        );
        // short prompts (< one block) hash their whole slice — distinct
        // short prompts still spread
        assert_ne!(
            routing_key(7, &[1, 2], block, 2),
            routing_key(7, &[1, 3], block, 2)
        );
        // and a different seed relocates everything
        assert_ne!(
            routing_key(7, &a, block, 2),
            routing_key(8, &a, block, 2)
        );
    }

    #[test]
    fn refcounted_sharing() {
        let mut a = PagedAllocator::new(4, 128);
        let p = a.allocate(1).unwrap()[0];
        a.retain(p).unwrap();
        a.release(p).unwrap();
        assert_eq!(a.used_pages(), 1, "still shared");
        a.release(p).unwrap();
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn double_free_rejected() {
        let mut a = PagedAllocator::new(2, 128);
        let p = a.allocate(1).unwrap()[0];
        a.release(p).unwrap();
        assert!(a.release(p).is_err());
        assert!(a.retain(p).is_err());
    }

    #[test]
    fn exhaustion_is_clean() {
        let mut a = PagedAllocator::new(2, 128);
        assert!(a.allocate(3).is_err());
        let _p = a.allocate(2).unwrap();
        assert!(a.allocate(1).is_err());
    }

    #[test]
    fn prop_allocator_conservation() {
        check("pages-conserved", 150, |r| {
            let total = r.range(1, 64);
            let mut a = PagedAllocator::new(total, 128);
            let mut held: Vec<Vec<PageId>> = Vec::new();
            for _ in 0..r.range(1, 80) {
                if r.bool(0.55) || held.is_empty() {
                    let want = r.range(1, 8);
                    if let Ok(p) = a.allocate(want) {
                        held.push(p);
                    }
                } else {
                    let i = r.range(0, held.len());
                    let p = held.swap_remove(i);
                    a.release_all(&p).map_err(|e| e.to_string())?;
                }
                let held_count: usize = held.iter().map(|v| v.len()).sum();
                crate::prop_assert!(
                    a.used_pages() == held_count,
                    "accounting drift: used {} vs held {held_count}",
                    a.used_pages()
                );
                crate::prop_assert!(
                    a.free_pages() + a.used_pages() == total,
                    "page leak"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn seq_cache_append_and_grow() {
        let mut c = SeqKvCache::new(2, 2, 4, 8);
        let row = c.row_elems();
        let k: Vec<f32> = (0..4 * row).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..4 * row).map(|i| -(i as f32)).collect();
        for l in 0..2 {
            c.append_layer(l, &k, &v, 4).unwrap();
        }
        c.advance(4);
        assert_eq!(c.len, 4);
        c.grow(16);
        assert_eq!(c.bucket, 16);
        // contents preserved
        assert_eq!(c.k[0][0..4 * row], k[..]);
        // further appends land after the preserved prefix
        for l in 0..2 {
            c.append_layer(l, &k, &v, 4).unwrap();
        }
        c.advance(4);
        assert_eq!(c.k[1][4 * row..8 * row], k[..]);
    }

    #[test]
    fn step_view_scatters_into_disjoint_caches() {
        let mut a = SeqKvCache::new(2, 1, 2, 4);
        let mut b = SeqKvCache::new(2, 1, 2, 8);
        // b already holds one position; its appends must land after it
        let row = b.row_elems();
        let pre = vec![9.0; row];
        for l in 0..2 {
            b.append_layer(l, &pre, &pre, 1).unwrap();
        }
        b.advance(1);

        let mut view = StepKv::new(vec![&mut a, &mut b]);
        assert_eq!(view.len(), 2);
        assert!(!view.is_empty());
        assert_eq!(view.bucket(0), 4);
        assert_eq!(view.bucket(1), 8);
        assert_eq!(view.pos(0), 0);
        assert_eq!(view.pos(1), 1);
        let ka = vec![1.0; row];
        let kb = vec![2.0; row];
        for l in 0..2 {
            let (k, v) = view.layer(1, l);
            assert_eq!(k[..row], pre[..], "read view sees resident rows");
            assert_eq!(v.len(), 8 * row);
            view.append(0, l, &ka, &ka, 1).unwrap();
            view.append(1, l, &kb, &kb, 1).unwrap();
        }
        view.advance(0, 1);
        view.advance(1, 1);
        assert_eq!(a.len, 1);
        assert_eq!(b.len, 2);
        assert_eq!(a.k[0][..row], ka[..]);
        assert_eq!(b.k[1][row..2 * row], kb[..], "scatter after cursor");
        assert_eq!(b.k[1][..row], pre[..], "resident rows untouched");
    }

    #[test]
    fn seq_cache_overflow_rejected() {
        let mut c = SeqKvCache::new(1, 1, 2, 4);
        let row = c.row_elems();
        let k = vec![0.0; 5 * row];
        assert!(c.append_layer(0, &k, &k, 5).is_err());
    }

    // ----- prefix cache ----------------------------------------------------

    const BLOCK: usize = 4;

    /// A tiny filled SeqKvCache whose row values are a deterministic
    /// function of (layer, position), so copies can be verified exactly.
    fn filled_cache(n_tokens: usize) -> SeqKvCache {
        let (n_layers, n_kv, d_head) = (2, 1, 2);
        let mut c = SeqKvCache::new(n_layers, n_kv, d_head, n_tokens.max(1));
        let row = c.row_elems();
        for pos in 0..n_tokens {
            for l in 0..n_layers {
                let base = (l * 1000 + pos) as f32;
                let k: Vec<f32> = (0..row).map(|i| base + i as f32).collect();
                let v: Vec<f32> = (0..row).map(|i| -(base + i as f32)).collect();
                c.append_layer(l, &k, &v, 1).unwrap();
            }
            c.advance(1);
        }
        c
    }

    fn prompt(n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| i * 7 % 251).collect()
    }

    #[test]
    fn adopt_roundtrip_is_exact() {
        let mut alloc = PagedAllocator::new(64, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 1 << 20);
        let toks = prompt(3 * BLOCK + 2);
        let src = filled_cache(toks.len());
        let n = pc.insert(1, &toks, usize::MAX, &src, &mut alloc);
        assert_eq!(n, 3, "three full blocks cacheable");
        assert_eq!(pc.entry_count(), 3);
        assert!(alloc.used_pages() > 0, "residency is accounted");

        let hit = pc.acquire(1, &toks).expect("prefix hit");
        assert_eq!(hit.tokens, 3 * BLOCK);
        let mut dst = SeqKvCache::new(2, 1, 2, toks.len());
        hit.copy_into(&mut dst).unwrap();
        pc.release(&hit);
        assert_eq!(dst.len, 3 * BLOCK);
        let row = src.row_elems();
        for l in 0..2 {
            assert_eq!(
                dst.k[l][..3 * BLOCK * row],
                src.k[l][..3 * BLOCK * row],
                "adopted K rows must be bit-identical"
            );
            assert_eq!(
                dst.v[l][..3 * BLOCK * row],
                src.v[l][..3 * BLOCK * row]
            );
        }
        assert_eq!(pc.stats().hits, 1);
        assert_eq!(pc.stats().blocks_reused, 3);
    }

    #[test]
    fn partial_overlap_adopts_shared_blocks_only() {
        let mut alloc = PagedAllocator::new(64, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 1 << 20);
        let a = prompt(4 * BLOCK);
        // dense_last-style exclusion: only cache 3 of the 4 full blocks
        pc.insert(7, &a, 3, &filled_cache(a.len()), &mut alloc);
        assert_eq!(pc.entry_count(), 3);

        // b shares exactly the first 2 blocks, then diverges
        let mut b = a[..2 * BLOCK].to_vec();
        b.extend(std::iter::repeat(999).take(2 * BLOCK));
        let hit = pc.acquire(7, &b).expect("partial hit");
        assert_eq!(hit.tokens, 2 * BLOCK);
        pc.release(&hit);

        // different config seed: no adoption across configurations
        assert!(pc.acquire(8, &a).is_none());
        // sub-block prompts can never adopt
        assert!(pc.acquire(7, &a[..BLOCK - 1]).is_none());
        // whole-prompt coverage is capped: one token must remain
        let exact = a[..2 * BLOCK].to_vec();
        let hit = pc.acquire(7, &exact).expect("capped hit");
        assert_eq!(hit.tokens, BLOCK, "last block left for the session");
        pc.release(&hit);
    }

    #[test]
    fn refcounts_release_pages_on_retire() {
        let mut alloc = PagedAllocator::new(8, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 1 << 20);
        let toks = prompt(2 * BLOCK + 1);
        pc.insert(3, &toks, usize::MAX, &filled_cache(toks.len()), &mut alloc);
        assert_eq!(alloc.used_pages(), 2);
        let hit = pc.acquire(3, &toks).unwrap();
        pc.release(&hit);
        // retiring the cache returns every page
        pc.clear(&mut alloc);
        assert_eq!(alloc.used_pages(), 0);
        assert_eq!(pc.entry_count(), 0);
        assert_eq!(pc.used_bytes(), 0);
    }

    #[test]
    fn eviction_never_frees_in_use_entries() {
        let mut alloc = PagedAllocator::new(64, BLOCK);
        // budget fits exactly two block entries of the test shape
        let entry_bytes = 2 * 2 * BLOCK * 2 * 4;
        let mut pc = PrefixCache::new(BLOCK, 2 * entry_bytes);
        let a = prompt(BLOCK + 1);
        let mut b = prompt(BLOCK + 1);
        b[0] = 777; // distinct first block
        pc.insert(5, &a, usize::MAX, &filled_cache(a.len()), &mut alloc);
        pc.insert(5, &b, usize::MAX, &filled_cache(b.len()), &mut alloc);
        assert_eq!(pc.entry_count(), 2);

        // pin both entries, then force pressure: nothing may be evicted
        let ha = pc.acquire(5, &a).unwrap();
        let hb = pc.acquire(5, &b).unwrap();
        let mut c = prompt(BLOCK + 1);
        c[0] = 888;
        let inserted =
            pc.insert(5, &c, usize::MAX, &filled_cache(c.len()), &mut alloc);
        assert_eq!(inserted, 0, "no room and nothing evictable");
        assert_eq!(pc.stats().evictions, 0);
        assert_eq!(pc.entry_count(), 2);
        // the pinned data is still intact and copyable
        let mut dst = SeqKvCache::new(2, 1, 2, BLOCK);
        ha.copy_into(&mut dst).unwrap();

        // unpin one: the next insert may now evict exactly the LRU one
        pc.release(&ha);
        pc.release(&hb);
        let used_before = alloc.used_pages();
        let inserted =
            pc.insert(5, &c, usize::MAX, &filled_cache(c.len()), &mut alloc);
        assert_eq!(inserted, 1);
        assert_eq!(pc.stats().evictions, 1);
        assert_eq!(pc.entry_count(), 2);
        assert_eq!(alloc.used_pages(), used_before, "evict+insert balances");
    }

    #[test]
    fn insert_is_idempotent_across_replicas() {
        let mut alloc = PagedAllocator::new(64, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 1 << 20);
        let toks = prompt(2 * BLOCK + 3);
        let src = filled_cache(toks.len());
        assert_eq!(pc.insert(9, &toks, usize::MAX, &src, &mut alloc), 2);
        // a second replica finishing the same prompt stores nothing new
        assert_eq!(pc.insert(9, &toks, usize::MAX, &src, &mut alloc), 0);
        assert_eq!(pc.entry_count(), 2);
        assert_eq!(pc.stats().insertions, 2);
    }

    #[test]
    fn compressed_entry_metadata_roundtrip() {
        let mut alloc = PagedAllocator::new(64, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 1 << 20);
        let toks = prompt(2 * BLOCK + 1);
        let src = filled_cache(toks.len());
        // block 0 staged with a keep-map (token-pruned rows covering a
        // 3x-wider span of the original prompt), block 1 dense
        let keep: Vec<u32> = (0..BLOCK as u32).map(|i| i * 3).collect();
        let prepared = vec![
            PreparedBlock::copy_from(&src, BLOCK, 0).with_keep(keep.clone()),
            PreparedBlock::copy_from(&src, BLOCK, 1),
        ];
        let n = pc.insert_prepared(11, &toks, usize::MAX, prepared,
                                   &mut alloc);
        assert_eq!(n, 2);
        assert_eq!(pc.stats().compressed_insertions, 1);
        let hit = pc.acquire(11, &toks).expect("hit");
        assert_eq!(hit.compressed_blocks(), 1);
        assert_eq!(hit.keep_map(0), Some(&keep[..]));
        assert_eq!(hit.keep_map(1), None);
        // metadata never affects the adopted rows
        let mut dst = SeqKvCache::new(2, 1, 2, toks.len());
        hit.copy_into(&mut dst).unwrap();
        assert_eq!(dst.len, 2 * BLOCK);
        let row = src.row_elems();
        assert_eq!(dst.k[0][..2 * BLOCK * row], src.k[0][..2 * BLOCK * row]);
        pc.release(&hit);
    }

    #[test]
    fn zero_budget_disables_cache() {
        let mut alloc = PagedAllocator::new(8, BLOCK);
        let mut pc = PrefixCache::new(BLOCK, 0);
        let toks = prompt(2 * BLOCK);
        assert!(!pc.enabled());
        assert_eq!(
            pc.insert(1, &toks, usize::MAX, &filled_cache(toks.len()),
                      &mut alloc),
            0
        );
        assert!(pc.acquire(1, &toks).is_none());
        assert_eq!(alloc.used_pages(), 0);
        // a disabled cache records no misses either (it never looked)
        assert_eq!(pc.stats().misses, 0);
    }

    #[test]
    fn prop_prefix_cache_page_conservation() {
        check("prefix-pages-conserved", 60, |r| {
            let total_pages = r.range(2, 32);
            let mut alloc = PagedAllocator::new(total_pages, BLOCK);
            let budget = r.range(1, 16) * 2 * 2 * BLOCK * 2 * 4;
            let mut pc = PrefixCache::new(BLOCK, budget);
            for _ in 0..r.range(1, 24) {
                let n = r.range(1, 5) * BLOCK + r.range(0, BLOCK);
                let mut toks = prompt(n);
                toks[0] = r.range(0, 1000) as i32;
                let src = filled_cache(n);
                if r.bool(0.6) {
                    pc.insert(1, &toks, usize::MAX, &src, &mut alloc);
                } else if let Some(hit) = pc.acquire(1, &toks) {
                    let mut dst = SeqKvCache::new(2, 1, 2, hit.tokens.max(1));
                    hit.copy_into(&mut dst).map_err(|e| e.to_string())?;
                    pc.release(&hit);
                }
                let expect = pc.entry_count() * alloc.pages_for(BLOCK);
                crate::prop_assert!(
                    alloc.used_pages() == expect,
                    "page drift: used {} vs entries want {expect}",
                    alloc.used_pages()
                );
                crate::prop_assert!(
                    pc.used_bytes() <= pc.budget_bytes(),
                    "budget exceeded: {} > {}",
                    pc.used_bytes(),
                    pc.budget_bytes()
                );
            }
            pc.clear(&mut alloc);
            crate::prop_assert!(alloc.used_pages() == 0, "pages leaked");
            Ok(())
        });
    }
}
