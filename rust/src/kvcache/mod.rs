//! KV-cache management.
//!
//! Two cooperating pieces:
//!
//! * [`PagedAllocator`] — a vLLM-style page pool for admission control:
//!   pages of `page_size` positions, ref-counted for prefix sharing, with
//!   exact accounting so the router can bound resident memory.
//! * [`SeqKvCache`] — the per-sequence host-resident cache the engine
//!   feeds to the bucketed AOT executables: contiguous padded buffers per
//!   layer, grown bucket-by-bucket, appended after each block step.

use anyhow::{anyhow, Result};

// ---------------------------------------------------------------------------
// Paged allocator
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PageId(pub u32);

#[derive(Debug)]
pub struct PagedAllocator {
    page_size: usize,
    ref_counts: Vec<u32>,
    free: Vec<PageId>,
}

impl PagedAllocator {
    pub fn new(total_pages: usize, page_size: usize) -> Self {
        PagedAllocator {
            page_size,
            ref_counts: vec![0; total_pages],
            free: (0..total_pages as u32).rev().map(PageId).collect(),
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page_size)
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.ref_counts.len() - self.free.len()
    }

    /// Can `positions` more positions be allocated right now?
    pub fn can_allocate(&self, positions: usize) -> bool {
        self.pages_for(positions) <= self.free.len()
    }

    pub fn allocate(&mut self, n_pages: usize) -> Result<Vec<PageId>> {
        if n_pages > self.free.len() {
            return Err(anyhow!(
                "kv pool exhausted: want {n_pages}, free {}",
                self.free.len()
            ));
        }
        let mut out = Vec::with_capacity(n_pages);
        for _ in 0..n_pages {
            let p = self.free.pop().unwrap();
            debug_assert_eq!(self.ref_counts[p.0 as usize], 0);
            self.ref_counts[p.0 as usize] = 1;
            out.push(p);
        }
        Ok(out)
    }

    /// Share an existing page (prefix reuse): bump its refcount.
    pub fn retain(&mut self, page: PageId) -> Result<()> {
        let rc = self
            .ref_counts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow!("bad page {page:?}"))?;
        if *rc == 0 {
            return Err(anyhow!("retain of free page {page:?}"));
        }
        *rc += 1;
        Ok(())
    }

    pub fn release(&mut self, page: PageId) -> Result<()> {
        let rc = self
            .ref_counts
            .get_mut(page.0 as usize)
            .ok_or_else(|| anyhow!("bad page {page:?}"))?;
        if *rc == 0 {
            return Err(anyhow!("double free of page {page:?}"));
        }
        *rc -= 1;
        if *rc == 0 {
            self.free.push(page);
        }
        Ok(())
    }

    pub fn release_all(&mut self, pages: &[PageId]) -> Result<()> {
        for &p in pages {
            self.release(p)?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Per-sequence host cache
// ---------------------------------------------------------------------------

/// Contiguous padded K/V buffers for one sequence, one pair per layer.
/// Layout per buffer: [bucket, n_kv_heads, d_head] row-major f32, matching
/// the AOT executable input shapes exactly.
#[derive(Debug, Clone)]
pub struct SeqKvCache {
    pub n_layers: usize,
    pub n_kv: usize,
    pub d_head: usize,
    pub bucket: usize,
    pub len: usize,
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl SeqKvCache {
    pub fn new(n_layers: usize, n_kv: usize, d_head: usize,
               bucket: usize) -> Self {
        let sz = bucket * n_kv * d_head;
        SeqKvCache {
            n_layers,
            n_kv,
            d_head,
            bucket,
            len: 0,
            k: vec![vec![0.0; sz]; n_layers],
            v: vec![vec![0.0; sz]; n_layers],
        }
    }

    pub fn row_elems(&self) -> usize {
        self.n_kv * self.d_head
    }

    /// Grow to a bigger bucket, preserving contents.
    pub fn grow(&mut self, new_bucket: usize) {
        assert!(new_bucket >= self.bucket);
        if new_bucket == self.bucket {
            return;
        }
        let row = self.row_elems();
        for l in 0..self.n_layers {
            self.k[l].resize(new_bucket * row, 0.0);
            self.v[l].resize(new_bucket * row, 0.0);
        }
        self.bucket = new_bucket;
    }

    /// Append `t` new rows for layer `l` (from the executable's k_new /
    /// v_new outputs, shape [t, n_kv, d_head]).
    pub fn append_layer(&mut self, l: usize, k_new: &[f32], v_new: &[f32],
                        t: usize) -> Result<()> {
        let row = self.row_elems();
        anyhow::ensure!(k_new.len() == t * row, "k_new wrong size");
        anyhow::ensure!(v_new.len() == t * row, "v_new wrong size");
        anyhow::ensure!(
            self.len + t <= self.bucket,
            "cache overflow: len {} + {t} > bucket {}",
            self.len,
            self.bucket
        );
        let dst = self.len * row;
        self.k[l][dst..dst + t * row].copy_from_slice(k_new);
        self.v[l][dst..dst + t * row].copy_from_slice(v_new);
        Ok(())
    }

    /// Advance the filled length after all layers appended a block.
    pub fn advance(&mut self, t: usize) {
        self.len += t;
        debug_assert!(self.len <= self.bucket);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn alloc_release_cycle() {
        let mut a = PagedAllocator::new(8, 128);
        assert!(a.can_allocate(1024));
        assert!(!a.can_allocate(1025));
        let pages = a.allocate(4).unwrap();
        assert_eq!(a.used_pages(), 4);
        a.release_all(&pages).unwrap();
        assert_eq!(a.used_pages(), 0);
        assert_eq!(a.free_pages(), 8);
    }

    #[test]
    fn refcounted_sharing() {
        let mut a = PagedAllocator::new(4, 128);
        let p = a.allocate(1).unwrap()[0];
        a.retain(p).unwrap();
        a.release(p).unwrap();
        assert_eq!(a.used_pages(), 1, "still shared");
        a.release(p).unwrap();
        assert_eq!(a.used_pages(), 0);
    }

    #[test]
    fn double_free_rejected() {
        let mut a = PagedAllocator::new(2, 128);
        let p = a.allocate(1).unwrap()[0];
        a.release(p).unwrap();
        assert!(a.release(p).is_err());
        assert!(a.retain(p).is_err());
    }

    #[test]
    fn exhaustion_is_clean() {
        let mut a = PagedAllocator::new(2, 128);
        assert!(a.allocate(3).is_err());
        let _p = a.allocate(2).unwrap();
        assert!(a.allocate(1).is_err());
    }

    #[test]
    fn prop_allocator_conservation() {
        check("pages-conserved", 150, |r| {
            let total = r.range(1, 64);
            let mut a = PagedAllocator::new(total, 128);
            let mut held: Vec<Vec<PageId>> = Vec::new();
            for _ in 0..r.range(1, 80) {
                if r.bool(0.55) || held.is_empty() {
                    let want = r.range(1, 8);
                    if let Ok(p) = a.allocate(want) {
                        held.push(p);
                    }
                } else {
                    let i = r.range(0, held.len());
                    let p = held.swap_remove(i);
                    a.release_all(&p).map_err(|e| e.to_string())?;
                }
                let held_count: usize = held.iter().map(|v| v.len()).sum();
                crate::prop_assert!(
                    a.used_pages() == held_count,
                    "accounting drift: used {} vs held {held_count}",
                    a.used_pages()
                );
                crate::prop_assert!(
                    a.free_pages() + a.used_pages() == total,
                    "page leak"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn seq_cache_append_and_grow() {
        let mut c = SeqKvCache::new(2, 2, 4, 8);
        let row = c.row_elems();
        let k: Vec<f32> = (0..4 * row).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..4 * row).map(|i| -(i as f32)).collect();
        for l in 0..2 {
            c.append_layer(l, &k, &v, 4).unwrap();
        }
        c.advance(4);
        assert_eq!(c.len, 4);
        c.grow(16);
        assert_eq!(c.bucket, 16);
        // contents preserved
        assert_eq!(c.k[0][0..4 * row], k[..]);
        // further appends land after the preserved prefix
        for l in 0..2 {
            c.append_layer(l, &k, &v, 4).unwrap();
        }
        c.advance(4);
        assert_eq!(c.k[1][4 * row..8 * row], k[..]);
    }

    #[test]
    fn seq_cache_overflow_rejected() {
        let mut c = SeqKvCache::new(1, 1, 2, 4);
        let row = c.row_elems();
        let k = vec![0.0; 5 * row];
        assert!(c.append_layer(0, &k, &k, 5).is_err());
    }
}
