//! longbench-sim: the LongBench substitute (DESIGN.md §3).
//!
//! Six task groups mirroring LongBench's English categories, built
//! synthetically so grading is programmatic:
//!
//! | group          | task                                             |
//! |----------------|--------------------------------------------------|
//! | single_doc_qa  | recall one planted `key: value` fact             |
//! | multi_doc_qa   | recall a fact from the *second* of several docs  |
//! | summarization  | produce the document's dominant (topic) words    |
//! | few_shot       | continue an in-context `x -> x!` mapping pattern |
//! | synthetic      | copy a marked passkey from earlier in the prompt |
//! | code           | close the bracket sequence of a nested "program" |
//!
//! Scores combine (a) teacher-forced answer likelihood from the engine
//! (primary — smooth, sensitive to sparsity-induced hidden-state error)
//! and (b) string overlap of greedy generations (reported alongside).

use crate::util::rng::Rng;

use super::WordBank;

/// The six longbench-sim task categories (see the module table).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskGroup {
    /// Recall one planted `key: value` fact.
    SingleDocQa,
    /// Recall a fact from the *second* of several documents.
    MultiDocQa,
    /// Produce the document's dominant (topic) words.
    Summarization,
    /// Continue an in-context `x -> x!` mapping pattern.
    FewShot,
    /// Copy a marked passkey from earlier in the prompt.
    Synthetic,
    /// Close the bracket sequence of a nested "program".
    Code,
}

impl TaskGroup {
    /// Every group, in table order.
    pub fn all() -> [TaskGroup; 6] {
        [
            TaskGroup::SingleDocQa,
            TaskGroup::MultiDocQa,
            TaskGroup::Summarization,
            TaskGroup::FewShot,
            TaskGroup::Synthetic,
            TaskGroup::Code,
        ]
    }

    /// Stable snake_case name (metrics keys, table columns).
    pub fn name(&self) -> &'static str {
        match self {
            TaskGroup::SingleDocQa => "single_doc_qa",
            TaskGroup::MultiDocQa => "multi_doc_qa",
            TaskGroup::Summarization => "summarization",
            TaskGroup::FewShot => "few_shot",
            TaskGroup::Synthetic => "synthetic",
            TaskGroup::Code => "code",
        }
    }
}

/// One generated task with its programmatically-known answer.
#[derive(Debug, Clone)]
pub struct Task {
    /// Which category the task belongs to.
    pub group: TaskGroup,
    /// Full prompt text.
    pub prompt: String,
    /// Gold continuation the model is scored against.
    pub answer: String,
}

/// Smallest `target_chars` accepted by [`TaskGen::generate`].
///
/// Below this, several groups used to degenerate *silently* — the
/// `saturating_sub` budget guards produced empty documents
/// (single_doc_qa body hits zero near `key+val+40` chars), zero-shot
/// few_shot prompts (no ` maps to ` example to infer the rule from)
/// and topic-free summaries — and the eval then graded noise while
/// reporting a normal-looking score. Generation now fails fast
/// instead.
pub const MIN_TASK_CHARS: usize = 128;

/// Deterministic task generator. `target_chars` sets the prompt length
/// (bytes == tokens for the byte tokenizer).
pub struct TaskGen {
    rng: Rng,
    bank: WordBank,
}

impl TaskGen {
    /// Deterministic generator for a seed.
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let bank = WordBank::new(&mut rng, 512);
        TaskGen { rng, bank }
    }

    /// Generate one task of `group` with a ~`target_chars` prompt.
    ///
    /// # Panics
    ///
    /// When `target_chars < `[`MIN_TASK_CHARS`] — prompts that small
    /// cannot carry the planted structure the grader scores against.
    pub fn generate(&mut self, group: TaskGroup, target_chars: usize) -> Task {
        assert!(
            target_chars >= MIN_TASK_CHARS,
            "longbench-sim target_chars {target_chars} is below the \
             {MIN_TASK_CHARS}-char minimum: prompts this small degenerate \
             (empty documents, zero-shot patterns) and the eval would \
             grade noise"
        );
        match group {
            TaskGroup::SingleDocQa => self.single_doc_qa(target_chars),
            TaskGroup::MultiDocQa => self.multi_doc_qa(target_chars),
            TaskGroup::Summarization => self.summarization(target_chars),
            TaskGroup::FewShot => self.few_shot(target_chars),
            TaskGroup::Synthetic => self.synthetic(target_chars),
            TaskGroup::Code => self.code(target_chars),
        }
    }

    fn single_doc_qa(&mut self, chars: usize) -> Task {
        let key = self.bank.uniform_word(&mut self.rng).to_string();
        let val = self.bank.uniform_word(&mut self.rng).to_string();
        let body = chars.saturating_sub(key.len() + val.len() + 40);
        let pre = self.bank.filler(&mut self.rng, body / 2);
        let post = self.bank.filler(&mut self.rng, body - body / 2);
        Task {
            group: TaskGroup::SingleDocQa,
            prompt: format!(
                "{pre} the {key} is {val}. {post}\nquestion: what is the {key}?\nanswer: the {key} is"
            ),
            answer: format!(" {val}"),
        }
    }

    fn multi_doc_qa(&mut self, chars: usize) -> Task {
        let n_docs = 3;
        let per = chars / n_docs;
        let mut docs = Vec::new();
        let mut facts = Vec::new();
        for i in 0..n_docs {
            let key = self.bank.uniform_word(&mut self.rng).to_string();
            let val = self.bank.uniform_word(&mut self.rng).to_string();
            let body = self
                .bank
                .filler(&mut self.rng, per.saturating_sub(key.len() + val.len() + 30));
            docs.push(format!(
                "document {i}: {body} the {key} is {val}."
            ));
            facts.push((key, val));
        }
        let (key, val) = facts[1].clone(); // ask about the middle doc
        Task {
            group: TaskGroup::MultiDocQa,
            prompt: format!(
                "{}\nquestion: what is the {key}?\nanswer: the {key} is",
                docs.join("\n")
            ),
            answer: format!(" {val}"),
        }
    }

    fn summarization(&mut self, chars: usize) -> Task {
        // a document dominated by one topic word; the "summary" names it
        let topic = self.bank.uniform_word(&mut self.rng).to_string();
        let mut parts = Vec::new();
        let mut total = 0;
        while total < chars.saturating_sub(40) {
            let mut s = self.bank.sentence(&mut self.rng);
            // the first sentence always names the topic — near the
            // minimum size a coin-flip-only placement can emit a
            // document that never mentions its own answer
            if parts.is_empty() || self.rng.bool(0.5) {
                s = format!("the {topic} {s}");
            }
            total += s.len() + 1;
            parts.push(s);
        }
        Task {
            group: TaskGroup::Summarization,
            prompt: format!(
                "{}\nsummary: this text is mostly about the",
                parts.join(" ")
            ),
            answer: format!(" {topic}"),
        }
    }

    fn few_shot(&mut self, chars: usize) -> Task {
        // pattern: "<word> maps to <word>x." repeated; infer the suffix rule
        let mut shots = Vec::new();
        let mut total = 0;
        while total < chars.saturating_sub(48) {
            let w = self.bank.uniform_word(&mut self.rng).to_string();
            let line = format!("{w} maps to {w}x.");
            total += line.len() + 1;
            shots.push(line);
        }
        let probe = self.bank.uniform_word(&mut self.rng).to_string();
        Task {
            group: TaskGroup::FewShot,
            prompt: format!("{}\n{probe} maps to", shots.join(" ")),
            answer: format!(" {probe}x"),
        }
    }

    fn synthetic(&mut self, chars: usize) -> Task {
        // passkey retrieval — the classic synthetic long-context task
        let passkey: String = (0..6)
            .map(|_| (b'a' + self.rng.range(0, 26) as u8) as char)
            .collect();
        let body = chars.saturating_sub(70);
        let pre = self.bank.filler(&mut self.rng, body / 3);
        let post = self.bank.filler(&mut self.rng, body - body / 3);
        Task {
            group: TaskGroup::Synthetic,
            prompt: format!(
                "{pre} the passkey is {passkey}. remember it. {post}\nthe passkey is"
            ),
            answer: format!(" {passkey}"),
        }
    }

    fn code(&mut self, chars: usize) -> Task {
        // nested "function" blocks; answer = the closing bracket sequence
        let mut prompt = String::new();
        let mut depth = 0usize;
        while prompt.len() < chars.saturating_sub(24) {
            if depth < 4 && (depth == 0 || self.rng.bool(0.55)) {
                let f = self.bank.uniform_word(&mut self.rng);
                prompt.push_str(&format!("fn {f}() {{ "));
                depth += 1;
            } else {
                prompt.push_str("} ");
                depth -= 1;
            }
        }
        let answer: String = " }".repeat(depth);
        Task {
            group: TaskGroup::Code,
            prompt: prompt.trim_end().to_string(),
            answer,
        }
    }
}

/// String-overlap grade in [0, 1]: token-level F1 between generated and
/// reference answers (LongBench-style qa_f1 without stemming).
pub fn overlap_score(generated: &str, reference: &str) -> f64 {
    let gt: Vec<&str> = generated.split_whitespace().collect();
    let rt: Vec<&str> = reference.split_whitespace().collect();
    if gt.is_empty() || rt.is_empty() {
        return 0.0;
    }
    let mut matched = 0usize;
    let mut used = vec![false; rt.len()];
    for g in &gt {
        if let Some(j) = rt
            .iter()
            .enumerate()
            .position(|(j, r)| !used[j] && r == g)
        {
            used[j] = true;
            matched += 1;
        }
    }
    if matched == 0 {
        return 0.0;
    }
    let p = matched as f64 / gt.len() as f64;
    let r = matched as f64 / rt.len() as f64;
    2.0 * p * r / (p + r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_have_planted_answers() {
        let mut g = TaskGen::new(1);
        for group in TaskGroup::all() {
            let t = g.generate(group, 1200);
            assert!(!t.answer.is_empty(), "{:?} empty answer", group);
            assert!(
                t.prompt.len() >= 600 && t.prompt.len() <= 2400,
                "{:?} prompt len {}",
                group,
                t.prompt.len()
            );
            // needle-style groups must contain the answer in the prompt
            if matches!(
                group,
                TaskGroup::SingleDocQa
                    | TaskGroup::MultiDocQa
                    | TaskGroup::Synthetic
            ) {
                assert!(
                    t.prompt.contains(t.answer.trim()),
                    "{:?} answer not in prompt",
                    group
                );
            }
        }
    }

    #[test]
    fn code_brackets_balance() {
        let mut g = TaskGen::new(2);
        for _ in 0..20 {
            let t = g.generate(TaskGroup::Code, 800);
            let opens = t.prompt.matches('{').count();
            let closes_prompt = t.prompt.matches('}').count();
            let closes_answer = t.answer.matches('}').count();
            assert_eq!(opens, closes_prompt + closes_answer);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = TaskGen::new(7).generate(TaskGroup::Synthetic, 1000);
        let t2 = TaskGen::new(7).generate(TaskGroup::Synthetic, 1000);
        assert_eq!(t1.prompt, t2.prompt);
        assert_eq!(t1.answer, t2.answer);
    }

    #[test]
    fn overlap_scoring() {
        assert!((overlap_score("the cat", "the cat") - 1.0).abs() < 1e-9);
        assert_eq!(overlap_score("dog", "cat"), 0.0);
        let half = overlap_score("the cat", "the dog");
        assert!(half > 0.4 && half < 0.6);
        assert_eq!(overlap_score("", "x"), 0.0);
    }

    /// Regression: the smallest accepted size must still produce
    /// structurally sound tasks in every group — non-empty filler
    /// around the planted fact, at least one few-shot example, the
    /// needle present in the haystack. Before the `MIN_TASK_CHARS`
    /// gate, sizes just below these thresholds silently emitted
    /// prompts with the structure missing.
    #[test]
    fn smallest_valid_size_is_not_degenerate() {
        let mut g = TaskGen::new(11);
        for group in TaskGroup::all() {
            let t = g.generate(group, MIN_TASK_CHARS);
            assert!(!t.answer.is_empty(), "{group:?} empty answer");
            assert!(
                t.prompt.len() >= MIN_TASK_CHARS / 2,
                "{group:?} prompt collapsed to {} chars",
                t.prompt.len()
            );
            match group {
                TaskGroup::SingleDocQa
                | TaskGroup::MultiDocQa
                | TaskGroup::Synthetic => assert!(
                    t.prompt.contains(t.answer.trim()),
                    "{group:?} needle missing from haystack"
                ),
                TaskGroup::FewShot => assert!(
                    t.prompt.matches(" maps to ").count() >= 2,
                    "few_shot has no in-context example to learn from"
                ),
                TaskGroup::Summarization => assert!(
                    t.prompt.contains(t.answer.trim()),
                    "summarization topic never appears in the document"
                ),
                TaskGroup::Code => assert!(
                    t.prompt.contains("fn "),
                    "code task has no function to close"
                ),
            }
        }
    }

    #[test]
    #[should_panic(expected = "below the")]
    fn undersized_target_fails_fast() {
        TaskGen::new(4).generate(TaskGroup::FewShot, MIN_TASK_CHARS - 1);
    }

    #[test]
    fn few_shot_rule_is_learnable() {
        let mut g = TaskGen::new(3);
        let t = g.generate(TaskGroup::FewShot, 900);
        // every shot demonstrates the append-x rule
        assert!(t.prompt.matches(" maps to ").count() >= 5);
        assert!(t.answer.ends_with('x'));
    }
}
