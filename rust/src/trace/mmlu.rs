//! mmlu-sim: multiple-choice evaluation substitute (paper Table 3
//! reports MMLU alongside LongBench for prefill+generation sparsity).
//!
//! Each item plants a fact in a short context and asks a 4-way multiple
//! choice question about it; the model is scored by comparing the
//! teacher-forced likelihood of each option continuation and picking the
//! argmax — exactly the standard MMLU likelihood protocol, so accuracy
//! is a real 0-100 scale with a 25% random floor.

use crate::util::rng::Rng;

use super::WordBank;

/// One multiple-choice item.
#[derive(Debug, Clone)]
pub struct McItem {
    /// Context + question, ending right before the answer.
    pub prompt: String,
    /// Four option continuations (appended after the prompt).
    pub options: [String; 4],
    /// Index of the correct option.
    pub correct: usize,
}

/// Deterministic multiple-choice item generator.
pub struct McGen {
    rng: Rng,
    bank: WordBank,
}

impl McGen {
    /// Generator for a seed (same seed → same item stream).
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let bank = WordBank::new(&mut rng, 512);
        McGen { rng, bank }
    }

    /// Generate one item with ~`context_chars` of planted-fact context.
    pub fn generate(&mut self, context_chars: usize) -> McItem {
        let key = self.bank.uniform_word(&mut self.rng).to_string();
        let val = self.bank.uniform_word(&mut self.rng).to_string();
        let body = context_chars.saturating_sub(key.len() + val.len() + 60);
        let pre = self.bank.filler(&mut self.rng, body / 2);
        let post = self.bank.filler(&mut self.rng, body - body / 2);
        let mut options: Vec<String> = Vec::with_capacity(4);
        let correct_text = format!(" {val}");
        // three distractors, distinct from the answer
        while options.len() < 3 {
            let w = self.bank.uniform_word(&mut self.rng).to_string();
            if w != val && !options.iter().any(|o| o == &format!(" {w}")) {
                options.push(format!(" {w}"));
            }
        }
        let correct = self.rng.range(0, 4);
        options.insert(correct, correct_text);
        McItem {
            prompt: format!(
                "{pre} the {key} is {val}. {post}\n\
                 question: what is the {key}?\nanswer: the {key} is"
            ),
            options: [
                options[0].clone(),
                options[1].clone(),
                options[2].clone(),
                options[3].clone(),
            ],
            correct,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn items_are_well_formed() {
        let mut g = McGen::new(1);
        for _ in 0..16 {
            let it = g.generate(600);
            assert!(it.correct < 4);
            assert!(it.prompt.contains(it.options[it.correct].trim()));
            // options distinct
            let mut opts = it.options.to_vec();
            opts.sort();
            opts.dedup();
            assert_eq!(opts.len(), 4);
        }
    }

    #[test]
    fn correct_position_is_uniform_ish() {
        let mut g = McGen::new(2);
        let mut counts = [0usize; 4];
        for _ in 0..200 {
            counts[g.generate(400).correct] += 1;
        }
        for c in counts {
            assert!(c > 20, "skewed correct positions: {counts:?}");
        }
    }

    #[test]
    fn deterministic() {
        let a = McGen::new(7).generate(500);
        let b = McGen::new(7).generate(500);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.correct, b.correct);
    }
}
