//! Workload generation.
//!
//! * [`WorkloadSpec`] — the three production workload families of paper
//!   Table 1 (programming / tool use / embodied agent), with prompt and
//!   output length distributions and Poisson arrivals, for the serving
//!   benches.
//! * [`longbench`] — the LongBench substitute: six synthetic task groups
//!   (single-doc QA, multi-doc QA, summarization, few-shot, synthetic,
//!   code) with programmatic answers, built from the same corpus family
//!   the model was trained on (DESIGN.md §3).

pub mod longbench;
pub mod mmlu;

use crate::util::rng::Rng;

/// One workload family: normal-ish prompt/output token distributions
/// (matching the mean ± std the paper reports in Table 1).
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Workload family name (Table 1 row).
    pub name: &'static str,
    /// Mean prompt length, tokens.
    pub prompt_mean: f64,
    /// Prompt length standard deviation.
    pub prompt_std: f64,
    /// Mean output length, tokens.
    pub output_mean: f64,
    /// Output length standard deviation.
    pub output_std: f64,
}

impl WorkloadSpec {
    /// Code-assistant workload (paper Table 1 row 1).
    pub const PROGRAMMING: WorkloadSpec = WorkloadSpec {
        name: "programming",
        prompt_mean: 3871.0,
        prompt_std: 1656.0,
        output_mean: 190.0,
        output_std: 343.0,
    };
    /// Tool-use / agent workload (paper Table 1 row 2).
    pub const TOOL_USE: WorkloadSpec = WorkloadSpec {
        name: "tool_use",
        prompt_mean: 1835.0,
        prompt_std: 742.0,
        output_mean: 43.0,
        output_std: 16.0,
    };
    /// Embodied-agent workload (paper Table 1 row 3).
    pub const EMBODIED_AGENT: WorkloadSpec = WorkloadSpec {
        name: "embodied_agent",
        prompt_mean: 2285.0,
        prompt_std: 471.0,
        output_mean: 16.0,
        output_std: 13.0,
    };

    /// The three paper workload families.
    pub fn all() -> [WorkloadSpec; 3] {
        [Self::PROGRAMMING, Self::TOOL_USE, Self::EMBODIED_AGENT]
    }

    /// Sample a prompt length (truncated normal, min 64).
    pub fn sample_prompt_len(&self, rng: &mut Rng) -> usize {
        rng.normal_trunc(self.prompt_mean, self.prompt_std, 64.0) as usize
    }

    /// Sample an output length (truncated normal, min 1).
    pub fn sample_output_len(&self, rng: &mut Rng) -> usize {
        rng.normal_trunc(self.output_mean, self.output_std, 1.0) as usize
    }

    /// Expected prompt:decode compute-intensity ratio (paper Table 1).
    pub fn prompt_decode_ratio(&self) -> f64 {
        self.prompt_mean / self.output_mean
    }
}

/// One request in a replayable trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival time from trace start, seconds.
    pub arrival_s: f64,
    /// Prompt length, tokens.
    pub prompt_tokens: usize,
    /// Output budget, tokens.
    pub output_tokens: usize,
    /// Originating workload family name.
    pub workload: &'static str,
}

/// Poisson-arrival trace over a workload mix.
pub fn generate_trace(specs: &[WorkloadSpec], rate_per_s: f64, n: usize,
                      max_prompt: usize, seed: u64) -> Vec<TraceRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        // exponential inter-arrival
        t += -(1.0 - rng.f64()).ln() / rate_per_s;
        let spec = &specs[rng.range(0, specs.len())];
        out.push(TraceRequest {
            arrival_s: t,
            prompt_tokens: spec.sample_prompt_len(&mut rng).min(max_prompt),
            output_tokens: spec.sample_output_len(&mut rng).max(1),
            workload: spec.name,
        });
    }
    out
}

/// Empirical summary of a generated trace (reproduces Table 1 rows).
pub fn trace_stats(reqs: &[TraceRequest], workload: &str)
                   -> Option<(f64, f64, f64, f64, f64)> {
    let xs: Vec<&TraceRequest> =
        reqs.iter().filter(|r| r.workload == workload).collect();
    if xs.is_empty() {
        return None;
    }
    let n = xs.len() as f64;
    let pm = xs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>() / n;
    let om = xs.iter().map(|r| r.output_tokens as f64).sum::<f64>() / n;
    let ps = (xs
        .iter()
        .map(|r| (r.prompt_tokens as f64 - pm).powi(2))
        .sum::<f64>()
        / n)
        .sqrt();
    let os = (xs
        .iter()
        .map(|r| (r.output_tokens as f64 - om).powi(2))
        .sum::<f64>()
        / n)
        .sqrt();
    Some((pm, ps, om, os, pm / om))
}

/// Shared corpus word machinery (mirrors python CorpusGen).
pub struct WordBank {
    words: Vec<String>,
}

impl WordBank {
    /// Generate a bank of `n_words` random lowercase words.
    pub fn new(rng: &mut Rng, n_words: usize) -> Self {
        let letters = b"abcdefghijklmnopqrstuvwxyz";
        let words = (0..n_words)
            .map(|_| {
                let n = rng.range(2, 9);
                (0..n)
                    .map(|_| letters[rng.range(0, 26)] as char)
                    .collect()
            })
            .collect();
        WordBank { words }
    }

    /// A word drawn Zipf-skewed (natural-ish frequency distribution).
    pub fn zipf_word(&self, rng: &mut Rng) -> &str {
        &self.words[rng.zipf(self.words.len().min(256), 1.2)]
    }

    /// A word drawn uniformly (good for planted keys/values).
    pub fn uniform_word(&self, rng: &mut Rng) -> &str {
        &self.words[rng.range(0, self.words.len())]
    }

    /// A random sentence of 4-12 Zipf words.
    pub fn sentence(&self, rng: &mut Rng) -> String {
        let n = rng.range(4, 13);
        let mut s = (0..n)
            .map(|_| self.zipf_word(rng).to_string())
            .collect::<Vec<_>>()
            .join(" ");
        s.push('.');
        s
    }

    /// Filler text of ~`target_chars`.
    pub fn filler(&self, rng: &mut Rng, target_chars: usize) -> String {
        let mut parts = Vec::new();
        let mut total = 0;
        while total < target_chars {
            let s = self.sentence(rng);
            total += s.len() + 1;
            parts.push(s);
        }
        let mut text = parts.join(" ");
        text.truncate(target_chars);
        text
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_ratios_reproduced() {
        // paper Table 1: 20.4:1, 42.7:1, 142.8:1
        assert!((WorkloadSpec::PROGRAMMING.prompt_decode_ratio() - 20.4).abs() < 0.05);
        assert!((WorkloadSpec::TOOL_USE.prompt_decode_ratio() - 42.7).abs() < 0.05);
        assert!((WorkloadSpec::EMBODIED_AGENT.prompt_decode_ratio() - 142.8).abs() < 0.05);
    }

    #[test]
    fn trace_matches_spec_distributions() {
        let trace = generate_trace(&[WorkloadSpec::TOOL_USE], 4.0, 2000,
                                   1 << 20, 42);
        let (pm, _ps, om, _os, ratio) =
            trace_stats(&trace, "tool_use").unwrap();
        assert!((pm - 1835.0).abs() < 80.0, "prompt mean {pm}");
        assert!((om - 43.0).abs() < 3.0, "output mean {om}");
        assert!((ratio - 42.7).abs() < 5.0, "ratio {ratio}");
    }

    #[test]
    fn arrivals_are_increasing_and_poisson_ish() {
        let trace = generate_trace(&WorkloadSpec::all(), 10.0, 1000,
                                   4096, 7);
        for w in trace.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let duration = trace.last().unwrap().arrival_s;
        let rate = 1000.0 / duration;
        assert!((rate - 10.0).abs() < 1.5, "rate {rate}");
    }

    #[test]
    fn prompt_caps_respected() {
        let trace = generate_trace(&[WorkloadSpec::PROGRAMMING], 1.0, 500,
                                   2048, 3);
        assert!(trace.iter().all(|r| r.prompt_tokens <= 2048));
        assert!(trace.iter().all(|r| r.output_tokens >= 1));
    }
}
