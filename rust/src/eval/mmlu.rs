//! mmlu-sim evaluation (paper Table 3, MMLU column): 4-way multiple
//! choice scored by teacher-forced option likelihood (the standard MMLU
//! protocol). Returns true accuracy with a 25% random floor.

use anyhow::Result;

use crate::engine::{Engine, SparsityConfig};
use crate::tokenizer::Tokenizer;
use crate::trace::mmlu::McGen;

/// mmlu-sim outcome.
#[derive(Debug, Clone)]
pub struct MmluResult {
    /// Accuracy on a 0-100 scale (25 = random).
    pub accuracy: f64,
    /// Items evaluated.
    pub n_items: usize,
}

/// Score `n_items` generated multiple-choice items under `cfg` by
/// teacher-forced option likelihood.
pub fn evaluate_mmlu(engine: &Engine, n_items: usize, context_chars: usize,
                     seed: u64, cfg: &SparsityConfig) -> Result<MmluResult> {
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    let mut gen = McGen::new(seed);
    let mut correct = 0usize;
    for _ in 0..n_items {
        let item = gen.generate(context_chars);
        let prompt = tok.encode(&item.prompt);
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (i, opt) in item.options.iter().enumerate() {
            let ans = tok.encode(opt);
            let s = engine.score_continuation(&prompt, &ans, cfg)?;
            if s.mean_logprob > best.0 {
                best = (s.mean_logprob, i);
            }
        }
        if best.1 == item.correct {
            correct += 1;
        }
    }
    Ok(MmluResult {
        accuracy: 100.0 * correct as f64 / n_items.max(1) as f64,
        n_items,
    })
}
