//! longbench-sim evaluation harness (paper Tables 2–7).
//!
//! Runs the six task groups through the engine under a sparsity
//! configuration and reports per-group scores plus the overall average
//! and relative gap vs a dense reference — the exact quantities of the
//! paper's result tables.
//!
//! Primary score: 100 × teacher-forced per-token likelihood of the gold
//! answer (smooth in sparsity-induced hidden-state error). A greedy
//! string-overlap score is computed alongside for the needle tasks.

pub mod analysis;
pub mod mmlu;

use std::collections::BTreeMap;

use anyhow::Result;

use crate::engine::{Engine, SparsityConfig};
use crate::tokenizer::Tokenizer;
use crate::trace::longbench::{overlap_score, Task, TaskGen, TaskGroup};

/// Evaluation suite configuration.
#[derive(Debug, Clone)]
pub struct EvalSpec {
    /// Tasks per group.
    pub tasks_per_group: usize,
    /// Prompt length in characters (byte tokens) per task.
    pub prompt_chars: usize,
    /// Task-generator seed (identical seed → identical task set).
    pub seed: u64,
    /// Also run greedy generation for the overlap score (slower).
    pub with_generation: bool,
    /// Generation budget per task when `with_generation`.
    pub max_gen_tokens: usize,
}

impl Default for EvalSpec {
    fn default() -> Self {
        EvalSpec {
            tasks_per_group: 4,
            prompt_chars: 1024,
            seed: 17,
            with_generation: false,
            max_gen_tokens: 16,
        }
    }
}

/// Per-group and aggregate scores.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Mean likelihood score per task group (0-100 scale).
    pub group_scores: BTreeMap<&'static str, f64>,
    /// Mean greedy-overlap score per group (0 unless generation ran).
    pub group_overlap: BTreeMap<&'static str, f64>,
    /// Mean of the group scores (the paper's "avg" column).
    pub average: f64,
    /// Total tasks evaluated.
    pub n_tasks: usize,
    /// Mean prefill wall-clock across tasks, milliseconds.
    pub mean_ttft_ms: f64,
}

impl EvalResult {
    /// Relative gap vs a reference average (paper's "Rel. Gap" column).
    pub fn rel_gap_pct(&self, reference_avg: f64) -> f64 {
        if reference_avg == 0.0 {
            return 0.0;
        }
        (self.average - reference_avg) / reference_avg * 100.0
    }
}

/// Build the deterministic task set for a spec (identical across
/// configurations, so dense and sparse runs see the same tasks).
pub fn build_tasks(spec: &EvalSpec) -> Vec<Task> {
    let mut gen = TaskGen::new(spec.seed);
    let mut tasks = Vec::new();
    for group in TaskGroup::all() {
        for _ in 0..spec.tasks_per_group {
            tasks.push(gen.generate(group, spec.prompt_chars));
        }
    }
    tasks
}

/// Evaluate one sparsity configuration over the task set.
pub fn evaluate(engine: &Engine, tasks: &[Task], cfg: &SparsityConfig,
                spec: &EvalSpec) -> Result<EvalResult> {
    let tok = Tokenizer::new(engine.manifest().model.vocab);
    let mut sums: BTreeMap<&'static str, (f64, f64, usize)> = BTreeMap::new();
    let mut ttft = 0.0;
    for task in tasks {
        let prompt = tok.encode(&task.prompt);
        let answer = tok.encode(&task.answer);
        let score =
            engine.score_continuation(&prompt, &answer, cfg)?;
        ttft += score.prefill.total.as_secs_f64() * 1e3;
        let overlap = if spec.with_generation {
            let gen = engine.generate(&prompt, spec.max_gen_tokens, cfg)?;
            overlap_score(&gen.text, &task.answer)
        } else {
            0.0
        };
        let e = sums.entry(task.group.name()).or_insert((0.0, 0.0, 0));
        e.0 += 100.0 * score.likelihood;
        e.1 += 100.0 * overlap;
        e.2 += 1;
    }
    let mut group_scores = BTreeMap::new();
    let mut group_overlap = BTreeMap::new();
    let mut total = 0.0;
    let mut n_groups = 0.0f64;
    for (g, (s, o, n)) in &sums {
        group_scores.insert(*g, s / *n as f64);
        group_overlap.insert(*g, o / *n as f64);
        total += s / *n as f64;
        n_groups += 1.0;
    }
    Ok(EvalResult {
        average: total / n_groups.max(1.0),
        group_scores,
        group_overlap,
        n_tasks: tasks.len(),
        mean_ttft_ms: ttft / tasks.len().max(1) as f64,
    })
}

/// Pretty one-line table row (paper Table 2 style).
pub fn format_row(label: &str, r: &EvalResult, rel_gap: f64) -> String {
    let g = |k: &str| r.group_scores.get(k).copied().unwrap_or(0.0);
    format!(
        "{label:28} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>7.2} {:>7.2} | avg {:>6.2}  gap {:>+6.2}%",
        g("single_doc_qa"),
        g("multi_doc_qa"),
        g("summarization"),
        g("few_shot"),
        g("synthetic"),
        g("code"),
        r.average,
        rel_gap,
    )
}

/// Column header matching [`format_row`].
pub const TABLE_HEADER: &str =
    "configuration                 1docQA  mdocQA   summ.  fewshot  synth.    code |    avg     gap";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_set_is_deterministic_and_balanced() {
        let spec = EvalSpec::default();
        let t1 = build_tasks(&spec);
        let t2 = build_tasks(&spec);
        assert_eq!(t1.len(), 6 * spec.tasks_per_group);
        assert_eq!(t1[0].prompt, t2[0].prompt);
        for group in TaskGroup::all() {
            assert_eq!(
                t1.iter().filter(|t| t.group == group).count(),
                spec.tasks_per_group
            );
        }
    }

    #[test]
    fn rel_gap_math() {
        let r = EvalResult {
            group_scores: BTreeMap::new(),
            group_overlap: BTreeMap::new(),
            average: 47.0,
            n_tasks: 0,
            mean_ttft_ms: 0.0,
        };
        assert!((r.rel_gap_pct(50.0) + 6.0).abs() < 1e-9);
    }
}
