//! Fidelity analysis: how sparsity-induced error propagates through the
//! blockwise prefill (the paper's §3.3 motivation for the error
//! compensator — "errors accumulate across layers and blocks").

use anyhow::Result;

use crate::engine::{Engine, SparsityConfig};

/// Per-block hidden-state divergence between a sparse and dense prefill.
#[derive(Debug, Clone)]
pub struct ErrorProfile {
    /// Relative L2 error of the last-position logits.
    pub logit_rel_l2: f64,
    /// Cosine similarity of the last-position logits.
    pub logit_cos: f64,
}

/// Prefill `tokens` under two configurations and measure the
/// last-position logit divergence of `b` relative to `a`.
pub fn compare_configs(engine: &Engine, tokens: &[i32],
                       a: &SparsityConfig, b: &SparsityConfig)
                       -> Result<ErrorProfile> {
    let ra = engine.prefill(tokens, a)?;
    let rb = engine.prefill(tokens, b)?;
    let (x, y) = (&ra.last_logits, &rb.last_logits);
    let dot: f64 = x.iter().zip(y).map(|(a, b)| (a * b) as f64).sum();
    let nx: f64 = x.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt();
    let ny: f64 = y.iter().map(|v| (v * v) as f64).sum::<f64>().sqrt();
    let diff: f64 = x
        .iter()
        .zip(y)
        .map(|(a, b)| ((a - b) * (a - b)) as f64)
        .sum::<f64>()
        .sqrt();
    Ok(ErrorProfile {
        logit_rel_l2: diff / nx.max(1e-12),
        logit_cos: dot / (nx * ny).max(1e-12),
    })
}

/// Error growth vs context length for a sparse config (drives the
/// compensator discussion in EXPERIMENTS.md).
pub fn error_vs_context(engine: &Engine, ctxs: &[usize],
                        cfg: &SparsityConfig,
                        make_prompt: impl Fn(usize) -> Vec<i32>)
                        -> Result<Vec<(usize, ErrorProfile)>> {
    let dense = SparsityConfig::dense();
    let mut out = Vec::new();
    for &ctx in ctxs {
        let prompt = make_prompt(ctx);
        out.push((ctx, compare_configs(engine, &prompt, &dense, cfg)?));
    }
    Ok(out)
}
