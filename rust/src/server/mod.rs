//! Minimal HTTP/1.1 JSON API on std::net (the vendored crate set has no
//! tokio/hyper; a thread-per-connection server is plenty for a CPU
//! engine whose executor is single-threaded anyway).
//!
//! Endpoints:
//! * `POST /generate`  — {"prompt": str, "max_tokens": n, "sparsity": s?}
//! * `GET  /metrics`   — Prometheus text
//! * `GET  /healthz`   — liveness

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::engine::SparsityConfig;
use crate::metrics::Metrics;
use crate::router::{Reject, Router};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Json};

pub struct Server {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    pub tokenizer: Tokenizer,
    pub default_sparsity: Option<f64>,
}

/// A parsed HTTP request (just enough of HTTP/1.1).
struct HttpReq {
    method: String,
    path: String,
    body: String,
}

fn read_request(stream: &mut TcpStream) -> Result<HttpReq> {
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("/").to_string();
    let mut content_len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                content_len = v.trim().parse().unwrap_or(0);
            }
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpReq {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    })
}

fn respond(stream: &mut TcpStream, status: u16, content_type: &str,
           body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        429 => "Too Many Requests",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    Ok(())
}

impl Server {
    /// Serve forever on `addr` (e.g. "127.0.0.1:8080").
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        eprintln!("[server] listening on {addr}");
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let this = self.clone();
            std::thread::spawn(move || {
                let mut stream = stream;
                if let Err(e) = this.handle(&mut stream) {
                    let _ = respond(
                        &mut stream,
                        500,
                        "application/json",
                        &Json::obj(vec![(
                            "error",
                            Json::Str(e.to_string()),
                        )])
                        .to_string(),
                    );
                }
            });
        }
        Ok(())
    }

    fn handle(&self, stream: &mut TcpStream) -> Result<()> {
        let req = read_request(stream)?;
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                respond(stream, 200, "text/plain", "ok")
            }
            ("GET", "/metrics") => {
                respond(stream, 200, "text/plain", &self.metrics.export())
            }
            ("POST", "/generate") => self.generate(stream, &req.body),
            _ => respond(stream, 404, "text/plain", "not found"),
        }
    }

    fn generate(&self, stream: &mut TcpStream, body: &str) -> Result<()> {
        let j = match json::parse(body) {
            Ok(j) => j,
            Err(e) => {
                return respond(
                    stream,
                    400,
                    "application/json",
                    &Json::obj(vec![("error", Json::Str(format!("bad json: {e}")))])
                        .to_string(),
                )
            }
        };
        let prompt_text = j
            .get("prompt")
            .and_then(|p| p.as_str())
            .ok_or_else(|| anyhow!("missing prompt"))?;
        let max_tokens = j
            .get("max_tokens")
            .and_then(|v| v.as_usize())
            .unwrap_or(32);
        let sparsity = j
            .get("sparsity")
            .and_then(|v| v.as_f64())
            .or(self.default_sparsity);
        let cfg = match sparsity {
            Some(s) if s > 0.0 => SparsityConfig::fastforward(s),
            _ => SparsityConfig::dense(),
        };
        let prompt = self.tokenizer.encode(prompt_text);
        let (tx, rx) = channel();
        match self.router.submit(prompt, max_tokens, cfg, tx) {
            Err(reject) => {
                let (code, msg) = match reject {
                    Reject::QueueFull => (429, "queue full".to_string()),
                    Reject::KvExhausted => (429, "kv pool exhausted".into()),
                    Reject::PromptTooLong { len, max } => {
                        (400, format!("prompt+gen {len} exceeds max {max}"))
                    }
                };
                respond(
                    stream,
                    code,
                    "application/json",
                    &Json::obj(vec![("error", Json::Str(msg))]).to_string(),
                )
            }
            Ok(id) => {
                let resp = rx
                    .recv()
                    .map_err(|_| anyhow!("executor dropped request"))?;
                let payload = Json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("text", Json::Str(resp.text)),
                    ("tokens", Json::Num(resp.tokens as f64)),
                    ("ttft_ms", Json::Num(resp.ttft_ms)),
                    ("tpot_ms", Json::Num(resp.tpot_ms)),
                    ("e2e_ms", Json::Num(resp.e2e_ms)),
                    (
                        "error",
                        resp.error.map(Json::Str).unwrap_or(Json::Null),
                    ),
                ]);
                respond(stream, 200, "application/json",
                        &payload.to_string())
            }
        }
    }
}
