//! Minimal HTTP/1.1 JSON API on std::net (the vendored crate set has no
//! tokio/hyper; a thread-per-connection server is plenty: connection
//! threads only parse/serialize, all model work happens on the executor
//! pool).
//!
//! Endpoints:
//! * `POST /generate`  — {"prompt": str, "max_tokens": n, "sparsity": s?,
//!   "attn_sparsity": a?, "token_keep_ratio": r?, "stream": bool?,
//!   "class": "interactive"|"batch"?, "deadline_ms": n?}
//! * `GET  /metrics`   — Prometheus text
//! * `GET  /healthz`   — liveness (503 while draining, so load
//!   balancers stop sending new work)
//! * `GET  /readyz`    — readiness: the pool is spawned *and* at least
//!   one replica is accepting; the cluster health-checker keys on this
//! * `POST /admin/drain` — begin drain: `/healthz` flips to 503 and new
//!   `/generate` requests are refused while in-flight streams finish
//!
//! **Streaming:** with `"stream": true` the reply is Server-Sent Events
//! (`Content-Type: text/event-stream`): one `first` event at prefill
//! completion, one `token` event per decoded token, one terminal `done`
//! event carrying the same JSON object the one-shot reply would have
//! had. The wire format is specified in docs/OPERATIONS.md §1. A client
//! that disconnects mid-stream is detected (failed write, or EOF probe
//! between events) and its session is cancelled so the executor
//! releases its KV pages.
//!
//! Robustness: request lines that don't parse as `METHOD /path ...`
//! get a 400 instead of being treated as an empty method/path, bodies
//! larger than [`MAX_BODY_BYTES`] get a 413 before any allocation,
//! non-numeric `content-length` values get a 400, and total bytes read
//! per connection are hard-capped ([`MAX_HEADER_BYTES`] +
//! [`MAX_BODY_BYTES`]) so endless request lines or header streams
//! cannot exhaust memory. A slow-loris client — connected but trickling
//! (or never sending) its request line/headers — holds a connection
//! thread at most [`Server::header_timeout`]: the socket carries a read
//! deadline until the request is fully read, and a deadline expiry gets
//! a 408.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::engine::SparsityConfig;
use crate::metrics::Metrics;
use crate::router::{CancelToken, Reject, Response, Router, SloClass,
                    SubmitOpts, TokenEvent};
use crate::tokenizer::Tokenizer;
use crate::util::json::{self, Json};

/// Upper bound on request bodies (1 MiB). A max-context prompt is a few
/// hundred KiB of JSON; anything bigger is rejected with 413 before the
/// body is read into memory.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// Upper bound on the request line + headers (16 KiB). Combined with
/// [`MAX_BODY_BYTES`] this caps total bytes read per connection, so a
/// client streaming an endless request line (no newline) or endless
/// headers cannot grow memory without bound.
pub const MAX_HEADER_BYTES: usize = 16 << 10;

/// Default [`Server::header_timeout`]: generous for humans with curl,
/// three orders of magnitude tighter than "forever".
pub const DEFAULT_HEADER_TIMEOUT: Duration = Duration::from_secs(5);

/// Liveness/readiness/drain state shared between the server, its
/// supervisor and the cluster health-checker.
///
/// * **ready** — flipped once by [`Server::serve`] after the listener
///   binds (the pool is spawned before the server starts). `/readyz`
///   also requires a live replica, so a pool whose every executor died
///   reports unready while staying alive.
/// * **draining** — flipped by `POST /admin/drain` (or the process'
///   signal handler). `/healthz` turns 503 so load balancers stop
///   sending new work, new `/generate` requests are refused with 503,
///   and in-flight streams finish undisturbed.
#[derive(Debug, Default)]
pub struct Lifecycle {
    ready: AtomicBool,
    draining: AtomicBool,
}

impl Lifecycle {
    /// Fresh state: not ready, not draining.
    pub fn new() -> Arc<Lifecycle> {
        Arc::new(Lifecycle::default())
    }

    /// Mark the process ready (idempotent).
    pub fn set_ready(&self) {
        self.ready.store(true, Ordering::Release);
    }

    /// Whether [`Lifecycle::set_ready`] has run.
    pub fn is_ready(&self) -> bool {
        self.ready.load(Ordering::Acquire)
    }

    /// Begin draining (idempotent): refuse new work, finish in-flight.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::Release);
    }

    /// Whether a drain has begun.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::Acquire)
    }
}

/// The HTTP front-end: owns the listener loop and shares the router /
/// metrics / tokenizer with every connection thread.
pub struct Server {
    /// Admission + dispatch into the executor pool.
    pub router: Arc<Router>,
    /// Registry served on `/metrics`.
    pub metrics: Arc<Metrics>,
    /// Byte-level tokenizer for request prompts.
    pub tokenizer: Tokenizer,
    /// Sparsity applied when a request doesn't specify one
    /// (None = dense).
    pub default_sparsity: Option<f64>,
    /// Attention block drop applied when a request doesn't specify
    /// `attn_sparsity` (None = dense attention). Orthogonal to FFN
    /// sparsity; the prefix cache keys on it, so mixed-config traffic
    /// never shares KV across attention configurations.
    pub default_attn_sparsity: Option<f64>,
    /// Speculative-prefill keep ratio applied when a request doesn't
    /// specify `token_keep_ratio` (None = prefill every prompt token).
    /// The prefix cache keys on it too: token-pruned KV is only ever
    /// shared between requests pruned under the same ratio.
    pub default_token_keep: Option<f64>,
    /// Ready/draining flags behind `/readyz`, `/healthz` and
    /// `/admin/drain` ([`Lifecycle::new`] for a fresh one).
    pub lifecycle: Arc<Lifecycle>,
    /// Slow-loris guard: the per-connection read deadline on the
    /// request line + headers + body ([`DEFAULT_HEADER_TIMEOUT`]
    /// unless tuned). Expiry answers 408 and closes the connection.
    pub header_timeout: Duration,
}

/// A parsed HTTP request (just enough of HTTP/1.1).
pub(crate) struct HttpReq {
    pub(crate) method: String,
    pub(crate) path: String,
    pub(crate) body: String,
}

/// Protocol-level rejection decided while reading the request.
pub(crate) struct HttpError {
    pub(crate) status: u16,
    pub(crate) message: &'static str,
}

/// Read one `\n`-terminated line, refusing to buffer more than `cap`
/// bytes: a client streaming an endless line gets a clean 400 after at
/// most `cap` + one buffer of memory, instead of growing a String
/// without bound the way `read_line` would.
fn read_line_capped<R: BufRead>(reader: &mut R, cap: usize)
                                -> Result<std::result::Result<String, HttpError>> {
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let chunk = reader.fill_buf()?;
        if chunk.is_empty() {
            return Err(anyhow!("connection closed mid-line"));
        }
        match chunk.iter().position(|&b| b == b'\n') {
            Some(i) => {
                buf.extend_from_slice(&chunk[..=i]);
                reader.consume(i + 1);
                if buf.len() > cap {
                    return Ok(Err(HttpError {
                        status: 400,
                        message: "headers too large",
                    }));
                }
                return Ok(Ok(String::from_utf8_lossy(&buf).into_owned()));
            }
            None => {
                let n = chunk.len();
                buf.extend_from_slice(chunk);
                reader.consume(n);
                if buf.len() > cap {
                    return Ok(Err(HttpError {
                        status: 400,
                        message: "headers too large",
                    }));
                }
            }
        }
    }
}

/// Read one request. Outer `Err` = I/O failure (connection is dead,
/// nothing can be sent); inner `Err` = protocol violation to answer
/// with the carried status code.
pub(crate) fn read_request(stream: &mut TcpStream)
                -> Result<std::result::Result<HttpReq, HttpError>> {
    // Hard cap on total bytes read as a backstop; on top of it, the
    // request line and headers are read through a separate
    // MAX_HEADER_BYTES budget with per-line caps, so oversized headers
    // get a clean 400 and can never eat into the body's share.
    let limit = (MAX_HEADER_BYTES + MAX_BODY_BYTES) as u64;
    let mut reader = BufReader::new(stream.try_clone()?.take(limit));
    let mut budget = MAX_HEADER_BYTES;
    let line = match read_line_capped(&mut reader, budget)? {
        Ok(l) => l,
        Err(e) => return Ok(Err(e)),
    };
    budget = budget.saturating_sub(line.len());
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p))
            if !m.is_empty()
                && m.chars().all(|c| c.is_ascii_uppercase())
                && p.starts_with('/') =>
        {
            (m.to_string(), p.to_string())
        }
        _ => {
            return Ok(Err(HttpError {
                status: 400,
                message: "malformed request line",
            }))
        }
    };
    let mut content_len = 0usize;
    loop {
        if budget == 0 {
            return Ok(Err(HttpError {
                status: 400,
                message: "headers too large",
            }));
        }
        let h = match read_line_capped(&mut reader, budget)? {
            Ok(l) => l,
            Err(e) => return Ok(Err(e)),
        };
        budget = budget.saturating_sub(h.len());
        let h = h.trim();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            if k.eq_ignore_ascii_case("content-length") {
                match v.trim().parse::<usize>() {
                    Ok(n) if n <= MAX_BODY_BYTES => content_len = n,
                    Ok(_) => {
                        return Ok(Err(HttpError {
                            status: 413,
                            message: "body exceeds maximum size",
                        }))
                    }
                    Err(_) => {
                        return Ok(Err(HttpError {
                            status: 400,
                            message: "invalid content-length",
                        }))
                    }
                }
            }
        }
    }
    let mut body = vec![0u8; content_len];
    if content_len > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(Ok(HttpReq {
        method,
        path,
        body: String::from_utf8_lossy(&body).into_owned(),
    }))
}

pub(crate) fn respond(stream: &mut TcpStream, status: u16,
                      content_type: &str, body: &str) -> Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        _ => "Internal Server Error",
    };
    write!(
        stream,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    Ok(())
}

pub(crate) fn error_json(msg: &str) -> String {
    Json::obj(vec![("error", Json::Str(msg.to_string()))]).to_string()
}

impl Server {
    /// Serve forever on `addr` (e.g. "127.0.0.1:8080"; port 0 binds an
    /// ephemeral port — the resolved address is printed). Marks the
    /// process ready once the listener is bound.
    pub fn serve(self: Arc<Self>, addr: &str) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        self.lifecycle.set_ready();
        eprintln!("[server] listening on {local}");
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let this = self.clone();
            std::thread::spawn(move || {
                let mut stream = stream;
                if let Err(e) = this.handle(&mut stream) {
                    let _ = respond(
                        &mut stream,
                        500,
                        "application/json",
                        &error_json(&e.to_string()),
                    );
                }
            });
        }
        Ok(())
    }

    fn handle(&self, stream: &mut TcpStream) -> Result<()> {
        // Slow-loris guard: the whole request (line + headers + body)
        // must arrive within header_timeout. Cleared before the
        // response so long-lived SSE streams are unaffected.
        let _ = stream.set_read_timeout(Some(self.header_timeout));
        let req = read_request(stream);
        let _ = stream.set_read_timeout(None);
        let req = match req {
            Ok(Ok(req)) => req,
            Ok(Err(e)) => {
                return respond(
                    stream,
                    e.status,
                    "application/json",
                    &error_json(e.message),
                )
            }
            Err(e) => {
                let timed_out = e
                    .downcast_ref::<std::io::Error>()
                    .map(|io| {
                        matches!(
                            io.kind(),
                            std::io::ErrorKind::WouldBlock
                                | std::io::ErrorKind::TimedOut
                        )
                    })
                    .unwrap_or(false);
                if timed_out {
                    return respond(
                        stream,
                        408,
                        "application/json",
                        &error_json("timed out reading request"),
                    );
                }
                return Err(e);
            }
        };
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/healthz") => {
                if self.lifecycle.is_draining() {
                    respond(stream, 503, "text/plain", "draining")
                } else {
                    respond(stream, 200, "text/plain", "ok")
                }
            }
            ("GET", "/readyz") => {
                let lc = &self.lifecycle;
                if lc.is_draining() {
                    respond(stream, 503, "text/plain", "draining")
                } else if !lc.is_ready() {
                    respond(stream, 503, "text/plain", "starting")
                } else if !self.router.has_alive_replica() {
                    respond(stream, 503, "text/plain",
                            "no replicas accepting")
                } else {
                    respond(stream, 200, "text/plain", "ready")
                }
            }
            ("POST", "/admin/drain") => {
                self.lifecycle.begin_drain();
                respond(
                    stream,
                    200,
                    "application/json",
                    &Json::obj(vec![("draining", Json::Bool(true))])
                        .to_string(),
                )
            }
            ("GET", "/metrics") => {
                respond(stream, 200, "text/plain", &self.metrics.export())
            }
            ("POST", "/generate") => {
                if self.lifecycle.is_draining() {
                    return respond(
                        stream,
                        503,
                        "application/json",
                        &error_json("draining"),
                    );
                }
                self.generate(stream, &req.body)
            }
            _ => respond(stream, 404, "text/plain", "not found"),
        }
    }

    fn generate(&self, stream: &mut TcpStream, body: &str) -> Result<()> {
        let j = match json::parse(body) {
            Ok(j) => j,
            Err(e) => {
                return respond(
                    stream,
                    400,
                    "application/json",
                    &error_json(&format!("bad json: {e}")),
                )
            }
        };
        let prompt_text = match j.get("prompt").and_then(|p| p.as_str()) {
            Some(p) => p,
            None => {
                return respond(
                    stream,
                    400,
                    "application/json",
                    &error_json("missing prompt"),
                )
            }
        };
        let max_tokens = j
            .get("max_tokens")
            .and_then(|v| v.as_usize())
            .unwrap_or(32);
        let sparsity = j
            .get("sparsity")
            .and_then(|v| v.as_f64())
            .or(self.default_sparsity);
        let mut cfg = match sparsity {
            Some(s) if s > 0.0 => SparsityConfig::fastforward(s),
            _ => SparsityConfig::dense(),
        };
        cfg.attn_sparsity = j
            .get("attn_sparsity")
            .and_then(|v| v.as_f64())
            .or(self.default_attn_sparsity)
            .filter(|&a| a > 0.0);
        cfg.token_keep_ratio = j
            .get("token_keep_ratio")
            .and_then(|v| v.as_f64())
            .or(self.default_token_keep)
            .filter(|&k| k < 1.0);
        let stream_mode = j
            .get("stream")
            .and_then(|v| v.as_bool())
            .unwrap_or(false);
        let class = match j.get("class").and_then(|v| v.as_str()) {
            None => SloClass::Interactive,
            Some(s) => match SloClass::parse(s) {
                Some(c) => c,
                None => {
                    return respond(
                        stream,
                        400,
                        "application/json",
                        &error_json(
                            "unknown class (interactive|batch)",
                        ),
                    )
                }
            },
        };
        let deadline_ms = j
            .get("deadline_ms")
            .and_then(|v| v.as_f64())
            .filter(|d| d.is_finite() && *d > 0.0);
        let cancel = CancelToken::new();
        let opts = SubmitOpts {
            class,
            deadline_ms,
            cancel: cancel.clone(),
        };
        let prompt = self.tokenizer.encode(prompt_text);
        let (tx, rx) = channel();
        match self.router.submit_with(prompt, max_tokens, cfg, opts, tx) {
            Err(reject) => {
                let (code, msg) = match reject {
                    Reject::QueueFull => (429, "queue full".to_string()),
                    Reject::KvExhausted => (429, "kv pool exhausted".into()),
                    Reject::Unavailable => {
                        (503, "no executor replicas available".into())
                    }
                    Reject::PromptTooLong { len, max } => {
                        (400, format!("prompt+gen {len} exceeds max {max}"))
                    }
                };
                respond(stream, code, "application/json", &error_json(&msg))
            }
            Ok(id) if stream_mode => {
                self.stream_sse(stream, id, &rx, &cancel)
            }
            Ok(id) => {
                let resp = Response::collect(&rx)
                    .ok_or_else(|| anyhow!("executor dropped request"))?;
                respond(stream, 200, "application/json",
                        &response_json(id, resp).to_string())
            }
        }
    }

    /// Forward a request's event stream as Server-Sent Events. A failed
    /// write or an EOF on the connection cancels the session so the
    /// executor releases its KV pages; either way the connection is
    /// ours to close (`Connection: close`).
    fn stream_sse(&self, stream: &mut TcpStream, id: u64,
                  rx: &Receiver<TokenEvent>, cancel: &CancelToken)
                  -> Result<()> {
        let _ = stream.set_nodelay(true);
        let disconnected = |this: &Self, cancel: &CancelToken| {
            cancel.cancel();
            this.metrics.record_stream_disconnect();
        };
        if write!(
            stream,
            "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
             Cache-Control: no-cache\r\nConnection: close\r\n\r\n"
        )
        .is_err()
        {
            disconnected(self, cancel);
            return Ok(());
        }
        loop {
            match rx.recv_timeout(Duration::from_millis(100)) {
                Ok(ev) => {
                    let is_done = matches!(ev, TokenEvent::Done(_));
                    let (name, data) = sse_frame(id, ev);
                    if write!(stream, "event: {name}\ndata: {data}\n\n")
                        .is_err()
                    {
                        disconnected(self, cancel);
                        return Ok(());
                    }
                    if is_done {
                        return Ok(());
                    }
                }
                Err(RecvTimeoutError::Timeout) => {
                    // probe for a client that went away between events
                    // (a long decode gap would otherwise hide the EOF
                    // until the next token write)
                    if peer_gone(stream) {
                        disconnected(self, cancel);
                        return Ok(());
                    }
                }
                Err(RecvTimeoutError::Disconnected) => {
                    let resp = Response::failed(
                        id,
                        "executor dropped request".to_string(),
                    );
                    let (name, data) =
                        sse_frame(id, TokenEvent::Done(resp));
                    let _ = write!(stream,
                                   "event: {name}\ndata: {data}\n\n");
                    return Ok(());
                }
            }
        }
    }
}

/// The one-shot / `done`-event JSON payload for a finished request.
fn response_json(id: u64, resp: Response) -> Json {
    Json::obj(vec![
        ("id", Json::Num(id as f64)),
        ("text", Json::Str(resp.text)),
        ("tokens", Json::Num(resp.tokens as f64)),
        ("ttft_ms", Json::Num(resp.ttft_ms)),
        ("tpot_ms", Json::Num(resp.tpot_ms)),
        ("e2e_ms", Json::Num(resp.e2e_ms)),
        ("reused_blocks", Json::Num(resp.reused_blocks as f64)),
        ("error", resp.error.map(Json::Str).unwrap_or(Json::Null)),
    ])
}

/// Serialize one [`TokenEvent`] as an (event-name, json-data) SSE pair.
fn sse_frame(id: u64, ev: TokenEvent) -> (&'static str, String) {
    match ev {
        TokenEvent::First { ttft_ms, reused_blocks } => (
            "first",
            Json::obj(vec![
                ("id", Json::Num(id as f64)),
                ("ttft_ms", Json::Num(ttft_ms)),
                ("reused_blocks", Json::Num(reused_blocks as f64)),
            ])
            .to_string(),
        ),
        TokenEvent::Token { token, text } => (
            "token",
            Json::obj(vec![
                ("token", Json::Num(token as f64)),
                ("text", Json::Str(text)),
            ])
            .to_string(),
        ),
        TokenEvent::Done(resp) => {
            ("done", response_json(id, resp).to_string())
        }
    }
}

/// Best-effort probe for a peer that closed the connection: a
/// non-blocking read returning EOF. `WouldBlock` (nothing to read) means
/// the client is still there; stray pipelined bytes are ignored.
fn peer_gone(stream: &TcpStream) -> bool {
    if stream.set_nonblocking(true).is_err() {
        return false;
    }
    let mut buf = [0u8; 16];
    let gone = matches!((&mut &*stream).read(&mut buf), Ok(0));
    let _ = stream.set_nonblocking(false);
    gone
}
