//! Expert mask selection: host-side top-K over neuron scores plus the
//! paper's ablation baselines (Table 7) and the CATS thresholding
//! comparator.

/// Where a block's expert indices come from (paper Table 7 variants).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExpertSource {
    /// Learned expert predictor (the paper's method).
    Trained,
    /// Per-block dynamic oracle: dense activation norms of the block
    /// itself (upper bound; infeasible in production).
    Oracle,
    /// GRIFFIN-style: experts picked on the first block, reused for all
    /// subsequent blocks.
    FirstBlockStatic,
    /// CATS-style (Lee et al. 2024): threshold the activation statistic
    /// instead of top-K. Cardinality is data-dependent, so the engine
    /// must pad/trim to the nearest compiled K — demonstrating the
    /// static-shape overhead the paper criticizes in §1.
    Cats,
}

/// Indices of the K largest scores, ascending order (the AOT gather
/// kernel requires sorted indices for coalesced weight slabs).
pub fn top_k_indices(scores: &[f32], k: usize) -> Vec<i32> {
    let k = k.min(scores.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    if k < scores.len() {
        // O(f) partial selection of the k largest by score. Score
        // descending, then index ascending — a *total* order
        // (`f32::total_cmp` never panics on NaN, unlike
        // `partial_cmp().unwrap()`), so the selection is deterministic
        // under tied scores and NaN-safe.
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            scores[b].total_cmp(&scores[a]).then_with(|| a.cmp(&b))
        });
        idx.truncate(k);
    }
    let mut out: Vec<i32> = idx.into_iter().map(|i| i as i32).collect();
    out.sort_unstable();
    out
}

/// CATS-style thresholding (Lee et al. 2024): keep neurons whose |score|
/// exceeds a threshold chosen to hit a target density on calibration
/// data. Returns (indices, achieved_density). Used as a baseline in the
/// ablation harness; unlike top-K its cardinality is data-dependent,
/// which is exactly why it breaks block-level batching during prefill
/// (paper §1) — we surface that as a variable K the engine must pad.
pub fn cats_threshold_indices(scores: &[f32], threshold: f32) -> Vec<i32> {
    let mut idx: Vec<i32> = scores
        .iter()
        .enumerate()
        .filter(|(_, &s)| s.abs() > threshold)
        .map(|(i, _)| i as i32)
        .collect();
    idx.sort_unstable();
    idx
}

/// Pick the CATS threshold achieving `density` on a score sample.
pub fn cats_calibrate_threshold(scores: &[f32], density: f64) -> f32 {
    let mut abs: Vec<f32> = scores.iter().map(|s| s.abs()).collect();
    // Descending total order — NaN-safe where partial_cmp would panic.
    abs.sort_by(|a, b| b.total_cmp(a));
    let keep = ((abs.len() as f64) * density).round() as usize;
    if keep == 0 {
        return f32::MAX;
    }
    if keep >= abs.len() {
        return -1.0;
    }
    abs[keep - 1]
}

/// Pad or trim an index set to exactly `k` entries (engine requirement:
/// artifact shapes are static). Pads with distinct unused indices from
/// `[0, f)` — never duplicates, which would double-count neurons
/// through W_down. Duplicate *input* indices are collapsed first for
/// the same reason (a regression found by the property suite: the old
/// implementation preserved input duplicates, so a duplicated CATS
/// index would have been double-counted). When fewer than `k` distinct
/// candidates exist in `[0, f)` the result is clamped to all `f` of
/// them — shorter than `k`, which the caller must treat as "run
/// dense".
pub fn pad_indices_to_k(mut idx: Vec<i32>, k: usize, f: usize) -> Vec<i32> {
    idx.retain(|&j| j >= 0 && (j as usize) < f);
    idx.sort_unstable();
    idx.dedup();
    idx.truncate(k);
    if idx.len() < k {
        let mut present = vec![false; f];
        for &j in &idx {
            present[j as usize] = true;
        }
        for cand in 0..f as i32 {
            if idx.len() == k {
                break;
            }
            if !present[cand as usize] {
                idx.push(cand);
            }
        }
        idx.sort_unstable();
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn naive_top_k(scores: &[f32], k: usize) -> Vec<i32> {
        let mut pairs: Vec<(f32, usize)> =
            scores.iter().cloned().zip(0..).collect();
        // Same total order as the fast path: score descending, index
        // ascending — so the two selections agree *exactly*, ties and
        // all (and neither can panic on NaN).
        pairs.sort_by(|a, b| {
            b.0.total_cmp(&a.0).then_with(|| a.1.cmp(&b.1))
        });
        let mut idx: Vec<i32> =
            pairs.iter().take(k).map(|&(_, i)| i as i32).collect();
        idx.sort_unstable();
        idx
    }

    #[test]
    fn matches_naive_small() {
        let scores = [0.1f32, 5.0, -2.0, 3.0, 3.5, 0.0];
        assert_eq!(top_k_indices(&scores, 3), vec![1, 3, 4]);
        assert_eq!(top_k_indices(&scores, 0), Vec::<i32>::new());
        assert_eq!(top_k_indices(&scores, 10).len(), 6);
    }

    #[test]
    fn prop_matches_naive() {
        check("topk-vs-naive", 200, |r| {
            let n = r.range(1, 600);
            let k = r.range(0, n + 1);
            let scores: Vec<f32> =
                (0..n).map(|_| (r.f64() * 20.0 - 10.0) as f32).collect();
            let fast = top_k_indices(&scores, k);
            let naive = naive_top_k(&scores, k);
            // same total order (score desc, index asc) → the index
            // *sets* agree exactly, ties included
            crate::prop_assert!(
                fast == naive,
                "top-k disagrees with naive: {fast:?} vs {naive:?}"
            );
            // sortedness + dedup
            for w in fast.windows(2) {
                crate::prop_assert!(w[0] < w[1], "not strictly sorted");
            }
            Ok(())
        });
    }

    /// The orderings are NaN-safe (`total_cmp`) and break ties by
    /// index: a poisoned score must not panic, and tied scores must
    /// select deterministically (lowest indices win).
    #[test]
    fn top_k_is_nan_safe_and_tie_deterministic() {
        // all-tied scores: the k lowest indices win
        let tied = [1.0f32; 8];
        assert_eq!(top_k_indices(&tied, 3), vec![0, 1, 2]);
        // NaN present: no panic, selection still well-defined and
        // repeatable
        let scores = [0.5f32, f32::NAN, 2.0, -1.0, 2.0, 0.0];
        let a = top_k_indices(&scores, 3);
        let b = top_k_indices(&scores, 3);
        assert_eq!(a, b, "NaN selection must be deterministic");
        assert_eq!(a.len(), 3);
        // calibration over NaN scores must not panic either
        let _ = cats_calibrate_threshold(&scores, 0.5);
    }

    #[test]
    fn cats_density_calibration() {
        let mut r = crate::util::rng::Rng::new(9);
        let scores: Vec<f32> =
            (0..512).map(|_| (r.normal()) as f32).collect();
        let th = cats_calibrate_threshold(&scores, 0.5);
        let idx = cats_threshold_indices(&scores, th);
        let density = idx.len() as f64 / scores.len() as f64;
        assert!((density - 0.5).abs() < 0.02, "density={density}");
    }

    #[test]
    fn pad_indices_distinct() {
        let idx = pad_indices_to_k(vec![3, 7], 5, 512);
        assert_eq!(idx.len(), 5);
        let mut d = idx.clone();
        d.dedup();
        assert_eq!(d.len(), 5);
        assert!(idx.contains(&3) && idx.contains(&7));
    }

    /// Regression: duplicate input indices must collapse (a duplicated
    /// neuron would be double-counted through W_down), and out-of-range
    /// input indices must be dropped, not gathered out of bounds.
    #[test]
    fn pad_indices_edge_cases() {
        // duplicates in the input collapse, then pad back to k
        let idx = pad_indices_to_k(vec![5, 5, 5, 9], 4, 16);
        assert_eq!(idx.len(), 4);
        for w in idx.windows(2) {
            assert!(w[0] < w[1], "strictly sorted, no dups: {idx:?}");
        }
        assert!(idx.contains(&5) && idx.contains(&9));
        // out-of-range entries dropped before padding
        let idx = pad_indices_to_k(vec![-3, 100], 3, 8);
        assert_eq!(idx.len(), 3);
        assert!(idx.iter().all(|&j| (0..8).contains(&j)));
        // k larger than the candidate space clamps to all f indices
        let idx = pad_indices_to_k(vec![1], 10, 4);
        assert_eq!(idx, vec![0, 1, 2, 3]);
        // k == 0 empties
        assert_eq!(pad_indices_to_k(vec![2, 3], 0, 8), Vec::<i32>::new());
    }

    #[test]
    fn prop_top_k_indices_invariants() {
        check("topk-invariants", 300, |r| {
            let n = r.range(1, 400);
            let k = r.range(0, n + 8); // k may exceed n
            let scores: Vec<f32> =
                (0..n).map(|_| (r.f64() * 4.0 - 2.0) as f32).collect();
            let idx = top_k_indices(&scores, k);
            crate::prop_assert!(
                idx.len() == k.min(n),
                "len {} != min(k={k}, n={n})",
                idx.len()
            );
            for w in idx.windows(2) {
                crate::prop_assert!(
                    w[0] < w[1],
                    "not strictly sorted (dup or disorder): {idx:?}"
                );
            }
            crate::prop_assert!(
                idx.iter().all(|&j| (0..n as i32).contains(&j)),
                "index out of range"
            );
            // selection property: every selected score >= every
            // unselected score
            if !idx.is_empty() && idx.len() < n {
                let sel: Vec<bool> = {
                    let mut v = vec![false; n];
                    for &j in &idx {
                        v[j as usize] = true;
                    }
                    v
                };
                let min_sel = idx
                    .iter()
                    .map(|&j| scores[j as usize])
                    .fold(f32::INFINITY, f32::min);
                let max_unsel = (0..n)
                    .filter(|&j| !sel[j])
                    .map(|j| scores[j])
                    .fold(f32::NEG_INFINITY, f32::max);
                crate::prop_assert!(
                    min_sel >= max_unsel,
                    "top-k violated: min selected {min_sel} < max \
                     unselected {max_unsel}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn prop_cats_indices_invariants() {
        check("cats-invariants", 200, |r| {
            let n = r.range(1, 400);
            let scores: Vec<f32> =
                (0..n).map(|_| (r.normal()) as f32).collect();
            let th = (r.f64() * 1.5) as f32;
            let idx = cats_threshold_indices(&scores, th);
            for w in idx.windows(2) {
                crate::prop_assert!(w[0] < w[1], "sorted + distinct");
            }
            crate::prop_assert!(
                idx.iter()
                    .all(|&j| scores[j as usize].abs() > th),
                "kept a below-threshold neuron"
            );
            let kept = idx.len();
            let expect =
                scores.iter().filter(|s| s.abs() > th).count();
            crate::prop_assert!(kept == expect, "cardinality");
            Ok(())
        });
    }

    #[test]
    fn prop_pad_indices_invariants() {
        check("pad-invariants", 300, |r| {
            let f = r.range(1, 300);
            let k = r.range(0, f + 8);
            let n_in = r.range(0, f + 4);
            // inputs may contain duplicates and out-of-range entries
            let input: Vec<i32> = (0..n_in)
                .map(|_| r.range_i64(-2, f as i64 + 2) as i32)
                .collect();
            let out = pad_indices_to_k(input.clone(), k, f);
            crate::prop_assert!(
                out.len() == k.min(f),
                "len {} != min(k={k}, f={f})",
                out.len()
            );
            for w in out.windows(2) {
                crate::prop_assert!(
                    w[0] < w[1],
                    "not strictly sorted / duplicate: {out:?}"
                );
            }
            crate::prop_assert!(
                out.iter().all(|&j| (0..f as i32).contains(&j)),
                "padded index out of range"
            );
            // in-range input indices survive unless trimmed by k
            let mut distinct: Vec<i32> = input
                .iter()
                .copied()
                .filter(|&j| (0..f as i32).contains(&j))
                .collect();
            distinct.sort_unstable();
            distinct.dedup();
            if distinct.len() <= k {
                for j in &distinct {
                    crate::prop_assert!(
                        out.contains(j),
                        "dropped a valid input index {j}"
                    );
                }
            }
            Ok(())
        });
    }
}
