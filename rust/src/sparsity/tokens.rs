//! Speculative-prefill token selection (Speculative Prefill /
//! FastKV-style): a cheap importance score is computed for every prompt
//! token once, and only the top-scoring tokens — plus mandatory *sink +
//! local* keep bands — survive into the main prefill. The surviving
//! tokens are prefilled at consecutive compacted positions, so their KV
//! occupies `ceil(keep · n)` rows instead of `n` and the prefix cache's
//! effective capacity multiplies by `1 / keep`.
//!
//! Everything here is **pure selection**: the function decides *which*
//! prompt tokens the engine prefills, never the scores themselves (the
//! engine's scoring pass lives in `engine/mod.rs`). Selection runs
//! sequentially on the dispatching thread, so it is invariant under
//! thread count and batch shape by construction — the same contract as
//! [`super::attn::select_blocks`].

/// Mandatory sink band: the first `SINK_TOKENS` prompt tokens are
/// always kept (attention-sink positions, StreamingLLM-style).
pub const SINK_TOKENS: usize = 4;

/// Mandatory local band: the last `LOCAL_TOKENS` prompt tokens are
/// always kept — the final token in particular must survive so the
/// last-position logits (and the decode continuation) exist.
pub const LOCAL_TOKENS: usize = 16;

/// Select the prompt tokens a speculative prefill keeps.
///
/// `scores[i]` is the importance estimate for prompt token `i`;
/// `keep_ratio ∈ [0, 1]` is the fraction of the prompt that survives.
/// The sink + local bands are always kept, and the overall target of
/// `ceil(keep_ratio · n)` tokens (clamped to at least the mandatory
/// band) is filled from the optional middle by score (ties broken
/// toward the lower token index). `keep_ratio == 1.0` is the identity
/// selection, and `keep_ratio == 0.0` degenerates to the sink + local
/// bands alone. Prompts no longer than the mandatory bands are kept
/// whole. Returns ascending, duplicate-free indices.
pub fn select_tokens(scores: &[f32], keep_ratio: f64) -> Vec<u32> {
    let n = scores.len();
    assert!(
        (0.0..=1.0).contains(&keep_ratio),
        "keep_ratio must be in [0, 1]"
    );
    if n <= SINK_TOKENS + LOCAL_TOKENS || keep_ratio >= 1.0 {
        return (0..n as u32).collect();
    }
    let mandatory =
        |i: usize| -> bool { i < SINK_TOKENS || i + LOCAL_TOKENS >= n };
    let n_mandatory = SINK_TOKENS + LOCAL_TOKENS;
    let target = ((keep_ratio * n as f64).ceil() as usize)
        .clamp(n_mandatory, n);
    let keep_optional = target - n_mandatory;
    let mut ranked: Vec<usize> =
        (0..n).filter(|&i| !mandatory(i)).collect();
    // score descending, then token index ascending — a total order, so
    // the pick is deterministic even under tied (or NaN) scores
    ranked.sort_by(|&a, &b| {
        scores[b]
            .total_cmp(&scores[a])
            .then_with(|| a.cmp(&b))
    });
    ranked.truncate(keep_optional);
    let mut out: Vec<u32> = (0..n)
        .filter(|&i| mandatory(i))
        .map(|i| i as u32)
        .chain(ranked.into_iter().map(|i| i as u32))
        .collect();
    out.sort_unstable();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_scores(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| (r.f64() * 8.0 - 4.0) as f32).collect()
    }

    /// Output is strictly ascending, duplicate-free and in range.
    #[test]
    fn prop_ascending_unique_in_range() {
        check("token-select-ascending", 300, |r| {
            let n = r.range(1, 200);
            let keep = r.f64();
            let scores = rand_scores(r, n);
            let sel = select_tokens(&scores, keep);
            crate::prop_assert!(
                sel.iter().all(|&i| (i as usize) < n),
                "out-of-range index: {sel:?} at n={n}"
            );
            for w in sel.windows(2) {
                crate::prop_assert!(
                    w[0] < w[1],
                    "not strictly ascending: {sel:?}"
                );
            }
            Ok(())
        });
    }

    /// The sink and local bands survive regardless of scores — even
    /// when every optional token outscores them.
    #[test]
    fn prop_sink_and_local_always_kept() {
        check("token-select-mandatory", 300, |r| {
            let n = r.range(1, 200);
            let keep = r.f64();
            // adversarial scores: mandatory tokens score worst
            let scores: Vec<f32> = (0..n)
                .map(|i| {
                    if i < SINK_TOKENS || i + LOCAL_TOKENS >= n {
                        -1e9
                    } else {
                        (r.f64() * 4.0) as f32
                    }
                })
                .collect();
            let sel = select_tokens(&scores, keep);
            for i in 0..SINK_TOKENS.min(n) {
                crate::prop_assert!(
                    sel.contains(&(i as u32)),
                    "sink token {i} dropped: {sel:?}"
                );
            }
            for i in n.saturating_sub(LOCAL_TOKENS)..n {
                crate::prop_assert!(
                    sel.contains(&(i as u32)),
                    "local token {i} dropped at n={n}: {sel:?}"
                );
            }
            Ok(())
        });
    }

    /// keep = 1.0 is the identity; keep = 0.0 degenerates to exactly
    /// the sink + local bands (whole short prompts survive intact).
    #[test]
    fn prop_degenerate_ratios() {
        check("token-select-degenerate", 200, |r| {
            let n = r.range(1, 200);
            let scores = rand_scores(r, n);
            let all = select_tokens(&scores, 1.0);
            crate::prop_assert!(
                all == (0..n as u32).collect::<Vec<_>>(),
                "keep=1.0 must be the identity: {all:?}"
            );
            let band = select_tokens(&scores, 0.0);
            let expect: Vec<u32> = (0..n)
                .filter(|&i| {
                    n <= SINK_TOKENS + LOCAL_TOKENS
                        || i < SINK_TOKENS
                        || i + LOCAL_TOKENS >= n
                })
                .map(|i| i as u32)
                .collect();
            crate::prop_assert!(
                band == expect,
                "keep=0 must keep only sink+local: {band:?} vs {expect:?}"
            );
            Ok(())
        });
    }

    /// Kept-count arithmetic: `ceil(keep · n)` tokens survive, clamped
    /// to at least the mandatory band (long prompts only — short
    /// prompts are kept whole).
    #[test]
    fn prop_keep_count() {
        check("token-select-count", 200, |r| {
            let n = r.range(SINK_TOKENS + LOCAL_TOKENS + 1, 400);
            let keep = r.f64();
            let scores = rand_scores(r, n);
            let sel = select_tokens(&scores, keep);
            let expect = ((keep * n as f64).ceil() as usize)
                .clamp(SINK_TOKENS + LOCAL_TOKENS, n);
            crate::prop_assert!(
                sel.len() == expect,
                "size {} != ceil({keep}·{n}) clamped = {expect}",
                sel.len()
            );
            Ok(())
        });
    }

    /// Selection is a pure function of its inputs — two invocations
    /// agree (the conformance suite re-checks the end-to-end claim at
    /// threads {1, 4} and B ∈ {1, 3}).
    #[test]
    fn prop_selection_deterministic() {
        check("token-select-deterministic", 100, |r| {
            let n = r.range(1, 200);
            let keep = r.f64();
            let scores = rand_scores(r, n);
            crate::prop_assert!(
                select_tokens(&scores, keep)
                    == select_tokens(&scores, keep),
                "selection not deterministic"
            );
            Ok(())
        });
    }

    /// NaN scores cannot poison the ordering: `total_cmp` gives NaN a
    /// fixed rank, the output stays well-formed and the mandatory
    /// bands still survive.
    #[test]
    fn prop_nan_scores_are_safe() {
        check("token-select-nan", 100, |r| {
            let n = r.range(SINK_TOKENS + LOCAL_TOKENS + 1, 120);
            let keep = r.f64();
            let scores: Vec<f32> = (0..n)
                .map(|_| {
                    if r.bool(0.3) { f32::NAN } else { r.f64() as f32 }
                })
                .collect();
            let sel = select_tokens(&scores, keep);
            for w in sel.windows(2) {
                crate::prop_assert!(w[0] < w[1], "not ascending");
            }
            crate::prop_assert!(
                sel.contains(&0) && sel.contains(&((n - 1) as u32)),
                "band lost under NaN scores: {sel:?}"
            );
            crate::prop_assert!(
                sel == select_tokens(&scores, keep),
                "NaN scores broke determinism"
            );
            Ok(())
        });
    }
}
